"""ShapeDtypeStruct stand-ins for every model input — the dry-run path.

``input_specs(cfg, shape, ctx)`` returns (args, kwargs-free) for the step
function of the shape's kind, with NamedShardings attached so ``jit(...).
lower(*args)`` sees the production layout without allocating anything.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig
from repro.models.decode import cache_specs
from repro.models.init import abstract_params
from repro.sharding.api import ShardingContext

WHISPER_TEXT_LEN = 448


def _sds(shape, dtype, ctx: Optional[ShardingContext], axes):
    sharding = None
    if ctx is not None:
        sharding = NamedSharding(ctx.mesh, ctx.pspec(axes))
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype), sharding=sharding)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                ctx: Optional[ShardingContext]) -> Dict:
    """Training/prefill batch: tokens/labels (+ frontend stubs)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "rnn":
        r = cfg.rnn
        return {
            "x": _sds((B, r.seq_len, r.input_size), "float32", ctx,
                      ("batch", None, None)),
            "y": _sds((B,), "int32", ctx, ("batch",)),
        }
    if cfg.enc_dec:
        out = {
            "frame_embeds": _sds((B, S, cfg.d_model), cfg.compute_dtype, ctx,
                                 ("batch", None, None)),
            "tokens": _sds((B, WHISPER_TEXT_LEN), "int32", ctx, ("batch", None)),
        }
        if shape.kind == "train":
            out["labels"] = _sds((B, WHISPER_TEXT_LEN), "int32", ctx,
                                 ("batch", None))
        return out
    if cfg.frontend == "vision":
        n_img = cfg.n_frontend_tokens
        out = {
            "tokens": _sds((B, S - n_img), "int32", ctx, ("batch", None)),
            "img_embeds": _sds((B, n_img, cfg.d_model), cfg.compute_dtype,
                               ctx, ("batch", None, None)),
        }
        if shape.kind == "train":
            out["labels"] = _sds((B, S), "int32", ctx, ("batch", None))
        return out
    out = {"tokens": _sds((B, S), "int32", ctx, ("batch", None))}
    if shape.kind == "train":
        out["labels"] = _sds((B, S), "int32", ctx, ("batch", None))
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig,
                       ctx: Optional[ShardingContext]) -> Tuple[Dict, Dict, object, object]:
    """(cache, tokens, pos) abstract inputs for decode_step."""
    B, S = shape.global_batch, shape.seq_len
    cspecs = cache_specs(cfg, B, S)
    cache = abstract_params(cspecs, ctx)
    tokens = _sds((B, 1), "int32", ctx, ("batch", None))
    pos = _sds((B,), "int32", ctx, ("batch",))
    return cache, tokens, pos
