"""Production mesh builders.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Functions (not module constants) so importing never touches jax device state.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use (2,4) etc. on 8 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))
