"""Production mesh builders.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Functions (not module constants) so importing never touches jax device state.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.kernels.compat import HAS_AXIS_TYPE, AxisType


def _mk(shape, axes) -> Mesh:
    if HAS_AXIS_TYPE:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    # older jax: make_mesh has no axis_types kwarg and every axis is "auto"
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary mesh (tests use (2,4) etc. on 8 host devices)."""
    return _mk(tuple(shape), tuple(axes))
