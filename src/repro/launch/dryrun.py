import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, record memory analysis, cost analysis, and the collective
schedule (trip-count-scaled) for the roofline.

The FIRST TWO LINES above must run before any jax import — jax locks the
device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun.json

Notes:
  * cost_analysis() counts `while` bodies once; per-layer FLOPs/bytes are
    therefore extrapolated from unrolled 1-layer / 2-layer probe compiles
    (exact for identical stacked layers), while the full scanned compile
    proves sharding coherence and memory fit.
  * Pallas kernels target TPU and do not lower on the CPU host platform;
    the dry-run uses the XLA model implementations (DESIGN.md §3).
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import SHAPES, ModelConfig, ShapeConfig, TrainConfig, cell_applicable
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.inputs import batch_specs, decode_input_specs
from repro.launch.mesh import make_production_mesh
from repro.models.decode import decode_step
from repro.models.init import abstract_params
from repro.models.model import build_model
from repro.models.transformer import forward as tf_forward, logits_fn
from repro.registry import ASSIGNED_ARCHS, get_config
from repro.sharding.api import sharding_context
from repro.sharding.auto import auto_overrides, dp_size
from repro.training.optimizer import OptState
from repro.training.train_step import make_train_step


def _abstract_opt_state(aparams, ctx, state_dtype="float32"):
    dt = jnp.dtype(state_dtype)
    mk = lambda s: jax.ShapeDtypeStruct(s.shape, dt, sharding=s.sharding)
    return OptState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        m={k: mk(v) for k, v in aparams.items()},
        v={k: mk(v) for k, v in aparams.items()},
    )


def pick_accum(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Largest accum <= cfg.grad_accum dividing the per-replica batch."""
    per = max(shape.global_batch // max(dp_size(mesh), 1), 1)
    a = min(cfg.grad_accum, per)
    while per % a:
        a -= 1
    return max(a, 1)


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh,
               *, probe_layers: Optional[int] = None):
    """Lower+compile one cell. probe_layers: unrolled-probe variant."""
    if probe_layers is not None:
        kw = dict(scan_layers=False, grad_accum=1, probe_unroll=True)
        if cfg.enc_dec:
            kw.update(n_encoder_layers=probe_layers,
                      n_decoder_layers=probe_layers, n_layers=probe_layers)
        elif cfg.family == "hybrid":
            kw["n_layers"] = probe_layers * len(cfg.rglru.pattern)
        else:
            kw["n_layers"] = probe_layers
        cfg = cfg.replace(**kw)

    overrides = auto_overrides(cfg, mesh, shape)
    kind = shape.kind
    with sharding_context(mesh, cfg.family, kind, overrides) as ctx:
        model = build_model(cfg)
        aparams = model.abstract_params(ctx)
        if kind == "train":
            accum = pick_accum(cfg, shape, mesh)
            # >100B params on 16GiB chips: bf16 optimizer moments + bf16
            # grad accumulation (documented precision tradeoff, DESIGN.md)
            big = cfg.param_count() > 100e9
            from repro.config import OptimizerConfig
            tc = TrainConfig(optimizer=OptimizerConfig(
                state_dtype="bfloat16" if big else "float32"))
            step = make_train_step(model, tc, grad_accum=accum,
                                   accum_dtype="bfloat16" if big else "float32",
                                   grad_shardings={k: s.sharding
                                                   for k, s in aparams.items()})
            aopt = _abstract_opt_state(aparams, ctx,
                                       tc.optimizer.state_dtype)
            batch = batch_specs(cfg, shape, ctx)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                aparams, aopt, batch)
        elif kind == "prefill":
            batch = batch_specs(cfg, shape, ctx)

            def prefill(params, b):
                hidden, _ = tf_forward(
                    cfg, params, b["tokens"], train=False,
                    img_embeds=b.get("img_embeds"),
                    frame_embeds=b.get("frame_embeds"))
                if probe_layers is not None:
                    # cost probe: keep every position live (unrolled layers
                    # otherwise let XLA dead-code-eliminate all non-final
                    # positions of the last layer, skewing per-layer FLOPs)
                    return jnp.sum(hidden.astype(jnp.float32))
                return logits_fn(cfg, params, hidden[:, -1:])

            lowered = jax.jit(prefill).lower(aparams, batch)
        else:  # decode
            cache, tokens, pos = decode_input_specs(cfg, shape, ctx)

            def serve_step(params, cache, tokens, pos):
                return decode_step(cfg, params, cache, tokens, pos)

            lowered = jax.jit(serve_step, donate_argnums=(1,)).lower(
                aparams, cache, tokens, pos)

        compiled = lowered.compile()
    return lowered, compiled


def cell_record(cfg: ModelConfig, shape: ShapeConfig, mesh,
                mesh_name: str, *, probes: bool) -> Dict:
    t0 = time.time()
    rec: Dict = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    lowered, compiled = lower_cell(cfg, shape, mesh)
    rec["compile_s"] = round(time.time() - t0, 1)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    # peak per-device = args + temp (+ out - aliased/donated)
    rec["memory"]["peak_bytes"] = (
        rec["memory"]["argument_bytes"] + rec["memory"]["temp_bytes"]
        + rec["memory"]["output_bytes"] - rec["memory"]["alias_bytes"])

    ca = compiled.cost_analysis() or {}
    rec["cost_raw"] = {"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0))}

    hlo = analyze_hlo(compiled.as_text())
    rec["collectives"] = {
        "wire_bytes_per_device": hlo.total_wire_bytes,
        # bf16-target equivalent: the CPU backend legalizes bf16 arith to
        # f32 (verified: all activations/weights appear as f32 in the
        # compiled HLO though they trace as bf16) — halve f32 collectives
        "wire_bytes_bf16equiv": hlo.total_wire_bytes_bf16,
        "by_kind": hlo.by_kind(),
        "op_counts": hlo.op_counts(),
    }

    if probes:
        try:
            rec["cost_extrapolated"] = _extrapolate_cost(cfg, shape, mesh)
        except Exception as e:  # probes are best-effort
            rec["cost_extrapolated_error"] = f"{type(e).__name__}: {e}"
    return rec


def _layer_count(cfg: ModelConfig) -> int:
    if cfg.enc_dec:
        return cfg.n_encoder_layers  # probes scale enc+dec together
    if cfg.family == "hybrid":
        return cfg.n_layers // len(cfg.rglru.pattern)  # super-blocks
    return cfg.n_layers


def _extrapolate_cost(cfg, shape, mesh) -> Dict:
    """fixed + L x per_layer from unrolled 1-layer / 2-layer probes."""
    costs = []
    for k in (1, 2):
        _, compiled = lower_cell(cfg, shape, mesh, probe_layers=k)
        ca = compiled.cost_analysis() or {}
        costs.append((float(ca.get("flops", 0.0)),
                      float(ca.get("bytes accessed", 0.0))))
    L = _layer_count(cfg)
    f1, b1 = costs[0]
    f2, b2 = costs[1]
    per_layer_f, per_layer_b = f2 - f1, b2 - b1
    fixed_f, fixed_b = f1 - per_layer_f, b1 - per_layer_b
    flops = fixed_f + L * per_layer_f
    bytes_ = fixed_b + L * per_layer_b
    # grad-accum correction: each extra microbatch re-reads the weights
    accum = pick_accum(cfg, shape, mesh) if shape.kind == "train" else 1
    if accum > 1:
        from repro.models.init import param_bytes
        from repro.models.model import build_model
        pb = param_bytes(build_model(cfg).param_specs()) / mesh.size
        bytes_ += (accum - 1) * pb
    return {"flops": flops, "bytes_accessed": bytes_,
            "per_layer_flops": per_layer_f, "fixed_flops": fixed_f,
            "accum": accum}


def run_cells(archs, shapes, meshes, out_path: Optional[str],
              probes: bool = True):
    results = []
    if out_path and os.path.exists(out_path):
        results = json.load(open(out_path))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results}

    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi_pod"))
        for arch in archs:
            cfg = get_config(arch)
            for shape_name in shapes:
                shape = SHAPES[shape_name]
                key = (cfg.name, shape.name, mesh_name)
                if key in done:
                    continue
                ok, why = cell_applicable(cfg, shape)
                if not ok:
                    rec = {"arch": cfg.name, "shape": shape.name,
                           "mesh": mesh_name, "skipped": why}
                else:
                    print(f"[dryrun] {cfg.name} x {shape.name} x {mesh_name} ...",
                          flush=True)
                    try:
                        rec = cell_record(cfg, shape, mesh, mesh_name,
                                          probes=probes and mesh_name == "single_pod")
                        print(f"  ok in {rec['compile_s']}s  "
                              f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB  "
                              f"wire={rec['collectives']['wire_bytes_per_device']/2**20:.1f}MiB",
                              flush=True)
                    except Exception as e:
                        rec = {"arch": cfg.name, "shape": shape.name,
                               "mesh": mesh_name,
                               "error": f"{type(e).__name__}: {e}",
                               "traceback": traceback.format_exc()[-2000:]}
                        print(f"  FAIL: {rec['error'][:200]}", flush=True)
                results.append(rec)
                if out_path:
                    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
                    with open(out_path + ".tmp", "w") as f:
                        json.dump(results, f, indent=1)
                    os.replace(out_path + ".tmp", out_path)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-probes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])
    results = run_cells(archs, shapes, meshes, args.out,
                        probes=not args.no_probes)
    n_ok = sum(1 for r in results if "memory" in r)
    n_skip = sum(1 for r in results if "skipped" in r)
    n_err = sum(1 for r in results if "error" in r)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} failed")
    if not args.out:
        print(json.dumps(results, indent=1)[:4000])


if __name__ == "__main__":
    main()
