"""Post-SPMD HLO text analysis: collective bytes with while-loop trip-count
scaling (scan over layers / microbatches executes its body N times — XLA
records ``known_trip_count`` in the while op's backend_config).

Outputs per-device "wire bytes" per collective kind using ring-algorithm
cost models:
  all-gather      : out_shard x (n-1)          (each device forwards n-1 shards)
  reduce-scatter  : in_shard  x (n-1)/n
  all-reduce      : 2 x operand x (n-1)/n      (RS + AG)
  all-to-all      : operand x (n-1)/n
  collective-permute : operand
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """'f32[8,32]' -> bytes; tuples '(f32[2], f32[4])' -> sum."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveOp:
    kind: str
    computation: str
    result_bytes: int
    operand_bytes: int
    group_size: int
    count: int = 1          # execution multiplier (while trip counts)
    is_f32: bool = False    # result dtype is f32 in the compiled HLO

    @property
    def wire_bytes(self) -> float:
        n = max(self.group_size, 2)
        if self.kind == "all-gather":
            return self.result_bytes * (n - 1) / n
        if self.kind == "all-reduce":
            return 2.0 * self.operand_bytes * (n - 1) / n
        if self.kind == "reduce-scatter":
            return self.operand_bytes * (n - 1) / n
        if self.kind == "all-to-all":
            return self.operand_bytes * (n - 1) / n
        return float(self.operand_bytes)  # collective-permute

    @property
    def wire_bytes_bf16(self) -> float:
        """bf16-target equivalent: XLA's CPU backend legalizes bf16 arith to
        f32, doubling every tensor in the compiled HLO vs the TPU target.
        f32 collectives are counted at half width under this correction."""
        return self.wire_bytes * (0.5 if self.is_f32 else 1.0)


@dataclass
class HloAnalysis:
    collectives: List[CollectiveOp] = field(default_factory=list)

    @property
    def total_wire_bytes(self) -> float:
        return sum(c.wire_bytes * c.count for c in self.collectives)

    @property
    def total_wire_bytes_bf16(self) -> float:
        return sum(c.wire_bytes_bf16 * c.count for c in self.collectives)

    def by_kind(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0.0) + c.wire_bytes * c.count
        return out

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for c in self.collectives:
            out[c.kind] = out.get(c.kind, 0) + c.count
        return out


_COMP_START = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(\([^)]*\)|[\w]+\[[\d,]*\](?:\{[^}]*\})?)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|body|condition)=%?([\w\.\-]+)")


def analyze_hlo(hlo_text: str) -> HloAnalysis:
    lines = hlo_text.splitlines()

    # pass 1: computation blocks, per-comp symbol tables, while edges
    comp = None
    sym: Dict[str, Dict[str, int]] = {}
    comp_collectives: Dict[str, List[Tuple[str, str, int, List[str]]]] = {}
    edges: Dict[str, List[Tuple[str, int]]] = {}   # comp -> [(callee, mult)]
    entry = None

    for raw in lines:
        line = raw.rstrip()
        m = _COMP_START.match(line.strip())
        if m:
            comp = m.group(2)
            sym.setdefault(comp, {})
            comp_collectives.setdefault(comp, [])
            edges.setdefault(comp, [])
            if m.group(1):
                entry = comp
            continue
        if comp is None:
            continue
        dm = _DEF_RE.match(line)
        if dm:
            sym[comp][dm.group(1)] = _shape_bytes(dm.group(2))
        # while -> body/cond with trip count
        if re.search(r"\bwhile\(", line):
            trip = 1
            tm = _TRIP_RE.search(line)
            if tm:
                trip = int(tm.group(1))
            for cm in _CALL_RE.finditer(line):
                edges[comp].append((cm.group(1), trip))
        elif "to_apply=" in line and ("call(" in line or "fusion(" in line
                                      or "reduce(" in line or "sort(" in line
                                      or "scatter(" in line or "map(" in line
                                      or "conditional(" in line):
            for cm in _CALL_RE.finditer(line):
                edges[comp].append((cm.group(1), 1))
        # collectives
        for kind in _COLLECTIVES:
            if re.search(rf"\b{kind}(?:-start)?\(", line):
                if kind == "all-reduce" and "all-reduce-done" in line:
                    continue
                if "-done(" in line:
                    continue
                dm2 = _DEF_RE.match(line)
                result_bytes = _shape_bytes(dm2.group(2)) if dm2 else 0
                gsize = 0
                gm = _GROUPS_RE.search(line)
                if gm:
                    gsize = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST_RE.search(line)
                    if gl:
                        first = gl.group(1).split("}")[0].strip("{ ")
                        gsize = len([x for x in first.split(",") if x.strip()])
                # operand names
                call = line.split(f"{kind}(", 1)
                if len(call) < 2 and f"{kind}-start(" in line:
                    call = line.split(f"{kind}-start(", 1)
                opnames = []
                if len(call) == 2:
                    args = call[1].split(")")[0]
                    opnames = re.findall(r"%([\w\.\-]+)", args)
                is_f32 = bool(dm2) and dm2.group(2).startswith(("f32", "(f32"))
                comp_collectives[comp].append((kind, result_bytes, gsize,
                                               opnames, is_f32))
                break

    # pass 2: execution counts via call-graph walk from ENTRY
    counts: Dict[str, int] = {}

    def visit(c: str, mult: int):
        counts[c] = counts.get(c, 0) + mult
        for callee, m in edges.get(c, []):
            if callee != c:
                visit(callee, mult * m)

    if entry is not None:
        visit(entry, 1)
    else:  # fall back: every computation counts once
        for c in sym:
            counts[c] = 1

    out = HloAnalysis()
    for c, colls in comp_collectives.items():
        mult = counts.get(c, 0)
        if mult == 0:
            continue
        for kind, result_bytes, gsize, opnames, is_f32 in colls:
            operand_bytes = sum(sym[c].get(n, 0) for n in opnames)
            if operand_bytes == 0:
                operand_bytes = result_bytes
            out.collectives.append(CollectiveOp(
                kind=kind, computation=c, result_bytes=result_bytes,
                operand_bytes=operand_bytes, group_size=max(gsize, 1),
                count=mult, is_f32=is_f32))
    return out
