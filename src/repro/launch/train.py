"""Training driver: ``python -m repro.launch.train --arch <id> [options]``.

Full production path: mesh + sharding context + sharded params/opt state +
microbatched train step + checkpoint manager + fault-tolerance hooks.  On
this CPU container it runs the small configs (paper taggers, tiny variants)
for real; large archs are exercised through the dry-run.
"""

from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.config import OptimizerConfig, TrainConfig
from repro.data import (flavor_tagging_dataset, lm_token_stream,
                        quickdraw_dataset, top_tagging_dataset)
from repro.ft import StragglerPolicy
from repro.launch.mesh import make_mesh
from repro.models.init import param_shardings
from repro.models.model import build_model
from repro.registry import get_config
from repro.sharding.api import sharding_context
from repro.sharding.auto import auto_overrides
from repro.testing import tiny_config
from repro.training import adamw_init, make_train_step

RNN_DATA = {
    "top-tagging": top_tagging_dataset,
    "flavor-tagging": flavor_tagging_dataset,
    "quickdraw": quickdraw_dataset,
}


def _rnn_batches(cfg, batch, seed=0):
    for key, fn in RNN_DATA.items():
        if key in cfg.name:
            x, y = fn(4096, seed=seed)
            step = 0
            while True:
                idx = np.random.RandomState(step).randint(0, len(x), batch)
                yield {"x": jnp.asarray(x[idx]), "y": jnp.asarray(y[idx])}
                step += 1
    raise KeyError(cfg.name)


def train(arch: str, steps: int = 100, batch: int = 64, lr: float = 1e-3,
          seq_len: int = 128, mesh_shape: Optional[tuple] = None,
          checkpoint_dir: Optional[str] = None, resume: bool = False,
          tiny: bool = False, log_every: int = 10):
    cfg = get_config(arch)
    if tiny:
        cfg = tiny_config(cfg)
        cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg)

    mesh = None
    if mesh_shape:
        mesh = make_mesh(mesh_shape, ("data", "model")[: len(mesh_shape)]
                         if len(mesh_shape) > 1 else ("data",))

    opt_cfg = OptimizerConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                              total_steps=steps, weight_decay=0.01)
    tc = TrainConfig(optimizer=opt_cfg)
    ckpt = CheckpointManager(checkpoint_dir) if checkpoint_dir else None
    straggler = StragglerPolicy()

    ov = auto_overrides(cfg, mesh) if mesh is not None else None
    with sharding_context(mesh, cfg.family, "train", ov):
        params = model.init(jax.random.PRNGKey(0))
        if mesh is not None:
            shardings = param_shardings(model.param_specs(),
                                        __import__("repro.sharding.api",
                                                   fromlist=["current_context"]
                                                   ).current_context())
            params = {k: jax.device_put(v, shardings[k])
                      for k, v in params.items()}
        opt_state = adamw_init(params, opt_cfg)
        start = 0
        if ckpt and resume and ckpt.latest_step() is not None:
            start, params, opt = ckpt.restore()
            if opt:
                opt_state = opt_state._replace(
                    step=jnp.asarray(opt["step"], jnp.int32),
                    m=opt["m"], v=opt["v"])
            print(f"[train] resumed from step {start}")

        step_fn = jax.jit(make_train_step(model, tc, grad_accum=1),
                          donate_argnums=(0, 1))

        if cfg.family == "rnn":
            batches = _rnn_batches(cfg, batch)
        else:
            stream = lm_token_stream(cfg.vocab_size, batch, seq_len)
            batches = ({"tokens": jnp.asarray(b["tokens"]),
                        "labels": jnp.asarray(b["labels"])} for b in stream)

        t_last = time.time()
        loss = float("nan")
        for i in range(start, steps):
            t0 = time.time()
            params, opt_state, metrics = step_fn(params, opt_state,
                                                 next(batches))
            straggler.record_step(0, time.time() - t0)
            if (i + 1) % log_every == 0 or i == steps - 1:
                loss = float(metrics["loss"])
                dt = (time.time() - t_last) / log_every
                t_last = time.time()
                print(f"[train] step {i+1}/{steps} loss={loss:.4f} "
                      f"acc={float(metrics.get('accuracy', 0)):.3f} "
                      f"{dt*1e3:.0f}ms/step", flush=True)
            if ckpt and (i + 1) % tc.checkpoint_every == 0:
                ckpt.save(i + 1, params, opt_state)
        if ckpt:
            ckpt.save(steps, params, opt_state)
    return params, loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--tiny", action="store_true",
                    help="reduced config (CPU-runnable for any arch)")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    train(args.arch, args.steps, args.batch, args.lr, args.seq_len,
          checkpoint_dir=args.checkpoint_dir, resume=args.resume,
          tiny=args.tiny)


if __name__ == "__main__":
    main()
