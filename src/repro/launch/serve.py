"""Serving driver: ``python -m repro.launch.serve --arch <id> [options]``.

RNN taggers (the paper's use case): load/train params, stand up the
RNNServingEngine, stream a synthetic request load through the micro-batcher,
report wall-clock latency/throughput alongside the analytical FPGA design
point for the same (mode, precision, reuse) — the paper's comparison.

LM archs: tiny-config LMServingEngine demo with continuous batching.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import FixedPointConfig
from repro.data import (flavor_tagging_dataset, quickdraw_dataset,
                        top_tagging_dataset)
from repro.models.model import build_model
from repro.registry import get_config
from repro.serving import LMServingEngine, RNNServingEngine
from repro.testing import tiny_config


def serve_rnn(arch: str, mode: str, n_requests: int, fixed_point: bool,
              reuse: int):
    cfg = get_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    fp = FixedPointConfig(16, 6) if fixed_point else None
    eng = RNNServingEngine(cfg, params, mode=mode, fp=fp)
    eng.warmup()

    r = cfg.rnn
    if "top-tagging" in cfg.name:
        x, _ = top_tagging_dataset(n_requests, seed=3)
    elif "flavor" in cfg.name:
        x, _ = flavor_tagging_dataset(n_requests, seed=3)
    else:
        x, _ = quickdraw_dataset(n_requests, seed=3)

    lat = []
    t0 = time.perf_counter()
    for i in range(n_requests):
        eng.batcher.submit(x[i])
        done = eng.batcher.run(eng.predict)
        lat.extend(d.latency_s for d in done)
    done = eng.batcher.drain()
    if done:
        out = eng.predict(np.stack([d.payload for d in done]))
        t = time.perf_counter()
        for i, d in enumerate(done):
            d.result, d.done_s = out[i], t
        lat.extend(d.latency_s for d in done)
    wall = time.perf_counter() - t0

    lat_ms = np.asarray(lat) * 1e3
    print(f"[serve] {arch} mode={mode} fp={'16,6' if fixed_point else 'off'}")
    print(f"  served {n_requests} requests in {wall:.2f}s "
          f"({n_requests/wall:.0f} ev/s)")
    print(f"  latency p50={np.percentile(lat_ms,50):.2f}ms "
          f"p99={np.percentile(lat_ms,99):.2f}ms")
    d = eng.fpga_design(reuse_kernel=reuse, reuse_recurrent=reuse,
                        strategy="resource" if reuse > 1 else "latency")
    print(f"  paired FPGA design point: latency {d.latency_min_us:.1f}-"
          f"{d.latency_max_us:.1f}us II={d.ii_cycles} "
          f"DSP={d.dsp} fits={d.fits} ({d.part})")
    print(f"  FPGA throughput @200MHz: {d.throughput_eps:.0f} ev/s "
          f"(batch-1; paper Sec 5.2 compares V100 batch-1 at 660 ev/s)")


def serve_lm(arch: str, n_requests: int):
    cfg = tiny_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, max_batch=4, max_seq=64)
    rng = np.random.RandomState(0)
    pending = [list(rng.randint(2, cfg.vocab_size, rng.randint(2, 8)))
               for _ in range(n_requests)]
    t0 = time.perf_counter()
    finished = {}
    while pending or any(s.active for s in eng.slots):
        while pending and eng.add_request(pending[0], max_new=8) is not None:
            pending.pop(0)
        finished.update(eng.tick())
    wall = time.perf_counter() - t0
    toks = sum(len(v) for v in finished.values())
    print(f"[serve] {arch} (tiny): {len(finished)} requests, {toks} tokens "
          f"in {wall:.2f}s ({toks/wall:.0f} tok/s, continuous batching)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="top-tagging-gru")
    ap.add_argument("--mode", default="static",
                    choices=["static", "nonstatic"])
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--fixed-point", action="store_true")
    ap.add_argument("--reuse", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if cfg.family == "rnn":
        serve_rnn(args.arch, args.mode, args.requests, args.fixed_point,
                  args.reuse)
    else:
        serve_lm(args.arch, min(args.requests, 12))


if __name__ == "__main__":
    main()
