"""Roofline analysis from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape) on the single-pod mesh:
  compute_s    = HLO_FLOPs_per_device / 197e12           (bf16 peak / chip)
  memory_s     = HLO_bytes_per_device / 819e9             (HBM bw / chip)
  collective_s = wire_bytes_per_device / 50e9             (1 ICI link, the
                 conservative single-link ring assumption; raw bytes are in
                 the record so any link-count model can be re-derived)
  MODEL_FLOPS  = 6*N_active*tokens (train) / 2*N_active*tokens (+ attention
                 terms) — the "useful" flops; ratio to HLO flops exposes
                 remat/causal-waste/dispatch overhead.
  roofline_fraction = (MODEL_FLOPS/chips/peak) / max(terms)
                 — the fraction of the dominant-bound step time that is
                 irreducible model compute. 1.0 = perfectly compute-bound
                 with zero waste.
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, Optional

from repro.config import SHAPES, TPU_V5E, ModelConfig, ShapeConfig
from repro.registry import get_config

CHIPS_SINGLE_POD = 256


def attention_model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Exact-schedule attention FLOPs (global, fwd; causal = triangular)."""
    if not cfg.n_heads:
        return 0.0
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.n_heads, cfg.head_dim
    if shape.kind == "decode":
        if cfg.enc_dec:
            # one token: self cache S + cross cache S (both sized by shape)
            return 4.0 * B * H * hd * (S + S) * cfg.n_decoder_layers
        if cfg.rglru is not None:
            n_att = sum(1 for i in range(cfg.n_layers)
                        if cfg.rglru.pattern[i % len(cfg.rglru.pattern)]
                        == "local_attn")
            return 4.0 * B * H * hd * min(cfg.rglru.window, S) * n_att
        # one token attends to the whole cache
        return 4.0 * B * H * hd * S * cfg.n_layers
    if cfg.enc_dec:
        Stxt = 448
        enc = 4 * B * S * S * H * hd * cfg.n_encoder_layers
        dec = 2 * B * Stxt * Stxt * H * hd * cfg.n_decoder_layers
        cross = 4 * B * Stxt * S * H * hd * cfg.n_decoder_layers
        return enc + dec + cross
    per_layer = 2.0 * B * S * S * H * hd          # causal half of 4BS^2Hhd
    if cfg.rglru is not None:
        n_att = sum(1 for i in range(cfg.n_layers)
                    if cfg.rglru.pattern[i % len(cfg.rglru.pattern)]
                    == "local_attn")
        w = min(cfg.rglru.window, S)
        return 4.0 * B * S * w * H * hd * n_att * 0.5 * 2
    return per_layer * cfg.n_layers


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    from repro.models.transformer import padded_vocab
    n = cfg.active_param_count()
    B, S = shape.global_batch, shape.seq_len
    att = attention_model_flops(cfg, shape)
    vd = padded_vocab(cfg) * cfg.d_model if cfg.family != "rnn" else 0
    emb_params = vd * (1 if cfg.tie_embeddings else 2)
    if shape.kind == "train":
        tokens = B * (448 if cfg.enc_dec else S)
        if cfg.enc_dec:
            tokens = B * (S + 448)  # encoder frames + decoder tokens
        return 6.0 * n * tokens + 3.0 * att
    if shape.kind == "prefill":
        # inference computes logits only for the final position; the
        # embedding lookup is a gather (~0 matmul flops)
        tokens = B * S
        return 2.0 * (n - emb_params) * tokens + 2.0 * vd * B + att
    # decode: one new token per sequence (logits every token)
    return 2.0 * (n - emb_params) * B + 2.0 * vd * B + att


def analyze_record(rec: Dict, hw=TPU_V5E, chips: int = CHIPS_SINGLE_POD
                   ) -> Optional[Dict]:
    if "memory" not in rec:
        return None
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    cost = rec.get("cost_extrapolated") or rec["cost_raw"]
    flops_dev = cost["flops"]
    bytes_dev = cost["bytes_accessed"]
    wire_dev = rec["collectives"]["wire_bytes_per_device"]
    # CPU-backend f32-legalization correction for bf16-target models
    # (DESIGN.md §6): tensors in the compiled HLO are f32 though the model
    # traces bf16; halve the byte-denominated terms for bf16 archs.
    if cfg.param_dtype == "bfloat16":
        wire_dev = rec["collectives"].get("wire_bytes_bf16equiv",
                                          wire_dev * 0.5)
        bytes_dev = bytes_dev * 0.5

    compute_s = flops_dev / hw.peak_flops_bf16
    memory_s = bytes_dev / hw.hbm_bw
    collective_s = wire_dev / hw.ici_link_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    hlo_global = flops_dev * chips
    ratio = mf / hlo_global if hlo_global else 0.0
    ideal_compute_s = mf / (chips * hw.peak_flops_bf16)
    frac = ideal_compute_s / max(max(terms.values()), 1e-12)

    suggestions = {
        "collective": "cut cross-device traffic: fewer FSDP weight "
                      "regathers (lower accum / 2D weight sharding), bf16 "
                      "collectives, overlap-friendly scan structure",
        "memory": "cut HBM traffic: tighter remat policy, bf16 "
                  "intermediates, fuse elementwise chains, smaller "
                  "microbatch working set",
        "compute": "raise useful-flop share: remove causal-masked waste, "
                   "reduce remat recompute, larger MXU-aligned tiles",
    }
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": rec["kind"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": ratio, "roofline_fraction": frac,
        "peak_gib": rec["memory"]["peak_bytes"] / 2 ** 30,
        "fits_hbm": rec["memory"]["peak_bytes"] <= hw.hbm_bytes,
        "suggestion": suggestions[dominant],
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac | peak GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r is None:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['peak_gib']:.1f} | "
            f"{'Y' if r['fits_hbm'] else 'N'} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="results/dryrun_v2.json")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    recs = json.load(open(args.inp))
    rows = []
    for rec in recs:
        if "skipped" in rec:
            rows.append(None)
            continue
        try:
            rows.append(analyze_record(rec))
        except Exception as e:
            print(f"skip {rec.get('arch')}x{rec.get('shape')}: {e}")
    with open(args.out + ".json", "w") as f:
        json.dump([r for r in rows if r], f, indent=1)
    md = markdown_table(rows)
    with open(args.out + ".md", "w") as f:
        f.write(md)
    print(md)


if __name__ == "__main__":
    main()
