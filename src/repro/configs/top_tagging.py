"""Paper benchmark 1: Top quark tagging (Table 1).

Sequence 20 x 6 features -> RNN(hidden 20) -> Dense(64, ReLU) -> sigmoid.
Trainable params: 3,569 (LSTM) / 3,089 (GRU); RNN-layer params 2,160 / 1,680.
Target: Xilinx Kintex UltraScale xcku115, 200 MHz, latency 1.7 us.
"""

from repro.config import ModelConfig, RNNConfig


def _cfg(cell: str) -> ModelConfig:
    return ModelConfig(
        name=f"top-tagging-{cell}",
        family="rnn",
        rnn=RNNConfig(
            cell=cell,
            hidden=20,
            seq_len=20,
            input_size=6,
            dense_sizes=(64,),
            n_outputs=1,
            output_activation="sigmoid",
        ),
        param_dtype="float32",
        compute_dtype="float32",
    )


def lstm_config() -> ModelConfig:
    return _cfg("lstm")


def gru_config() -> ModelConfig:
    return _cfg("gru")


CONFIG = lstm_config()
