"""mamba2-780m [ssm]: 48L d_model=1536, attention-free, vocab=50280, ssm_state=128.

SSD (state-space duality) blocks: d_inner = 2*d_model = 3072, head_dim 64,
48 value heads, n_groups=1, conv width 4. [arXiv:2405.21060; unverified]

This is the paper-technique showcase arch: the SSD recurrence is a linear RNN;
decode is the "static mode" single-block state update, prefill is the chunked
scan. long_500k runs here (state is O(1) in context length).
"""

from repro.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    tie_embeddings=True,
    grad_accum=4,   # residual-store footprint at batch 256 x 4k (no SP for SSM)
    norm_type="rmsnorm",
    param_dtype="bfloat16",
)
