"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.

Squared-ReLU MLP, GQA. [arXiv:2402.16819; unverified]
Largest assigned arch — dry-run uses bf16 params + heavy grad accumulation.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    mlp_type="relu2",
    norm_type="layernorm",
    rope_theta=10000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    grad_accum=8,   # §Perf NEM-2: accum 4 cut wire 22% but peak 36->49GB; 8 is the HBM pareto
)
