"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064. phi3-mini backbone + CLIP frontend (STUB: input_specs()
provides precomputed patch embeddings prepended to the token sequence).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""

from repro.config import ModelConfig

N_PATCH_TOKENS = 576  # 24x24 CLIP-L/14 patch grid @ 336px (stubbed)

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    head_dim=96,
    d_ff=8192,
    vocab_size=32064,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=10000.0,
    frontend="vision",
    n_frontend_tokens=N_PATCH_TOKENS,
    tie_embeddings=False,
    param_dtype="bfloat16",
    grad_accum=2,
)
