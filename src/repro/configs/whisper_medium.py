"""whisper-medium [audio]: enc-dec, 24+24L d_model=1024 16H (MHA kv=16)
d_ff=4096 vocab=51865. Conv frontend is a STUB: input_specs() provides
precomputed frame embeddings [B, T, d_model]. [arXiv:2212.04356; unverified]

Shapes: train_4k = encoder over 4096 frames + decoder over 448 tokens;
prefill_32k = encoder over 32768 frames; decode_32k = decoder step with
32k self-attention KV cache + cross-attention over 32k encoder frames.
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    enc_dec=True,
    n_layers=24,               # per stack (24 encoder + 24 decoder)
    n_encoder_layers=24,
    n_decoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    mlp_type="gelu",
    norm_type="layernorm",
    frontend="audio",
    max_encoder_len=1500,
    tie_embeddings=True,
    param_dtype="bfloat16",
)

# decoder text length used in train cells (whisper max target length)
TRAIN_TEXT_LEN = 448
