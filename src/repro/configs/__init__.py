"""Architecture configs: 10 assigned archs + the paper's 3 RNN benchmarks.

Each module exposes ``CONFIG`` (a ModelConfig).  Use
``repro.registry.get_config(name)`` or ``--arch <id>`` on the launchers.
"""

from repro.registry import ARCHS, get_config, list_archs  # noqa: F401
