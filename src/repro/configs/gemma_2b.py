"""gemma-2b [dense]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000.

GeGLU MLP, head_dim=256 (note: 8*256 = 2048), MQA on the 2b model.
[arXiv:2403.08295; hf]
"""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    rope_theta=10000.0,
    tie_embeddings=True,
    logits_softcap=30.0,
    param_dtype="bfloat16",
    grad_accum=4,     # 256k-vocab f32 logits: keep microbatch loss under HBM
)
