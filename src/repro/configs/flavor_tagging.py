"""Paper benchmark 2: Jet flavor tagging (Table 1).

Sequence 15 x 6 track features -> RNN(hidden 120) -> Dense(50) -> Dense(10)
-> softmax(3).  Params: 67,553 (LSTM) / 52,673 (GRU); RNN layer 60,960 / 46,080.
Target: xcku115, 200 MHz.
"""

from repro.config import ModelConfig, RNNConfig


def _cfg(cell: str) -> ModelConfig:
    return ModelConfig(
        name=f"flavor-tagging-{cell}",
        family="rnn",
        rnn=RNNConfig(
            cell=cell,
            hidden=120,
            seq_len=15,
            input_size=6,
            dense_sizes=(50, 10),
            n_outputs=3,
            output_activation="softmax",
        ),
        param_dtype="float32",
        compute_dtype="float32",
    )


def lstm_config() -> ModelConfig:
    return _cfg("lstm")


def gru_config() -> ModelConfig:
    return _cfg("gru")


CONFIG = lstm_config()
