"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000. Griffin: RG-LRU + local attention, pattern (rglru, rglru, local).
[arXiv:2402.19427; unverified]

The RG-LRU layer IS the paper's recurrent cell at LLM scale: a gated linear
recurrence with elementwise state update.  This arch is the paper-technique
hillclimb representative.  long_500k runs here (local window + O(1) LRU state).
"""

from repro.config import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_type="geglu",
    norm_type="rmsnorm",
    rglru=RGLRUConfig(lru_width=4096, conv_width=4, window=2048,
                      pattern=("rglru", "rglru", "local_attn")),
    tie_embeddings=True,
    logits_softcap=30.0,
    param_dtype="bfloat16",
    grad_accum=4,   # hybrid blocks have no SP residual: bound the store
)
