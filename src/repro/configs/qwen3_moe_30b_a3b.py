"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert,
vocab=151936, 128 routed experts top-8, no shared experts.
[hf:Qwen/Qwen3-30B-A3B; hf]

128 experts divide the 16-way model axis -> true expert parallelism
(8 experts per device).
"""

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=768,
    vocab_size=151936,
    moe=MoEConfig(n_experts=128, top_k=8, n_shared_experts=0, d_ff_expert=768,
                  capacity_factor=1.25),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1000000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    grad_accum=4,   # §Perf MOE-2 refuted accum=2: wire unchanged, peak +11GB (EXPERIMENTS.md)
)
