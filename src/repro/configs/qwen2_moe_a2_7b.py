"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (MHA kv=16) d_ff=1408/expert,
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]

60 experts do not divide the 16-way model axis -> expert weights use
expert-TP (d_ff sharded over 'model', experts replicated along 'data' FSDP).
"""

from repro.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    moe=MoEConfig(n_experts=60, top_k=4, n_shared_experts=4, d_ff_expert=1408,
                  capacity_factor=1.25),
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1000000.0,
    tie_embeddings=False,
    param_dtype="bfloat16",
    grad_accum=4,   # bound MoE dispatch buffers + residual store
)
