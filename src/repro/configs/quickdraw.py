"""Paper benchmark 3: QuickDraw 5-class stroke classification (Table 1).

Sequence 100 x 3 (x, y, t) -> RNN(hidden 128) -> Dense(256) -> Dense(128)
-> softmax(5).  Params: 134,149 (LSTM) / 117,637 (GRU); RNN 67,584 / 51,072.
Target: Xilinx Alveo U250, 200 MHz.
"""

from repro.config import ModelConfig, RNNConfig


def _cfg(cell: str) -> ModelConfig:
    return ModelConfig(
        name=f"quickdraw-{cell}",
        family="rnn",
        rnn=RNNConfig(
            cell=cell,
            hidden=128,
            seq_len=100,
            input_size=3,
            dense_sizes=(256, 128),
            n_outputs=5,
            output_activation="softmax",
        ),
        param_dtype="float32",
        compute_dtype="float32",
    )


def lstm_config() -> ModelConfig:
    return _cfg("lstm")


def gru_config() -> ModelConfig:
    return _cfg("gru")


CONFIG = lstm_config()
