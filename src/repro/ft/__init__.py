from repro.ft.monitor import HeartbeatMonitor, StragglerPolicy  # noqa: F401
from repro.ft.elastic import ElasticPlan, plan_elastic_restart  # noqa: F401
