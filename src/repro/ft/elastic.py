"""Elastic restart planning: after losing nodes, pick the largest valid mesh
from the survivors, re-derive shardings, and resume from the last checkpoint.

The checkpoint format is mesh-agnostic (full arrays + manifest), so the only
work is choosing the new mesh shape and rebuilding shardings — which
``plan_elastic_restart`` does deterministically so every surviving worker
computes the SAME plan without coordination.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    dropped_chips: int
    global_batch_scale: float      # rescale batch to keep per-chip batch const


# preference order: keep the model axis intact (resharding TP weights is the
# expensive direction), shrink data parallelism first, then drop pods.
_CANDIDATE_MESHES: List[Tuple[Tuple[int, ...], Tuple[str, ...]]] = [
    ((2, 16, 16), ("pod", "data", "model")),
    ((16, 16), ("data", "model")),
    ((8, 16), ("data", "model")),
    ((4, 16), ("data", "model")),
    ((2, 16), ("data", "model")),
    ((1, 16), ("data", "model")),
    ((8, 8), ("data", "model")),
    ((4, 8), ("data", "model")),
    ((4, 4), ("data", "model")),
    ((2, 4), ("data", "model")),
    ((2, 2), ("data", "model")),
    ((1, 2), ("data", "model")),
    ((1, 1), ("data", "model")),
]


def plan_elastic_restart(healthy_chips: int,
                         original_chips: int = 512) -> Optional[ElasticPlan]:
    """Largest candidate mesh that fits the surviving chip count."""
    for shape, axes in _CANDIDATE_MESHES:
        n = 1
        for s in shape:
            n *= s
        if n <= healthy_chips:
            dp_old = original_chips // 16 if original_chips >= 16 else 1
            dp_new = n // shape[-1]
            return ElasticPlan(
                mesh_shape=shape, mesh_axes=axes,
                dropped_chips=original_chips - n,
                global_batch_scale=dp_new / max(dp_old, 1))
    return None
