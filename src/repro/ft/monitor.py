"""Heartbeat + straggler machinery for 1000-node runs.

Single-process container: the transport is injectable (tests feed synthetic
heartbeats); in production the send/recv hooks bind to the cluster fabric
(GCS bucket heartbeat files, etcd, or the TPU runtime's own health API).

Policies implemented:
  * HeartbeatMonitor — declares a worker dead after `timeout_s` of silence;
    surviving workers converge on the same dead-set (it is a pure function
    of the shared heartbeat table) and trigger an elastic restart (ft.elastic).
  * StragglerPolicy — tracks per-step durations; a worker is a straggler if
    its step time exceeds median x threshold for `patience` consecutive
    steps.  Response at scale: evict (treat as failure) or rebalance
    (shrink its grad-accum share) — returned as an action string.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set


@dataclass
class HeartbeatMonitor:
    n_workers: int
    timeout_s: float = 60.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def beat(self, worker: int, now: Optional[float] = None):
        self.last_seen[worker] = time.time() if now is None else now

    def dead_workers(self, now: Optional[float] = None) -> Set[int]:
        now = time.time() if now is None else now
        dead = set()
        for w in range(self.n_workers):
            seen = self.last_seen.get(w)
            if seen is None or now - seen > self.timeout_s:
                dead.add(w)
        return dead

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_workers(now)


@dataclass
class StragglerPolicy:
    threshold: float = 1.5          # x median step time
    patience: int = 3
    history: Dict[int, List[float]] = field(default_factory=dict)
    strikes: Dict[int, int] = field(default_factory=dict)

    def record_step(self, worker: int, duration_s: float):
        self.history.setdefault(worker, []).append(duration_s)

    def _medians(self) -> Optional[float]:
        last = [v[-1] for v in self.history.values() if v]
        if not last:
            return None
        s = sorted(last)
        return s[len(s) // 2]

    def evaluate(self) -> Dict[int, str]:
        """worker -> action in {'ok', 'warn', 'evict'}."""
        med = self._medians()
        out: Dict[int, str] = {}
        if med is None:
            return out
        for w, v in self.history.items():
            if not v:
                continue
            if v[-1] > self.threshold * med:
                self.strikes[w] = self.strikes.get(w, 0) + 1
            else:
                self.strikes[w] = 0
            n = self.strikes[w]
            out[w] = "evict" if n >= self.patience else (
                "warn" if n > 0 else "ok")
        return out
