"""Trigger-grade streaming: admission control, load shedding, degradation.

The paper's L1T scenario is a hard-real-time stream: a new collision every
25 ns, a fixed decision deadline, and NO elastic buffer — an event that
cannot be decided in time is not slowed down, it is *dropped*, and the
trigger menu is *degraded* (coarser algorithms) before the farm is allowed
to fall over.  This module brings that discipline to the serving layer:

  ingest -> feature-prep -> admission -> queue -> infer -> decision-sink

with a monotonic timestamp at every stage boundary and three explicit
overload mechanisms, all accounted per schedule key — a request is always
exactly one of ``answered | shed | failed`` (plus transient
``pending | queued``), never silently lost:

  * **Admission control** — a token bucket refilled at the *priced*
    throughput of the current rung's :class:`DesignPoint`
    (``core.hls.admission_rate_eps``): traffic beyond what the resolved
    design can sustain is shed at ingest, before it costs anything.
  * **Deadline-aware shedding** — at enqueue, the projected completion
    (single-server queue model: current backlog x per-event occupancy
    ``ii_s`` + service latency) is checked against the request's absolute
    deadline; a request that cannot make it is shed NOW, not after wasting
    a server slot.  The check repeats at dispatch, so injected stalls
    convert would-be deadline misses into late sheds — an ANSWERED
    request's result is available within its deadline.
  * **Graceful degradation** — a ladder of pre-warmed cheaper design
    points (higher reuse factor, or native-int when legal) from the
    autotuned frontier (``autotune.degradation_ladder``).  Sustained queue
    depth above ``high_water`` downgrades one rung (admission rate rises
    with the rung's priced throughput); sustained depth at or below
    ``low_water`` recovers one rung.  Every rung is compiled at pipeline
    construction — a downgrade never pays a compile.

Two clock domains, deliberately separate: *stage timestamps* live in the
pipeline clock (injectable — :class:`~repro.serving.faults.VirtualClock`
for deterministic replay, ``time.perf_counter`` live), while *service
times* come from the analytical model (``service_model="analytical"``:
``estimate.service_s`` / ``ii_s`` of the rung actually executed) or from
an EWMA of measured flush wall-clock (``"measured"``).  Analytical replay
is exactly reproducible: same arrival trace in, same sheds, same
downgrades, same per-stage percentiles out.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hls import DesignPoint, admission_rate_eps, price_point
from repro.serving.batcher import KeyStats
from repro.serving.faults import FaultInjector, InjectedFault

#: pipeline stages, in order; every boundary gets a monotonic stamp
STAGES = ("ingest", "prep", "queue", "infer", "sink")

#: why a request can be shed (each has its own per-key counter)
SHED_REASONS = ("admission", "deadline", "queue_full")

SERVICE_MODELS = ("analytical", "measured")
EXEC_MODES = ("batch", "one")


@dataclass
class StreamRequest:
    """One event moving through the pipeline.

    ``stamps`` maps stage name -> the pipeline-clock time at which the
    stage COMPLETED for this request; stamps are monotone non-decreasing
    in stage order.  ``deadline_s`` is absolute (arrival + deadline);
    the pipeline guarantees an answered request's ``infer`` stamp is
    within it whenever the service model is analytical.
    """

    payload: Any
    arrival_s: float
    deadline_s: float
    req_id: int
    key: str                      # schedule key of the rung at admission
    rung: int                     # ladder index at admission
    stamps: Dict[str, float] = field(default_factory=dict)
    status: str = "pending"       # pending|queued|answered|shed|failed
    shed_reason: Optional[str] = None
    error: Optional[BaseException] = None
    features: Any = None
    result: Any = None

    @property
    def latency_s(self) -> Optional[float]:
        """Arrival -> decision-sink, the end-to-end number."""
        t = self.stamps.get("sink")
        return None if t is None else t - self.arrival_s

    @property
    def infer_latency_s(self) -> Optional[float]:
        """Arrival -> inference result available (the deadline governs
        THIS stamp; the sink may legitimately run after it)."""
        t = self.stamps.get("infer")
        return None if t is None else t - self.arrival_s

    @property
    def remaining_s(self) -> float:
        """Budget left at the latest stamped point."""
        t = max(self.stamps.values()) if self.stamps else self.arrival_s
        return self.deadline_s - t


@dataclass
class TokenBucket:
    """Classic token bucket: ``rate_eps`` tokens/s, capacity ``burst``.

    The burst absorbs float rounding at exactly-priced arrival rates (a
    1.0x replay must not shed) and lets a short backlog form under real
    overload so the watermark machinery can see it.
    """

    rate_eps: float
    burst: float = 16.0
    tokens: float = field(init=False)
    t_last: Optional[float] = None

    def __post_init__(self):
        if self.rate_eps <= 0:
            raise ValueError(f"rate_eps must be > 0: {self.rate_eps}")
        self.tokens = float(self.burst)

    def set_rate(self, rate_eps: float) -> None:
        if rate_eps <= 0:
            raise ValueError(f"rate_eps must be > 0: {rate_eps}")
        self.rate_eps = rate_eps

    def try_take(self, now: float) -> bool:
        if self.t_last is None:
            self.t_last = now
        self.tokens = min(float(self.burst),
                          self.tokens + (now - self.t_last) * self.rate_eps)
        self.t_last = now
        if self.tokens >= 1.0 - 1e-9:
            self.tokens -= 1.0
            return True
        return False


@dataclass
class KeyCounts:
    """Per-schedule-key request accounting — the exactness invariant
    ``submitted == answered + failed + shed + in_flight`` is checked by
    :meth:`StreamingPipeline.verify_accounting`."""

    submitted: int = 0
    admitted: int = 0
    answered: int = 0
    failed: int = 0
    shed_admission: int = 0
    shed_deadline: int = 0
    shed_queue_full: int = 0
    deadline_miss: int = 0        # answered but infer stamp past deadline
                                  # (possible only under the measured model)

    @property
    def shed(self) -> int:
        return self.shed_admission + self.shed_deadline + self.shed_queue_full

    def as_dict(self) -> Dict[str, int]:
        return {"submitted": self.submitted, "admitted": self.admitted,
                "answered": self.answered, "failed": self.failed,
                "shed": self.shed, "shed_admission": self.shed_admission,
                "shed_deadline": self.shed_deadline,
                "shed_queue_full": self.shed_queue_full,
                "deadline_miss": self.deadline_miss}


class StreamingPipeline:
    """Deadline-aware streaming front end over an :class:`RNNServingEngine`.

    ``ladder`` is a sequence of :class:`DesignPoint` rungs with strictly
    ascending priced throughput — rung 0 is the quality point, later rungs
    are the degraded (cheaper, faster) fallbacks (see
    ``autotune.degradation_ladder``).  ``None`` builds a one-rung ladder
    from the engine's resolved schedule.

    ``push(payload, now=...)`` runs ingest + feature prep + the admission
    and shed gates; ``pump(now=...)`` dispatches every queued request whose
    simulated service start has arrived; ``drain()`` force-runs the queue
    dry (end of stream).  All three accept an explicit ``now`` for
    deterministic replay and fall back to the pipeline clock.
    """

    def __init__(self, engine=None,
                 ladder: Optional[Sequence[DesignPoint]] = None,
                 *,
                 router=None,
                 deadline_us: float,
                 clock_mhz: float = 200.0,
                 utilization: float = 1.0,
                 burst: float = 16.0,
                 max_queue: int = 64,
                 high_water: int = 8,
                 low_water: int = 1,
                 sustain: int = 3,
                 recovery_sustain: Optional[int] = None,
                 feature_fn: Optional[Callable[[Any], Any]] = None,
                 decision_fn: Optional[Callable[[np.ndarray], Any]] = None,
                 exec_mode: str = "batch",
                 service_model: str = "analytical",
                 stage_budgets_us: Optional[Dict[str, float]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 faults: Optional[FaultInjector] = None,
                 prewarm: bool = True):
        if deadline_us <= 0:
            raise ValueError(f"deadline_us must be > 0: {deadline_us}")
        if exec_mode not in EXEC_MODES:
            raise ValueError(f"exec_mode {exec_mode!r} not in {EXEC_MODES}")
        if service_model not in SERVICE_MODELS:
            raise ValueError(
                f"service_model {service_model!r} not in {SERVICE_MODELS}")
        if not 0 <= low_water < high_water:
            raise ValueError(f"need 0 <= low_water < high_water: "
                             f"{low_water}, {high_water}")
        if max_queue < 1:
            raise ValueError(f"max_queue must be >= 1: {max_queue}")

        # replicated serving: a Router replaces the single engine for the
        # infer stage; admission and the occupancy model scale with the
        # pool's HEALTHY replica count (re-rated live as replicas retire /
        # re-admit), while schedule resolution and prewarm go through the
        # pool's reference engine
        self.router = router
        if router is not None:
            if engine is not None:
                raise ValueError(
                    "pass either engine= or router=, not both: the router's "
                    "pool supplies the engines")
            engine = router.reference_engine
        elif engine is None:
            raise ValueError("StreamingPipeline needs an engine or a router")
        self.engine = engine
        if ladder is None:
            sched, fp = engine.resolve()
            ladder = (price_point(engine.cfg, sched, fp,
                                  clock_mhz=clock_mhz),)
        self.ladder: Tuple[DesignPoint, ...] = tuple(ladder)
        if not self.ladder:
            raise ValueError("ladder must have at least one rung")
        for a, b in zip(self.ladder, self.ladder[1:]):
            if b.throughput_eps(clock_mhz) <= a.throughput_eps(clock_mhz):
                raise ValueError(
                    f"ladder throughput must be strictly ascending: "
                    f"{a.key} ({a.throughput_eps(clock_mhz):.0f} eps) -> "
                    f"{b.key} ({b.throughput_eps(clock_mhz):.0f} eps)")

        self.deadline_s = deadline_us * 1e-6
        self.clock_mhz = clock_mhz
        self.utilization = utilization
        self.max_queue = max_queue
        self.high_water = high_water
        self.low_water = low_water
        self.sustain = sustain
        # recovery is deliberately stickier than downgrade: a drained queue
        # right after a downgrade is the downgrade WORKING, not the
        # overload ending — recovering on the same streak would oscillate
        self.recovery_sustain = (recovery_sustain if recovery_sustain
                                 is not None else 4 * sustain)
        self.feature_fn = feature_fn
        self.decision_fn = decision_fn
        self.exec_mode = exec_mode
        self.service_model = service_model
        self.stage_budgets_us = dict(stage_budgets_us or {})
        self.faults = faults if faults is not None else FaultInjector()
        self._clock = clock if clock is not None else time.perf_counter

        self.rung = 0
        self._capacity_seen = self.capacity()
        self._bucket = TokenBucket(self._rung_rate(0) * self._capacity_seen,
                                   burst=burst)
        self._queue: List[StreamRequest] = []
        self._server_free_s = float("-inf")
        self._last_now = float("-inf")
        self._ids = itertools.count()
        self._ewma_s: Optional[float] = None   # measured service model
        self._hi_streak = 0
        self._lo_streak = 0

        self.counts: Dict[str, KeyCounts] = {}
        self.downgrades = 0
        self.recoveries = 0
        self.rerates = 0              # admission re-rates on capacity change
        self.clock_steps = 0          # backwards clock steps absorbed
        self._stage_sim: Dict[str, KeyStats] = {s: KeyStats() for s in STAGES}
        self._stage_wall: Dict[str, KeyStats] = {s: KeyStats()
                                                 for s in ("prep", "infer",
                                                           "sink")}
        self._stage_over: Dict[str, int] = {s: 0 for s in STAGES}

        # every rung's executable exists before traffic: a downgrade under
        # overload must never pay a compile (with a router, on EVERY
        # replica — failover must be zero-warmup too)
        engines = ([rep.engine for rep in router.pool]
                   if router is not None else [engine])
        for eng in engines:
            for pt in self.ladder:
                eng._ensure_key(pt.schedule, pt.fp)
            if prewarm:
                eng.prewarm(schedules=[pt.schedule for pt in self.ladder],
                            fps=[pt.fp for pt in self.ladder])

    # -- clocks & rates ------------------------------------------------------

    def _now(self, now: Optional[float] = None) -> float:
        """Read the pipeline clock, clamped monotone.  A backwards step
        (misbehaving host clock) is absorbed — time holds still rather than
        running backwards — and counted in ``clock_steps``.

        Only CLOCK READS move the monotone floor.  Per-request stage stamps
        routinely lie in the future of the driving clock (the server
        finishes an event at ``start + service`` while the next arrival is
        already being pushed) — they are projections, not observations, and
        must never clamp subsequent clock reads upward."""
        t = self._clock() if now is None else now
        if t < self._last_now:
            self.clock_steps += 1
            t = self._last_now
        self._last_now = t
        return t

    def _rung_rate(self, rung: int) -> float:
        return admission_rate_eps(self.ladder[rung].estimate, self.clock_mhz,
                                  utilization=self.utilization)

    def capacity(self) -> int:
        """Healthy replicas backing the infer stage (1 without a router;
        floored at 1 — a fully dark pool still drains at single-replica
        pace rather than dividing by zero, and sheds on failure instead)."""
        if self.router is None:
            return 1
        return max(self.router.healthy_count(), 1)

    def _rerate(self) -> None:
        """Scale admission to the CURRENT healthy capacity: K healthy
        replicas sustain K x the rung's priced throughput, and a retirement
        mid-stream tightens admission instead of letting the queue grow
        into deadline sheds.  Called from push/pump; counted when the
        capacity actually changed."""
        cap = self.capacity()
        if cap == self._capacity_seen:
            return
        self._capacity_seen = cap
        self.rerates += 1
        self._bucket.set_rate(self._rung_rate(self.rung) * cap)

    @property
    def current_point(self) -> DesignPoint:
        return self.ladder[self.rung]

    def admission_rate(self) -> float:
        """Current token-bucket refill rate (events/s)."""
        return self._bucket.rate_eps

    def _service_s(self, rung: int) -> Optional[float]:
        """Per-event service latency; None = no estimate yet (measured
        model before the first flush) — such events are admitted."""
        if self.service_model == "analytical":
            return self.ladder[rung].estimate.service_s(self.clock_mhz)
        return self._ewma_s

    def _occupancy_s(self, rung: int) -> float:
        """Seconds of server the event occupies (II for a pipelined
        design — later events overlap the latency tail).  With a router,
        K healthy replicas drain K events per interval, so the
        single-server free pointer becomes a K-server fluid model."""
        if self.service_model == "analytical":
            occ = self.ladder[rung].estimate.ii_s(self.clock_mhz)
        else:
            occ = self._ewma_s or 0.0
        return occ / self.capacity()

    # -- accounting ----------------------------------------------------------

    def _count(self, key: str) -> KeyCounts:
        return self.counts.setdefault(key, KeyCounts())

    def _record_stage(self, stage: str, dt: float, wall: Optional[float] = None
                      ) -> None:
        self._stage_sim[stage].record_one(dt)
        if wall is not None:
            self._stage_wall[stage].record_one(wall)
        budget = self.stage_budgets_us.get(stage)
        if budget is not None and dt > budget * 1e-6:
            self._stage_over[stage] += 1

    def _shed(self, r: StreamRequest, reason: str, t: float) -> StreamRequest:
        r.status = "shed"
        r.shed_reason = reason
        r.stamps.setdefault("shed", t)
        setattr(self._count(r.key), f"shed_{reason}",
                getattr(self._count(r.key), f"shed_{reason}") + 1)
        return r

    def _fail(self, r: StreamRequest, e: BaseException, t: float
              ) -> StreamRequest:
        r.status = "failed"
        r.error = e
        r.stamps.setdefault("failed", t)
        self._count(r.key).failed += 1
        return r

    # -- the single-server queue projection ----------------------------------

    def _projected_free_s(self) -> float:
        """When the server frees up after the current backlog (each queued
        event occupies ``ii_s`` of its rung)."""
        free = self._server_free_s
        for q in self._queue:
            start = max(q.stamps["prep"], free)
            free = start + self._occupancy_s(q.rung)
        return free

    # -- ingest + admission (per event) --------------------------------------

    def push(self, payload: Any, now: Optional[float] = None) -> StreamRequest:
        """Run one event through ingest, feature prep, and the admission /
        shed gates.  Returns the request with its fate already decided
        (``queued``, ``shed``, or ``failed``) — an admitted request is
        answered by a later :meth:`pump` / :meth:`drain`."""
        t = self._now(now)
        self._rerate()
        r = StreamRequest(payload=payload, arrival_s=t,
                          deadline_s=t + self.deadline_s,
                          req_id=next(self._ids),
                          key=self.current_point.key, rung=self.rung)
        self._count(r.key).submitted += 1

        # ingest: the hand-off from the detector/feed into the pipeline
        try:
            t += self.faults.stall_s("ingest")
            self.faults.check("ingest")
        except Exception as e:
            return self._fail(r, e, t)
        r.stamps["ingest"] = t
        self._record_stage("ingest", t - r.arrival_s)

        # admission: token bucket at the rung's priced throughput
        if not self._bucket.try_take(t):
            return self._shed(r, "admission", t)

        # feature prep: real compute (wall-clocked) + simulated stall
        w0 = time.perf_counter()
        try:
            self.faults.check("prep")
            r.features = (payload if self.feature_fn is None
                          else self.feature_fn(payload))
        except Exception as e:
            return self._fail(r, e, t)
        wall = time.perf_counter() - w0
        t += self.faults.stall_s("prep")
        r.stamps["prep"] = t
        self._record_stage("prep", t - r.stamps["ingest"], wall=wall)

        # bounded queue: an overfull queue is an explicit shed, not growth
        if len(self._queue) >= self.max_queue:
            self._shed(r, "queue_full", t)
            self._watermark()
            return r

        # deadline-aware shed: projected completion behind the backlog
        svc = self._service_s(r.rung)
        if svc is not None:
            start = max(t, self._projected_free_s())
            if start + svc > r.deadline_s + 1e-12:
                self._shed(r, "deadline", t)
                self._watermark()
                return r

        self._queue.append(r)
        r.status = "queued"
        self._count(r.key).admitted += 1
        self._watermark()
        return r

    # -- dispatch ------------------------------------------------------------

    def pump(self, now: Optional[float] = None, force: bool = False
             ) -> List[StreamRequest]:
        """Dispatch every queued request whose service start has arrived
        (``force`` ignores the clock — the end-of-stream drain).  Returns
        the requests completed this call (answered or failed) plus any
        late sheds."""
        t = self._now(now)
        self._rerate()
        done: List[StreamRequest] = []

        # an infer-stage stall holds the server itself: it pushes the free
        # pointer BEFORE the dispatch-time deadline re-check, so requests
        # the stall pushed past their deadline shed late instead of being
        # answered late
        stall = self.faults.stall_s("infer")
        if stall > 0:
            self._server_free_s = max(self._server_free_s, t) + stall

        dispatch: List[StreamRequest] = []
        while self._queue:
            q = self._queue[0]
            start = max(q.stamps["prep"], self._server_free_s)
            svc = self._service_s(q.rung)
            # a doomed request sheds NOW even if the server isn't free yet:
            # its projected start only ever grows, so waiting for the clock
            # to reach it would just hold a dead entry in the queue (and
            # inflate the watermark depth with work that will never run)
            if svc is not None and start + svc > q.deadline_s + 1e-12:
                self._queue.pop(0)
                done.append(self._shed(q, "deadline", start))
                continue
            if not force and start > t:
                break
            self._queue.pop(0)
            q.stamps["queue"] = start
            self._record_stage("queue", start - q.stamps["prep"])
            self._server_free_s = start + self._occupancy_s(q.rung)
            dispatch.append(q)

        done.extend(self._execute(dispatch))
        self._watermark()
        return done

    def drain(self, now: Optional[float] = None) -> List[StreamRequest]:
        """Force-run the queue dry (end of stream / shutdown).  Bounded:
        every iteration strictly shrinks the queue, so this cannot spin."""
        done: List[StreamRequest] = []
        while self._queue:
            before = len(self._queue)
            done.extend(self.pump(now=now, force=True))
            assert len(self._queue) < before, "drain made no progress"
        return done

    # -- execution -----------------------------------------------------------

    def _execute(self, dispatch: List[StreamRequest]) -> List[StreamRequest]:
        if not dispatch:
            return []
        # group by rung so co-batching lands each request on its admitted
        # rung's queue (a request is NEVER silently re-scheduled after
        # admission: its deadline projection priced THIS rung)
        groups: Dict[int, List[StreamRequest]] = {}
        for q in dispatch:
            groups.setdefault(q.rung, []).append(q)

        if self.router is not None:
            # replicated infer: each event runs the router's full ladder
            # (timeout -> retry -> hedge -> failover); a routed request
            # that still ends failed/shed surfaces as THIS request's
            # failure, others unaffected
            for rung, qs in groups.items():
                pt = self.ladder[rung]
                for q in qs:
                    w0 = time.perf_counter()
                    rr = self.router.submit(q.features, schedule=pt.schedule,
                                            fp=pt.fp, now=q.stamps["queue"])
                    if rr.status != "answered":
                        err = rr.error if rr.error is not None else \
                            RuntimeError(f"routed request shed: "
                                         f"{rr.shed_reason}")
                        self._fail(q, err, q.stamps["queue"])
                        continue
                    self._finish(q, rr.result, time.perf_counter() - w0)
        elif self.exec_mode == "one":
            for rung, qs in groups.items():
                pt = self.ladder[rung]
                for q in qs:
                    w0 = time.perf_counter()
                    try:
                        out = self.engine.predict_one(q.features,
                                                      schedule=pt.schedule,
                                                      fp=pt.fp)
                    except Exception as e:
                        self._fail(q, e, q.stamps["queue"])
                        continue
                    self._finish(q, out, time.perf_counter() - w0)
        else:
            pairs = []
            for rung, qs in groups.items():
                pt = self.ladder[rung]
                for q in qs:
                    pairs.append((q, self.engine.submit(q.features,
                                                        schedule=pt.schedule,
                                                        fp=pt.fp)))
            w0 = time.perf_counter()
            self.engine.flush(force=True)
            wall = (time.perf_counter() - w0) / max(len(pairs), 1)
            for q, ereq in pairs:
                if ereq.error is not None:
                    # the engine's per-key flush isolation attached the
                    # error; surface it on THIS request, others unaffected
                    self._fail(q, ereq.error, q.stamps["queue"])
                else:
                    self._finish(q, ereq.result, wall)
        return dispatch

    def _finish(self, q: StreamRequest, out: np.ndarray, wall_s: float
                ) -> None:
        svc = self._service_s(q.rung)
        if self.service_model == "measured":
            # EWMA of measured per-event wall-clock feeds the next
            # projections (the live-traffic mode, where there is no
            # analytical clock domain to trust)
            self._ewma_s = (wall_s if self._ewma_s is None
                            else 0.7 * self._ewma_s + 0.3 * wall_s)
            t_infer = q.stamps["queue"] + wall_s
        else:
            t_infer = q.stamps["queue"] + (svc or 0.0)
        q.stamps["infer"] = t_infer
        self._record_stage("infer", t_infer - q.stamps["queue"], wall=wall_s)
        if t_infer > q.deadline_s + 1e-12:
            self._count(q.key).deadline_miss += 1

        # decision sink: the trigger decision leaves the pipeline
        w0 = time.perf_counter()
        try:
            self.faults.check("sink")
            q.result = (out if self.decision_fn is None
                        else self.decision_fn(out))
        except Exception as e:
            self._fail(q, e, t_infer)
            return
        wall = time.perf_counter() - w0
        t_sink = t_infer + self.faults.stall_s("sink")
        q.stamps["sink"] = t_sink
        self._record_stage("sink", t_sink - t_infer, wall=wall)
        q.status = "answered"
        self._count(q.key).answered += 1

    # -- degradation ladder --------------------------------------------------

    def _watermark(self) -> None:
        """Hysteresis over queue depth: sustained ``high_water`` depth
        downgrades one rung, sustained ``low_water`` depth recovers one."""
        depth = len(self._queue)
        if depth >= self.high_water:
            self._hi_streak += 1
            self._lo_streak = 0
            if self._hi_streak >= self.sustain \
                    and self.rung + 1 < len(self.ladder):
                self.rung += 1
                self.downgrades += 1
                self._hi_streak = 0
                self._bucket.set_rate(self._rung_rate(self.rung)
                                      * self.capacity())
        elif depth <= self.low_water:
            self._lo_streak += 1
            self._hi_streak = 0
            if self._lo_streak >= self.recovery_sustain and self.rung > 0:
                self.rung -= 1
                self.recoveries += 1
                self._lo_streak = 0
                self._bucket.set_rate(self._rung_rate(self.rung)
                                      * self.capacity())
        else:
            self._hi_streak = 0
            self._lo_streak = 0

    # -- invariants & reporting ----------------------------------------------

    def in_flight(self, key: Optional[str] = None) -> int:
        if key is None:
            return len(self._queue)
        return sum(1 for q in self._queue if q.key == key)

    def verify_accounting(self) -> Dict[str, Dict[str, int]]:
        """Assert the exactness invariant per key:
        ``submitted == answered + failed + shed + in_flight`` — every
        submitted request is accounted for, none lost, none double-counted.
        Returns the per-key counters on success."""
        out: Dict[str, Dict[str, int]] = {}
        for key, c in self.counts.items():
            accounted = c.answered + c.failed + c.shed + self.in_flight(key)
            if accounted != c.submitted:
                raise AssertionError(
                    f"accounting broken for {key!r}: submitted="
                    f"{c.submitted} but answered={c.answered} + failed="
                    f"{c.failed} + shed={c.shed} + in_flight="
                    f"{self.in_flight(key)} = {accounted}")
            out[key] = c.as_dict()
        return out

    def stage_report(self) -> Dict[str, Dict]:
        """Per-stage budget report: simulated-clock percentiles (the
        replay-honest column), wall-clock percentiles where the stage does
        real compute, the stage budget, and the over-budget count."""
        out: Dict[str, Dict] = {}
        for stage in STAGES:
            sim = self._stage_sim[stage]
            row: Dict[str, Any] = {"sim": sim.summary()}
            if stage in self._stage_wall and self._stage_wall[stage].served:
                row["wall"] = self._stage_wall[stage].summary()
            row["budget_us"] = self.stage_budgets_us.get(stage)
            row["over_budget"] = self._stage_over[stage]
            out[stage] = row
        return out

    def report(self) -> Dict[str, Any]:
        """Everything the overload acceptance criteria look at."""
        return {
            "stages": self.stage_report(),
            "keys": {k: c.as_dict() for k, c in self.counts.items()},
            "ladder": [{"key": pt.key,
                        "throughput_eps": pt.throughput_eps(self.clock_mhz),
                        "latency_us": pt.latency_us(self.clock_mhz),
                        "dsp": pt.dsp}
                       for pt in self.ladder],
            "rung": self.rung,
            "downgrades": self.downgrades,
            "recoveries": self.recoveries,
            "rerates": self.rerates,
            "capacity": self.capacity(),
            "clock_steps": self.clock_steps,
            "admission_rate_eps": self.admission_rate(),
            "in_flight": self.in_flight(),
            "deadline_us": self.deadline_s * 1e6,
        }


def format_stream_report(pipe: StreamingPipeline, *,
                         include_serve: bool = True) -> str:
    """Render the per-stage budget table + per-key accounting + ladder
    state, with the engine's measured-vs-analytical ``serve_report`` table
    beside it (the two reports share the schedule keys)."""
    from repro.serving.engine import format_serve_report

    rep = pipe.report()
    lines = [f"stream: deadline {rep['deadline_us']:.2f}us, admission "
             f"{rep['admission_rate_eps']:.0f} eps, rung {rep['rung']}, "
             f"downgrades {rep['downgrades']}, recoveries "
             f"{rep['recoveries']}, clock steps {rep['clock_steps']}",
             "",
             f"{'stage':8s} {'events':>7s} {'sim p50':>10s} {'sim p99':>10s} "
             f"{'sim max':>10s} {'budget':>9s} {'over':>5s}"]
    for stage, row in rep["stages"].items():
        s = row["sim"]
        budget = row["budget_us"]
        lines.append(
            f"{stage:8s} {int(s['served']):7d} "
            f"{s['latency_p50_s'] * 1e6:8.2f}us "
            f"{s['latency_p99_s'] * 1e6:8.2f}us "
            f"{s['latency_max_s'] * 1e6:8.2f}us "
            f"{'' if budget is None else f'{budget:7.2f}us':>9s} "
            f"{row['over_budget']:5d}")
    lines += ["", f"{'schedule key':38s} {'subm':>6s} {'ans':>6s} "
                  f"{'shed':>6s} {'fail':>5s} {'adm/dl/qf':>11s} "
                  f"{'miss':>5s}"]
    for key, c in rep["keys"].items():
        lines.append(
            f"{key:38s} {c['submitted']:6d} {c['answered']:6d} "
            f"{c['shed']:6d} {c['failed']:5d} "
            f"{c['shed_admission']}/{c['shed_deadline']}"
            f"/{c['shed_queue_full']:>3d} {c['deadline_miss']:5d}")
    lines += ["", "ladder (rung: key, priced throughput, latency):"]
    for i, row in enumerate(rep["ladder"]):
        mark = " <- current" if i == rep["rung"] else ""
        lines.append(f"  [{i}] {row['key']:38s} "
                     f"{row['throughput_eps']:10.0f} eps "
                     f"{row['latency_us']:7.2f}us  dsp {row['dsp']}{mark}")
    if include_serve:
        lines += ["", format_serve_report(pipe.engine.serve_report(
            pipe.clock_mhz))]
    return "\n".join(lines)
