"""LM serving engine: prefill + decode with slot-based continuous batching,
folded onto the SAME schedule-key abstraction as the RNN engine.

The decode step is the paper's static-mode schedule at LLM scale (state
resident, II = 1 token); the slot manager implements continuous batching —
finished sequences free their slot, new requests join mid-flight without
stalling running ones (vLLM-style, sized for fixed-shape XLA programs).

Schedule keys (ROADMAP item, closed): requests may carry a
``KernelSchedule`` and are routed by the stable ``schedule_key`` hash into
per-key decoders — each key owns its slot pool, its KV cache, ONE jit trace
of the decode step, and its ``KeyStats`` counters, exactly mirroring the RNN
engine's keyed jit-cache path.  Requests whose keys differ never share a
decode batch (they would retrace); requests with no schedule ride the
``DEFAULT_SCHEDULE_KEY`` decoder, which preserves the original single-pool
behavior bit-for-bit.

Schedule-DRIVEN decode (PR 5): a keyed decoder's schedule now changes what
its trace executes — ``decode_step(..., schedule=)`` runs the reuse-tiled,
weight-resident kernel path (fused q|k|v / MLP gate matmuls, R column-tile
passes in-block), with the packed weight layout derived ONCE per
(params, schedule key) at decoder construction and fed to the jit trace as
an input, so per-key decode batches genuinely differ in executed tiling —
bit-identically to the einsum path (conformance-enforced).
``serve_report`` pairs each key's measured tokens/s (decoded tokens over
decode wall-clock) with ``estimate_lm_decode`` of the SAME schedule object
— the decode path's measured-vs-analytical two-column table.

Speculative decode (PR 9): a key may additionally carry a ``SpecConfig``
(engine default or per request) — its decoder then drafts K tokens per
round on the cheap side of the R asymmetry (n-gram ``CacheTable`` or a
high-R model draft step) and verifies all K+1 positions in ONE batched
``decode_steps`` pass on its own schedule, with exact greedy-match
acceptance (``serving/speculative.py``).  Keys with speculation get a
``-spec[...]`` suffix so they never share a trace or KV cache with plain
traffic; steady-state tokens/s counts ACCEPTED tokens only — drafted-but-
rejected work is visible in the per-key accept_rate / drafted / rejected
columns instead, and ``verify_spec_accounting`` enforces
``drafted == accepted + rejected`` exactly.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.hls.resources import estimate_lm_decode
from repro.kernels.schedule import (DEFAULT_SCHEDULE_KEY, KernelSchedule,
                                    cache_meta, schedule_key)
from repro.models.decode import (cache_specs, decode_schedulable, decode_step,
                                 pack_decode_params)
from repro.serving.batcher import KeyStats, _now
from repro.serving.compile_cache import CachedExecutor, CompileCache
from repro.serving.engine import EngineClosedError
from repro.serving.speculative import (SpecConfig, SpeculativeDecoder,
                                       accept_chunk)


@dataclass
class Slot:
    active: bool = False
    req_id: int = -1
    pos: int = 0
    tokens: List[int] = field(default_factory=list)
    max_new: int = 16
    arrival_s: float = 0.0


class _KeyedDecoder:
    """One schedule key's continuous-batching state: slot pool + KV cache +
    the key's single jit trace of the decode step + serving counters.

    With a schedule, the trace EXECUTES the scheduled kernel path: the
    weight-resident packed layout is derived once here (host-side, via the
    kernels' residency cache) and passed to the jit'd step as an input, so
    the per-token program re-derives nothing — and two decoders with
    different schedules compile genuinely different tilings."""

    def __init__(self, cfg: ModelConfig, key: str,
                 schedule: Optional[KernelSchedule], *, max_batch: int,
                 max_seq: int, cache_dtype: str, params: Optional[Dict] = None,
                 compile_cache: Optional[CompileCache] = None,
                 spec: Optional[SpecConfig] = None):
        self.key = key
        self.schedule = schedule
        self.spec_dec = (SpeculativeDecoder(
            cfg, key, schedule, spec, max_batch=max_batch, max_seq=max_seq,
            cache_dtype=cache_dtype, params=params,
            compile_cache=compile_cache)
            if spec is not None and spec.k > 0 else None)
        self.scheduled = schedule is not None and decode_schedulable(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.slots = [Slot() for _ in range(max_batch)]
        specs = cache_specs(cfg, max_batch, max_seq, cache_dtype)
        self.cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
                      for k, s in specs.items()}
        self.stats = KeyStats()
        self.traces = 0
        self.tokens = 0                  # decoded tokens (per-key tokens/s)
        self.decode_s = 0.0              # wall-clock spent in decode steps
        self.packed = (pack_decode_params(cfg, params, schedule)
                       if self.scheduled and params is not None else None)

        def step(params, cache, tokens, pos, packed=None):
            # Python side effect runs at COLD lower/compile time only: one
            # trace per key is the keyed-cache efficiency criterion (RNN
            # engine parity); a warm persistent-cache hit deserializes the
            # executable without tracing, so this stays 0 on a warm start
            self.traces += 1
            return decode_step(cfg, params, cache, tokens, pos,
                               schedule=schedule, packed=packed)

        meta = {"kind": "lm_decode_step", "cfg": repr(cfg),
                "max_batch": max_batch, "max_seq": max_seq,
                "cache_dtype": cache_dtype,
                **cache_meta(schedule, None)}
        self._step = CachedExecutor(
            jax.jit(step, donate_argnums=(1,)),
            compile_cache if compile_cache is not None else CompileCache(),
            key, meta, name_hint=f"lm-{key}")

    def warm_step(self, params: Dict) -> Dict:
        """Ensure this key's decode-step executable exists without ticking
        (nothing executes, the KV cache is untouched): lowers against the
        exact shapes ``_tick_decoder`` calls with — warm over a persistent
        cache, compile-and-store when cold.  Speculative keys warm their
        verify (and draft) executables instead: those are the only
        programs their ticks run."""
        if self.spec_dec is not None:
            return self.spec_dec.warm(params, self.cache)
        tok = jax.ShapeDtypeStruct((self.max_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((self.max_batch,), jnp.int32)
        args = (params, self.cache, tok, pos)
        if self.packed is not None:
            args = args + (self.packed,)
        return self._step.warm(*args)

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots)

    def free_slot(self) -> Optional[Slot]:
        for s in self.slots:
            if not s.active:
                return s
        return None


class LMServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 max_batch: int = 4, max_seq: int = 256,
                 cache_dtype: str = "float32",
                 schedule: Optional[KernelSchedule] = None,
                 cache_dir: Optional[str] = None,
                 spec: Optional[SpecConfig] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.schedule = schedule            # default-request schedule
        self.spec = spec                    # default-request speculation
        self.compile_cache = CompileCache(cache_dir)
        self._decoders: Dict[str, _KeyedDecoder] = {}
        self._next_req = 0
        self._closed = False
        # eagerly build the default decoder: same allocation behavior as the
        # pre-keyed engine for schedule-less traffic
        self._decoder_for(self.schedule)

    # -- keyed decoders ------------------------------------------------------

    def _resolve_spec(self, spec: Optional[SpecConfig]
                      ) -> Optional[SpecConfig]:
        spec = spec if spec is not None else self.spec
        return None if spec is None or spec.k == 0 else spec

    def _key_for(self, schedule: Optional[KernelSchedule],
                 spec: Optional[SpecConfig] = None) -> str:
        schedule = schedule if schedule is not None else self.schedule
        key = (DEFAULT_SCHEDULE_KEY if schedule is None
               else schedule_key(schedule))
        spec = self._resolve_spec(spec)
        if spec is not None:
            # dash-separated suffix: KernelSchedule.from_key still parses
            # the schedule part; speculative keys never share a trace or
            # KV cache with plain traffic on the same schedule
            key = key + "-" + spec.key_token()
        return key

    def _decoder_for(self, schedule: Optional[KernelSchedule],
                     spec: Optional[SpecConfig] = None) -> _KeyedDecoder:
        sched = schedule if schedule is not None else self.schedule
        spc = self._resolve_spec(spec)
        key = self._key_for(sched, spec)
        dec = self._decoders.get(key)
        if dec is None:
            dec = _KeyedDecoder(self.cfg, key, sched,
                                max_batch=self.max_batch,
                                max_seq=self.max_seq,
                                cache_dtype=self.cache_dtype,
                                params=self.params,
                                compile_cache=self.compile_cache,
                                spec=spc)
            self._decoders[key] = dec
        return dec

    def prewarm(self, schedules: Optional[List[Optional[KernelSchedule]]]
                = None) -> Dict[str, Dict]:
        """Zero-warmup for the decode path: build each schedule's keyed
        decoder and make its step executable exist before the first tick —
        deserialized from a warm ``cache_dir`` (zero jit compiles) or
        compiled once and stored.  No schedules: the engine default."""
        out: Dict[str, Dict] = {}
        for sched in (schedules if schedules is not None else [None]):
            dec = self._decoder_for(sched)
            out[dec.key] = dec.warm_step(self.params)
        return out

    def keys(self) -> List[str]:
        return list(self._decoders)

    def trace_count(self, key: str) -> int:
        dec = self._decoders.get(key)
        return 0 if dec is None else dec.traces

    @property
    def slots(self) -> List[Slot]:
        """Default-key slot pool (single-tenant compatibility view)."""
        return self._decoder_for(None).slots

    # -- request management --------------------------------------------------
    def add_request(self, prompt: List[int], max_new: int = 16,
                    now: Optional[float] = None,
                    schedule: Optional[KernelSchedule] = None,
                    spec: Optional[SpecConfig] = None
                    ) -> Optional[int]:
        """Claim a slot on the request's schedule-key decoder; None when that
        key's pool is full (keys never borrow each other's slots — they
        could not share a decode batch anyway)."""
        if self._closed:
            raise EngineClosedError("LMServingEngine")
        dec = self._decoder_for(schedule, spec)
        s = dec.free_slot()
        if s is None:
            return None                 # this key's queue is full
        s.active = True
        s.req_id = self._next_req
        self._next_req += 1
        s.pos = 0
        s.tokens = list(prompt)
        s.max_new = max_new
        # monotonic clock (batcher._now), matching the RNN path: wall-clock
        # time.time() made request latencies NTP-step sensitive
        s.arrival_s = _now() if now is None else now
        s._prompt_len = len(prompt)
        s._observed = 0                 # n-gram table watermark (spec keys)
        return s.req_id

    def _advance_prompt_or_sample(self, s: Slot, logits_row) -> int:
        """Teacher-force remaining prompt tokens, then greedy-sample."""
        if s.pos + 1 < s._prompt_len:
            return s.tokens[s.pos + 1]
        return int(jnp.argmax(logits_row))

    # -- one engine tick: every active slot decodes one token ----------------
    def _tick_decoder(self, dec: _KeyedDecoder,
                      now: Optional[float]) -> Dict[int, List[int]]:
        tokens = np.zeros((dec.max_batch, 1), np.int32)
        pos = np.zeros((dec.max_batch,), np.int32)
        n_active = 0
        for i, s in enumerate(dec.slots):
            if s.active:
                tokens[i, 0] = s.tokens[s.pos]
                pos[i] = s.pos
                n_active += 1
        traces_before = dec.traces
        t0 = time.perf_counter()
        if dec.packed is not None:
            logits, dec.cache = dec._step(
                self.params, dec.cache, jnp.asarray(tokens),
                jnp.asarray(pos), dec.packed)
        else:
            logits, dec.cache = dec._step(
                self.params, dec.cache, jnp.asarray(tokens), jnp.asarray(pos))
        logits = np.asarray(logits[:, 0])
        # tokens/s bookkeeping: real wall-clock of the decode step (the
        # latency counters below use the caller's logical clock instead);
        # the tick that traced/compiled is excluded — steady-state rate
        if dec.traces == traces_before:
            dec.decode_s += time.perf_counter() - t0
            dec.tokens += n_active

        finished: Dict[int, List[int]] = {}
        for i, s in enumerate(dec.slots):
            if not s.active:
                continue
            nxt = self._advance_prompt_or_sample(s, logits[i])
            if s.pos + 1 >= s._prompt_len:
                s.tokens.append(nxt)
            s.pos += 1
            done = (len(s.tokens) - s._prompt_len >= s.max_new
                    or s.pos >= dec.max_seq - 1)
            if done:
                finished[s.req_id] = list(s.tokens)
                s.active = False        # slot freed for the next request
                # same clock domain as add_request: monotonic by default,
                # the caller's logical clock when both pass ``now``
                t = _now() if now is None else now
                dec.stats.record_one(t - s.arrival_s)
        if finished:
            dec.stats.batches += 1
        return finished

    # -- one speculative round: draft K, verify K+1 in one pass --------------
    def _tick_spec(self, dec: _KeyedDecoder,
                   now: Optional[float]) -> Dict[int, List[int]]:
        sd = dec.spec_dec
        if sd.table is not None:
            # feed newly observed tokens (prompt + accepted continuations)
            # into the n-gram table before drafting this round
            for s in dec.slots:
                if s.active:
                    sd.table.observe(s.tokens, start=getattr(s, "_observed", 0))
                    s._observed = len(s.tokens)
        rows: List[Optional[tuple]] = [None] * dec.max_batch
        for i, s in enumerate(dec.slots):
            if s.active:
                rows[i] = (s.tokens, s._prompt_len, s.pos)
        kv, chunk, greedy, wall, traced = sd.round(self.params, dec.cache,
                                                   rows)
        dec.cache = kv
        dec.traces = sd.verify_traces   # serve_report / trace_count parity

        finished: Dict[int, List[int]] = {}
        emitted_total = 0
        keep = np.zeros((dec.max_batch,), np.int32)
        for i, s in enumerate(dec.slots):
            if not s.active:
                continue
            adv = accept_chunk(
                [int(t) for t in chunk[i]], [int(g) for g in greedy[i]],
                tokens=s.tokens, plen=s._prompt_len, pos=s.pos,
                max_new=s.max_new, max_seq=dec.max_seq)
            s.tokens.extend(adv.emitted)
            s.pos += adv.advanced
            emitted_total += len(adv.emitted)
            sd.drafted += adv.drafted
            sd.accepted += adv.accepted
            sd.rejected += adv.rejected
            keep[i] = s.pos
            if adv.done:
                finished[s.req_id] = list(s.tokens)
                s.active = False
                keep[i] = 0             # trim frees the whole row
                t = _now() if now is None else now
                dec.stats.record_one(t - s.arrival_s)
        if sd.spec.trim:
            # optional rollback hygiene — outside the timed window: the
            # exactness argument does not need it (see speculative.py)
            dec.cache = sd.trim(dec.cache, keep)
        # steady-state tokens/s: ACCEPTED tokens only, never drafted-but-
        # rejected ones; rounds that traced/compiled are excluded
        if not traced:
            dec.decode_s += wall
            dec.tokens += emitted_total
        if finished:
            dec.stats.batches += 1
        return finished

    def tick(self, now: Optional[float] = None) -> Dict[int, List[int]]:
        """One decode step on every key with active slots (keys never mix
        in a batch); returns all requests finished this tick."""
        finished: Dict[int, List[int]] = {}
        for dec in self._decoders.values():
            if dec.any_active:
                if dec.spec_dec is not None:
                    finished.update(self._tick_spec(dec, now))
                else:
                    finished.update(self._tick_decoder(dec, now))
        return finished

    def serve_report(self, clock_mhz: float = 200.0) -> Dict[str, Dict]:
        """Measured serving stats per schedule key, in the RNN engine's
        report shape.  The measured column now carries per-key tokens/s
        (decoded tokens over decode-step wall-clock) next to the request
        latency counters; keys whose trace EXECUTES the scheduled kernels
        pair it with ``estimate_lm_decode`` of the SAME schedule object —
        the decode path's two-column table.  Schedule-less keys, and
        schedules on families whose decode step is not matmul-shaped (the
        einsum fallback), stay estimate-less: an estimate must never
        describe kernels that did not run."""
        report: Dict[str, Dict] = {}
        for key, dec in self._decoders.items():
            measured = dec.stats.summary()
            measured["tokens"] = float(dec.tokens)
            measured["decode_s"] = dec.decode_s
            measured["tokens_per_s"] = (dec.tokens / dec.decode_s
                                        if dec.decode_s > 0 else 0.0)
            analytical = None
            if dec.scheduled:
                analytical = estimate_lm_decode(
                    dec.schedule, self.cfg).report_row(clock_mhz)
                analytical["scheduled_kernels"] = True
            sd = dec.spec_dec
            report[key] = {"schedule": dec.schedule,
                           "fp": None,
                           "traces": dec.traces,
                           "accept_rate": sd.accept_rate if sd else None,
                           "draft_traces": sd.draft_traces if sd else 0,
                           "spec": sd.report_row() if sd else None,
                           "measured": measured,
                           "analytical": analytical,
                           "compile": self.compile_cache.report_row(key)}
        return report

    def verify_spec_accounting(self) -> Dict[str, Dict]:
        """Exact-sum invariant for every speculative key (PR 8's
        ``verify_accounting`` style): drafted == accepted + rejected, no
        token drafted ever unaccounted.  Raises AssertionError naming the
        broken key/counters; returns the per-key counter dict on success."""
        out: Dict[str, Dict] = {}
        for key, dec in self._decoders.items():
            sd = dec.spec_dec
            if sd is None:
                continue
            if sd.drafted != sd.accepted + sd.rejected:
                raise AssertionError(
                    f"speculative accounting broken for key {key}: "
                    f"drafted ({sd.drafted}) != accepted ({sd.accepted}) "
                    f"+ rejected ({sd.rejected})")
            if min(sd.drafted, sd.accepted, sd.rejected) < 0:
                raise AssertionError(
                    f"speculative accounting broken for key {key}: "
                    f"negative counter (drafted={sd.drafted}, "
                    f"accepted={sd.accepted}, rejected={sd.rejected})")
            out[key] = {"drafted": sd.drafted, "accepted": sd.accepted,
                        "rejected": sd.rejected, "rounds": sd.rounds,
                        "accept_rate": sd.accept_rate}
        return out

    def run_to_completion(self, max_ticks: int = 512,
                          now: Optional[float] = None) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            out.update(self.tick(now=now))
            if not any(d.any_active for d in self._decoders.values()):
                break
        return out

    # -- lifecycle -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def drain(self, max_ticks: int = 512,
              now: Optional[float] = None) -> Dict[int, List[int]]:
        """Decode every active slot on every keyed decoder to completion
        and return the finished sequences — no slot left active, no
        request stranded mid-decode.  The engine stays open."""
        return self.run_to_completion(max_ticks=max_ticks, now=now)

    def close(self, max_ticks: int = 512,
              now: Optional[float] = None) -> Dict[int, List[int]]:
        """Drain, then refuse new requests: ``add_request`` raises
        :class:`EngineClosedError` from now on.  Idempotent — the
        replica-retirement hook, mirroring the RNN engine."""
        if self._closed:
            return {}
        finished = self.drain(max_ticks=max_ticks, now=now)
        self._closed = True
        return finished
