"""LM serving engine: prefill + decode with slot-based continuous batching.

The decode step is the paper's static-mode schedule at LLM scale (state
resident, II = 1 token); the slot manager implements continuous batching —
finished sequences free their slot, new requests join mid-flight without
stalling running ones (vLLM-style, sized for fixed-shape XLA programs).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as tf
from repro.models.decode import cache_specs, decode_step
from repro.models.init import init_params
from repro.serving.batcher import KeyStats


@dataclass
class Slot:
    active: bool = False
    req_id: int = -1
    pos: int = 0
    tokens: List[int] = field(default_factory=list)
    max_new: int = 16
    arrival_s: float = 0.0


class LMServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 max_batch: int = 4, max_seq: int = 256,
                 cache_dtype: str = "float32"):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.slots = [Slot() for _ in range(max_batch)]
        specs = cache_specs(cfg, max_batch, max_seq, cache_dtype)
        self.cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
                      for k, s in specs.items()}

        def step(params, cache, tokens, pos):
            return decode_step(cfg, params, cache, tokens, pos)

        self._step = jax.jit(step, donate_argnums=(1,))
        self._next_req = 0
        # per-engine serving counters, same shape as the RNN engine's
        # per-key stats (the LM engine has one implicit "decode" key)
        self._stats = KeyStats()

    # -- request management --------------------------------------------------
    def add_request(self, prompt: List[int], max_new: int = 16,
                    now: Optional[float] = None) -> Optional[int]:
        for s in self.slots:
            if not s.active:
                s.active = True
                s.req_id = self._next_req
                self._next_req += 1
                s.pos = 0
                s.tokens = list(prompt)
                s.max_new = max_new
                s.arrival_s = time.time() if now is None else now
                s._prompt_len = len(prompt)
                return s.req_id
        return None                     # queue full

    def _advance_prompt_or_sample(self, s: Slot, logits_row) -> int:
        """Teacher-force remaining prompt tokens, then greedy-sample."""
        if s.pos + 1 < s._prompt_len:
            return s.tokens[s.pos + 1]
        return int(jnp.argmax(logits_row))

    # -- one engine tick: every active slot decodes one token ----------------
    def tick(self, now: Optional[float] = None) -> Dict[int, List[int]]:
        if not any(s.active for s in self.slots):
            return {}
        tokens = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s.active:
                tokens[i, 0] = s.tokens[s.pos]
                pos[i] = s.pos
        logits, self.cache = self._step(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos))
        logits = np.asarray(logits[:, 0])

        finished: Dict[int, List[int]] = {}
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            nxt = self._advance_prompt_or_sample(s, logits[i])
            if s.pos + 1 >= s._prompt_len:
                s.tokens.append(nxt)
            s.pos += 1
            done = (len(s.tokens) - s._prompt_len >= s.max_new
                    or s.pos >= self.max_seq - 1)
            if done:
                finished[s.req_id] = list(s.tokens)
                s.active = False        # slot freed for the next request
                # same clock domain as add_request: wall time by default,
                # the caller's logical clock when both pass ``now``
                t = time.time() if now is None else now
                self._stats.record_one(t - s.arrival_s)
        if finished:
            self._stats.batches += 1
        return finished

    def serve_report(self) -> Dict[str, Dict]:
        """Measured serving stats in the RNN engine's report shape (no
        analytical column — the HLS model covers the RNN family only)."""
        return {"decode": {"measured": self._stats.summary(),
                           "analytical": None}}

    def run_to_completion(self, max_ticks: int = 512,
                          now: Optional[float] = None) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            out.update(self.tick(now=now))
            if not any(s.active for s in self.slots):
                break
        return out
