"""LM serving engine: prefill + decode with slot-based continuous batching,
folded onto the SAME schedule-key abstraction as the RNN engine.

The decode step is the paper's static-mode schedule at LLM scale (state
resident, II = 1 token); the slot manager implements continuous batching —
finished sequences free their slot, new requests join mid-flight without
stalling running ones (vLLM-style, sized for fixed-shape XLA programs).

Schedule keys (ROADMAP item, closed): requests may carry a
``KernelSchedule`` and are routed by the stable ``schedule_key`` hash into
per-key decoders — each key owns its slot pool, its KV cache, ONE jit trace
of the decode step, and its ``KeyStats`` counters, exactly mirroring the RNN
engine's keyed jit-cache path.  Requests whose keys differ never share a
decode batch (they would retrace); requests with no schedule ride the
``DEFAULT_SCHEDULE_KEY`` decoder, which preserves the original single-pool
behavior bit-for-bit.

Schedule-DRIVEN decode (PR 5): a keyed decoder's schedule now changes what
its trace executes — ``decode_step(..., schedule=)`` runs the reuse-tiled,
weight-resident kernel path (fused q|k|v / MLP gate matmuls, R column-tile
passes in-block), with the packed weight layout derived ONCE per
(params, schedule key) at decoder construction and fed to the jit trace as
an input, so per-key decode batches genuinely differ in executed tiling —
bit-identically to the einsum path (conformance-enforced).
``serve_report`` pairs each key's measured tokens/s (decoded tokens over
decode wall-clock) with ``estimate_lm_decode`` of the SAME schedule object
— the decode path's measured-vs-analytical two-column table.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.hls.resources import estimate_lm_decode
from repro.kernels.schedule import (DEFAULT_SCHEDULE_KEY, KernelSchedule,
                                    cache_meta, schedule_key)
from repro.models.decode import (cache_specs, decode_schedulable, decode_step,
                                 pack_decode_params)
from repro.serving.batcher import KeyStats, _now
from repro.serving.compile_cache import CachedExecutor, CompileCache


@dataclass
class Slot:
    active: bool = False
    req_id: int = -1
    pos: int = 0
    tokens: List[int] = field(default_factory=list)
    max_new: int = 16
    arrival_s: float = 0.0


class _KeyedDecoder:
    """One schedule key's continuous-batching state: slot pool + KV cache +
    the key's single jit trace of the decode step + serving counters.

    With a schedule, the trace EXECUTES the scheduled kernel path: the
    weight-resident packed layout is derived once here (host-side, via the
    kernels' residency cache) and passed to the jit'd step as an input, so
    the per-token program re-derives nothing — and two decoders with
    different schedules compile genuinely different tilings."""

    def __init__(self, cfg: ModelConfig, key: str,
                 schedule: Optional[KernelSchedule], *, max_batch: int,
                 max_seq: int, cache_dtype: str, params: Optional[Dict] = None,
                 compile_cache: Optional[CompileCache] = None):
        self.key = key
        self.schedule = schedule
        self.scheduled = schedule is not None and decode_schedulable(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.slots = [Slot() for _ in range(max_batch)]
        specs = cache_specs(cfg, max_batch, max_seq, cache_dtype)
        self.cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
                      for k, s in specs.items()}
        self.stats = KeyStats()
        self.traces = 0
        self.tokens = 0                  # decoded tokens (per-key tokens/s)
        self.decode_s = 0.0              # wall-clock spent in decode steps
        self.packed = (pack_decode_params(cfg, params, schedule)
                       if self.scheduled and params is not None else None)

        def step(params, cache, tokens, pos, packed=None):
            # Python side effect runs at COLD lower/compile time only: one
            # trace per key is the keyed-cache efficiency criterion (RNN
            # engine parity); a warm persistent-cache hit deserializes the
            # executable without tracing, so this stays 0 on a warm start
            self.traces += 1
            return decode_step(cfg, params, cache, tokens, pos,
                               schedule=schedule, packed=packed)

        meta = {"kind": "lm_decode_step", "cfg": repr(cfg),
                "max_batch": max_batch, "max_seq": max_seq,
                "cache_dtype": cache_dtype,
                **cache_meta(schedule, None)}
        self._step = CachedExecutor(
            jax.jit(step, donate_argnums=(1,)),
            compile_cache if compile_cache is not None else CompileCache(),
            key, meta, name_hint=f"lm-{key}")

    def warm_step(self, params: Dict) -> Dict:
        """Ensure this key's decode-step executable exists without ticking
        (nothing executes, the KV cache is untouched): lowers against the
        exact shapes ``_tick_decoder`` calls with — warm over a persistent
        cache, compile-and-store when cold."""
        tok = jax.ShapeDtypeStruct((self.max_batch, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((self.max_batch,), jnp.int32)
        args = (params, self.cache, tok, pos)
        if self.packed is not None:
            args = args + (self.packed,)
        return self._step.warm(*args)

    @property
    def any_active(self) -> bool:
        return any(s.active for s in self.slots)

    def free_slot(self) -> Optional[Slot]:
        for s in self.slots:
            if not s.active:
                return s
        return None


class LMServingEngine:
    def __init__(self, cfg: ModelConfig, params: Dict, *,
                 max_batch: int = 4, max_seq: int = 256,
                 cache_dtype: str = "float32",
                 schedule: Optional[KernelSchedule] = None,
                 cache_dir: Optional[str] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.schedule = schedule            # default-request schedule
        self.compile_cache = CompileCache(cache_dir)
        self._decoders: Dict[str, _KeyedDecoder] = {}
        self._next_req = 0
        # eagerly build the default decoder: same allocation behavior as the
        # pre-keyed engine for schedule-less traffic
        self._decoder_for(self.schedule)

    # -- keyed decoders ------------------------------------------------------

    def _key_for(self, schedule: Optional[KernelSchedule]) -> str:
        schedule = schedule if schedule is not None else self.schedule
        return (DEFAULT_SCHEDULE_KEY if schedule is None
                else schedule_key(schedule))

    def _decoder_for(self, schedule: Optional[KernelSchedule]
                     ) -> _KeyedDecoder:
        sched = schedule if schedule is not None else self.schedule
        key = self._key_for(sched)
        dec = self._decoders.get(key)
        if dec is None:
            dec = _KeyedDecoder(self.cfg, key, sched,
                                max_batch=self.max_batch,
                                max_seq=self.max_seq,
                                cache_dtype=self.cache_dtype,
                                params=self.params,
                                compile_cache=self.compile_cache)
            self._decoders[key] = dec
        return dec

    def prewarm(self, schedules: Optional[List[Optional[KernelSchedule]]]
                = None) -> Dict[str, Dict]:
        """Zero-warmup for the decode path: build each schedule's keyed
        decoder and make its step executable exist before the first tick —
        deserialized from a warm ``cache_dir`` (zero jit compiles) or
        compiled once and stored.  No schedules: the engine default."""
        out: Dict[str, Dict] = {}
        for sched in (schedules if schedules is not None else [None]):
            dec = self._decoder_for(sched)
            out[dec.key] = dec.warm_step(self.params)
        return out

    def keys(self) -> List[str]:
        return list(self._decoders)

    def trace_count(self, key: str) -> int:
        dec = self._decoders.get(key)
        return 0 if dec is None else dec.traces

    @property
    def slots(self) -> List[Slot]:
        """Default-key slot pool (single-tenant compatibility view)."""
        return self._decoder_for(None).slots

    # -- request management --------------------------------------------------
    def add_request(self, prompt: List[int], max_new: int = 16,
                    now: Optional[float] = None,
                    schedule: Optional[KernelSchedule] = None
                    ) -> Optional[int]:
        """Claim a slot on the request's schedule-key decoder; None when that
        key's pool is full (keys never borrow each other's slots — they
        could not share a decode batch anyway)."""
        dec = self._decoder_for(schedule)
        s = dec.free_slot()
        if s is None:
            return None                 # this key's queue is full
        s.active = True
        s.req_id = self._next_req
        self._next_req += 1
        s.pos = 0
        s.tokens = list(prompt)
        s.max_new = max_new
        # monotonic clock (batcher._now), matching the RNN path: wall-clock
        # time.time() made request latencies NTP-step sensitive
        s.arrival_s = _now() if now is None else now
        s._prompt_len = len(prompt)
        return s.req_id

    def _advance_prompt_or_sample(self, s: Slot, logits_row) -> int:
        """Teacher-force remaining prompt tokens, then greedy-sample."""
        if s.pos + 1 < s._prompt_len:
            return s.tokens[s.pos + 1]
        return int(jnp.argmax(logits_row))

    # -- one engine tick: every active slot decodes one token ----------------
    def _tick_decoder(self, dec: _KeyedDecoder,
                      now: Optional[float]) -> Dict[int, List[int]]:
        tokens = np.zeros((dec.max_batch, 1), np.int32)
        pos = np.zeros((dec.max_batch,), np.int32)
        n_active = 0
        for i, s in enumerate(dec.slots):
            if s.active:
                tokens[i, 0] = s.tokens[s.pos]
                pos[i] = s.pos
                n_active += 1
        traces_before = dec.traces
        t0 = time.perf_counter()
        if dec.packed is not None:
            logits, dec.cache = dec._step(
                self.params, dec.cache, jnp.asarray(tokens),
                jnp.asarray(pos), dec.packed)
        else:
            logits, dec.cache = dec._step(
                self.params, dec.cache, jnp.asarray(tokens), jnp.asarray(pos))
        logits = np.asarray(logits[:, 0])
        # tokens/s bookkeeping: real wall-clock of the decode step (the
        # latency counters below use the caller's logical clock instead);
        # the tick that traced/compiled is excluded — steady-state rate
        if dec.traces == traces_before:
            dec.decode_s += time.perf_counter() - t0
            dec.tokens += n_active

        finished: Dict[int, List[int]] = {}
        for i, s in enumerate(dec.slots):
            if not s.active:
                continue
            nxt = self._advance_prompt_or_sample(s, logits[i])
            if s.pos + 1 >= s._prompt_len:
                s.tokens.append(nxt)
            s.pos += 1
            done = (len(s.tokens) - s._prompt_len >= s.max_new
                    or s.pos >= dec.max_seq - 1)
            if done:
                finished[s.req_id] = list(s.tokens)
                s.active = False        # slot freed for the next request
                # same clock domain as add_request: monotonic by default,
                # the caller's logical clock when both pass ``now``
                t = _now() if now is None else now
                dec.stats.record_one(t - s.arrival_s)
        if finished:
            dec.stats.batches += 1
        return finished

    def tick(self, now: Optional[float] = None) -> Dict[int, List[int]]:
        """One decode step on every key with active slots (keys never mix
        in a batch); returns all requests finished this tick."""
        finished: Dict[int, List[int]] = {}
        for dec in self._decoders.values():
            if dec.any_active:
                finished.update(self._tick_decoder(dec, now))
        return finished

    def serve_report(self, clock_mhz: float = 200.0) -> Dict[str, Dict]:
        """Measured serving stats per schedule key, in the RNN engine's
        report shape.  The measured column now carries per-key tokens/s
        (decoded tokens over decode-step wall-clock) next to the request
        latency counters; keys whose trace EXECUTES the scheduled kernels
        pair it with ``estimate_lm_decode`` of the SAME schedule object —
        the decode path's two-column table.  Schedule-less keys, and
        schedules on families whose decode step is not matmul-shaped (the
        einsum fallback), stay estimate-less: an estimate must never
        describe kernels that did not run."""
        report: Dict[str, Dict] = {}
        for key, dec in self._decoders.items():
            measured = dec.stats.summary()
            measured["tokens"] = float(dec.tokens)
            measured["decode_s"] = dec.decode_s
            measured["tokens_per_s"] = (dec.tokens / dec.decode_s
                                        if dec.decode_s > 0 else 0.0)
            analytical = None
            if dec.scheduled:
                analytical = estimate_lm_decode(
                    dec.schedule, self.cfg).report_row(clock_mhz)
                analytical["scheduled_kernels"] = True
            report[key] = {"schedule": dec.schedule,
                           "fp": None,
                           "traces": dec.traces,
                           "measured": measured,
                           "analytical": analytical,
                           "compile": self.compile_cache.report_row(key)}
        return report

    def run_to_completion(self, max_ticks: int = 512,
                          now: Optional[float] = None) -> Dict[int, List[int]]:
        out: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            out.update(self.tick(now=now))
            if not any(d.any_active for d in self._decoders.values()):
                break
        return out
