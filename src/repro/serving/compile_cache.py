"""Persistent AOT compile cache — zero-warmup serving.

Every new ``(schedule_key, batch-shape bucket)`` pair used to pay a
first-request jit compile: a latency cliff on every engine start, deploy,
and new tenant target — exactly the regime the paper's multi-design-point
serving story cares about (the kernel is microseconds; the compile shell
around it is seconds).  This module closes that cliff the way AOT serving
frameworks do (export/compile ahead of time, load artifacts at serve time):

  * :class:`CompileCache` serializes compiled XLA executables
    (``jax.jit(...).lower(...).compile()`` +
    ``jax.experimental.serialize_executable``) to a cache directory, one
    file per content hash of ``{jax/jaxlib version, platform, cfg,
    schedule_key, fp, argument shapes}``.  Any load / deserialize failure
    degrades gracefully to a fresh compile (warn, never crash) — a
    corrupted or stale entry costs one cold compile, not an outage.
  * :class:`CachedExecutor` wraps one jit'd function and dispatches each
    distinct argument-shape signature to its own compiled executable:
    warm signatures load from disk with ZERO jit traces; cold signatures
    lower/compile once (the wrapped function's trace-time side effects —
    the engines' trace counters — run exactly then) and are stored for
    the next process.
  * Writes are concurrency-safe for N worker replicas sharing one cache
    directory: serialize to a unique temp file, then atomic
    ``os.replace`` — readers only ever see complete entries.

Per-logical-key cold/warm counters feed the engines' ``serve_report``
(the ``compile`` column: hit rate + first-request compile seconds).
"""

from __future__ import annotations

import hashlib
import json
import os
import pickle
import time
import uuid
import warnings
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

#: bump to invalidate every existing cache entry (serialization layout)
_FORMAT_VERSION = 1

_SUFFIX = ".jaxcache"


def _env_meta() -> Dict[str, str]:
    """The toolchain axes that invalidate a serialized executable: an
    artifact compiled by one jaxlib for one platform must never be fed to
    another."""
    import jax
    import jaxlib

    devs = jax.devices()
    return {
        "format": str(_FORMAT_VERSION),
        "jax": jax.__version__,
        "jaxlib": jaxlib.__version__,
        "platform": jax.default_backend(),
        "n_devices": str(len(devs)),
        "device_kind": devs[0].device_kind if devs else "none",
    }


def fingerprint(meta: Dict[str, Any]) -> str:
    """Stable content hash of an entry's metadata (sorted-key JSON)."""
    blob = json.dumps(meta, sort_keys=True, default=repr)
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


def _slug(name: str, limit: int = 48) -> str:
    safe = "".join(c if c.isalnum() or c in "-_." else "_" for c in name)
    return safe[:limit] or "entry"


@dataclass
class KeyCompileStats:
    """Per-logical-key (schedule key) compile accounting."""

    cold: int = 0                       # fresh lower+compile (one jit trace)
    warm: int = 0                       # served from a deserialized artifact
    errors: int = 0                     # load/store failures (fell back)
    quarantined: int = 0                # known-corrupt entries skipped
    first_compile_s: Optional[float] = None

    def summary(self) -> Dict[str, float]:
        total = self.cold + self.warm
        return {
            "cold": float(self.cold),
            "warm": float(self.warm),
            "errors": float(self.errors),
            "quarantined": float(self.quarantined),
            "hit_rate": (self.warm / total) if total else 0.0,
            "first_compile_s": self.first_compile_s,
        }


class CompileCache:
    """Directory of serialized executables shared by serving engines.

    ``cache_dir=None`` disables persistence but keeps the accounting: every
    signature then costs exactly one in-process cold compile (the pre-PR
    behavior), and ``serve_report`` still shows honest cold counts.
    """

    def __init__(self, cache_dir: Optional[os.PathLike | str] = None):
        self.dir = Path(cache_dir) if cache_dir is not None else None
        self.enabled = self.dir is not None
        if self.enabled:
            self.dir.mkdir(parents=True, exist_ok=True)
        self._env = _env_meta()
        self._stats: Dict[str, KeyCompileStats] = {}
        # negative cache: entry paths that already failed to deserialize.
        # Without it a known-corrupt entry was re-read, re-unpickled and
        # re-warned on EVERY request (the warn-and-fall-back path has no
        # memory) — the fallback stayed correct but each request paid the
        # doomed deserialization attempt.  First failure warns and
        # quarantines; later lookups skip the file silently until a
        # successful store replaces it.
        self._quarantine: set = set()

    # -- accounting ----------------------------------------------------------

    def stats(self, key: str) -> KeyCompileStats:
        return self._stats.setdefault(key, KeyCompileStats())

    def report_row(self, key: str) -> Dict[str, float]:
        return self.stats(key).summary()

    def record_cold(self, key: str, compile_s: float) -> None:
        st = self.stats(key)
        st.cold += 1
        if st.first_compile_s is None:
            st.first_compile_s = compile_s

    def record_warm(self, key: str) -> None:
        self.stats(key).warm += 1

    @property
    def cold_compiles(self) -> int:
        return sum(s.cold for s in self._stats.values())

    @property
    def warm_hits(self) -> int:
        return sum(s.warm for s in self._stats.values())

    # -- entry identity ------------------------------------------------------

    def entry_meta(self, meta: Dict[str, Any]) -> Dict[str, Any]:
        return {**self._env, **meta}

    def entry_path(self, name_hint: str, meta: Dict[str, Any]) -> Path:
        assert self.dir is not None
        full = self.entry_meta(meta)
        return self.dir / f"{_slug(name_hint)}-{fingerprint(full)}{_SUFFIX}"

    # -- load / store --------------------------------------------------------

    def load(self, name_hint: str, meta: Dict[str, Any],
             key: str) -> Optional[Callable]:
        """Deserialize the entry for ``meta``; None on miss OR any failure
        (corrupted file, version skew inside the payload, pickle error) —
        the caller falls back to a cold compile."""
        if not self.enabled:
            return None
        path = self.entry_path(name_hint, meta)
        if str(path) in self._quarantine:
            # known corrupt: don't re-attempt (and re-warn) every request
            self.stats(key).quarantined += 1
            return None
        if not path.exists():
            return None
        try:
            from jax.experimental import serialize_executable
            with open(path, "rb") as f:
                doc = pickle.load(f)
            want = self.entry_meta(meta)
            if doc.get("meta") != want:
                raise ValueError(
                    f"entry metadata mismatch (hash collision or stale "
                    f"format): {path.name}")
            return serialize_executable.deserialize_and_load(
                doc["payload"], doc["in_tree"], doc["out_tree"])
        except Exception as e:  # corrupted/stale entry: warn ONCE, fall back
            self.stats(key).errors += 1
            self._quarantine.add(str(path))
            warnings.warn(
                f"compile cache entry {path.name} unusable "
                f"({type(e).__name__}: {e}); falling back to jit compile "
                f"(entry quarantined — not re-read until overwritten)",
                RuntimeWarning, stacklevel=2)
            return None

    def store(self, name_hint: str, meta: Dict[str, Any], compiled: Any,
              key: str) -> bool:
        """Serialize ``compiled`` under its content hash.

        Write-temp-then-rename: safe under concurrent writers (N replicas
        sharing one directory race benignly — last complete write wins,
        readers never observe a partial file)."""
        if not self.enabled:
            return False
        path = self.entry_path(name_hint, meta)
        tmp = path.with_name(
            f"{path.name}.tmp.{os.getpid()}.{uuid.uuid4().hex[:8]}")
        try:
            from jax.experimental import serialize_executable
            payload, in_tree, out_tree = serialize_executable.serialize(
                compiled)
            with open(tmp, "wb") as f:
                pickle.dump({"meta": self.entry_meta(meta),
                             "payload": payload,
                             "in_tree": in_tree,
                             "out_tree": out_tree}, f)
            os.replace(tmp, path)
            # a fresh, complete entry now lives at this path: lift any
            # quarantine from a corrupt predecessor
            self._quarantine.discard(str(path))
            return True
        except Exception as e:  # unserializable executable, full disk, ...
            self.stats(key).errors += 1
            warnings.warn(
                f"compile cache store failed for {path.name} "
                f"({type(e).__name__}: {e}); serving uncached",
                RuntimeWarning, stacklevel=2)
            try:
                if tmp.exists():
                    tmp.unlink()
            except OSError:
                pass
            return False


def _arg_signature(args: Tuple[Any, ...]) -> Tuple:
    """Hashable (shape, dtype) signature over every array leaf, plus the
    pytree structure — the shape-bucket identity of one executable."""
    import jax

    leaves, treedef = jax.tree_util.tree_flatten(args)
    return (str(treedef),
            tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


class CachedExecutor:
    """One jit'd function, dispatched per argument-shape signature to AOT
    executables that persist across processes.

    Call it exactly like the jit'd function (positional args).  The first
    call with a new signature either loads the serialized executable (warm
    — zero jit traces) or lowers/compiles once (cold — the wrapped
    function's trace-time side effects run) and stores the artifact.
    :meth:`warm` does the same from ``jax.ShapeDtypeStruct`` avals without
    executing — the engines' pre-warm path.
    """

    def __init__(self, jitted: Callable, cache: CompileCache, key: str,
                 meta: Dict[str, Any], name_hint: Optional[str] = None):
        self._jitted = jitted
        self._cache = cache
        self.key = key
        self._meta = dict(meta)
        self._name = name_hint if name_hint is not None else key
        self._compiled: Dict[Tuple, Callable] = {}

    def _acquire(self, sig: Tuple, args: Tuple[Any, ...]) -> Callable:
        meta = {**self._meta, "treedef": sig[0], "leaves": sig[1]}
        fn = self._cache.load(self._name, meta, self.key)
        if fn is not None:
            self._cache.record_warm(self.key)
        else:
            t0 = time.perf_counter()
            fn = self._jitted.lower(*args).compile()
            self._cache.record_cold(self.key, time.perf_counter() - t0)
            self._cache.store(self._name, meta, fn, self.key)
        self._compiled[sig] = fn
        return fn

    def __call__(self, *args):
        sig = _arg_signature(args)
        fn = self._compiled.get(sig)
        if fn is None:
            fn = self._acquire(sig, args)
        return fn(*args)

    def warm(self, *args) -> Dict[str, Any]:
        """Ensure the executable for this signature exists WITHOUT running
        it; args may mix real arrays and ``jax.ShapeDtypeStruct`` avals.
        Returns ``{"status": "hot"|"warm"|"cold", "compile_s": float}``."""
        sig = _arg_signature(args)
        if sig in self._compiled:
            return {"status": "hot", "compile_s": 0.0}
        cold_before = self._cache.stats(self.key).cold
        t0 = time.perf_counter()
        self._acquire(sig, args)
        dt = time.perf_counter() - t0
        cold = self._cache.stats(self.key).cold > cold_before
        return {"status": "cold" if cold else "warm",
                "compile_s": dt if cold else 0.0}

    def compiled_signatures(self) -> int:
        return len(self._compiled)
