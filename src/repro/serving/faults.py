"""Fault-injection harness for the streaming pipeline — chaos, on purpose.

A hard-real-time trigger path is judged by how it fails, not how it runs:
when a stage hiccups, a kernel throws, a cache entry rots, or the clock
steps backwards, the pipeline must degrade predictably — shed, downgrade,
or fail THAT request with the error attached — never deadlock, never lose a
request silently, never corrupt another tenant's results.  This module
provides the controlled faults the chaos test suite drives through
:class:`~repro.serving.streaming.StreamingPipeline`:

  * :class:`FaultInjector` — armable per-stage *stalls* (extra seconds
    charged at a stage boundary, visible to the deadline projections) and
    *failures* (exceptions raised inside a stage, caught per request);
  * :func:`break_engine_key` — replaces ONE schedule key's compiled infer
    fn with one that raises N times then recovers: the flush-exception
    fault the batcher's per-key isolation must contain;
  * :func:`corrupt_cache_entries` — truncates/garbles persistent compile
    cache artifacts on disk: the quarantine path's trigger;
  * :class:`VirtualClock` — a drivable clock for deterministic replay,
    with :meth:`VirtualClock.step_back` as the misbehaving-clock fault
    (the pipeline's monotonic clamp must absorb it);
  * **replica-grade faults** for the replicated-serving router
    (:mod:`repro.serving.router`): :func:`crash_replica` (every call on
    that replica raises — the dead-board fault), :func:`slow_replica`
    (injected per-call stall, the straggler fault the timeout/hedge
    machinery must beat) and :func:`flapping` (alternating healthy /
    unhealthy calls — the worst case for health scoring, which must not
    thrash the ring on every blip).  All three arm a
    :class:`ReplicaFaultSet` with the same ``after``/``times`` counters
    and ``fired`` audit log as the stage faults.

Faults are one-shot by default (``times=1``) and consumed in arm order, so
a chaos scenario reads as a script: arm, run, assert the degradation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional


class InjectedFault(RuntimeError):
    """The exception a ``fail`` arm raises inside a pipeline stage."""


@dataclass
class _Arm:
    kind: str                   # "stall" | "fail"
    stage: str
    seconds: float = 0.0        # stall only
    exc: Optional[BaseException] = None   # fail only
    after: int = 0              # skip this many matching checks first
    remaining: int = 1          # then fire this many times


@dataclass
class FaultInjector:
    """Scriptable per-stage faults; a default (empty) injector is inert.

    ``stall(stage, seconds)`` charges extra seconds at that stage boundary
    — in a replay the stall lands in the simulated clock domain, so
    deadline projections and the per-stage budget report see it honestly.
    ``fail(stage)`` raises :class:`InjectedFault` (or a supplied exception)
    when the pipeline enters that stage; the pipeline converts it into a
    per-request failure with the error attached.
    """

    _arms: List[_Arm] = field(default_factory=list)
    fired: List[str] = field(default_factory=list)   # audit log

    # -- arming --------------------------------------------------------------

    def stall(self, stage: str, seconds: float, *, times: int = 1,
              after: int = 0) -> "FaultInjector":
        if seconds < 0:
            raise ValueError(f"stall seconds must be >= 0: {seconds}")
        self._arms.append(_Arm("stall", stage, seconds=seconds,
                               after=after, remaining=times))
        return self

    def fail(self, stage: str, exc: Optional[BaseException] = None, *,
             times: int = 1, after: int = 0) -> "FaultInjector":
        self._arms.append(_Arm("fail", stage, exc=exc, after=after,
                               remaining=times))
        return self

    # -- consumption (the pipeline calls these at stage boundaries) ----------

    def _take(self, kind: str, stage: str) -> Optional[_Arm]:
        for arm in self._arms:
            if arm.kind != kind or arm.stage != stage or arm.remaining <= 0:
                continue
            if arm.after > 0:
                arm.after -= 1
                continue
            arm.remaining -= 1
            self.fired.append(f"{kind}:{stage}")
            return arm
        return None

    def stall_s(self, stage: str) -> float:
        """Seconds of injected stall at this stage boundary (0.0 = none)."""
        arm = self._take("stall", stage)
        return arm.seconds if arm is not None else 0.0

    def check(self, stage: str) -> None:
        """Raise the armed failure for this stage, if any."""
        arm = self._take("fail", stage)
        if arm is not None:
            raise arm.exc if arm.exc is not None else InjectedFault(
                f"injected fault at stage {stage!r}")

    def armed(self) -> int:
        """Arms that have not fully fired yet."""
        return sum(1 for a in self._arms if a.remaining > 0)


# ---------------------------------------------------------------------------
# Replica-level faults (the router's chaos surface)
# ---------------------------------------------------------------------------


class ReplicaCrashed(RuntimeError):
    """The exception a crashed (or flapping-down) replica raises on every
    call — predict AND heartbeat, so health probes see the crash too."""


@dataclass
class _ReplicaArm:
    kind: str                   # "crash" | "stall" | "flap"
    seconds: float = 0.0        # stall only
    after: int = 0              # skip this many calls before arming
    remaining: Optional[int] = None   # fired-call budget; None = forever
    period: int = 1             # flap only: calls per healthy/unhealthy phase
    calls: int = 0              # flap phase counter (post-``after`` calls)

    @property
    def live(self) -> bool:
        return self.remaining is None or self.remaining > 0


@dataclass
class ReplicaFaultSet:
    """Armable per-replica faults, consumed on every replica call.

    The router talks to a replica only through calls (predict, heartbeat);
    a replica fault is therefore a per-call transformation: raise
    (:class:`ReplicaCrashed`) or stall (seconds added to the call's
    simulated service time).  Arms carry the same ``after``/``times``
    counters as :class:`FaultInjector` and every firing lands in the
    ``fired`` audit log as ``"<kind>:<replica_id>"``.
    """

    replica_id: str = "?"
    _arms: List[_ReplicaArm] = field(default_factory=list)
    fired: List[str] = field(default_factory=list)

    def on_call(self) -> float:
        """Consume one call: returns the injected stall seconds and/or
        raises :class:`ReplicaCrashed`.  Stalls accumulate across arms;
        the first crash-grade arm to fire raises (after charging any
        stall already accumulated is pointless — the caller sees the
        exception, not the duration)."""
        stall = 0.0
        for arm in self._arms:
            if not arm.live:
                continue
            if arm.after > 0:
                arm.after -= 1
                continue
            if arm.kind == "stall":
                if arm.remaining is not None:
                    arm.remaining -= 1
                stall += arm.seconds
                self.fired.append(f"stall:{self.replica_id}")
            elif arm.kind == "crash":
                if arm.remaining is not None:
                    arm.remaining -= 1
                self.fired.append(f"crash:{self.replica_id}")
                raise ReplicaCrashed(
                    f"replica {self.replica_id!r} crashed (injected)")
            elif arm.kind == "flap":
                phase = arm.calls
                arm.calls += 1
                # phases of ``period`` calls: healthy first, then down, ...
                if (phase // arm.period) % 2 == 1:
                    if arm.remaining is not None:
                        arm.remaining -= 1
                    self.fired.append(f"flap:{self.replica_id}")
                    raise ReplicaCrashed(
                        f"replica {self.replica_id!r} is flapping "
                        f"(down phase, injected)")
        return stall

    def armed(self) -> int:
        return sum(1 for a in self._arms if a.live)

    def clear(self) -> None:
        """Heal the replica: drop every arm (the repair-crew hook the
        re-admission tests use)."""
        self._arms.clear()


def _replica_faults(replica) -> ReplicaFaultSet:
    fs = getattr(replica, "faults", None)
    if not isinstance(fs, ReplicaFaultSet):
        raise TypeError(
            f"{replica!r} has no ReplicaFaultSet — replica faults arm an "
            f"EngineReplica (repro.serving.replica), not a bare engine")
    return fs


def crash_replica(replica, *, after: int = 0,
                  times: Optional[int] = None) -> _ReplicaArm:
    """Arm a crash: every call (predict and heartbeat) raises
    :class:`ReplicaCrashed`.  ``times=None`` crashes forever (the
    dead-board fault); a finite ``times`` models a transient outage that
    the router's probe loop should re-admit."""
    arm = _ReplicaArm("crash", after=after, remaining=times)
    _replica_faults(replica)._arms.append(arm)
    return arm


def slow_replica(replica, seconds: float, *, after: int = 0,
                 times: Optional[int] = None) -> _ReplicaArm:
    """Arm a straggler: every call is charged ``seconds`` of simulated
    stall.  A stall beyond the router's per-request timeout turns the
    attempt into a timeout (retried elsewhere); a stall beyond the hedge
    threshold lets the hedged duplicate win."""
    if seconds < 0:
        raise ValueError(f"stall seconds must be >= 0: {seconds}")
    arm = _ReplicaArm("stall", seconds=seconds, after=after, remaining=times)
    _replica_faults(replica)._arms.append(arm)
    return arm


def flapping(replica, *, period: int = 1, after: int = 0,
             times: Optional[int] = None) -> _ReplicaArm:
    """Arm alternating healthy/unhealthy phases of ``period`` calls each
    (healthy phase first).  ``times`` bounds the number of FAILED calls,
    so ``times=k`` means exactly k crashes interleaved with successes —
    the pattern that punishes naive last-call health scoring."""
    if period < 1:
        raise ValueError(f"flap period must be >= 1: {period}")
    arm = _ReplicaArm("flap", period=period, after=after, remaining=times)
    _replica_faults(replica)._arms.append(arm)
    return arm


# ---------------------------------------------------------------------------
# Engine-level faults
# ---------------------------------------------------------------------------


class _FlakyInfer:
    """Wraps one compiled infer fn: raises ``times`` times, then delegates.

    Replacing the engine's ``_infer_cache`` entry (looked up per call by
    ``_predict_key``) exercises the REAL failure path: the exception
    surfaces inside the batcher's flush, which must fail only that key's
    batch and keep serving every other queue.
    """

    def __init__(self, real: Callable, exc: BaseException, times: int):
        self.real = real
        self.exc = exc
        self.times = times
        self.raised = 0

    def __call__(self, *args, **kwargs):
        if self.times > 0:
            self.times -= 1
            self.raised += 1
            raise self.exc
        return self.real(*args, **kwargs)


def break_engine_key(engine, key: str, exc: Optional[BaseException] = None,
                     *, times: int = 1) -> _FlakyInfer:
    """Arm a flush exception on one schedule key of an RNNServingEngine.

    The key's compiled infer fn is swapped for a raiser that fails the
    next ``times`` flushes of THAT key only, then recovers.  Returns the
    wrapper (``.raised`` counts firings) — the original fn is preserved
    inside it, so recovery needs no re-compile.
    """
    if key not in engine._infer_cache:
        raise KeyError(f"engine has no compiled key {key!r}; serve or "
                       f"prewarm it first")
    flaky = _FlakyInfer(engine._infer_cache[key],
                        exc if exc is not None
                        else InjectedFault(f"injected flush fault on {key}"),
                        times)
    engine._infer_cache[key] = flaky
    return flaky


# ---------------------------------------------------------------------------
# Persistent-cache faults
# ---------------------------------------------------------------------------


def corrupt_cache_entries(cache_dir, *, pattern: str = f"*.jaxcache",
                          payload: bytes = b"\x00corrupt\x00") -> int:
    """Overwrite every matching compile-cache artifact with garbage bytes.

    Models bit rot / torn writes from outside the process (the atomic
    tmp-then-rename writer can't produce these itself).  Returns the number
    of entries corrupted; the CompileCache must warn once, quarantine, and
    fall back to a cold compile — never crash, never serve garbage.
    """
    n = 0
    for p in Path(cache_dir).glob(pattern):
        p.write_bytes(payload)
        n += 1
    return n


# ---------------------------------------------------------------------------
# Clock faults
# ---------------------------------------------------------------------------


class VirtualClock:
    """Drivable clock for deterministic replay: ``clock()`` -> seconds.

    ``advance`` moves time forward (the replay driver's tick);
    ``step_back`` is the FAULT — a clock that jumps backwards (NTP step,
    TSC skew).  The pipeline's monotonic clamp must absorb backwards steps
    without negative latencies or corrupted accounting.
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("advance must be >= 0; use step_back for the "
                             "backwards-clock fault")
        self.t += dt
        return self.t

    def step_back(self, dt: float) -> float:
        self.t -= dt
        return self.t
