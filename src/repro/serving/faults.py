"""Fault-injection harness for the streaming pipeline — chaos, on purpose.

A hard-real-time trigger path is judged by how it fails, not how it runs:
when a stage hiccups, a kernel throws, a cache entry rots, or the clock
steps backwards, the pipeline must degrade predictably — shed, downgrade,
or fail THAT request with the error attached — never deadlock, never lose a
request silently, never corrupt another tenant's results.  This module
provides the controlled faults the chaos test suite drives through
:class:`~repro.serving.streaming.StreamingPipeline`:

  * :class:`FaultInjector` — armable per-stage *stalls* (extra seconds
    charged at a stage boundary, visible to the deadline projections) and
    *failures* (exceptions raised inside a stage, caught per request);
  * :func:`break_engine_key` — replaces ONE schedule key's compiled infer
    fn with one that raises N times then recovers: the flush-exception
    fault the batcher's per-key isolation must contain;
  * :func:`corrupt_cache_entries` — truncates/garbles persistent compile
    cache artifacts on disk: the quarantine path's trigger;
  * :class:`VirtualClock` — a drivable clock for deterministic replay,
    with :meth:`VirtualClock.step_back` as the misbehaving-clock fault
    (the pipeline's monotonic clamp must absorb it).

Faults are one-shot by default (``times=1``) and consumed in arm order, so
a chaos scenario reads as a script: arm, run, assert the degradation.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path
from typing import Callable, List, Optional


class InjectedFault(RuntimeError):
    """The exception a ``fail`` arm raises inside a pipeline stage."""


@dataclass
class _Arm:
    kind: str                   # "stall" | "fail"
    stage: str
    seconds: float = 0.0        # stall only
    exc: Optional[BaseException] = None   # fail only
    after: int = 0              # skip this many matching checks first
    remaining: int = 1          # then fire this many times


@dataclass
class FaultInjector:
    """Scriptable per-stage faults; a default (empty) injector is inert.

    ``stall(stage, seconds)`` charges extra seconds at that stage boundary
    — in a replay the stall lands in the simulated clock domain, so
    deadline projections and the per-stage budget report see it honestly.
    ``fail(stage)`` raises :class:`InjectedFault` (or a supplied exception)
    when the pipeline enters that stage; the pipeline converts it into a
    per-request failure with the error attached.
    """

    _arms: List[_Arm] = field(default_factory=list)
    fired: List[str] = field(default_factory=list)   # audit log

    # -- arming --------------------------------------------------------------

    def stall(self, stage: str, seconds: float, *, times: int = 1,
              after: int = 0) -> "FaultInjector":
        if seconds < 0:
            raise ValueError(f"stall seconds must be >= 0: {seconds}")
        self._arms.append(_Arm("stall", stage, seconds=seconds,
                               after=after, remaining=times))
        return self

    def fail(self, stage: str, exc: Optional[BaseException] = None, *,
             times: int = 1, after: int = 0) -> "FaultInjector":
        self._arms.append(_Arm("fail", stage, exc=exc, after=after,
                               remaining=times))
        return self

    # -- consumption (the pipeline calls these at stage boundaries) ----------

    def _take(self, kind: str, stage: str) -> Optional[_Arm]:
        for arm in self._arms:
            if arm.kind != kind or arm.stage != stage or arm.remaining <= 0:
                continue
            if arm.after > 0:
                arm.after -= 1
                continue
            arm.remaining -= 1
            self.fired.append(f"{kind}:{stage}")
            return arm
        return None

    def stall_s(self, stage: str) -> float:
        """Seconds of injected stall at this stage boundary (0.0 = none)."""
        arm = self._take("stall", stage)
        return arm.seconds if arm is not None else 0.0

    def check(self, stage: str) -> None:
        """Raise the armed failure for this stage, if any."""
        arm = self._take("fail", stage)
        if arm is not None:
            raise arm.exc if arm.exc is not None else InjectedFault(
                f"injected fault at stage {stage!r}")

    def armed(self) -> int:
        """Arms that have not fully fired yet."""
        return sum(1 for a in self._arms if a.remaining > 0)


# ---------------------------------------------------------------------------
# Engine-level faults
# ---------------------------------------------------------------------------


class _FlakyInfer:
    """Wraps one compiled infer fn: raises ``times`` times, then delegates.

    Replacing the engine's ``_infer_cache`` entry (looked up per call by
    ``_predict_key``) exercises the REAL failure path: the exception
    surfaces inside the batcher's flush, which must fail only that key's
    batch and keep serving every other queue.
    """

    def __init__(self, real: Callable, exc: BaseException, times: int):
        self.real = real
        self.exc = exc
        self.times = times
        self.raised = 0

    def __call__(self, *args, **kwargs):
        if self.times > 0:
            self.times -= 1
            self.raised += 1
            raise self.exc
        return self.real(*args, **kwargs)


def break_engine_key(engine, key: str, exc: Optional[BaseException] = None,
                     *, times: int = 1) -> _FlakyInfer:
    """Arm a flush exception on one schedule key of an RNNServingEngine.

    The key's compiled infer fn is swapped for a raiser that fails the
    next ``times`` flushes of THAT key only, then recovers.  Returns the
    wrapper (``.raised`` counts firings) — the original fn is preserved
    inside it, so recovery needs no re-compile.
    """
    if key not in engine._infer_cache:
        raise KeyError(f"engine has no compiled key {key!r}; serve or "
                       f"prewarm it first")
    flaky = _FlakyInfer(engine._infer_cache[key],
                        exc if exc is not None
                        else InjectedFault(f"injected flush fault on {key}"),
                        times)
    engine._infer_cache[key] = flaky
    return flaky


# ---------------------------------------------------------------------------
# Persistent-cache faults
# ---------------------------------------------------------------------------


def corrupt_cache_entries(cache_dir, *, pattern: str = f"*.jaxcache",
                          payload: bytes = b"\x00corrupt\x00") -> int:
    """Overwrite every matching compile-cache artifact with garbage bytes.

    Models bit rot / torn writes from outside the process (the atomic
    tmp-then-rename writer can't produce these itself).  Returns the number
    of entries corrupted; the CompileCache must warn once, quarantine, and
    fall back to a cold compile — never crash, never serve garbage.
    """
    n = 0
    for p in Path(cache_dir).glob(pattern):
        p.write_bytes(payload)
        n += 1
    return n


# ---------------------------------------------------------------------------
# Clock faults
# ---------------------------------------------------------------------------


class VirtualClock:
    """Drivable clock for deterministic replay: ``clock()`` -> seconds.

    ``advance`` moves time forward (the replay driver's tick);
    ``step_back`` is the FAULT — a clock that jumps backwards (NTP step,
    TSC skew).  The pipeline's monotonic clamp must absorb backwards steps
    without negative latencies or corrupted accounting.
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError("advance must be >= 0; use step_back for the "
                             "backwards-clock fault")
        self.t += dt
        return self.t

    def step_back(self, dt: float) -> float:
        self.t -= dt
        return self.t
