from repro.kernels.schedule import schedule_key  # noqa: F401
from repro.serving.batcher import (  # noqa: F401
    KeyStats,
    MicroBatcher,
    QueueFullError,
    Request,
)
from repro.serving.compile_cache import (  # noqa: F401
    CachedExecutor,
    CompileCache,
    KeyCompileStats,
)
from repro.serving.engine import (  # noqa: F401
    EngineClosedError,
    RNNServingEngine,
    format_serve_report,
)
from repro.serving.faults import (  # noqa: F401
    FaultInjector,
    InjectedFault,
    ReplicaCrashed,
    ReplicaFaultSet,
    VirtualClock,
    break_engine_key,
    corrupt_cache_entries,
    crash_replica,
    flapping,
    slow_replica,
)
from repro.serving.lm_engine import LMServingEngine  # noqa: F401
from repro.serving.replica import (  # noqa: F401
    EngineReplica,
    ReplicaPool,
)
from repro.serving.router import (  # noqa: F401
    HashRing,
    ReplicaTimeout,
    RoutedRequest,
    Router,
    RouterPolicy,
    format_router_report,
)
from repro.serving.speculative import (  # noqa: F401
    CacheTable,
    RowAdvance,
    SpecConfig,
    SpeculativeDecoder,
    accept_chunk,
    speculative_generate,
)
from repro.serving.streaming import (  # noqa: F401
    SHED_REASONS,
    STAGES,
    StreamingPipeline,
    StreamRequest,
    TokenBucket,
    format_stream_report,
)
