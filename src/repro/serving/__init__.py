from repro.serving.engine import RNNServingEngine  # noqa: F401
from repro.serving.lm_engine import LMServingEngine  # noqa: F401
from repro.serving.batcher import MicroBatcher, Request  # noqa: F401
