from repro.kernels.schedule import schedule_key  # noqa: F401
from repro.serving.batcher import (  # noqa: F401
    KeyStats,
    MicroBatcher,
    QueueFullError,
    Request,
)
from repro.serving.compile_cache import (  # noqa: F401
    CachedExecutor,
    CompileCache,
    KeyCompileStats,
)
from repro.serving.engine import (  # noqa: F401
    RNNServingEngine,
    format_serve_report,
)
from repro.serving.faults import (  # noqa: F401
    FaultInjector,
    InjectedFault,
    VirtualClock,
    break_engine_key,
    corrupt_cache_entries,
)
from repro.serving.lm_engine import LMServingEngine  # noqa: F401
from repro.serving.speculative import (  # noqa: F401
    CacheTable,
    RowAdvance,
    SpecConfig,
    SpeculativeDecoder,
    accept_chunk,
    speculative_generate,
)
from repro.serving.streaming import (  # noqa: F401
    SHED_REASONS,
    STAGES,
    StreamingPipeline,
    StreamRequest,
    TokenBucket,
    format_stream_report,
)
