"""RNN serving engine — the paper's deliverable as a multi-tenant service.

Wraps a trained tagger with schedule-aware serving: every request optionally
carries a :class:`KernelSchedule` (plus fixed-point config), and the engine

  * co-batches requests by the stable ``schedule_key`` hash — requests that
    compile to the same kernel share a batch, requests that differ never mix
    (a multi-tenant FPGA farm serving several reuse-factor design points at
    once);
  * keeps ONE jit trace per schedule hash: flushed batches are padded to the
    key's ``max_batch`` (zero rows — row-wise bit-identical on every
    backend), so mixed-schedule traffic never retraces;
  * shares batches across ragged (variable seq_len) jet streams, either by
    length-bucketing sub-batches (bit-identical to direct ``predict``) or by
    a pad-and-mask scan (single batch, XLA datapath);
  * reports, per schedule key, measured wall-clock latency/throughput paired
    with ``core.hls.estimate_schedule`` of the SAME schedule object — the
    paper's measured-vs-analytical two-column comparison;
  * resolves :class:`~repro.autotune.DesignTarget`\\ s to schedules through
    the Pareto explorer (``auto_schedule`` / ``submit(target=...)``): a
    queue can be opened with a latency/resource budget instead of an
    explicit ``KernelSchedule``, and the static/nonstatic/pipeline mode is
    auto-picked from ``estimate_schedule`` pricing.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.autotune import DesignTarget, SpaceSpec
from repro.autotune import select as autotune_select
from repro.config import FixedPointConfig, ModelConfig
from repro.core.hls import (DesignPoint, HLSDesign, RNNDesignPoint,
                            estimate_design, estimate_schedule)
from repro.kernels.schedule import (DEFAULT_SCHEDULE_KEY, KernelSchedule,
                                    cache_meta, schedule_key)
from repro.models import rnn_tagger
from repro.serving.batcher import KeyStats, MicroBatcher, Request, _pad_stack
from repro.serving.compile_cache import CachedExecutor, CompileCache

RAGGED_POLICIES = ("bucket", "mask")


class EngineClosedError(RuntimeError):
    """Submit/predict on a closed engine: the replica was retired (drained
    and closed) and must never accept new work — the router re-places its
    keys instead.  A clear error beats a silently stranded request."""

    def __init__(self, what: str = "engine"):
        super().__init__(
            f"{what} is closed: it was drained and retired, so new requests "
            f"must be routed to a live replica (close() flushed every "
            f"queued request to a terminal state first)")


@dataclass
class RNNServingEngine:
    cfg: ModelConfig
    params: Dict
    mode: Optional[str] = None            # static | nonstatic | pipeline |
                                          # None: from the schedule / config
                                          # (pipeline implies the hoisted
                                          # input projection; its queue key
                                          # carries the -hoist/-ii tokens)
    impl: str = "xla"                     # xla | pallas
    fp: Optional[FixedPointConfig] = None
    max_batch: int = 256
    schedule: Optional[KernelSchedule] = None   # default-request schedule
    ragged: str = "bucket"                # bucket (bit-exact) | mask (one
                                          # padded batch, XLA datapath)
    pad_batches: bool = True              # pad flushes to max_batch: one jit
                                          # trace per schedule hash
    cache_dir: Optional[str] = None       # persistent AOT compile cache; a
                                          # warm dir serves the first request
                                          # of a FRESH engine with zero jit
                                          # compiles (N replicas may share it)
    _infer_cache: Dict[str, Callable] = field(default_factory=dict, repr=False)
    _key_specs: Dict[str, Tuple[KernelSchedule, Optional[FixedPointConfig]]] \
        = field(default_factory=dict, repr=False)
    _traces: Dict[str, int] = field(default_factory=dict, repr=False)
    _target_points: Dict[Tuple, DesignPoint] \
        = field(default_factory=dict, repr=False)
    # batch-1 fast path: its own jit traces + counters, so the batched
    # one-trace-per-key invariant and its stats stay untouched
    _one_cache: Dict[str, Callable] = field(default_factory=dict, repr=False)
    _one_traces: Dict[str, int] = field(default_factory=dict, repr=False)
    _one_stats: Dict[str, KeyStats] = field(default_factory=dict, repr=False)
    _closed: bool = field(default=False, repr=False)

    def __post_init__(self):
        if self.ragged not in RAGGED_POLICIES:
            raise ValueError(f"ragged {self.ragged!r} not in {RAGGED_POLICIES}")
        self.batcher = MicroBatcher(max_batch=self.max_batch)
        self.compile_cache = CompileCache(self.cache_dir)

    # -- schedule resolution -------------------------------------------------

    @property
    def resolved_schedule(self) -> KernelSchedule:
        """The schedule executed for requests that don't carry one: the
        engine's explicit schedule or the config-derived one, with the legacy
        ``mode`` / ``impl`` fields folded in so the key names what runs."""
        s = self.schedule if self.schedule is not None \
            else self.cfg.rnn.kernel_schedule()
        if self.mode is not None and s.mode != self.mode:
            s = s.replace(mode=self.mode)
        if self.impl == "xla" and s.backend != "xla":
            s = s.replace(backend="xla")
        return s

    @property
    def resolved_mode(self) -> str:
        return self.resolved_schedule.mode

    def resolve(self, schedule: Optional[KernelSchedule] = None,
                fp: Optional[FixedPointConfig] = None
                ) -> Tuple[KernelSchedule, Optional[FixedPointConfig]]:
        """(schedule, fp) a request with these overrides actually executes."""
        return (schedule if schedule is not None else self.resolved_schedule,
                fp if fp is not None else self.fp)

    # -- target-driven auto-scheduling ---------------------------------------

    def _default_spec(self, target: DesignTarget) -> SpaceSpec:
        """The slice of schedule space this engine can execute: its backend
        family, a kernel-friendly block_batch, the full legal R/mode/hoist
        axes.  Callers needing other axes pass an explicit spec."""
        backend = "xla" if self.impl == "xla" else "pallas_interpret"
        return SpaceSpec(backends=(backend,),
                         block_batches=(min(8, self.max_batch),))

    def schedule_for_target(self, target: DesignTarget, *,
                            spec: Optional[SpaceSpec] = None,
                            measure_top_k: int = 0) -> DesignPoint:
        """Resolve a DesignTarget to the priced point this engine will run.

        Memoized per (target, spec, measure_top_k) — all frozen/hashable —
        so a stream of requests carrying the same target resolves the
        explorer once and then co-batches on the selected schedule's key
        like any explicit-schedule stream, while the same target under a
        DIFFERENT space spec resolves independently (never served from the
        other spec's cache).  Raises ``InfeasibleTargetError`` (with the
        nearest-to-feasible point named) when the budget cannot be met.
        """
        memo = (target, spec, measure_top_k)
        pt = self._target_points.get(memo)
        if pt is None:
            import dataclasses
            eff = target
            if eff.fp is None and self.fp is not None:
                # price with the fp the engine will actually serve with
                eff = dataclasses.replace(eff, fp=self.fp)
            pt = autotune_select(self.cfg, eff,
                                 spec or self._default_spec(target),
                                 measure_top_k=measure_top_k)
            self._target_points[memo] = pt
        return pt

    def auto_schedule(self, target: DesignTarget, *,
                      spec: Optional[SpaceSpec] = None,
                      measure_top_k: int = 0,
                      warmup: bool = True) -> DesignPoint:
        """Make a DesignTarget this engine's default design point.

        The selected schedule becomes the engine default — subsequent
        ``predict`` / ``submit`` calls without an explicit schedule execute
        it (and the default queue reports it) — closing the ROADMAP
        "scheduler-over-schedules" item: the per-queue static / nonstatic /
        pipeline choice comes from ``estimate_schedule`` via the explorer
        instead of the caller.
        """
        pt = self.schedule_for_target(target, spec=spec,
                                      measure_top_k=measure_top_k)
        self.schedule = pt.schedule
        self.mode = None                 # the schedule is now authoritative
        self.impl = "pallas" if pt.schedule.use_pallas else "xla"
        if target.fp is not None:
            self.fp = pt.fp
        if warmup:
            self.warmup()
        return pt

    def _ensure_key(self, sched: KernelSchedule,
                    fp: Optional[FixedPointConfig]) -> str:
        key = schedule_key(sched, fp)
        if key not in self._infer_cache:
            self._key_specs[key] = (sched, fp)
            self._infer_cache[key] = self._make_infer(key, sched, fp)
        return key

    def _executor_meta(self, kind: str, sched: KernelSchedule,
                       fp: Optional[FixedPointConfig]) -> Dict:
        """Content identity of one compiled serving executable: the model
        config plus the EXHAUSTIVE schedule/fp axes (``cache_meta``, not the
        routing key — a future schedule axis must invalidate entries, not
        silently share them).  The toolchain axes (jaxlib, platform) are
        appended by the CompileCache itself; argument shapes by the
        executor."""
        return {"kind": kind, "cfg": repr(self.cfg),
                **cache_meta(sched, fp)}

    def _make_infer(self, key: str, sched: KernelSchedule,
                    fp: Optional[FixedPointConfig]) -> Callable:
        cfg = self.cfg
        impl = "pallas" if sched.use_pallas else "xla"

        def infer(params, x, lengths=None):
            # Python side effect runs at COLD lower/compile time only:
            # counts jit traces per schedule hash (the co-batching
            # efficiency criterion).  A warm cache hit deserializes the
            # executable instead of tracing, so this never runs — which is
            # exactly what trace_count() == 0 after a warm start asserts.
            self._traces[key] = self._traces.get(key, 0) + 1
            return rnn_tagger.forward(cfg, params, x, fp=fp, impl=impl,
                                      schedule=sched, lengths=lengths)

        return CachedExecutor(jax.jit(infer), self.compile_cache, key,
                              self._executor_meta("rnn_infer", sched, fp))

    def trace_count(self, key: str) -> int:
        return self._traces.get(key, 0)

    # -- direct batched inference -------------------------------------------

    def _resolve_default_key(self, key: str) -> str:
        """Requests on the bare DEFAULT_SCHEDULE_KEY queue (submitted via
        the batcher with no schedule) execute the engine's RESOLVED
        schedule: route them to its compiled key instead of KeyErroring on
        a queue that never had a kernel."""
        if key == DEFAULT_SCHEDULE_KEY:
            return self._ensure_key(*self.resolve())
        return key

    def _predict_key(self, key: str, x: np.ndarray,
                     lengths: Optional[np.ndarray] = None) -> np.ndarray:
        fn = self._infer_cache[self._resolve_default_key(key)]
        if lengths is None:
            return np.asarray(fn(self.params, jnp.asarray(x)))
        return np.asarray(fn(self.params, jnp.asarray(x),
                             jnp.asarray(lengths, jnp.int32)))

    def predict(self, x: np.ndarray,
                schedule: Optional[KernelSchedule] = None,
                fp: Optional[FixedPointConfig] = None,
                target: Optional[DesignTarget] = None) -> np.ndarray:
        """[b, T, in] -> [b, n_outputs] under the request's schedule (or the
        schedule auto-picked for its ``target``)."""
        self._check_open()
        if target is not None and schedule is None:
            pt = self.schedule_for_target(target)
            schedule, fp = pt.schedule, fp if fp is not None else pt.fp
        key = self._ensure_key(*self.resolve(schedule, fp))
        return self._predict_key(key, x)

    def predict_ragged(self, xs: List[np.ndarray],
                       schedule: Optional[KernelSchedule] = None,
                       fp: Optional[FixedPointConfig] = None) -> List[np.ndarray]:
        """Variable-length requests sharing one logical batch.  ``bucket``
        groups by seq_len (bit-identical to per-length predict on every
        backend); ``mask`` pads to the max length and freezes each row's
        state past its true length (one batch, XLA-cell datapath)."""
        self._check_open()
        key = self._ensure_key(*self.resolve(schedule, fp))
        pad, lengths, _ = _pad_stack(list(xs))
        if self.ragged == "mask":
            # through _predict_padded, NOT _predict_key: a direct call would
            # compile one trace per distinct request count, silently
            # breaking the one-trace-per-key invariant the co-batching
            # design is built on
            out = self._predict_padded(key, pad, lengths)
            return [out[i] for i in range(len(xs))]
        return self._bucket_predict(key, xs, lengths)

    def _bucket_predict(self, key: str, xs: List[np.ndarray],
                        lengths: np.ndarray) -> List[np.ndarray]:
        out: List[Optional[np.ndarray]] = [None] * len(xs)
        for t in sorted({int(n) for n in lengths}):
            idx = [i for i, n in enumerate(lengths) if int(n) == t]
            sub = np.stack([np.asarray(xs[i])[:t] for i in idx])
            res = self._predict_padded(key, sub)
            for j, i in enumerate(idx):
                out[i] = res[j]
        return out                           # type: ignore[return-value]

    def warmup(self, schedule: Optional[KernelSchedule] = None,
               fp: Optional[FixedPointConfig] = None) -> Dict[str, Dict]:
        """Warm ONE (schedule, fp) pair's serving-shape executable — from
        the persistent cache when possible, else compile-and-store."""
        return self.prewarm(schedules=[schedule], fps=[fp])

    def prewarm(self, targets: Optional[List[DesignTarget]] = None,
                schedules: Optional[List[Optional[KernelSchedule]]] = None,
                fps: Optional[List[Optional[FixedPointConfig]]] = None
                ) -> Dict[str, Dict]:
        """Zero-warmup entry point: make the serving-bucket executables for
        a list of targets and/or schedules exist BEFORE traffic arrives.

        Each (schedule, fp) pair — targets are resolved through the
        explorer first — is lowered against the key's serving shape bucket
        (``max_batch`` rows x the config's sequence) from
        ``jax.ShapeDtypeStruct`` avals, so nothing executes.  Over a warm
        ``cache_dir`` this deserializes stored artifacts (zero jit
        compiles); cold entries compile once and are stored for the next
        engine / replica.  Returns per-key
        ``{"status": "hot"|"warm"|"cold", "compile_s": ...}``.
        """
        pairs: List[Tuple[Optional[KernelSchedule],
                          Optional[FixedPointConfig]]] = []
        for t in (targets or ()):
            pt = self.schedule_for_target(t)
            pairs.append((pt.schedule, pt.fp))
        if schedules is not None:
            fps = fps if fps is not None else [None] * len(schedules)
            pairs.extend(zip(schedules, fps))
        if not pairs:
            pairs.append((None, None))   # the engine's resolved default
        r = self.cfg.rnn
        out: Dict[str, Dict] = {}
        for sched, fp in pairs:
            key = self._ensure_key(*self.resolve(sched, fp))
            mb, _ = self.batcher.policy(key)
            rows = mb if self.pad_batches else 1
            x = jax.ShapeDtypeStruct((rows, r.seq_len, r.input_size),
                                     jnp.float32)
            out[key] = self._infer_cache[key].warm(self.params, x)
        return out

    # -- batch-1 latency fast path ------------------------------------------

    def _make_one_infer(self, key: str, sched: KernelSchedule,
                        fp: Optional[FixedPointConfig]) -> Callable:
        cfg = self.cfg
        impl = "pallas" if sched.use_pallas else "xla"

        def infer(params, x):
            # trace-time side effect: fast-path traces counted separately
            # from the batched path's (the one-trace-per-key invariant of
            # the co-batching tests must not see this trace)
            self._one_traces[key] = self._one_traces.get(key, 0) + 1
            return rnn_tagger.forward(cfg, params, x, fp=fp, impl=impl,
                                      schedule=sched)

        return CachedExecutor(jax.jit(infer), self.compile_cache, key,
                              self._executor_meta("rnn_one", sched, fp),
                              name_hint=f"{key}-one")

    def predict_one(self, x: np.ndarray,
                    schedule: Optional[KernelSchedule] = None,
                    fp: Optional[FixedPointConfig] = None,
                    target: Optional[DesignTarget] = None) -> np.ndarray:
        """Single-event inference: ``[T, in] -> [n_outputs]`` — the paper's
        single-collision latency scenario.

        Skips the batcher entirely: no queueing, no pad-to-``max_batch``
        round trip — ONE single-row scheduled step through a dedicated
        batch-1 jit trace of the request's schedule (row-wise bit-identical
        to the batched path, so ``predict_one(x) == predict(x[None])[0]``
        exactly; conformance-enforced).  Steady-state wall-clock is
        recorded per key (compile calls excluded) and reported by
        ``serve_report`` as the ``fast_path`` column.
        """
        self._check_open()
        if target is not None and schedule is None:
            pt = self.schedule_for_target(target)
            schedule, fp = pt.schedule, fp if fp is not None else pt.fp
        sched, fpr = self.resolve(schedule, fp)
        key = self._ensure_key(sched, fpr)   # registers specs for reporting
        fn = self._one_cache.get(key)
        if fn is None:
            fn = self._one_cache[key] = self._make_one_infer(key, sched, fpr)
        traces_before = self._one_traces.get(key, 0)
        t0 = time.perf_counter()
        out = np.asarray(fn(self.params, jnp.asarray(x)[None]))[0]
        if self._one_traces.get(key, 0) == traces_before:   # steady state
            self._one_stats.setdefault(key, KeyStats()).record_one(
                time.perf_counter() - t0)
        return out

    def one_trace_count(self, key: str) -> int:
        return self._one_traces.get(key, 0)

    # -- lifecycle -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def _check_open(self) -> None:
        if self._closed:
            raise EngineClosedError("RNNServingEngine")

    def drain(self, now: Optional[float] = None) -> List[Request]:
        """Flush EVERY per-key queue to completion (force, below-threshold
        leftovers included) and return the flushed requests — every queued
        request reaches a terminal state (answered, or failed with the
        error attached); none is stranded.  The engine stays open: drain is
        the quiesce step, :meth:`close` the retire step."""
        return self.flush(now=now, force=True)

    def close(self, now: Optional[float] = None) -> List[Request]:
        """Drain, then refuse all new work: ``submit`` / ``predict`` /
        ``predict_one`` / ``serve`` raise :class:`EngineClosedError` from
        now on.  Idempotent — a second close drains nothing and returns
        ``[]``.  This is the replica-retirement hook the router relies on:
        after ``close()`` returns, no request is in flight on this engine
        and none can sneak in."""
        if self._closed:
            return []
        flushed = self.drain(now=now)
        self._closed = True
        return flushed

    # -- schedule-keyed serving ---------------------------------------------

    def submit(self, x: np.ndarray,
               schedule: Optional[KernelSchedule] = None,
               fp: Optional[FixedPointConfig] = None,
               target: Optional[DesignTarget] = None,
               now: Optional[float] = None) -> Request:
        """Enqueue one request ([T, in] payload) on its schedule's queue.

        A request may carry a ``target`` instead of a schedule: the engine
        resolves it through the explorer (memoized), so a stream of
        same-target requests lands on one auto-picked queue — per-queue
        mode selection without any caller-side schedule plumbing.
        """
        self._check_open()
        if target is not None and schedule is None:
            pt = self.schedule_for_target(target)
            schedule, fp = pt.schedule, fp if fp is not None else pt.fp
        sched, fpr = self.resolve(schedule, fp)
        key = self._ensure_key(sched, fpr)
        return self.batcher.submit(x, now=now, key=key, schedule=sched,
                                   fp=fpr)

    def _pad_rows(self, x: np.ndarray, key: str) -> Tuple[np.ndarray, int]:
        b = x.shape[0]
        mb, _ = self.batcher.policy(key)
        if not self.pad_batches or b >= mb:
            return x, b
        pad = np.zeros((mb - b,) + x.shape[1:], x.dtype)
        return np.concatenate([x, pad], axis=0), b

    def _predict_padded(self, key: str, x: np.ndarray,
                        lengths: Optional[np.ndarray] = None) -> np.ndarray:
        """Key-cached inference with the batch padded to the key's
        max_batch: constant shapes, so mixed-schedule traffic costs at most
        one jit trace per schedule hash.  Zero rows are row-wise inert on
        every backend (verified by the conformance suite)."""
        xp, b = self._pad_rows(np.asarray(x), key)
        if lengths is not None and xp.shape[0] != len(lengths):
            lp = np.zeros((xp.shape[0],), np.int32)
            lp[:b] = lengths
            lengths = lp
        return self._predict_key(key, xp, lengths)[:b]

    def _flush_fn(self, key: str) -> Callable:
        """The infer function handed to the batcher for one queue; accepts
        ``lengths`` so ragged flushes route through the engine's policy."""
        def fn(x, lengths=None):
            if lengths is None:
                return self._predict_padded(key, x)
            if self.ragged == "mask":
                return self._predict_padded(key, x, lengths=lengths)
            res = self._bucket_predict(
                key, [np.asarray(x[i]) for i in range(x.shape[0])],
                np.asarray(lengths))
            return np.stack(res)
        return fn

    def flush(self, now: Optional[float] = None,
              force: bool = False) -> List[Request]:
        """Flush every ready queue (fair round-robin across schedule keys);
        ``force`` also flushes below-threshold leftovers (end of stream)."""
        return self.batcher.run_all(self._flush_fn, now=now, force=force)

    def serve(self, payloads, schedules=None, fps=None,
              now: Optional[float] = None) -> List[Request]:
        """Convenience: submit a whole stream (parallel lists), then flush to
        completion.  Returns the requests in submission order."""
        n = len(payloads)
        schedules = schedules if schedules is not None else [None] * n
        fps = fps if fps is not None else [None] * n
        reqs = [self.submit(x, schedule=s, fp=f, now=now)
                for x, s, f in zip(payloads, schedules, fps)]
        self.flush(now=now, force=True)
        return reqs

    # -- measured throughput/latency ----------------------------------------

    def benchmark(self, batch: int, iters: int = 20,
                  schedule: Optional[KernelSchedule] = None,
                  fp: Optional[FixedPointConfig] = None) -> Dict[str, float]:
        """Measured latency/throughput for one schedule key, paired with the
        analytical estimate of the same schedule object."""
        r = self.cfg.rnn
        sched, fpr = self.resolve(schedule, fp)
        key = self._ensure_key(sched, fpr)
        x = np.random.RandomState(0).randn(
            batch, r.seq_len, r.input_size).astype(np.float32)
        # through _predict_padded, NOT _predict_key: benchmarking at
        # arbitrary batch sizes must measure (and compile) the SAME padded
        # serving-shape executable the flush path runs — a direct call per
        # distinct batch size would silently stack extra traces on the key
        self._predict_padded(key, x)                # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            self._predict_padded(key, x)
        dt = (time.perf_counter() - t0) / iters
        est = estimate_schedule(sched, r, fpr)
        return {"key": key, "batch": batch, "latency_s": dt,
                "throughput_eps": batch / dt,
                "latency_cycles": est.latency_cycles,
                "ii_cycles": est.ii_cycles, "dsp": est.dsp}

    # -- measured vs analytical, per schedule key ---------------------------

    def serve_report(self, clock_mhz: float = 200.0) -> Dict[str, Dict]:
        """Per schedule key: measured serving stats (from the batcher's
        per-key counters) next to ``estimate_schedule`` of the SAME schedule
        object the queue executed — the paper's two-column table.

        Requests served on the bare DEFAULT_SCHEDULE_KEY queue report the
        engine's RESOLVED schedule (the kernel they actually executed) with
        its estimate, not an estimate-less row.  Compiles always belong to
        the resolved key's own row: the default row reports ``traces: 0``
        and points at ``resolved_key`` — attributing the resolved key's
        trace count to BOTH rows would double-report the same compiles
        whenever both queues saw traffic.

        Each row also carries the ``compile`` column — the persistent
        cache's per-key cold/warm split (hit rate + first-request compile
        seconds), the zero-warmup acceptance signal."""
        specs = dict(self._key_specs)
        resolved_from: Dict[str, str] = {}
        if (DEFAULT_SCHEDULE_KEY in self.batcher.stats
                and DEFAULT_SCHEDULE_KEY not in specs):
            sched, fpr = self.resolve()
            specs[DEFAULT_SCHEDULE_KEY] = (sched, fpr)
            resolved_from[DEFAULT_SCHEDULE_KEY] = schedule_key(sched, fpr)
        report: Dict[str, Dict] = {}
        for key, (sched, fpr) in specs.items():
            est = estimate_schedule(sched, self.cfg.rnn, fpr)
            report[key] = {
                "schedule": sched,
                "fp": fpr,
                "traces": 0 if key in resolved_from else self.trace_count(key),
                "measured": self.batcher.key_stats(key).summary(),
                "analytical": est.report_row(clock_mhz),
                "compile": self.compile_cache.report_row(key),
            }
            if key in resolved_from:
                report[key]["resolved_key"] = resolved_from[key]
            if key in self._one_stats:
                # the batch-1 fast path's steady-state latency, next to the
                # batched queue's — the paper's single-event column
                report[key]["fast_path"] = self._one_stats[key].summary()
        return report

    # -- paired FPGA design point -------------------------------------------

    def fpga_design(self, reuse_kernel: int = 1, reuse_recurrent: int = 1,
                    strategy: str = "latency", part: str = "xcku115"
                    ) -> HLSDesign:
        return estimate_design(RNNDesignPoint(
            self.cfg, self.fp or FixedPointConfig(),
            reuse_kernel, reuse_recurrent, self.resolved_mode,
            strategy, part))


def format_serve_report(report: Dict[str, Dict],
                        clock_mhz: float = 200.0) -> str:
    """Render serve_report() as the measured-vs-analytical table."""
    lines = [f"{'schedule key':38s} {'served':>6s} {'meas p50':>10s} "
             f"{'meas p99':>10s} {'est lat':>9s} {'est II':>8s} {'DSP':>6s} "
             f"{'cold/warm':>9s} {'hit':>5s}"]
    for key, row in report.items():
        m, a = row["measured"], row["analytical"]
        c = row.get("compile", {})
        cw = f"{int(c.get('cold', 0))}/{int(c.get('warm', 0))}"
        lines.append(
            f"{key:38s} {int(m['served']):6d} "
            f"{m['latency_p50_s'] * 1e3:8.2f}ms "
            f"{m['latency_p99_s'] * 1e3:8.2f}ms "
            f"{a['latency_us']:7.2f}us {a['ii_cycles']:8d} {a['dsp']:6d} "
            f"{cw:>9s} {c.get('hit_rate', 0.0):4.0%}")
    return "\n".join(lines)
