"""RNN serving engine — the paper's deliverable as a service.

Wraps a trained tagger with: execution mode (static scan / non-static
unrolled / Pallas weights-resident kernel), optional fixed-point datapath,
micro-batching, and a latency report that pairs measured wall-clock numbers
with the analytical FPGA design point (core.hls) for the same configuration
— the two columns the paper compares.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FixedPointConfig, ModelConfig
from repro.core.hls import HLSDesign, RNNDesignPoint, estimate_design
from repro.models import rnn_tagger
from repro.serving.batcher import MicroBatcher


@dataclass
class RNNServingEngine:
    cfg: ModelConfig
    params: Dict
    mode: Optional[str] = None            # static | nonstatic | None: from
                                          # the schedule / config
    impl: str = "xla"                     # xla | pallas
    fp: Optional[FixedPointConfig] = None
    max_batch: int = 256
    schedule: Optional[object] = None     # KernelSchedule override

    def __post_init__(self):
        cfg, fp, mode, impl = self.cfg, self.fp, self.mode, self.impl
        schedule = self.schedule

        def infer(params, x):
            return rnn_tagger.forward(cfg, params, x, fp=fp, mode=mode,
                                      impl=impl, schedule=schedule)

        self._infer = jax.jit(infer)
        self.batcher = MicroBatcher(max_batch=self.max_batch)

    # -- direct batched inference -------------------------------------------
    def predict(self, x: np.ndarray) -> np.ndarray:
        return np.asarray(self._infer(self.params, jnp.asarray(x)))

    def warmup(self):
        r = self.cfg.rnn
        self.predict(np.zeros((1, r.seq_len, r.input_size), np.float32))

    # -- measured throughput/latency ----------------------------------------
    def benchmark(self, batch: int, iters: int = 20) -> Dict[str, float]:
        r = self.cfg.rnn
        x = np.random.RandomState(0).randn(
            batch, r.seq_len, r.input_size).astype(np.float32)
        self.predict(x[:1])                         # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            self.predict(x)
        dt = (time.perf_counter() - t0) / iters
        return {"batch": batch, "latency_s": dt,
                "throughput_eps": batch / dt}

    # -- paired FPGA design point -------------------------------------------
    @property
    def resolved_mode(self) -> str:
        if self.mode is not None:
            return self.mode
        if self.schedule is not None:
            return self.schedule.mode
        return self.cfg.rnn.mode

    def fpga_design(self, reuse_kernel: int = 1, reuse_recurrent: int = 1,
                    strategy: str = "latency", part: str = "xcku115"
                    ) -> HLSDesign:
        return estimate_design(RNNDesignPoint(
            self.cfg, self.fp or FixedPointConfig(),
            reuse_kernel, reuse_recurrent, self.resolved_mode,
            strategy, part))
