"""Micro-batching request queue — the trigger-style serving front end.

The paper's L1T scenario is a hard-real-time stream (one inference per
collision, 40 MHz); the coprocessor scenario (QuickDraw on Alveo) is a
batched service.  MicroBatcher implements the latter: requests accumulate
until `max_batch` or `max_wait_s`, then flush as one batch — the policy the
paper's FPGA-vs-GPU throughput comparison (Sec. 5.2) hinges on (batch-1
latency vs batched throughput).
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass
class Request:
    payload: Any
    arrival_s: float
    req_id: int
    result: Any = None
    done_s: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s


@dataclass
class MicroBatcher:
    max_batch: int = 64
    max_wait_s: float = 0.002
    _queue: List[Request] = field(default_factory=list)
    _ids: "itertools.count" = field(default_factory=itertools.count)

    def submit(self, payload: Any, now: Optional[float] = None) -> Request:
        r = Request(payload, time.time() if now is None else now,
                    next(self._ids))
        self._queue.append(r)
        return r

    def ready(self, now: Optional[float] = None) -> bool:
        if not self._queue:
            return False
        if len(self._queue) >= self.max_batch:
            return True
        now = time.time() if now is None else now
        return now - self._queue[0].arrival_s >= self.max_wait_s

    def drain(self) -> List[Request]:
        batch, self._queue = (self._queue[: self.max_batch],
                              self._queue[self.max_batch:])
        return batch

    def run(self, infer_fn: Callable[[np.ndarray], np.ndarray],
            now: Optional[float] = None) -> List[Request]:
        """Flush one batch through infer_fn; stamps results + latencies."""
        if not self.ready(now):
            return []
        batch = self.drain()
        x = np.stack([r.payload for r in batch])
        out = np.asarray(infer_fn(x))
        t = time.time() if now is None else now
        for i, r in enumerate(batch):
            r.result = out[i]
            r.done_s = t
        return batch
