"""Schedule-keyed micro-batching request queues — the serving front end.

The paper's L1T scenario is a hard-real-time stream (one inference per
collision, 40 MHz); the coprocessor scenario (QuickDraw on Alveo) is a
batched service.  MicroBatcher implements the latter, generalized to the
multi-tenant case PR 1's scheduling layer created: every compiled kernel
variant — a (KernelSchedule, FixedPointConfig) pair — gets its OWN queue,
keyed by the stable ``schedule_key`` hash.  Requests for the same key stack
into one batch (they execute the same kernel); requests for different keys
never mix (they would retrace / recompile).  Each key has an independent
``max_batch`` / ``max_wait_s`` flush policy, keys are drained fairly
(round-robin), and per-key latency/throughput counters feed the engine's
measured-vs-analytical ``serve_report``.

Ragged payloads (variable seq_len jet streams) within one queue are legal:
``run`` pads them to the per-batch max, hands the true lengths to the infer
function when it accepts a ``lengths`` keyword, and un-pads per-request
results shaped exactly like the padded payload (element-wise transforms).
"""

from __future__ import annotations

import inspect
import itertools
import time
import warnings
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.kernels.schedule import DEFAULT_SCHEDULE_KEY, schedule_key


def _now() -> float:
    """Monotonic clock for every arrival/done stamp.

    ``time.time()`` is wall-clock: an NTP step between submit and flush
    produced negative (or wildly wrong) latencies in KeyStats.  All batcher
    timing now uses ``time.perf_counter`` — the same clock domain the
    engines' steady-state measurements already use — and the ``now=``
    injection hooks stay, so tests drive a logical clock as before."""
    return time.perf_counter()


@dataclass
class Request:
    payload: Any
    arrival_s: float
    req_id: int
    key: str = DEFAULT_SCHEDULE_KEY
    schedule: Any = None               # Optional[KernelSchedule]
    fp: Any = None                     # Optional[FixedPointConfig]
    result: Any = None
    done_s: Optional[float] = None
    error: Optional[BaseException] = None   # the flush failure, attached —
                                            # a failed request is REPORTED,
                                            # never silently dropped

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s

    @property
    def status(self) -> str:
        """Exactly one of pending | answered | failed."""
        if self.error is not None:
            return "failed"
        return "pending" if self.done_s is None else "answered"


class QueueFullError(RuntimeError):
    """Explicit bounded-queue reject: the submitter is told, counted per
    key, and may shed / retry / downgrade — never a silent drop."""

    def __init__(self, key: str, bound: int):
        self.key = key
        self.bound = bound
        super().__init__(
            f"queue {key!r} is full ({bound} pending): the admission layer "
            f"must shed or downgrade instead of queueing unboundedly")


# percentile window: enough samples for stable p99, bounded memory for
# long-running engines (totals stay exact via the scalar counters)
_MAX_LATENCY_SAMPLES = 4096


@dataclass
class KeyStats:
    """Per-schedule-key serving counters (the measured column).

    ``served`` / ``latency_sum_s`` / ``latency_max_s`` are exact lifetime
    totals; ``latencies_s`` is a bounded window of the most recent samples,
    used only for the percentile columns.
    """

    served: int = 0
    batches: int = 0
    failed: int = 0                    # flush-fn exceptions, per request
    rejected: int = 0                  # bounded-queue explicit rejects
    latency_sum_s: float = 0.0
    latency_max_s: float = 0.0
    latencies_s: List[float] = field(default_factory=list)

    def record_one(self, latency_s: float) -> None:
        self.served += 1
        self.latency_sum_s += latency_s
        self.latency_max_s = max(self.latency_max_s, latency_s)
        self.latencies_s.append(latency_s)
        if len(self.latencies_s) > 2 * _MAX_LATENCY_SAMPLES:
            del self.latencies_s[:-_MAX_LATENCY_SAMPLES]

    def record(self, batch: List[Request]) -> None:
        self.batches += 1
        for r in batch:
            self.record_one(r.latency_s or 0.0)

    def record_failed(self, n: int) -> None:
        self.failed += n

    def record_rejected(self) -> None:
        self.rejected += 1

    def summary(self) -> Dict[str, float]:
        n = max(self.served, 1)
        lat = np.asarray(self.latencies_s) if self.latencies_s else np.zeros(1)
        return {
            "served": float(self.served),
            "batches": float(self.batches),
            "failed": float(self.failed),
            "rejected": float(self.rejected),
            "mean_batch": float(self.served) / max(self.batches, 1),
            "latency_mean_s": self.latency_sum_s / n,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p99_s": float(np.percentile(lat, 99)),
            "latency_max_s": self.latency_max_s,
        }


def _pad_stack(payloads: List[np.ndarray]) -> Tuple[np.ndarray, np.ndarray, bool]:
    """Stack payloads, padding axis 0 (time) to the per-batch max.

    Returns (stacked, lengths, ragged).  Equal-shape payloads take the
    plain ``np.stack`` path and report ragged=False.
    """
    arrs = [np.asarray(p) for p in payloads]
    dtypes = {a.dtype for a in arrs}
    if len(dtypes) != 1:
        # padding with arrs[0].dtype would silently down/up-cast the other
        # payloads; mixed-dtype requests cannot share a compiled trace
        # anyway, so this is a routing bug at the submitter — say so
        raise ValueError(
            f"mixed payload dtypes in one batch: {sorted(map(str, dtypes))} "
            f"— requests with different dtypes cannot share a trace; route "
            f"them to different schedule keys")
    lengths = np.asarray([a.shape[0] if a.ndim else 1 for a in arrs], np.int32)
    shapes = {a.shape for a in arrs}
    if len(shapes) == 1:
        return np.stack(arrs), lengths, False
    tails = {a.shape[1:] for a in arrs}
    if len(tails) != 1:
        raise ValueError(f"payloads differ beyond the sequence axis: {shapes}")
    t_max = int(lengths.max())
    out = np.zeros((len(arrs), t_max) + arrs[0].shape[1:], arrs[0].dtype)
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
    return out, lengths, True


def _accepts_lengths(fn: Callable) -> bool:
    try:
        return "lengths" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


@dataclass
class MicroBatcher:
    """Multi-queue batcher: one FIFO per schedule key, fair round-robin drain.

    ``max_batch`` / ``max_wait_s`` are the default flush policy; individual
    keys override via :meth:`set_policy`.  The single-queue API of the
    original batcher (submit/ready/drain/run with no key) still works — it
    operates on the ``default`` key, or on the fair-next key when several
    queues are live.
    """

    max_batch: int = 64
    max_wait_s: float = 0.002
    max_queue: Optional[int] = None    # default per-key pending bound;
                                       # None = unbounded (pre-PR behavior)
    _queues: Dict[str, List[Request]] = field(default_factory=dict)
    _policy: Dict[str, Tuple[int, float]] = field(default_factory=dict)
    _bounds: Dict[str, Optional[int]] = field(default_factory=dict)
    _stats: Dict[str, KeyStats] = field(default_factory=dict)
    _ids: "itertools.count" = field(default_factory=itertools.count)
    _rr: int = 0                       # round-robin cursor over key order

    # -- policy / introspection ---------------------------------------------

    def set_policy(self, key: str, *, max_batch: Optional[int] = None,
                   max_wait_s: Optional[float] = None,
                   max_queue: Optional[int] = ...) -> None:  # type: ignore
        mb, mw = self.policy(key)
        self._policy[key] = (max_batch if max_batch is not None else mb,
                             max_wait_s if max_wait_s is not None else mw)
        if max_queue is not ...:       # ... = leave the bound untouched
            self._bounds[key] = max_queue

    def policy(self, key: str) -> Tuple[int, float]:
        return self._policy.get(key, (self.max_batch, self.max_wait_s))

    def queue_bound(self, key: str) -> Optional[int]:
        """Pending-request cap for one key (None = unbounded)."""
        return self._bounds.get(key, self.max_queue)

    def keys(self) -> List[str]:
        """Keys in first-seen order (the round-robin order)."""
        return list(self._queues)

    def pending(self, key: Optional[str] = None) -> int:
        if key is not None:
            return len(self._queues.get(key, ()))
        return sum(len(q) for q in self._queues.values())

    def key_stats(self, key: str) -> KeyStats:
        return self._stats.setdefault(key, KeyStats())

    @property
    def stats(self) -> Dict[str, KeyStats]:
        return self._stats

    # -- submission ----------------------------------------------------------

    def submit(self, payload: Any, now: Optional[float] = None,
               key: Optional[str] = None, schedule: Any = None,
               fp: Any = None) -> Request:
        """Enqueue one request.  The queue key is, in priority order: the
        explicit ``key``, ``schedule_key(schedule, fp)`` when either is
        given, else the default queue.

        A bounded queue (``max_queue`` / ``set_policy(max_queue=...)``) that
        is already full raises :class:`QueueFullError` — an EXPLICIT reject,
        counted in the key's stats, so overload backpressure reaches the
        submitter instead of growing the queue without limit."""
        if key is None:
            key = (schedule_key(schedule, fp)
                   if schedule is not None or fp is not None
                   else DEFAULT_SCHEDULE_KEY)
        bound = self.queue_bound(key)
        if bound is not None and len(self._queues.get(key, ())) >= bound:
            self.key_stats(key).record_rejected()
            raise QueueFullError(key, bound)
        r = Request(payload, _now() if now is None else now,
                    next(self._ids), key=key, schedule=schedule, fp=fp)
        self._queues.setdefault(key, []).append(r)
        return r

    # -- readiness -----------------------------------------------------------

    def ready_key(self, key: str, now: Optional[float] = None) -> bool:
        q = self._queues.get(key)
        if not q:
            return False
        mb, mw = self.policy(key)
        if len(q) >= mb:
            return True
        now = _now() if now is None else now
        return now - q[0].arrival_s >= mw

    def ready_keys(self, now: Optional[float] = None) -> List[str]:
        now = _now() if now is None else now
        return [k for k in self._queues if self.ready_key(k, now)]

    def ready(self, now: Optional[float] = None) -> bool:
        return bool(self.ready_keys(now))

    def _next_key(self, now: Optional[float], ready_only: bool
                  ) -> Optional[str]:
        """Fair key selection: scan keys round-robin from the cursor."""
        keys = self.keys()
        if not keys:
            return None
        n = len(keys)
        for off in range(n):
            k = keys[(self._rr + off) % n]
            if ready_only and not self.ready_key(k, now):
                continue
            if not ready_only and not self._queues.get(k):
                continue
            self._rr = (keys.index(k) + 1) % n
            return k
        return None

    # -- draining ------------------------------------------------------------

    def drain(self, key: Optional[str] = None) -> List[Request]:
        """Dequeue up to the key's max_batch requests (FIFO).  With no key,
        the fair-next non-empty queue is drained (ready or not — this is the
        shutdown / leftovers path)."""
        if key is None:
            key = self._next_key(None, ready_only=False)
            if key is None:
                return []
        q = self._queues.get(key, [])
        mb, _ = self.policy(key)
        batch, self._queues[key] = q[:mb], q[mb:]
        return batch

    def run(self, infer_fn: Callable, now: Optional[float] = None,
            key: Optional[str] = None, force: bool = False) -> List[Request]:
        """Flush ONE batch from one queue through infer_fn; stamps results,
        latencies, and per-key counters.

        With no ``key``, the fair-next ready queue flushes (round-robin
        across schedule keys).  ``force`` flushes even below the policy
        thresholds — the end-of-stream path.

        Ragged batches are zero-padded to the per-batch max sequence length.
        An infer function whose output depends on sequence length (any
        recurrent model) must accept a ``lengths`` keyword to see the true
        lengths — the engine's flush functions do; a plain function gets the
        padded batch (and a RuntimeWarning), and per-request results whose
        shape equals the padded payload shape are un-padded on the way out.

        An exception raised BY the infer function fails exactly this batch:
        every drained request comes back with the error attached
        (``status == "failed"``, counted in the key's stats) instead of the
        exception propagating with the batch lost — so one key's broken
        kernel can never drop another key's queued requests in
        :meth:`run_all`.  (Payload-shape errors from padding still raise:
        they are routing bugs at the submitter, and the existing contract.)
        """
        if key is None:
            key = self._next_key(now, ready_only=not force)
            if key is None:
                return []
        elif not force and not self.ready_key(key, now):
            return []
        batch = self.drain(key)
        if not batch:
            return []
        x, lengths, ragged = _pad_stack([r.payload for r in batch])
        try:
            if ragged and _accepts_lengths(infer_fn):
                out = np.asarray(infer_fn(x, lengths=lengths))
            else:
                if ragged:
                    warnings.warn(
                        "ragged batch padded for an infer function without a "
                        "'lengths' parameter: sequence-dependent models will "
                        "compute on the zero padding", RuntimeWarning,
                        stacklevel=2)
                out = np.asarray(infer_fn(x))
        except Exception as e:
            t = _now() if now is None else now
            for r in batch:
                r.error = e
                r.done_s = t
            self.key_stats(key).record_failed(len(batch))
            warnings.warn(
                f"flush of queue {key!r} failed ({type(e).__name__}: {e}); "
                f"{len(batch)} request(s) failed with the error attached, "
                f"other queues unaffected", RuntimeWarning, stacklevel=2)
            return batch
        t = _now() if now is None else now
        for i, r in enumerate(batch):
            res = out[i]
            # un-pad only outputs shaped exactly like the padded payload
            # (element-wise transforms); anything else is returned as-is
            if ragged and res.shape == x.shape[1:]:
                res = res[: lengths[i]]
            r.result = res
            r.done_s = t
        self.key_stats(key).record(batch)
        return batch

    def run_all(self, infer_for_key: Callable[[str], Callable],
                now: Optional[float] = None, force: bool = False
                ) -> List[Request]:
        """Flush every ready (or, with force, every non-empty) queue once
        round-robin until nothing is left to flush.  ``infer_for_key`` maps a
        schedule key to that key's compiled infer function."""
        done: List[Request] = []
        while True:
            key = self._next_key(now, ready_only=not force)
            if key is None:
                return done
            done.extend(self.run(infer_for_key(key), now=now, key=key,
                                 force=force))
