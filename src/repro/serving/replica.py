"""Replica pool: N independent engine instances behind one router.

Trigger-grade DAQ deployments of hls4ml-style networks put many identical
boards behind a dispatcher — throughput comes from replication, and the
system keeps answering when one board stalls or dies.  This module is that
layer in software: an :class:`EngineReplica` wraps ONE
:class:`~repro.serving.engine.RNNServingEngine` (its own ``MicroBatcher``,
its own jit/trace state) plus the replica-grade fault surface
(:class:`~repro.serving.faults.ReplicaFaultSet`), and a
:class:`ReplicaPool` builds N of them from one (config, params) pair —
sharing ONE persistent compile-cache directory, so a replica that takes
over a failed peer's keys starts zero-warmup (PR 7's concurrent-replica
atomic writes exist exactly for this).

The router (:mod:`repro.serving.router`) talks to replicas only through
:meth:`EngineReplica.predict` / :meth:`EngineReplica.heartbeat`; both
consume the fault set, so an injected crash is indistinguishable from a
dead board at the call boundary — which is the point.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.config import FixedPointConfig, ModelConfig
from repro.kernels.schedule import KernelSchedule
from repro.serving.engine import RNNServingEngine
from repro.serving.faults import ReplicaFaultSet


class EngineReplica:
    """One engine instance with an identity, a fault surface, and counters.

    ``predict`` is the single-event serving call (the engine's batch-1
    fast path — row-wise bit-identical to the batched path, conformance-
    enforced, so ANY replica's answer equals a single-replica engine's).
    It returns ``(result, stall_s)``: the injected straggler stall is
    reported in the SIMULATED clock domain for the router's timeout /
    hedge projections, never slept.
    """

    def __init__(self, replica_id: str, engine: RNNServingEngine):
        self.replica_id = replica_id
        self.engine = engine
        self.faults = ReplicaFaultSet(replica_id=replica_id)
        self.calls = 0                 # predict calls attempted
        self.served = 0                # predict calls that returned
        self.errors = 0                # predict calls that raised
        self.heartbeats = 0
        self.stalled_s = 0.0           # total injected stall charged

    def __repr__(self) -> str:
        return (f"EngineReplica({self.replica_id!r}, calls={self.calls}, "
                f"errors={self.errors})")

    # -- the router-facing call surface --------------------------------------

    def heartbeat(self) -> float:
        """Liveness probe: consumes one fault-set call like any other —
        a crashed replica fails its heartbeats, a straggler's heartbeat
        reports its stall — and returns the stall seconds (0.0 healthy)."""
        self.heartbeats += 1
        return self.faults.on_call()

    def predict(self, x: np.ndarray,
                schedule: Optional[KernelSchedule] = None,
                fp: Optional[FixedPointConfig] = None
                ) -> Tuple[np.ndarray, float]:
        """One single-event inference on this replica: ``[T, in] ->
        ([n_outputs], injected_stall_s)``.  Raises whatever the fault set
        (or the engine) raises — the router converts that into the
        retry/failover ladder."""
        self.calls += 1
        try:
            stall = self.faults.on_call()
            out = self.engine.predict_one(x, schedule=schedule, fp=fp)
        except Exception:
            self.errors += 1
            raise
        self.served += 1
        self.stalled_s += stall
        return out, stall

    # -- lifecycle (delegated to the engine's PR 10 hooks) -------------------

    @property
    def closed(self) -> bool:
        return self.engine.closed

    def drain(self):
        """Flush every queued request on this replica's engine to a
        terminal state (the retirement quiesce step)."""
        return self.engine.drain()

    def close(self):
        return self.engine.close()

    # -- reporting -----------------------------------------------------------

    def report_row(self) -> Dict:
        return {"calls": self.calls, "served": self.served,
                "errors": self.errors, "heartbeats": self.heartbeats,
                "stalled_s": self.stalled_s,
                "faults_armed": self.faults.armed(),
                "faults_fired": len(self.faults.fired),
                "closed": self.closed}


class ReplicaPool:
    """N identically configured replicas sharing one compile-cache dir.

    ``build`` is the canonical constructor: one (cfg, params) pair, N
    fresh :class:`RNNServingEngine` instances (each with its own batcher
    and jit state — replicas share NO mutable serving state), all pointed
    at the same ``cache_dir`` so the first replica to compile a schedule
    key stores the executable every other replica (and every failover)
    deserializes — zero-warmup failover.
    """

    def __init__(self, replicas: List[EngineReplica]):
        if not replicas:
            raise ValueError("a ReplicaPool needs at least one replica")
        ids = [r.replica_id for r in replicas]
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate replica ids: {ids}")
        self.replicas = list(replicas)
        self._by_id = {r.replica_id: r for r in self.replicas}

    @classmethod
    def build(cls, cfg: ModelConfig, params: Dict, n: int, *,
              cache_dir: Optional[str] = None,
              make_engine: Optional[Callable[[int], RNNServingEngine]] = None,
              **engine_kw) -> "ReplicaPool":
        """N replicas of one model.  ``make_engine(i)`` overrides engine
        construction (tests inject pre-warmed or oddly configured
        engines); the default builds ``RNNServingEngine(cfg, params,
        cache_dir=cache_dir, **engine_kw)`` per replica."""
        if n < 1:
            raise ValueError(f"replica count must be >= 1: {n}")
        reps = []
        for i in range(n):
            eng = (make_engine(i) if make_engine is not None
                   else RNNServingEngine(cfg, params, cache_dir=cache_dir,
                                         **engine_kw))
            reps.append(EngineReplica(f"r{i}", eng))
        return cls(reps)

    # -- container protocol --------------------------------------------------

    def __len__(self) -> int:
        return len(self.replicas)

    def __iter__(self) -> Iterator[EngineReplica]:
        return iter(self.replicas)

    def ids(self) -> List[str]:
        return [r.replica_id for r in self.replicas]

    def get(self, replica_id: str) -> EngineReplica:
        return self._by_id[replica_id]

    @property
    def reference(self) -> EngineReplica:
        """The schedule-resolution reference (replicas are identically
        configured, so any one resolves requests for the whole pool)."""
        return self.replicas[0]

    # -- pool-wide operations ------------------------------------------------

    def prewarm(self, schedules=None, fps=None) -> Dict[str, Dict]:
        """Warm every replica's executables for the given schedules; over
        a shared ``cache_dir`` the first replica compiles-and-stores and
        the rest deserialize (warm)."""
        out: Dict[str, Dict] = {}
        for rep in self.replicas:
            out[rep.replica_id] = rep.engine.prewarm(schedules=schedules,
                                                     fps=fps)
        return out

    def drain_all(self) -> Dict[str, List]:
        return {r.replica_id: r.drain() for r in self.replicas}

    def close_all(self) -> Dict[str, List]:
        return {r.replica_id: r.close() for r in self.replicas}
