"""Speculative decode on the scheduled step: draft cheap, verify dense.

The paper's central trade is reuse factor R against initiation interval —
high-R schedules are slow per step but nearly free in resources.  That is
exactly the asymmetry speculative decoding exploits: draft K tokens per
round on a cheap resident schedule (a high-R LM decode step, or an n-gram
``CacheTable`` whose drafts cost nothing at all), then verify all K+1
positions in ONE batched pass on the dense R1 schedule
(:func:`repro.models.decode.decode_steps`).  Acceptance is exact greedy
match — a draft token survives only if it equals the argmax the verify
pass produced at the preceding position — so the emitted token sequence is
bit-identical to sequential greedy decode, always.  Speculation changes
only how many sequential steps the wall-clock pays for, never the tokens.

KV-cache correctness without rollback: each round's verify writes the full
window ``[pos, pos+K]`` per row, and a row advances by at most K+1, so the
next round's window always covers (and overwrites) any stale wrong-branch
entries before a query can attend to them — positions below ``pos`` hold
exactly the values sequential decode would have written.  ``kv_trim``
(rollback to the first rejected position) is therefore OPTIONAL hygiene,
exposed via ``SpecConfig(trim=True)`` and conformance-tested, not a
correctness requirement.

The ``CacheTable`` follows SNIPPETS.md §3 (the `pie` speculative-decoding
app): a suffix-keyed n-gram table with LRU eviction over contexts and a
small most-recently-promoted candidate row per context — accepted
continuations are promoted to the front, so hot loops in the stream draft
themselves for free.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.kernels.schedule import KernelSchedule, cache_meta
from repro.models.decode import (decode_schedulable, decode_step,
                                 decode_steps, kv_trim, pack_decode_params)
from repro.serving.compile_cache import CachedExecutor, CompileCache


# ---------------------------------------------------------------------------
# configuration


@dataclass(frozen=True)
class SpecConfig:
    """Per-key speculative-decode configuration.

    ``k`` draft tokens per round (``k=0`` disables speculation — the key
    decodes sequentially, bit-for-bit the plain engine path).  ``draft``
    is the cheap resident schedule the model-draft steps run on; ``None``
    selects the free n-gram ``CacheTable`` draft instead.  ``trim``
    additionally rolls the KV cache back to the accepted frontier after
    every round (see module docstring — optional hygiene, not required
    for exactness)."""

    k: int = 4
    draft: Optional[KernelSchedule] = None
    ngram_n: int = 3
    capacity: int = 4096
    lru_size: int = 4
    trim: bool = False

    def __post_init__(self):
        if self.k < 0:
            raise ValueError(f"k must be >= 0, got {self.k}")
        if self.ngram_n < 1:
            raise ValueError(f"ngram_n must be >= 1, got {self.ngram_n}")
        if self.capacity < 1 or self.lru_size < 1:
            raise ValueError("capacity and lru_size must be >= 1")

    def key_token(self) -> str:
        """Dash-free serving-key suffix: appended to the schedule key as
        ``<schedule_key>-spec[...]``, it must survive a round-trip through
        ``KernelSchedule.from_key`` (which ignores unknown dash-separated
        tokens), so no dashes may appear inside."""
        if self.k == 0:
            return ""
        if self.draft is None:
            d = f"ngram{self.ngram_n}"
        else:
            d = "draft[" + self.draft.key().replace("-", "_") + "]"
        t = "_trim" if self.trim else ""
        return f"spec[k{self.k}_{d}{t}]"


# ---------------------------------------------------------------------------
# n-gram draft table (SNIPPETS.md §3: suffix-keyed, LRU-evicted, promoted
# on accept)


class CacheTable:
    """Bounded n-gram → continuation table.

    Keys are ``n``-token context tuples; each maps to a small list of
    candidate next tokens, most-recently-promoted first (at most
    ``lru_size`` per context).  The table itself holds at most
    ``capacity`` contexts; inserting beyond that evicts the least
    recently used context.  Lookups and inserts both count as context
    use.  Invariants (property-tested): ``len(table) <= capacity``
    always; a candidate row never holds duplicates; a just-inserted
    (context, token) pair is an immediate hit; eviction order is exactly
    LRU over contexts."""

    def __init__(self, n: int = 3, capacity: int = 1024, lru_size: int = 4):
        if n < 1 or capacity < 1 or lru_size < 1:
            raise ValueError("n, capacity and lru_size must all be >= 1")
        self.n = n
        self.capacity = capacity
        self.lru_size = lru_size
        self._table: "OrderedDict[Tuple[int, ...], List[int]]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._table)

    def candidates(self, context: Sequence[int]) -> List[int]:
        return list(self._table.get(tuple(int(t) for t in context), ()))

    def insert(self, context: Sequence[int], nxt: int) -> None:
        ctx = tuple(int(t) for t in context)
        if len(ctx) != self.n:
            return                      # only n-length suffixes are keys
        t = int(nxt)
        row = self._table.get(ctx)
        if row is None:
            self._table[ctx] = [t]
            if len(self._table) > self.capacity:
                self._table.popitem(last=False)     # LRU context out
                self.evictions += 1
            return
        self._table.move_to_end(ctx)
        if t in row:                    # promote, never duplicate
            row.remove(t)
        row.insert(0, t)
        while len(row) > self.lru_size:
            row.pop()                   # least-recently-promoted candidate

    def lookup(self, context: Sequence[int]) -> Optional[int]:
        ctx = tuple(int(t) for t in context)
        row = self._table.get(ctx)
        if not row:
            self.misses += 1
            return None
        self.hits += 1
        self._table.move_to_end(ctx)    # a lookup is a use
        return row[0]

    def observe(self, tokens: Sequence[int], start: int = 0) -> None:
        """Feed every (n-gram suffix → next token) pair of ``tokens``
        whose target index is ``>= start`` (the caller's watermark, so a
        growing stream is observed incrementally without rescans)."""
        toks = [int(t) for t in tokens]
        for j in range(max(int(start), self.n), len(toks)):
            self.insert(toks[j - self.n:j], toks[j])

    def draft(self, tokens: Sequence[int], k: int) -> List[int]:
        """K speculative continuations of ``tokens``: chain MRU lookups on
        the rolling n-token suffix; on a miss, repeat the last token (a
        cheap bet that costs nothing when wrong — rejection just falls
        back to the verify pass's own token)."""
        toks = [int(t) for t in tokens]
        ctx = toks[-self.n:]
        last = toks[-1] if toks else 0
        out: List[int] = []
        for _ in range(int(k)):
            cand = self.lookup(ctx) if len(ctx) == self.n else None
            t = last if cand is None else int(cand)
            out.append(t)
            ctx = (ctx + [t])[-self.n:]
            last = t
        return out


# ---------------------------------------------------------------------------
# exact greedy-match acceptance


@dataclass
class RowAdvance:
    """Outcome of one row's acceptance walk over a verified chunk."""

    emitted: List[int]
    advanced: int
    drafted: int
    accepted: int
    rejected: int
    done: bool


def accept_chunk(inputs: Sequence[int], greedy: Sequence[int], *,
                 tokens: Sequence[int], plen: int, pos: int,
                 max_new: int, max_seq: int = 1 << 30) -> RowAdvance:
    """Walk one row's verified chunk exactly as the sequential engine tick
    would have: ``inputs[i]`` is the token fed at position ``pos+i``,
    ``greedy[i]`` the verify pass's argmax there.  Teacher-force while
    inside the prompt, emit greedy tokens after it, and stop at the first
    position whose fed token does not match — everything after a mismatch
    is a wrong-branch draft.  ``drafted`` counts every speculative input
    in the chunk (``pos+i >= len(tokens)``); ``accepted`` those consumed
    matching; ``rejected = drafted - accepted`` exactly, by construction.

    The advance/done logic replicates the sequential tick bit-for-bit:
    emit iff the next position leaves the prompt; done when ``max_new``
    fresh tokens exist or the row hits ``max_seq - 1``."""
    S = len(inputs)
    toks = list(tokens)
    n_tok = len(toks)
    drafted = sum(1 for i in range(1, S) if pos + i >= n_tok)
    emitted: List[int] = []
    advanced = accepted = 0
    n = n_tok
    p = pos
    done = False
    for i in range(S):
        nxt = int(toks[p + 1]) if p + 1 < plen else int(greedy[i])
        if p + 1 >= plen:
            emitted.append(nxt)
            n += 1
        p += 1
        advanced += 1
        done = (n - plen >= max_new) or (p >= max_seq - 1)
        if done or i + 1 >= S:
            break
        if int(inputs[i + 1]) != nxt:
            break                       # first rejection: stop the walk
        if pos + i + 1 >= n_tok:
            accepted += 1               # a draft was consumed matching
    return RowAdvance(emitted=emitted, advanced=advanced, drafted=drafted,
                      accepted=accepted, rejected=drafted - accepted,
                      done=done)


def speculative_generate(step_fn: Callable[[List[int]], np.ndarray],
                         prompt: Sequence[int], max_new: int, *,
                         k: int = 4,
                         draft_fn: Optional[Callable[[List[int], int],
                                                     Sequence[int]]] = None,
                         table: Optional[CacheTable] = None,
                         max_seq: int = 1 << 30
                         ) -> Tuple[List[int], Dict[str, int]]:
    """Reference speculative driver over a stateless next-token oracle
    (``step_fn(context) -> logits``), for conformance against the plain
    sequential greedy loop — including fixed-point oracles (native int8)
    where the engine's KV path does not apply.  Returns the generated
    tokens (bit-identical to sequential greedy by the exact-match
    invariant) plus drafted/accepted/rejected/rounds counters."""
    if k > 0 and draft_fn is None and table is None:
        table = CacheTable()
    toks = [int(t) for t in prompt]
    plen = len(toks)
    stats = {"drafted": 0, "accepted": 0, "rejected": 0, "rounds": 0}
    observed = 0
    while len(toks) - plen < max_new and len(toks) < max_seq:
        if table is not None:
            table.observe(toks, start=observed)
            observed = len(toks)
        pos = len(toks) - 1
        if k > 0:
            drafts = (list(draft_fn(toks, k)) if draft_fn is not None
                      else table.draft(toks, k))[:k]
        else:
            drafts = []
        inputs = [toks[-1]] + [int(d) for d in drafts]
        greedy: List[int] = []
        ctx = list(toks)
        for i, t in enumerate(inputs):
            if i > 0:
                ctx = ctx + [int(t)]
            greedy.append(int(np.argmax(np.asarray(step_fn(ctx)))))
        adv = accept_chunk(inputs, greedy, tokens=toks, plen=plen, pos=pos,
                           max_new=max_new, max_seq=max_seq)
        toks.extend(adv.emitted)
        stats["drafted"] += adv.drafted
        stats["accepted"] += adv.accepted
        stats["rejected"] += adv.rejected
        stats["rounds"] += 1
        if adv.done:
            break
    return toks[plen:], stats


# ---------------------------------------------------------------------------
# the engine-side decoder: one jit trace each for draft and verify


class SpeculativeDecoder:
    """Executors and counters for one serving key's speculative rounds.

    Owns the verify executor (ONE jit trace of ``decode_steps`` over the
    fixed ``[max_batch, k+1]`` chunk shape) and, for model drafts, the
    draft executor (ONE trace of ``decode_step`` on the cheap schedule).
    The KV cache stays owned by the keyed decoder — ``round`` threads it
    through draft steps and the verify pass and hands it back."""

    def __init__(self, cfg: ModelConfig, key: str,
                 schedule: Optional[KernelSchedule], spec: SpecConfig, *,
                 max_batch: int, max_seq: int, cache_dtype: str,
                 params: Optional[Dict] = None,
                 compile_cache: Optional[CompileCache] = None):
        if spec.k < 1:
            raise ValueError("SpeculativeDecoder needs k >= 1 "
                             "(k=0 means speculation is disabled)")
        self.cfg = cfg
        self.key = key
        self.schedule = schedule
        self.spec = spec
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.verify_traces = 0
        self.draft_traces = 0
        self.drafted = 0
        self.accepted = 0
        self.rejected = 0
        self.rounds = 0
        scheduled = decode_schedulable(cfg) and params is not None
        self.packed = (pack_decode_params(cfg, params, schedule)
                       if scheduled and schedule is not None else None)
        self.table = (CacheTable(spec.ngram_n, spec.capacity, spec.lru_size)
                      if spec.draft is None else None)
        self.draft_packed = (pack_decode_params(cfg, params, spec.draft)
                             if scheduled and spec.draft is not None else None)
        cache = compile_cache if compile_cache is not None else CompileCache()

        def verify(params, kv, tokens, pos, packed=None):
            self.verify_traces += 1     # cold lower/compile only
            return decode_steps(cfg, params, kv, tokens, pos,
                                schedule=schedule, packed=packed)

        meta = {"kind": "lm_decode_steps", "cfg": repr(cfg),
                "max_batch": max_batch, "max_seq": max_seq,
                "cache_dtype": cache_dtype, "chunk": spec.k + 1,
                "spec": spec.key_token(), **cache_meta(schedule, None)}
        self._verify = CachedExecutor(
            jax.jit(verify, donate_argnums=(1,)), cache, key, meta,
            name_hint=f"lmverify-{key}")

        self._draft = None
        if spec.draft is not None:
            def draft_step(params, kv, tokens, pos, packed=None):
                self.draft_traces += 1
                return decode_step(cfg, params, kv, tokens, pos,
                                   schedule=spec.draft, packed=packed)

            dmeta = {"kind": "lm_draft_step", "cfg": repr(cfg),
                     "max_batch": max_batch, "max_seq": max_seq,
                     "cache_dtype": cache_dtype, "spec": spec.key_token(),
                     **cache_meta(spec.draft, None)}
            self._draft = CachedExecutor(
                jax.jit(draft_step), cache, key, dmeta,
                name_hint=f"lmdraft-{key}")

        self._trim = (jax.jit(kv_trim, donate_argnums=(0,))
                      if spec.trim else None)

    # -- warmup --------------------------------------------------------------

    def warm(self, params: Dict, kv: Dict) -> Dict[str, Dict]:
        """Make this key's verify (and draft) executables exist without
        executing anything — warm over a persistent cache, compile-and-
        store when cold.  Shapes match exactly what ``round`` calls."""
        pos = jax.ShapeDtypeStruct((self.max_batch,), jnp.int32)
        vtok = jax.ShapeDtypeStruct((self.max_batch, self.spec.k + 1),
                                    jnp.int32)
        args = (params, kv, vtok, pos)
        if self.packed is not None:
            args = args + (self.packed,)
        out = {"verify": self._verify.warm(*args)}
        if self._draft is not None:
            dtok = jax.ShapeDtypeStruct((self.max_batch, 1), jnp.int32)
            dargs = (params, kv, dtok, pos)
            if self.draft_packed is not None:
                dargs = dargs + (self.draft_packed,)
            out["draft"] = self._draft.warm(*dargs)
        return out

    # -- one speculative round ----------------------------------------------

    def round(self, params: Dict, kv: Dict,
              rows: Sequence[Optional[Tuple[Sequence[int], int, int]]]
              ) -> Tuple[Dict, np.ndarray, np.ndarray, float, bool]:
        """Draft + verify one chunk for every row.  ``rows[b]`` is
        ``(tokens, prompt_len, pos)`` for an active slot, None otherwise.
        Returns ``(kv, chunk [B,S], greedy [B,S], wall_s, traced)`` —
        the caller runs :func:`accept_chunk` per row and applies the
        advances; ``traced`` flags a round that paid a trace/compile
        (excluded from steady-state tokens/s)."""
        B, S = self.max_batch, self.spec.k + 1
        chunk = np.zeros((B, S), np.int32)
        posv = np.zeros((B,), np.int32)
        known = np.full((B,), S, np.int32)      # inactive rows: no drafts
        t0 = time.perf_counter()
        traces0 = self.verify_traces + self.draft_traces
        for b, row in enumerate(rows):
            if row is None:
                continue
            toks, _plen, pos = row
            posv[b] = pos
            nk = min(S, len(toks) - pos)        # known (non-draft) prefix
            chunk[b, :nk] = [int(t) for t in toks[pos:pos + nk]]
            known[b] = nk
        if self.table is not None:
            for b, row in enumerate(rows):
                if row is None or known[b] >= S:
                    continue
                toks, _plen, _pos = row
                nk = int(known[b])
                prefix = [int(t) for t in toks[:int(posv[b]) + nk]]
                chunk[b, nk:] = self.table.draft(prefix, S - nk)
        elif self._draft is not None and int(known.min()) < S:
            for i in range(1, S):
                step_pos = posv + (i - 1)
                args = (params, kv, jnp.asarray(chunk[:, i - 1:i]),
                        jnp.asarray(step_pos))
                if self.draft_packed is not None:
                    args = args + (self.draft_packed,)
                dlog, kv = self._draft(*args)
                need = known <= i               # rows drafting position i
                if need.any():
                    nxt = np.asarray(jnp.argmax(dlog[:, 0], axis=-1))
                    chunk[:, i] = np.where(need, nxt.astype(np.int32),
                                           chunk[:, i])
        args = (params, kv, jnp.asarray(chunk), jnp.asarray(posv))
        if self.packed is not None:
            args = args + (self.packed,)
        logits, kv = self._verify(*args)
        greedy = np.asarray(jnp.argmax(logits, axis=-1))
        wall = time.perf_counter() - t0
        traced = (self.verify_traces + self.draft_traces) != traces0
        self.rounds += 1
        return kv, chunk, greedy, wall, traced

    def trim(self, kv: Dict, keep: np.ndarray) -> Dict:
        """Optional post-round rollback to the accepted frontier."""
        if self._trim is None:
            return kv
        return self._trim(kv, jnp.asarray(keep.astype(np.int32)))

    @property
    def accept_rate(self) -> Optional[float]:
        return (self.accepted / self.drafted) if self.drafted else None

    def report_row(self) -> Dict[str, object]:
        return {"k": self.spec.k,
                "draft": (None if self.spec.draft is None
                          else self.spec.draft.key()),
                "ngram_n": self.spec.ngram_n if self.spec.draft is None
                else None,
                "trim": self.spec.trim,
                "rounds": self.rounds,
                "drafted": self.drafted,
                "accepted": self.accepted,
                "rejected": self.rejected,
                "accept_rate": self.accept_rate,
                "verify_traces": self.verify_traces,
                "draft_traces": self.draft_traces,
                "table_hits": self.table.hits if self.table else None,
                "table_misses": self.table.misses if self.table else None}
