"""Schedule-hash-aware router: health checks, retry/hedge/failover, and
exactly-once accounting over a :class:`~repro.serving.replica.ReplicaPool`.

The paper's designs run inside trigger farms where throughput comes from
many identical boards behind a dispatcher and the system must keep
answering when one of them stalls or dies.  This module is that dispatcher:

  * **Placement** — requests land on a replica by consistent hash of their
    ``schedule_key`` (a hash ring with virtual nodes): same key, same
    replica — the co-batching/jit-residency locality the schedule-keyed
    engines are built on — and when a replica dies its keys re-place to
    the next ring node while every other key stays put.
  * **Health** — per-replica sliding-window error rate + consecutive-
    failure streak + latency EWMA; heartbeat probes re-admit a retired
    replica after ``probe_successes`` consecutive successes.
  * **The robustness ladder** — per-request timeout (a straggler's answer
    is discarded, never surfaced) -> retry with exponential backoff +
    deterministic jitter on a DIFFERENT replica -> optional hedged
    duplicate for tail latency (first answer wins, the loser is cancelled
    and de-duplicated by request id) -> mark-unhealthy + drain + re-place
    keys -> re-admit after probe successes.
  * **Exactly-once accounting** — every submitted request reaches exactly
    one terminal state (``answered | failed | shed``) across any
    interleaving of crashes, retries and hedges;
    :meth:`Router.verify_router_accounting` asserts the exact sum
    ``submitted == answered + failed + shed + in_flight`` per key, that
    the counters agree with the request objects themselves, that hedges
    reconcile (``hedges == hedge_wins + hedge_cancelled``) and that an
    answered request surfaced exactly ONE result.

Outputs stay bit-identical to a single-replica engine for every surviving
request: replicas are identically configured engines over the same params,
and the serving call is the conformance-enforced batch-1 fast path — which
replica answers never changes WHAT is answered.

Two clock domains, as in :mod:`~repro.serving.streaming`: real inference
executes on the host, while service times (and injected straggler stalls)
live in the simulated clock — timeouts, hedges and the per-replica
occupancy model are projections over analytical service times, so a chaos
replay over a :class:`~repro.serving.faults.VirtualClock` is exactly
reproducible.
"""

from __future__ import annotations

import bisect
import hashlib
import itertools
import random
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config import FixedPointConfig
from repro.core.hls import estimate_schedule
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.serving.engine import EngineClosedError, RNNServingEngine
from repro.serving.replica import EngineReplica, ReplicaPool

#: request terminal states (pending is the only transient one)
TERMINAL_STATES = ("answered", "failed", "shed")

#: attempt outcomes; "cancelled" marks a hedged duplicate whose (identical)
#: answer was discarded during de-duplication
ATTEMPT_OUTCOMES = ("ok", "error", "timeout", "cancelled")


class ReplicaTimeout(RuntimeError):
    """An attempt whose simulated service exceeded the per-request timeout;
    its answer (if any) is discarded and the request retried elsewhere."""


def _stable_hash(s: str) -> int:
    """Platform/process-stable 64-bit hash (Python's ``hash`` is salted;
    placement must not move between runs)."""
    return int.from_bytes(hashlib.sha256(s.encode()).digest()[:8], "big")


class HashRing:
    """Consistent hash ring with virtual nodes.

    ``ordered(key)`` returns every replica id exactly once, in ring order
    starting from the key's position — index 0 is the primary placement,
    the rest are the failover order.  Removing a node (skipping it while
    walking) re-places only the keys that mapped to it; every other key's
    placement is untouched — the property that makes failover cheap for
    schedule-keyed jit/residency state.
    """

    def __init__(self, ids: Sequence[str], vnodes: int = 32):
        if not ids:
            raise ValueError("hash ring needs at least one node")
        if vnodes < 1:
            raise ValueError(f"vnodes must be >= 1: {vnodes}")
        self.vnodes = vnodes
        pts = []
        for rid in ids:
            for v in range(vnodes):
                pts.append((_stable_hash(f"{rid}#{v}"), rid))
        pts.sort()
        self._points = [p for p, _ in pts]
        self._ids = [rid for _, rid in pts]

    def ordered(self, key: str) -> List[str]:
        start = bisect.bisect_left(self._points, _stable_hash(key))
        seen: List[str] = []
        n = len(self._ids)
        for off in range(n):
            rid = self._ids[(start + off) % n]
            if rid not in seen:
                seen.append(rid)
        return seen


@dataclass(frozen=True)
class RouterPolicy:
    """Every knob of the robustness ladder, in one frozen record.

    timeout_s            per-attempt budget in the SIMULATED clock domain:
                         an attempt whose (analytical + injected-stall)
                         service exceeds it is a timeout — answer
                         discarded, retried elsewhere
    max_retries          extra attempts after the primary (each on a
                         different replica while one is available)
    backoff_base_s       first retry delay; grows by ``backoff_mult`` per
                         attempt, +/- ``jitter`` fraction (seeded PRNG —
                         deterministic replay)
    hedge_after_s        None = hedging off; else a successful primary
                         slower than this fires ONE duplicate on another
                         replica — first answer wins, loser cancelled
    detect_s             how long a crashed call takes to detect (refused
                         connection ~ 0; timeouts detect at ``timeout_s``)
    window               sliding-window size for the error-rate score
    min_window           samples required before the rate can retire
    max_error_rate       window error rate beyond which a replica retires
    consecutive_failures retire immediately after this many in a row
    probe_successes      consecutive heartbeat OKs to re-admit
    probe_interval_s     simulated seconds between automatic probe sweeps
    vnodes               virtual nodes per replica on the hash ring
    seed                 jitter PRNG seed
    """

    timeout_s: float = 0.050
    max_retries: int = 2
    backoff_base_s: float = 1e-4
    backoff_mult: float = 2.0
    jitter: float = 0.25
    hedge_after_s: Optional[float] = None
    detect_s: float = 0.0
    window: int = 32
    min_window: int = 4
    max_error_rate: float = 0.5
    consecutive_failures: int = 3
    probe_successes: int = 2
    probe_interval_s: float = 0.010
    vnodes: int = 32
    seed: int = 0

    def __post_init__(self):
        if self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0: {self.timeout_s}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0: {self.max_retries}")
        if not 0 <= self.jitter < 1:
            raise ValueError(f"jitter must be in [0, 1): {self.jitter}")
        if self.consecutive_failures < 1:
            raise ValueError("consecutive_failures must be >= 1")
        if self.probe_successes < 1:
            raise ValueError("probe_successes must be >= 1")
        if not 0 < self.max_error_rate <= 1:
            raise ValueError(
                f"max_error_rate must be in (0, 1]: {self.max_error_rate}")


@dataclass
class Attempt:
    """One try of one request on one replica (primary, retry, or hedge)."""

    replica_id: str
    kind: str                      # primary | retry | hedge
    t_start_s: float
    service_s: float = 0.0         # simulated service incl. injected stall
    done_s: float = 0.0            # completion (ok) or detection (error)
    outcome: str = "ok"
    error: Optional[BaseException] = None
    result: Any = None             # surfaced only on the winning attempt


@dataclass
class RoutedRequest:
    """One request moving through the router; ends in exactly one of
    ``answered | failed | shed`` (``attempts`` is the full audit trail —
    every replica it touched, every timeout, the cancelled hedge loser)."""

    payload: Any
    req_id: int
    key: str
    schedule: Optional[KernelSchedule]
    fp: Optional[FixedPointConfig]
    arrival_s: float
    status: str = "pending"
    result: Any = None
    error: Optional[BaseException] = None
    shed_reason: Optional[str] = None
    done_s: Optional[float] = None
    winner: Optional[str] = None   # replica id that answered
    hedged: bool = False
    attempts: List[Attempt] = field(default_factory=list)

    @property
    def latency_s(self) -> Optional[float]:
        return None if self.done_s is None else self.done_s - self.arrival_s

    @property
    def retries(self) -> int:
        return sum(1 for a in self.attempts if a.kind == "retry")


@dataclass
class RouterCounts:
    """Per-schedule-key exact-sum counters (the accounting invariant)."""

    submitted: int = 0
    answered: int = 0
    failed: int = 0
    shed: int = 0
    retries: int = 0
    timeouts: int = 0
    hedges: int = 0
    hedge_wins: int = 0
    hedge_cancelled: int = 0
    duplicates: int = 0            # discarded duplicate OK answers
    re_placements: int = 0         # primary placement moved (failover)

    def as_dict(self) -> Dict[str, int]:
        return {k: getattr(self, k) for k in (
            "submitted", "answered", "failed", "shed", "retries", "timeouts",
            "hedges", "hedge_wins", "hedge_cancelled", "duplicates",
            "re_placements")}


@dataclass
class ReplicaHealth:
    """Sliding-window health state the router keeps per replica."""

    window: Deque[bool] = field(default_factory=lambda: deque(maxlen=32))
    healthy: bool = True
    consecutive_errors: int = 0
    probe_oks: int = 0
    latency_ewma_s: Optional[float] = None
    retired: int = 0               # times marked unhealthy
    readmitted: int = 0

    def record(self, ok: bool, latency_s: Optional[float] = None) -> None:
        self.window.append(ok)
        if ok:
            self.consecutive_errors = 0
            if latency_s is not None:
                self.latency_ewma_s = (
                    latency_s if self.latency_ewma_s is None
                    else 0.7 * self.latency_ewma_s + 0.3 * latency_s)
        else:
            self.consecutive_errors += 1

    def error_rate(self) -> float:
        if not self.window:
            return 0.0
        return 1.0 - sum(self.window) / len(self.window)

    def report_row(self) -> Dict:
        return {"healthy": self.healthy,
                "error_rate": self.error_rate(),
                "consecutive_errors": self.consecutive_errors,
                "latency_ewma_s": self.latency_ewma_s,
                "window": len(self.window),
                "probe_oks": self.probe_oks,
                "retired": self.retired,
                "readmitted": self.readmitted}


class Router:
    """The dispatcher over a :class:`ReplicaPool` — see the module doc.

    ``submit(x, schedule=..., now=...)`` runs one request through the full
    ladder synchronously and returns it in a terminal state;
    ``submit(..., defer=True)`` queues it (``in_flight``) for a later
    :meth:`flush` — the window in which a replica can die with requests
    pending, which the chaos suite exploits.  All timing accepts an
    explicit ``now`` (simulated seconds) for deterministic replay.
    """

    def __init__(self, pool: ReplicaPool, *,
                 policy: Optional[RouterPolicy] = None,
                 clock=None, clock_mhz: float = 200.0):
        self.pool = pool
        self.policy = policy if policy is not None else RouterPolicy()
        self.clock_mhz = clock_mhz
        self._clock = clock if clock is not None else time.perf_counter
        self._rng = random.Random(self.policy.seed)
        self._ring = HashRing(pool.ids(), vnodes=self.policy.vnodes)
        self._health: Dict[str, ReplicaHealth] = {
            rid: ReplicaHealth(window=deque(maxlen=self.policy.window))
            for rid in pool.ids()}
        self._server_free: Dict[str, float] = {rid: float("-inf")
                                               for rid in pool.ids()}
        self._placements: Dict[str, str] = {}     # key -> last primary id
        self._service_cache: Dict[str, Tuple[float, float]] = {}
        self._ids = itertools.count()
        self._last_now = float("-inf")
        self._last_probe_s = float("-inf")
        self._pending: List[RoutedRequest] = []
        self._requests: List[RoutedRequest] = []
        self.counts: Dict[str, RouterCounts] = {}
        self.events: List[str] = []               # retire/readmit audit log
        self._closed = False

    # -- clocks & pricing ----------------------------------------------------

    def _now(self, now: Optional[float] = None) -> float:
        t = self._clock() if now is None else now
        if t < self._last_now:
            t = self._last_now
        self._last_now = t
        return t

    def _price(self, key: str, schedule: KernelSchedule,
               fp: Optional[FixedPointConfig]) -> Tuple[float, float]:
        """(service_s, occupancy_s) of one event under this key's schedule
        — the analytical clock domain, memoized per key."""
        pair = self._service_cache.get(key)
        if pair is None:
            est = estimate_schedule(schedule, self.reference_engine.cfg.rnn,
                                    fp)
            pair = (est.service_s(self.clock_mhz), est.ii_s(self.clock_mhz))
            self._service_cache[key] = pair
        return pair

    @property
    def reference_engine(self) -> RNNServingEngine:
        return self.pool.reference.engine

    # -- health & placement --------------------------------------------------

    def healthy_ids(self) -> List[str]:
        return [rid for rid in self.pool.ids() if self._health[rid].healthy]

    def healthy_count(self) -> int:
        return len(self.healthy_ids())

    def place(self, key: str, exclude: Sequence[str] = ()
              ) -> Optional[EngineReplica]:
        """The first healthy, non-excluded replica in the key's ring
        order; None when nothing qualifies."""
        for rid in self._ring.ordered(key):
            if rid in exclude or not self._health[rid].healthy:
                continue
            return self.pool.get(rid)
        return None

    def _note_primary_placement(self, key: str, rid: str) -> None:
        prev = self._placements.get(key)
        if prev is not None and prev != rid:
            self._count(key).re_placements += 1
        self._placements[key] = rid

    def _retire(self, rep: EngineReplica) -> None:
        """Mark unhealthy, quiesce (drain — every queued request on that
        engine reaches a terminal state), and let the ring re-place its
        keys.  The replica stays OPEN: a later probe streak re-admits it."""
        h = self._health[rep.replica_id]
        if not h.healthy:
            return
        h.healthy = False
        h.probe_oks = 0
        h.retired += 1
        self.events.append(f"retire:{rep.replica_id}")
        rep.drain()

    def _note_outcome(self, rep: EngineReplica, ok: bool,
                      latency_s: Optional[float] = None) -> None:
        h = self._health[rep.replica_id]
        h.record(ok, latency_s)
        if ok:
            return
        if (h.consecutive_errors >= self.policy.consecutive_failures
                or (len(h.window) >= self.policy.min_window
                    and h.error_rate() > self.policy.max_error_rate)):
            self._retire(rep)

    def probe(self, now: Optional[float] = None) -> Dict[str, bool]:
        """Heartbeat every UNHEALTHY replica once; ``probe_successes``
        consecutive OKs re-admit it to the ring (keys flow back via
        consistent hashing — no state to rebuild, the shared compile
        cache keeps it zero-warmup)."""
        t = self._now(now)
        self._last_probe_s = t
        out: Dict[str, bool] = {}
        for rep in self.pool:
            h = self._health[rep.replica_id]
            if h.healthy:
                continue
            try:
                stall = rep.heartbeat()
                ok = stall <= self.policy.timeout_s
            except Exception:
                ok = False
            out[rep.replica_id] = ok
            if not ok:
                h.probe_oks = 0
                continue
            h.probe_oks += 1
            if h.probe_oks >= self.policy.probe_successes:
                h.healthy = True
                h.probe_oks = 0
                h.consecutive_errors = 0
                h.window.clear()
                h.readmitted += 1
                self.events.append(f"readmit:{rep.replica_id}")
        return out

    def _maybe_probe(self, t: float) -> None:
        if t - self._last_probe_s >= self.policy.probe_interval_s:
            self.probe(now=t)

    # -- accounting ----------------------------------------------------------

    def _count(self, key: str) -> RouterCounts:
        return self.counts.setdefault(key, RouterCounts())

    def in_flight(self, key: Optional[str] = None) -> int:
        if key is None:
            return len(self._pending)
        return sum(1 for r in self._pending if r.key == key)

    def _answer(self, r: RoutedRequest, att: Attempt) -> None:
        if r.status != "pending":       # de-dup by request id: first wins
            self._count(r.key).duplicates += 1
            att.result = None
            att.outcome = "cancelled"
            return
        r.status = "answered"
        r.result = att.result
        r.winner = att.replica_id
        r.done_s = att.done_s
        self._count(r.key).answered += 1

    def _fail(self, r: RoutedRequest, e: BaseException, t: float) -> None:
        r.status = "failed"
        r.error = e
        r.done_s = t
        self._count(r.key).failed += 1

    def _shed(self, r: RoutedRequest, reason: str, t: float) -> None:
        r.status = "shed"
        r.shed_reason = reason
        r.done_s = t
        self._count(r.key).shed += 1

    # -- the attempt (one try on one replica) --------------------------------

    def _attempt(self, rep: EngineReplica, r: RoutedRequest,
                 t_queue: float, kind: str) -> Attempt:
        start = max(t_queue, self._server_free[rep.replica_id])
        att = Attempt(replica_id=rep.replica_id, kind=kind, t_start_s=start)
        r.attempts.append(att)
        try:
            out, stall = rep.predict(r.payload, schedule=r.schedule, fp=r.fp)
        except Exception as e:
            # crash-grade failure: detected ~immediately (refused call),
            # no server time occupied — the board is gone, not busy
            att.outcome = "error"
            att.error = e
            att.done_s = start + self.policy.detect_s
            self._note_outcome(rep, False)
            return att
        service, occupancy = self._price(r.key, *self._spec_of(r))
        att.service_s = service + stall
        self._server_free[rep.replica_id] = start + occupancy + stall
        if att.service_s > self.policy.timeout_s:
            # the answer exists but arrived past the budget: discard it —
            # surfacing it AND the retry's answer would double-answer
            att.outcome = "timeout"
            att.error = ReplicaTimeout(
                f"attempt on {rep.replica_id!r} took "
                f"{att.service_s * 1e6:.1f}us > timeout "
                f"{self.policy.timeout_s * 1e6:.1f}us")
            att.done_s = start + self.policy.timeout_s
            self._count(r.key).timeouts += 1
            self._note_outcome(rep, False)
        else:
            att.outcome = "ok"
            att.result = out
            att.done_s = start + att.service_s
            self._note_outcome(rep, True, att.service_s)
        return att

    def _spec_of(self, r: RoutedRequest
                 ) -> Tuple[KernelSchedule, Optional[FixedPointConfig]]:
        return self.reference_engine.resolve(r.schedule, r.fp)

    # -- the ladder (timeout -> retry -> hedge -> failover) ------------------

    def _serve_one(self, r: RoutedRequest, t: float) -> None:
        tried: List[str] = []
        t_cursor = t
        last_err: Optional[BaseException] = None
        for i in range(self.policy.max_retries + 1):
            rep = self.place(r.key, exclude=tried)
            if rep is None:
                # every untried replica is down; fall back to retrying an
                # already-tried one (it may have recovered) before giving up
                rep = self.place(r.key)
            if rep is None:
                self._shed(r, "no_healthy_replica", t_cursor)
                return
            if i == 0:
                self._note_primary_placement(r.key, rep.replica_id)
            else:
                self._count(r.key).retries += 1
            att = self._attempt(rep, r, t_cursor, "primary" if i == 0
                                else "retry")
            if att.outcome == "ok":
                win = self._maybe_hedge(r, att, tried)
                self._answer(r, win)
                return
            last_err = att.error
            tried.append(rep.replica_id)
            backoff = (self.policy.backoff_base_s
                       * self.policy.backoff_mult ** i)
            backoff *= 1.0 + self.policy.jitter * (2 * self._rng.random() - 1)
            t_cursor = att.done_s + backoff
        self._fail(r, last_err if last_err is not None else RuntimeError(
            "all attempts failed"), t_cursor)

    def _maybe_hedge(self, r: RoutedRequest, att: Attempt,
                     tried: List[str]) -> Attempt:
        """A successful-but-slow primary fires one duplicate on a different
        replica; the earlier simulated completion wins, the loser is
        cancelled and its (identical) answer discarded — de-duplicated by
        request id, counted in ``duplicates``."""
        p = self.policy
        if p.hedge_after_s is None or att.service_s <= p.hedge_after_s:
            return att
        other = self.place(r.key, exclude=list(tried) + [att.replica_id])
        if other is None:
            return att
        c = self._count(r.key)
        c.hedges += 1
        r.hedged = True
        hatt = self._attempt(other, r, att.t_start_s + p.hedge_after_s,
                             "hedge")
        if hatt.outcome == "ok" and hatt.done_s < att.done_s:
            c.hedge_wins += 1
            c.duplicates += 1
            att.outcome = "cancelled"
            att.result = None
            return hatt
        c.hedge_cancelled += 1
        if hatt.outcome == "ok":
            c.duplicates += 1
            hatt.outcome = "cancelled"
            hatt.result = None
        return att

    # -- the serving surface -------------------------------------------------

    def submit(self, x: np.ndarray,
               schedule: Optional[KernelSchedule] = None,
               fp: Optional[FixedPointConfig] = None,
               now: Optional[float] = None,
               defer: bool = False) -> RoutedRequest:
        """Route one request.  Immediate mode (default) runs the full
        ladder and returns the request in a terminal state; ``defer=True``
        leaves it pending (``in_flight``) until :meth:`flush`."""
        if self._closed:
            raise EngineClosedError("Router")
        t = self._now(now)
        self._maybe_probe(t)
        sched, fpr = self.reference_engine.resolve(schedule, fp)
        key = schedule_key(sched, fpr)
        r = RoutedRequest(payload=x, req_id=next(self._ids), key=key,
                          schedule=sched, fp=fpr, arrival_s=t)
        self._requests.append(r)
        self._count(key).submitted += 1
        if defer:
            self._pending.append(r)
            return r
        self._serve_one(r, t)
        return r

    def flush(self, now: Optional[float] = None) -> List[RoutedRequest]:
        """Serve every deferred request (FIFO).  Replicas that died since
        ``submit`` are simply failed over — the pending window is exactly
        where the chaos suite kills them."""
        t = self._now(now)
        batch, self._pending = self._pending, []
        for r in batch:
            self._serve_one(r, max(t, r.arrival_s))
        return batch

    def serve(self, payloads, schedules=None, fps=None,
              now: Optional[float] = None) -> List[RoutedRequest]:
        """Convenience: submit a stream (parallel lists) immediately."""
        n = len(payloads)
        schedules = schedules if schedules is not None else [None] * n
        fps = fps if fps is not None else [None] * n
        return [self.submit(x, schedule=s, fp=f, now=now)
                for x, s, f in zip(payloads, schedules, fps)]

    # -- lifecycle -----------------------------------------------------------

    @property
    def closed(self) -> bool:
        return self._closed

    def drain(self, now: Optional[float] = None) -> List[RoutedRequest]:
        """Flush deferred requests and quiesce every replica engine."""
        done = self.flush(now=now)
        self.pool.drain_all()
        return done

    def close(self, now: Optional[float] = None) -> List[RoutedRequest]:
        """Drain, close every replica, refuse new submits.  Idempotent."""
        if self._closed:
            return []
        done = self.drain(now=now)
        self.pool.close_all()
        self._closed = True
        return done

    # -- invariants & reporting ----------------------------------------------

    def verify_router_accounting(self) -> Dict[str, Dict[str, int]]:
        """Assert the exact-sum invariant per key AND that the counters
        agree with the request objects: ``submitted == answered + failed +
        shed + in_flight``; terminal states are exclusive; an answered
        request surfaced exactly one result (hedged duplicates cancelled
        and counted); hedges reconcile.  Raises ``AssertionError`` naming
        the broken key; returns the per-key counters on success."""
        by_key: Dict[str, Dict[str, int]] = {}
        for r in self._requests:
            d = by_key.setdefault(r.key, {"answered": 0, "failed": 0,
                                          "shed": 0, "pending": 0})
            d[r.status if r.status in TERMINAL_STATES else "pending"] += 1
            ok_surfaced = sum(1 for a in r.attempts if a.outcome == "ok")
            want = 1 if r.status == "answered" else 0
            if ok_surfaced != want:
                raise AssertionError(
                    f"request {r.req_id} ({r.status}) surfaced "
                    f"{ok_surfaced} results, expected {want} — duplicate "
                    f"or lost answer")
            if r.status == "answered" and (r.result is None
                                           or r.error is not None):
                raise AssertionError(
                    f"request {r.req_id} answered without a clean result")
            if r.status == "failed" and r.error is None:
                raise AssertionError(
                    f"request {r.req_id} failed without an error attached")
            if r.status == "shed" and r.shed_reason is None:
                raise AssertionError(
                    f"request {r.req_id} shed without a reason")
        out: Dict[str, Dict[str, int]] = {}
        for key, c in self.counts.items():
            infl = self.in_flight(key)
            accounted = c.answered + c.failed + c.shed + infl
            if accounted != c.submitted:
                raise AssertionError(
                    f"router accounting broken for {key!r}: submitted="
                    f"{c.submitted} but answered={c.answered} + failed="
                    f"{c.failed} + shed={c.shed} + in_flight={infl} = "
                    f"{accounted}")
            obj = by_key.get(key, {"answered": 0, "failed": 0, "shed": 0,
                                   "pending": 0})
            for st in ("answered", "failed", "shed"):
                if obj[st] != getattr(c, st):
                    raise AssertionError(
                        f"counter/object disagreement for {key!r}: "
                        f"{st} counter={getattr(c, st)} but "
                        f"{obj[st]} request objects")
            if obj["pending"] != infl:
                raise AssertionError(
                    f"in_flight disagreement for {key!r}: {infl} pending "
                    f"in the queue, {obj['pending']} request objects")
            if c.hedges != c.hedge_wins + c.hedge_cancelled:
                raise AssertionError(
                    f"hedge reconciliation broken for {key!r}: hedges="
                    f"{c.hedges} != wins={c.hedge_wins} + cancelled="
                    f"{c.hedge_cancelled}")
            out[key] = {**c.as_dict(), "in_flight": infl}
        return out

    def router_report(self) -> Dict[str, Dict]:
        """Per-replica health + serving rows (each replica's own
        ``serve_report`` aggregated underneath) and per-key routing
        counters with current placement — the farm-level two-column
        table."""
        replicas: Dict[str, Dict] = {}
        for rep in self.pool:
            row = {**rep.report_row(),
                   **self._health[rep.replica_id].report_row()}
            served = 0.0
            for key, srow in rep.engine.serve_report(self.clock_mhz).items():
                served += srow["measured"]["served"]
                fast = srow.get("fast_path")
                if fast is not None:
                    served += fast["served"]
            row["engine_served"] = served
            replicas[rep.replica_id] = row
        keys = {key: {**c.as_dict(), "in_flight": self.in_flight(key),
                      "placement": self._placements.get(key)}
                for key, c in self.counts.items()}
        return {"replicas": replicas, "keys": keys,
                "pool": {"n": len(self.pool),
                         "healthy": self.healthy_count(),
                         "events": list(self.events)}}


def format_router_report(router: Router) -> str:
    """Render router_report() as the per-replica / per-key tables."""
    rep = router.router_report()
    lines = [f"router: {rep['pool']['healthy']}/{rep['pool']['n']} healthy, "
             f"events: {', '.join(rep['pool']['events']) or 'none'}",
             "",
             f"{'replica':10s} {'ok':>3s} {'calls':>6s} {'errs':>5s} "
             f"{'err%':>5s} {'ewma':>9s} {'ret/adm':>7s}"]
    for rid, row in rep["replicas"].items():
        ewma = row["latency_ewma_s"]
        lines.append(
            f"{rid:10s} {'y' if row['healthy'] else 'N':>3s} "
            f"{row['calls']:6d} {row['errors']:5d} "
            f"{row['error_rate']:4.0%} "
            f"{'' if ewma is None else f'{ewma * 1e6:7.2f}us':>9s} "
            f"{row['retired']}/{row['readmitted']:>3d}")
    lines += ["", f"{'schedule key':38s} {'subm':>5s} {'ans':>5s} "
                  f"{'fail':>4s} {'shed':>4s} {'rtry':>4s} {'hdg':>4s} "
                  f"{'dup':>4s} {'repl':>4s} {'at':>4s}"]
    for key, c in rep["keys"].items():
        lines.append(
            f"{key:38s} {c['submitted']:5d} {c['answered']:5d} "
            f"{c['failed']:4d} {c['shed']:4d} {c['retries']:4d} "
            f"{c['hedges']:4d} {c['duplicates']:4d} "
            f"{c['re_placements']:4d} {str(c['placement']):>4s}")
    return "\n".join(lines)
