"""Reduced configs for smoke tests: same family, tiny dims."""

from __future__ import annotations

import dataclasses

from repro.config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig


def tiny_config(full: ModelConfig) -> ModelConfig:
    """Shrink an assigned arch to CPU-testable size, keeping its family,
    attention grouping structure, MLP type, and block pattern."""
    kw = dict(
        n_layers=min(full.n_layers, 2 if not full.rglru else 4),
        d_model=64,
        vocab_size=256,
        d_ff=128,
        param_dtype="float32",
        compute_dtype="float32",
        grad_accum=1,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        remat="none",
    )
    if full.n_heads:
        ratio = max(full.n_heads // max(full.n_kv_heads, 1), 1)
        n_heads = 4
        kw.update(n_heads=n_heads,
                  n_kv_heads=max(n_heads // ratio, 1),
                  head_dim=16)
    if full.moe is not None:
        kw["moe"] = dataclasses.replace(
            full.moe, n_experts=8,
            top_k=min(full.moe.top_k, 2),
            n_shared_experts=min(full.moe.n_shared_experts, 1),
            d_ff_expert=32)
        kw["d_ff"] = 32
    if full.ssm is not None:
        kw["ssm"] = dataclasses.replace(full.ssm, d_state=16, head_dim=16,
                                        chunk_size=8)
    if full.rglru is not None:
        kw["rglru"] = dataclasses.replace(full.rglru, lru_width=64, window=16)
        kw["n_layers"] = 4  # one super-block + 1 remainder
    if full.enc_dec:
        kw.update(n_encoder_layers=2, n_decoder_layers=2, n_layers=2,
                  max_encoder_len=32)
    if full.frontend == "vision":
        kw["n_frontend_tokens"] = 8
    if full.rnn is not None:
        return full  # paper taggers are already tiny
    return dataclasses.replace(full, **kw)
