"""Test support: reduced smoke-test configs + the golden-model conformance
harness for the kernel scheduling layer.

The conformance harness is the safety net of the reuse-factor refactor:
every (kernel x mode x reuse_factor x dtype) cell must reproduce the XLA
``lax.scan`` reference (kernels/ref.py) within dtype tolerance.  Tests and
benchmarks both drive it via :func:`assert_schedule_conformance`.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import ModelConfig, MoEConfig, RGLRUConfig, SSMConfig
from repro.kernels.schedule import KernelSchedule


def tiny_config(full: ModelConfig) -> ModelConfig:
    """Shrink an assigned arch to CPU-testable size, keeping its family,
    attention grouping structure, MLP type, and block pattern."""
    kw = dict(
        n_layers=min(full.n_layers, 2 if not full.rglru else 4),
        d_model=64,
        vocab_size=256,
        d_ff=128,
        param_dtype="float32",
        compute_dtype="float32",
        grad_accum=1,
        attn_chunk_q=32,
        attn_chunk_kv=32,
        remat="none",
    )
    if full.n_heads:
        ratio = max(full.n_heads // max(full.n_kv_heads, 1), 1)
        n_heads = 4
        kw.update(n_heads=n_heads,
                  n_kv_heads=max(n_heads // ratio, 1),
                  head_dim=16)
    if full.moe is not None:
        kw["moe"] = dataclasses.replace(
            full.moe, n_experts=8,
            top_k=min(full.moe.top_k, 2),
            n_shared_experts=min(full.moe.n_shared_experts, 1),
            d_ff_expert=32)
        kw["d_ff"] = 32
    if full.ssm is not None:
        kw["ssm"] = dataclasses.replace(full.ssm, d_state=16, head_dim=16,
                                        chunk_size=8)
    if full.rglru is not None:
        kw["rglru"] = dataclasses.replace(full.rglru, lru_width=64, window=16)
        kw["n_layers"] = 4  # one super-block + 1 remainder
    if full.enc_dec:
        kw.update(n_encoder_layers=2, n_decoder_layers=2, n_layers=2,
                  max_encoder_len=32)
    if full.frontend == "vision":
        kw["n_frontend_tokens"] = 8
    if full.rnn is not None:
        return full  # paper taggers are already tiny
    return dataclasses.replace(full, **kw)


# ---------------------------------------------------------------------------
# Golden-model conformance harness for KernelSchedule
# ---------------------------------------------------------------------------

# default absolute/relative tolerance per dtype: fp32 accumulation error over
# a scan; bf16 inputs round at ~2^-8
CONFORMANCE_TOL: Dict[str, float] = {"float32": 3e-5, "bfloat16": 2e-2}


def make_kernel_inputs(kernel: str, *, B: int = 4, T: int = 12, F: int = 6,
                       H: int = 20, M: int = 32, K: int = 64, N: int = 48,
                       dtype: str = "float32", seed: int = 0
                       ) -> Tuple:
    """Deterministic inputs for one scheduled kernel.

    lstm/gru use (B, T, F, H); rglru uses (B, T, H) with H as the width;
    reuse_matmul uses (M, K, N).
    """
    import jax.numpy as jnp

    rng = np.random.RandomState(seed)
    dt = jnp.dtype(dtype)
    if kernel in ("lstm", "gru"):
        g = 4 if kernel == "lstm" else 3
        xs = jnp.asarray(rng.randn(B, T, F), dtype=dt)
        W = jnp.asarray(rng.randn(F, g * H) * 0.3, dtype=dt)
        U = jnp.asarray(rng.randn(H, g * H) * 0.3, dtype=dt)
        bshape = (g * H,) if kernel == "lstm" else (2, g * H)
        b = jnp.asarray(rng.randn(*bshape) * 0.1, dtype=dt)
        return xs, W, U, b
    if kernel == "rglru":
        a = jnp.asarray(np.exp(-np.abs(rng.randn(B, T, H))), dtype=dt)
        bx = jnp.asarray(rng.randn(B, T, H), dtype=dt)
        return a, bx
    if kernel == "reuse_matmul":
        x = jnp.asarray(rng.randn(M, K), dtype=dt)
        w = jnp.asarray(rng.randn(K, N), dtype=dt)
        return x, w
    raise KeyError(f"unknown kernel {kernel!r}")


def assert_schedule_conformance(kernel: str, schedule: KernelSchedule, *,
                                dtype: str = "float32",
                                tol: Optional[float] = None,
                                seed: int = 0, **shape_kw) -> float:
    """Run one (kernel x schedule x dtype) cell against the XLA golden model.

    Returns the max abs error; raises AssertionError beyond tolerance.
    Shape kwargs (B, T, F, H, M, K, N) pass through to make_kernel_inputs —
    ragged batches and off-lane hidden sizes are legal, the scheduling layer
    owns the padding.
    """
    from repro.kernels import ops

    scheduled, golden = ops.SCHEDULED_KERNELS[kernel]
    inputs = make_kernel_inputs(kernel, dtype=dtype, seed=seed, **shape_kw)
    got = np.asarray(scheduled(*inputs, schedule=schedule), np.float32)
    want = np.asarray(golden(*inputs), np.float32)
    assert got.shape == want.shape, (kernel, schedule, got.shape, want.shape)
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    limit = CONFORMANCE_TOL[dtype] if tol is None else tol
    scale = max(1.0, float(np.max(np.abs(want)))) if want.size else 1.0
    assert err <= limit * scale, (
        f"{kernel} diverged from golden model under {schedule}: "
        f"max_err={err:.3e} > {limit * scale:.3e} (dtype={dtype}, "
        f"shapes={shape_kw})")
    return err


# ---------------------------------------------------------------------------
# Quantized golden models (numpy integer references) + conformance harness
# ---------------------------------------------------------------------------
#
# The native int8/int4 kernels (kernels/quantized.py) are verified against
# INDEPENDENT numpy references that re-implement each cell's hls4ml
# quantization points with integer matmuls (exact int32 accumulation, like
# the hardware) and f32 activations.  Inputs come PTQ'd (weights on the fp
# grid), under which native == emulation is bit-exact; the only legal
# divergence from the numpy golden is an activation landing a half-ulp away
# from a rounding tie (numpy's exp vs XLA's — one grid step), hence the
# default tolerance of 2 x fixed_point_error_bound = one grid step.

#: the configs the conformance suite pins for the native datapath:
#: ap_fixed<8,3> (int8 storage, scale 2^5) and ap_fixed<4,2> (nibble-packed)
def native_fp_configs():
    from repro.config import FixedPointConfig

    return {"int8": FixedPointConfig(8, 3), "int4": FixedPointConfig(4, 2)}


def _np_sigmoid(x):
    return (1.0 / (1.0 + np.exp(-x.astype(np.float32)))).astype(np.float32)


def _np_tanh(x):
    return np.tanh(x.astype(np.float32))


def _np_ints(x, fp):
    """On-grid f32 values -> integer grid indices (exact)."""
    return np.round(np.asarray(x, np.float64) * fp.scale).astype(np.int64)


def quantized_golden_lstm(xs, W, U, b, fp) -> np.ndarray:
    """Numpy integer reference of the quantized LSTM scan: int64 gate
    accumulators over PTQ'd weights, quantize_np at every datapath point of
    ``cells.lstm_cell_quantized``.  Returns the final hidden state."""
    from repro.core.quant.fixed_point import quantize_np

    q = lambda v: quantize_np(v, fp)                       # noqa: E731
    xs = np.asarray(xs, np.float32)
    Wq, Uq = _np_ints(q(np.asarray(W)), fp), _np_ints(q(np.asarray(U)), fp)
    bq = q(np.asarray(b))
    B, T, _ = xs.shape
    H = np.asarray(U).shape[0]
    inv2 = np.float32(1.0 / (fp.scale * fp.scale))
    h = np.zeros((B, H), np.float32)
    c = np.zeros((B, H), np.float32)
    for t in range(T):
        xi = _np_ints(q(xs[:, t]), fp)
        hi = _np_ints(h, fp)
        z = q((xi @ Wq).astype(np.float32) * inv2
              + (hi @ Uq).astype(np.float32) * inv2 + bq)
        i, f, g, o = (z[:, :H], z[:, H:2 * H], z[:, 2 * H:3 * H], z[:, 3 * H:])
        i, f, o = q(_np_sigmoid(i)), q(_np_sigmoid(f)), q(_np_sigmoid(o))
        g = q(_np_tanh(g))
        c = q(q(f * c) + q(i * g))
        h = q(o * q(_np_tanh(c)))
    return h


def quantized_golden_gru(xs, W, U, b, fp) -> np.ndarray:
    """Numpy integer reference of the quantized GRU (reset_after) scan."""
    from repro.core.quant.fixed_point import quantize_np

    q = lambda v: quantize_np(v, fp)                       # noqa: E731
    xs = np.asarray(xs, np.float32)
    Wq, Uq = _np_ints(q(np.asarray(W)), fp), _np_ints(q(np.asarray(U)), fp)
    bq = q(np.asarray(b))
    B, T, _ = xs.shape
    H = np.asarray(U).shape[0]
    inv2 = np.float32(1.0 / (fp.scale * fp.scale))
    h = np.zeros((B, H), np.float32)
    for t in range(T):
        xi = _np_ints(q(xs[:, t]), fp)
        hi = _np_ints(h, fp)
        zx = q((xi @ Wq).astype(np.float32) * inv2 + bq[0])
        zh = q((hi @ Uq).astype(np.float32) * inv2 + bq[1])
        zxz, zxr, zxh = np.split(zx, 3, axis=-1)
        zhz, zhr, zhh = np.split(zh, 3, axis=-1)
        z = q(_np_sigmoid(zxz + zhz))
        r = q(_np_sigmoid(zxr + zhr))
        hh = q(_np_tanh(q(zxh + q(r * zhh))))
        h = q(q(z * h) + q((1.0 - z) * hh))
    return h


def quantized_golden_rglru(a, bx, fp) -> np.ndarray:
    """Numpy integer reference of the quantized RG-LRU recurrence — ALL
    integer arithmetic (the native kernel is matmul-free), so the kernel
    must match bit-for-bit."""
    a, bx = np.asarray(a, np.float32), np.asarray(bx, np.float32)
    from repro.core.quant.fixed_point import quantize_np

    lo = int(round(fp.min_value * fp.scale))
    hi = int(round(fp.max_value * fp.scale))
    F = fp.fractional_bits
    ai = _np_ints(quantize_np(a, fp), fp)
    bi = _np_ints(quantize_np(bx, fp), fp)
    B, T, W = a.shape
    h = np.zeros((B, W), np.int64)
    hs = []
    for t in range(T):
        acc = ai[:, t] * h + (bi[:, t] << F)
        # round-half-even of acc / 2^F on the integer grid, then saturate
        h = np.clip(np.round(acc.astype(np.float64) / fp.scale), lo, hi
                    ).astype(np.int64)
        hs.append(h)
    return (np.stack(hs, axis=1) / fp.scale).astype(np.float32)


def quantized_golden_reuse_matmul(x, w, fp) -> np.ndarray:
    """Numpy integer reference of the quantized scheduled matmul
    z = q(q(x) @ q(w)) — exact int accumulation, must match bit-for-bit."""
    from repro.core.quant.fixed_point import quantize_np

    xi = _np_ints(quantize_np(np.asarray(x), fp), fp)
    wi = _np_ints(quantize_np(np.asarray(w), fp), fp)
    acc = (xi @ wi).astype(np.float32) / np.float32(fp.scale * fp.scale)
    return quantize_np(acc, fp)


QUANTIZED_GOLDENS = {
    "lstm": quantized_golden_lstm,
    "gru": quantized_golden_gru,
    "rglru": quantized_golden_rglru,
    "reuse_matmul": quantized_golden_reuse_matmul,
}


def make_quantized_inputs(kernel: str, fp, *, dtype: str = "float32",
                          seed: int = 0, **shape_kw) -> Tuple:
    """make_kernel_inputs with the WEIGHTS PTQ'd onto the fp grid (exact
    host-side quantize_np) — the regime where native == emulation bitwise;
    activations/inputs stay raw, the datapath quantizes them."""
    import jax.numpy as jnp

    from repro.core.quant.fixed_point import quantize_np

    inputs = make_kernel_inputs(kernel, dtype=dtype, seed=seed, **shape_kw)
    if kernel in ("lstm", "gru"):
        xs, W, U, b = inputs
        return (xs,) + tuple(jnp.asarray(quantize_np(np.asarray(v), fp))
                             for v in (W, U, b))
    return inputs


def assert_quantized_conformance(kernel: str, schedule: KernelSchedule,
                                 fp, *, tol: Optional[float] = None,
                                 seed: int = 0, **shape_kw) -> float:
    """Run one (kernel x schedule x fp) cell against its numpy integer
    golden model.  Default tolerance: ONE grid step
    (2 x fixed_point_error_bound) — the matmul/Hadamard datapath is exact,
    only an activation rounding tie may move a value one step.

    Returns the max abs error; raises AssertionError beyond tolerance.
    """
    from repro.core.quant.fixed_point import fixed_point_error_bound
    from repro.kernels import ops

    scheduled, _ = ops.SCHEDULED_KERNELS[kernel]
    inputs = make_quantized_inputs(kernel, fp, seed=seed, **shape_kw)
    got = np.asarray(scheduled(*inputs, schedule=schedule, fp=fp),
                     np.float32)
    want = QUANTIZED_GOLDENS[kernel](*inputs, fp)
    assert got.shape == want.shape, (kernel, schedule, got.shape, want.shape)
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    limit = 2.0 * fixed_point_error_bound(fp) if tol is None else tol
    assert err <= limit, (
        f"{kernel} diverged from quantized golden model under {schedule} "
        f"fp=ap_fixed<{fp.total_bits},{fp.integer_bits}>: max_err={err:.3e} "
        f"> {limit:.3e} (seed={seed}, shapes={shape_kw})")
    return err


# ---------------------------------------------------------------------------
# End-to-end serving conformance (engine output vs the lax.scan golden model)
# ---------------------------------------------------------------------------


def serving_golden(cfg: ModelConfig, params, x, fp=None, mode=None,
                   lengths=None) -> np.ndarray:
    """Golden served output: the full tagger forward pass on the XLA
    ``lax.scan`` reference datapath (kernels/ref.py semantics) — what every
    engine (mode x impl x schedule x fp) cell must reproduce."""
    import jax.numpy as jnp

    from repro.models import rnn_tagger

    return np.asarray(rnn_tagger.forward(
        cfg, params, jnp.asarray(x), fp=fp, mode=mode, impl="xla",
        lengths=None if lengths is None else jnp.asarray(lengths)),
        np.float32)


def assert_serving_conformance(engine, x, *, schedule: Optional[KernelSchedule]
                               = None, fp=None, tol: Optional[float] = None,
                               dtype: str = "float32") -> float:
    """One engine.predict cell against the golden model, with the same
    tolerance discipline as :func:`assert_schedule_conformance`.

    Returns the max abs error; raises AssertionError beyond tolerance.
    """
    got = np.asarray(engine.predict(x, schedule=schedule, fp=fp), np.float32)
    sched, fpr = engine.resolve(schedule, fp)
    want = serving_golden(engine.cfg, engine.params, x, fp=fpr,
                          mode=sched.mode)
    assert got.shape == want.shape, (sched, got.shape, want.shape)
    err = float(np.max(np.abs(got - want))) if got.size else 0.0
    limit = CONFORMANCE_TOL[dtype] if tol is None else tol
    scale = max(1.0, float(np.max(np.abs(want)))) if want.size else 1.0
    assert err <= limit * scale, (
        f"engine diverged from golden model under {sched} fp={fpr}: "
        f"max_err={err:.3e} > {limit * scale:.3e}")
    return err
