"""Synthetic QuickDraw-style stroke dataset (paper Sec. 4.3 stand-in).

Five classes (ant, butterfly, bee, mosquito, snail) as distinct parametric
stroke processes; each drawing is 100 timestamped pen positions (x, y, t),
matching the paper's input format (100 x 3).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

SEQ = 100
CLASSES = ("ant", "butterfly", "bee", "mosquito", "snail")


def _stroke(rng, label: int) -> np.ndarray:
    t = np.linspace(0, 1, SEQ)
    jitter = lambda s: rng.randn(SEQ) * s
    if label == 0:      # ant: three body blobs + leg zigzags
        seg = (t * 3).astype(int)
        cx = np.array([-0.5, 0.0, 0.5])[np.clip(seg, 0, 2)]
        ang = 2 * np.pi * ((t * 3) % 1.0) * (2 + rng.rand())
        x = cx + 0.18 * np.cos(ang)
        y = 0.15 * np.sin(ang) + 0.25 * np.sign(np.sin(12 * np.pi * t)) * (t > 0.7)
    elif label == 1:    # butterfly: two large lobes (lemniscate)
        ang = 2 * np.pi * t * (1.5 + 0.2 * rng.rand())
        x = 0.8 * np.sin(ang)
        y = 0.6 * np.sin(ang) * np.cos(ang) + 0.1 * np.sin(5 * ang)
    elif label == 2:    # bee: blob + wide zigzag flight path
        x = np.where(t < 0.5, 0.3 * np.cos(4 * np.pi * t),
                     -1 + 4 * (t - 0.5) + 0.0)
        y = np.where(t < 0.5, 0.2 * np.sin(4 * np.pi * t),
                     0.4 * np.sign(np.sin(16 * np.pi * t)))
    elif label == 3:    # mosquito: long thin legs, tiny body
        seg = (t * 6).astype(int) % 2
        x = np.where(seg == 0, 0.1 * np.cos(20 * t), (t - 0.5) * 1.8)
        y = np.where(seg == 0, 0.1 * np.sin(20 * t), -0.8 * t + 0.2)
    else:               # snail: spiral shell + base line
        ang = 4 * np.pi * t
        r = 0.08 + 0.6 * t
        x = np.where(t < 0.8, r * np.cos(ang), -0.6 + 1.8 * (t - 0.8) * 5)
        y = np.where(t < 0.8, r * np.sin(ang), -0.55)
    x = x + jitter(0.02)
    y = y + jitter(0.02)
    # pen speed variation -> non-uniform timestamps like real strokes
    dt = np.abs(rng.randn(SEQ)) * 0.3 + 1.0
    ts = np.cumsum(dt)
    ts = ts / ts[-1]
    return np.stack([x, y, ts], 1).astype(np.float32)


def quickdraw_dataset(n: int, seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n, 100, 3], y [n] in 0..4)."""
    rng = np.random.RandomState(seed)
    y = rng.randint(0, 5, n).astype(np.int32)
    x = np.stack([_stroke(rng, int(t)) for t in y])
    return x, y
