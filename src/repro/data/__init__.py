from repro.data.jets import top_tagging_dataset  # noqa: F401
from repro.data.tracks import flavor_tagging_dataset  # noqa: F401
from repro.data.quickdraw import quickdraw_dataset  # noqa: F401
from repro.data.lm_synthetic import lm_token_stream  # noqa: F401
