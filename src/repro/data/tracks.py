"""Synthetic jet-flavor-tagging dataset (paper Sec. 4.2 stand-in).

The discriminating physics: b/c hadrons fly O(mm) before decaying, so their
tracks have large transverse impact parameters d0 with large significance
S(d0); light jets' tracks point back to the primary vertex.  We simulate
per-track (pT/pT_jet, dR, d0, dz, S(d0), S(dz)) for 3 classes
(b=0, c=1, light=2), S(d0)-ordered, padded to 15 tracks — the structure the
paper's RNNIP-style tagger consumes.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

N_TRACKS = 15
N_FEATURES = 6

# decay-length scale (mm) and number of displaced tracks per class
_CLASS = {
    0: {"flight": 5.0, "n_disp": (3, 6)},   # b
    1: {"flight": 2.0, "n_disp": (1, 4)},   # c
    2: {"flight": 0.0, "n_disp": (0, 1)},   # light
}


def _make_jet(rng: np.random.RandomState, label: int) -> np.ndarray:
    spec = _CLASS[label]
    n_trk = rng.randint(6, N_TRACKS + 1)
    n_disp = rng.randint(*spec["n_disp"]) if spec["n_disp"][1] > spec["n_disp"][0] else 0
    d0_res = 0.02                                       # 20um resolution
    tracks = []
    for i in range(n_trk):
        displaced = i < n_disp
        if displaced and spec["flight"] > 0:
            lxy = rng.exponential(spec["flight"])
            d0 = lxy * np.abs(rng.randn()) * 0.1 + rng.randn() * d0_res
            dz = lxy * np.abs(rng.randn()) * 0.15 + rng.randn() * 2 * d0_res
        else:
            d0 = rng.randn() * d0_res
            dz = rng.randn() * 2 * d0_res
        pt_frac = rng.beta(1.2, 6.0)
        dr = np.abs(rng.randn()) * 0.15
        s_d0 = d0 / d0_res
        s_dz = dz / (2 * d0_res)
        tracks.append([pt_frac, dr, d0, dz, s_d0, s_dz])

    tracks.sort(key=lambda t: -abs(t[4]))               # |S(d0)| ordering
    arr = np.zeros((N_TRACKS, N_FEATURES), np.float32)
    arr[: len(tracks)] = np.asarray(tracks[:N_TRACKS], np.float32)
    arr[:, 2] = np.tanh(arr[:, 2])                      # bound d0/dz tails
    arr[:, 3] = np.tanh(arr[:, 3])
    arr[:, 4] = np.tanh(arr[:, 4] / 10.0) * 10.0
    arr[:, 5] = np.tanh(arr[:, 5] / 10.0) * 10.0
    return arr


def flavor_tagging_dataset(n: int, seed: int = 0
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n, 15, 6], y [n] in {0:b, 1:c, 2:light})."""
    rng = np.random.RandomState(seed)
    y = rng.randint(0, 3, n).astype(np.int32)
    x = np.stack([_make_jet(rng, int(t)) for t in y])
    return x, y
