"""Synthetic top-tagging dataset (paper Sec. 4.1 stand-in).

MadGraph/Pythia are not available offline, so we simulate the *feature
structure* the paper's RNN learns: top jets have 3-prong substructure
(t -> Wb -> qqb) with mass ~173 GeV spread across subjets; light-quark jets
are single-prong with a steeply falling fragmentation spectrum.  Particles
carry the paper's six features (pT, eta, phi, E, dR-from-axis, pid), are
pT-ordered and padded to 20 — an RNN separates these at AUC ~0.9+, giving a
faithful substrate for the quantization scans (Fig. 2).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

N_PARTICLES = 20
N_FEATURES = 6


def _make_jet(rng: np.random.RandomState, is_top: bool) -> np.ndarray:
    jet_pt = 1000.0 * (1 + 0.01 * rng.randn())        # 1 TeV window
    if is_top:
        # 3 subjet cores within dR ~ 2m/pT ~ 0.35 of the axis; sometimes
        # collimated enough to look 1-2 prong (realistic overlap)
        n_cores = 3
        scale = 0.5 if rng.rand() < 0.25 else 1.0
        core_dr = scale * 0.35 * np.abs(rng.randn(n_cores) * 0.4 + 1.0) / 2
        core_phi = rng.uniform(0, 2 * np.pi, n_cores)
        core_frac = rng.dirichlet([4.0, 3.0, 2.0])
    else:
        # QCD jets occasionally radiate a hard secondary prong
        n_cores = 2 if rng.rand() < 0.3 else 1
        core_dr = np.concatenate([[0.02 * np.abs(rng.randn())],
                                  0.2 * np.abs(rng.randn(n_cores - 1)) + 0.05])
        core_phi = rng.uniform(0, 2 * np.pi, n_cores)
        core_frac = (np.array([1.0]) if n_cores == 1
                     else rng.dirichlet([6.0, 1.5]))

    n_part = rng.randint(12, N_PARTICLES + 1)
    parts = []
    for _ in range(n_part):
        c = rng.choice(n_cores, p=core_frac)
        # fragmentation: z ~ falling spectrum within the subjet
        z = rng.beta(1.0, 4.0 if is_top else 6.0)
        pt = jet_pt * core_frac[c] * z
        spread = 0.06 if is_top else 0.03
        dr = core_dr[c] + spread * np.abs(rng.randn())
        ang = core_phi[c] + 0.3 * rng.randn()
        eta = dr * np.cos(ang)
        phi = dr * np.sin(ang)
        energy = pt * np.cosh(eta + 0.0)
        pid = float(rng.choice([-211, 211, 22, 130, 11],
                               p=[0.3, 0.3, 0.25, 0.1, 0.05])) / 211.0
        parts.append([pt, eta, phi, energy, dr, pid])

    parts.sort(key=lambda p: -p[0])                   # pT ordering
    arr = np.zeros((N_PARTICLES, N_FEATURES), np.float32)
    arr[: len(parts)] = np.asarray(parts[:N_PARTICLES], np.float32)
    # detector smearing
    arr[: len(parts), 1:3] += rng.randn(len(parts), 2).astype(np.float32) * 0.01
    arr[: len(parts), 4] = np.abs(arr[: len(parts), 4]
                                  + rng.randn(len(parts)) * 0.02)
    # normalize scales (log-pT/E, raw angles)
    arr[:, 0] = np.log1p(arr[:, 0]) / 7.0
    arr[:, 3] = np.log1p(arr[:, 3]) / 7.0
    return arr


def top_tagging_dataset(n: int, seed: int = 0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x [n, 20, 6], y [n] in {0,1}); deterministic in seed."""
    rng = np.random.RandomState(seed)
    y = rng.randint(0, 2, n).astype(np.int32)
    x = np.stack([_make_jet(rng, bool(t)) for t in y])
    return x, y
