"""Deterministic synthetic LM token pipeline: zipfian unigrams + first-order
markov bigram structure (so the loss actually decreases), document packing
with EOS, host-sharded loading for multi-process pods."""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

EOS = 1


def _markov_row_sampler(rng: np.random.RandomState, vocab: int):
    """Cheap structured bigram: next ~ (cur * a + b) mod zipf-bucket."""
    a = rng.randint(3, 97) | 1
    b = rng.randint(1, vocab)

    def next_token(cur: np.ndarray, noise: np.ndarray) -> np.ndarray:
        zipf = np.minimum(noise, vocab - 1)
        structured = (cur * a + b) % vocab
        pick = (noise % 4 == 0)
        return np.where(pick, zipf, structured)

    return next_token


def lm_token_stream(
    vocab_size: int,
    batch: int,
    seq_len: int,
    *,
    seed: int = 0,
    process_index: int = 0,
    process_count: int = 1,
) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {'tokens': [b_local, S], 'labels': [b_local, S]} forever.
    Deterministic in (seed, step, process_index); each process gets a
    disjoint batch shard."""
    assert batch % process_count == 0
    b_local = batch // process_count
    step = 0
    while True:
        rng = np.random.RandomState(
            (seed * 1_000_003 + step) % (2 ** 31 - 1))
        nxt = _markov_row_sampler(rng, vocab_size)
        # zipfian noise source
        noise = rng.zipf(1.3, size=(batch, seq_len + 1)).astype(np.int64)
        toks = np.empty((batch, seq_len + 1), np.int32)
        toks[:, 0] = noise[:, 0] % vocab_size
        for t in range(1, seq_len + 1):
            toks[:, t] = nxt(toks[:, t - 1].astype(np.int64),
                             noise[:, t]).astype(np.int32)
        # sprinkle document boundaries
        doc_mask = rng.rand(batch, seq_len + 1) < (1.0 / 512)
        toks = np.where(doc_mask, EOS, toks) % vocab_size
        lo = process_index * b_local
        sl = slice(lo, lo + b_local)
        yield {"tokens": toks[sl, :-1], "labels": toks[sl, 1:].copy()}
        step += 1
