"""Per-(arch, mesh, shape) sharding auto-configuration.

Divisibility drives the layout: a logical axis is TP-sharded over 'model'
only when its size divides the axis; otherwise it falls back (replication or
an alternative parallel dim), and attention picks the 'sp' schedule when the
head count does not divide the TP width (gemma-2b: 8 heads, deepseek-coder:
56 heads on a 16-wide axis).
"""

from __future__ import annotations

from typing import Dict, Optional

from jax.sharding import Mesh

from repro.config import ModelConfig, ShapeConfig


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        n *= mesh.shape.get(a, 1)
    return n


def auto_overrides(cfg: ModelConfig, mesh: Mesh,
                   shape: Optional[ShapeConfig] = None) -> Dict[str, object]:
    tp = mesh.shape.get("model", 1)
    dp = dp_size(mesh)
    ov: Dict[str, object] = {}

    if cfg.family == "rnn":
        return ov

    # batch divisibility (long_500k has global_batch=1)
    if shape is not None and shape.global_batch % max(dp, 1) != 0:
        if shape.global_batch % mesh.shape.get("data", 1) == 0:
            ov["batch"] = "data"
        else:
            ov["batch"] = None

    if cfg.n_heads:
        heads_div = cfg.n_heads % tp == 0
        kv_div = cfg.n_kv_heads % tp == 0
        if not heads_div:
            ov["heads"] = None
            ov["__attn_mode__"] = "sp"
        if not kv_div:
            ov["kv_heads"] = None

    if cfg.d_ff and cfg.d_ff % tp != 0:
        ov["ffn"] = None

    # vocab-parallel loss requires divisibility (whisper pads, see transformer)
    from repro.models.transformer import padded_vocab
    if padded_vocab(cfg) % tp != 0:
        ov["vocab"] = None

    if cfg.ssm is not None:
        from repro.models.ssm import ssm_dims
        d_in, h, conv_dim = ssm_dims(cfg)
        if h % tp != 0:
            ov["ssm_heads"] = None
        if d_in % tp != 0 or conv_dim % tp != 0:
            ov["ssm_inner"] = None

    if cfg.rglru is not None:
        w = cfg.rglru.lru_width or cfg.d_model
        if w % tp != 0:
            ov["lru_width"] = None

    # SP residual requires seq % tp == 0 (and is train/prefill only)
    if shape is not None and shape.kind in ("train", "prefill"):
        if shape.seq_len % tp != 0:
            ov["seq"] = None
            ov["seq_chunks"] = None
    if shape is not None and shape.kind == "decode":
        # kv cache seq dim must divide the model axis
        if shape.seq_len % tp != 0:
            ov["kv_seq"] = None
        if cfg.rglru is not None and min(cfg.rglru.window, shape.seq_len) % tp != 0:
            ov["kv_seq"] = None
        # big-weight archs: TP alone leaves GiBs of bf16 weights per chip
        # (worse when head counts don't divide the axis and attention
        # weights replicate); switch to 2D weight sharding (embed over
        # 'data') with the batch replicated — activation psums are tiny at
        # decode, weight gathers are avoided entirely.  Threshold 2 GiB:
        # deepseek-33b (4.17e9 B = 3.9 GiB) sat just under the original
        # 4 GiB cut and served with 12.7 GiB of replicated attention
        # weights (§Perf D4).
        if cfg.family != "rnn":
            wb = cfg.param_count() * 2 / max(tp, 1)
            if wb > 2 * 2 ** 30 and "data" in mesh.axis_names:
                ov["batch"] = None
                ov["embed"] = "data"
                if shape.seq_len % (tp * mesh.shape["data"]) == 0:
                    ov["kv_seq"] = ("data", "model")

    return ov
