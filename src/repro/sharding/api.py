"""Sharding context: thread (mesh, rules, data axes) through model code.

Model code calls ``constrain(x, 'batch', 'seq', 'embed_act')``.  With no
active context (unit tests, single-device runs) this is the identity, so the
model zoo runs unmodified on 1 CPU device.
"""

from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.rules import MeshAxes, rules_for

_STATE = threading.local()


@dataclass
class ShardingContext:
    mesh: Mesh
    rules: Dict[str, MeshAxes]
    data_axes: Tuple[str, ...] = ("data",)
    overrides: Dict[str, MeshAxes] = field(default_factory=dict)

    def resolve(self, logical: Optional[str]) -> MeshAxes:
        if logical is None:
            return None
        if logical in self.overrides:
            axis = self.overrides[logical]
        elif logical in self.rules:
            axis = self.rules[logical]
        else:
            raise KeyError(f"unknown logical axis {logical!r}")
        if axis == "__data__":
            return self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        return axis

    def pspec(self, logical_axes: Tuple[Optional[str], ...]) -> P:
        used = set()
        out = []
        for name in logical_axes:
            axis = self.resolve(name)
            # a mesh axis may appear at most once in a PartitionSpec; on
            # conflict the later dim is left unsharded (documented behaviour)
            flat = axis if isinstance(axis, tuple) else (axis,) if axis else ()
            if any(a in used for a in flat):
                out.append(None)
                continue
            used.update(flat)
            out.append(axis)
        return P(*out)


def current_context() -> Optional[ShardingContext]:
    return getattr(_STATE, "ctx", None)


@contextlib.contextmanager
def sharding_context(
    mesh: Optional[Mesh],
    family: str = "dense",
    kind: str = "train",
    overrides: Optional[Dict[str, MeshAxes]] = None,
):
    """Activate sharding for model code. mesh=None -> no-op context."""
    if mesh is None:
        yield None
        return
    data_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    ctx = ShardingContext(
        mesh=mesh,
        rules=dict(rules_for(family, kind)),
        data_axes=data_axes or (mesh.axis_names[0],),
        overrides=dict(overrides or {}),
    )
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = ctx
    try:
        yield ctx
    finally:
        _STATE.ctx = prev


def logical_to_pspec(logical_axes: Tuple[Optional[str], ...]) -> Optional[P]:
    ctx = current_context()
    if ctx is None:
        return None
    return ctx.pspec(logical_axes)


def named_sharding(logical_axes: Tuple[Optional[str], ...]) -> Optional[NamedSharding]:
    ctx = current_context()
    if ctx is None:
        return None
    return NamedSharding(ctx.mesh, ctx.pspec(logical_axes))


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op without context)."""
    ctx = current_context()
    if ctx is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical_axes)} axes for rank-{x.ndim} array"
        )
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, ctx.pspec(tuple(logical_axes)))
    )
