from repro.sharding.api import (  # noqa: F401
    ShardingContext,
    current_context,
    sharding_context,
    constrain,
    logical_to_pspec,
    named_sharding,
)
from repro.sharding.rules import RULE_PROFILES, rules_for  # noqa: F401
