"""Logical-axis -> mesh-axis rules (MaxText-style), per parallelism profile.

Logical axes used by the model zoo:

  batch        global batch                    -> all data-parallel axes
  seq          sequence (residual storage)     -> 'model' when SP is on
  seq_nosp     sequence, never sharded
  embed        d_model                         -> FSDP ('data') on weights
  embed_act    d_model on activations          -> unsharded
  heads        query heads                     -> 'model' (TP)
  kv_heads     kv heads                        -> 'model' if divisible else None
  kv_heads_r   kv heads, forced replicated
  head_dim     per-head dim                    -> unsharded
  ffn          MLP hidden                      -> 'model' (TP)
  vocab        vocabulary                      -> 'model' (parallel xent)
  experts      MoE experts                     -> 'model' (EP)
  expert_cap   expert capacity                 -> unsharded
  ssm_heads    mamba value heads               -> 'model' (TP)
  ssm_state    SSM state dim                   -> unsharded
  lru_width    RG-LRU width                    -> 'model' (TP)
  conv         conv taps                       -> unsharded
  layers       stacked-scan layer dim          -> unsharded
  rnn_hidden / rnn_gates / rnn_in              paper RNN tagger dims

A rule maps logical name -> mesh axis (str | tuple | None).  ``data_axes`` in
the context decides what 'batch' means ('data' alone or ('pod','data')).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple, Union

MeshAxes = Union[None, str, Tuple[str, ...]]

# Profile: family -> {logical axis -> mesh axis}.  'batch' and FSDP axes are
# filled dynamically from the context's data axes.
_BASE: Dict[str, MeshAxes] = {
    "batch": "__data__",          # placeholder -> ctx.data_axes
    "seq": None,
    "seq_nosp": None,
    "embed": "__data__",          # FSDP shard of weight d_model dim
    "embed_nofsdp": None,
    "embed_act": None,
    "heads": "model",
    "kv_heads": "model",
    "kv_heads_r": None,
    "head_dim": None,
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "expert_ffn": None,
    "ssm_heads": "model",
    "ssm_state": None,
    "ssm_inner": "model",
    "lru_width": "model",
    "conv": None,
    "layers": None,
    "seq_chunks": "model",        # SP attention chunk-grid dim
    "rnn_hidden": None,
    "rnn_gates": None,
    "rnn_in": None,
    "kv_seq": None,               # kv-cache sequence dim (decode)
    "qkv_fused": "model",
}

# dense transformers: Megatron TP + SP residuals + FSDP
_DENSE = dict(_BASE)
_DENSE.update({"seq": "model"})

# MoE: no SP (model axis is used by experts/ffn); EP over 'model'
_MOE = dict(_BASE)

# SSM / hybrid: TP over heads/width, sequence unsharded (recurrence is local)
_SSM = dict(_BASE)
_HYBRID = dict(_BASE)

# enc-dec (whisper-scale is small): TP + FSDP, no SP (short decoder seqs)
_ENCDEC = dict(_BASE)

# paper RNN taggers: replicated (they are kilobyte-scale) — batch DP only
_RNN = dict(_BASE)
_RNN.update({"heads": None, "ffn": None, "vocab": None, "embed": None})

# decode profiles: kv cache seq dim sharded over 'model' (flash-decode),
# weights TP as usual, no FSDP gathering needed (inference)
_DECODE = dict(_BASE)
_DECODE.update({"kv_seq": "model", "seq": None, "embed": None})

_DECODE_MOE = dict(_DECODE)
_DECODE_SSM = dict(_DECODE)

RULE_PROFILES: Dict[str, Dict[str, MeshAxes]] = {
    "dense": _DENSE,
    "moe": _MOE,
    "ssm": _SSM,
    "hybrid": _HYBRID,
    "audio": _ENCDEC,
    "vlm": _DENSE,
    "rnn": _RNN,
    "dense_decode": _DECODE,
    "moe_decode": _DECODE_MOE,
    "ssm_decode": _DECODE_SSM,
    "hybrid_decode": _DECODE_SSM,
    "audio_decode": _DECODE,
    "vlm_decode": _DECODE,
    "rnn_decode": _RNN,
}


def rules_for(family: str, kind: str = "train") -> Dict[str, MeshAxes]:
    key = family if kind in ("train", "prefill") else f"{family}_decode"
    if key not in RULE_PROFILES:
        key = family
    return RULE_PROFILES[key]
