"""Analytical HLS design-space model for RNN layers — reproduces the paper's
latency / II / resource tables without Vivado.

The model encodes hls4ml's documented scaling laws:
  * static latency  = seq_len x (R_kernel + c_pipe) cycles       (Tables 2-4)
  * static II       = latency (a new inference waits for the whole sequence)
  * non-static II   = single-block II (=1 fully pipelined)        (Table 5)
  * non-static res  = seq_len x static resources                  (Fig. 6)
  * DSP             = (mults / R) x packing(W)  — flat in W until the DSP
                      input width (18b) is exceeded, then doubles  (Figs 3)
  * FF/LUT          ~ W x mults / R (+ base)  — linear in precision (Figs 4-5)
  * GRU : LSTM      = 3 : 4 in everything matmul-driven           (Sec. 5.2)
  * hoisted input   = kernel-GEMM mults leave the (replicated) sequential
                      blocks and come back once as a shared pipelined front
                      stage; pipeline mode II = the schedule's ii target

Pipeline constants c_pipe and the (constant-in-R) max-latency offsets are
calibrated per benchmark against Tables 2-4; benchmarks/bench_latency_
resources.py asserts the reproduction accuracy against every table entry.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.config import FixedPointConfig, ModelConfig, RNNConfig
from repro.core.hls.resources import (
    FPGA_PARTS,
    FPGAPart,
    ScheduleEstimate,
    estimate_schedule,
    gate_count,
    mults_per_dsp,
    resolved_axes,
)
from repro.kernels.schedule import KernelSchedule


# per-benchmark calibration: (c_pipe cycles, max-min latency offset cycles,
# latency-strategy per-step cycles)
_CALIB: Dict[str, Tuple[int, int, int]] = {
    "top-tagging": (20, 820, 17),
    "flavor-tagging": (37, 3620, 45),
    "quickdraw": (22, 25720, 40),
}
_DEFAULT_CALIB = (24, 2000, 20)


def _calib_for(name: str):
    for key, v in _CALIB.items():
        if key in name:
            return v
    return _DEFAULT_CALIB


@dataclass(frozen=True)
class RNNDesignPoint:
    cfg: ModelConfig
    fp: FixedPointConfig = field(default_factory=FixedPointConfig)
    reuse_kernel: int = 1
    reuse_recurrent: int = 1
    mode: str = "static"               # static | nonstatic | pipeline
    strategy: str = "resource"         # latency | resource
    part: str = "xcku115"
    clock_mhz: float = 200.0
    # hoisted input projection: the kernel (xW) GEMM runs as one shared
    # fully-pipelined front stage instead of inside every sequential block
    hoist_input: bool = False
    hoist_reuse: int = 1               # reuse of the hoisted front GEMM
    ii: int = 0                        # pipeline mode: target II in cycles
                                       # (0 = one block's reuse passes)


@dataclass(frozen=True)
class HLSDesign:
    latency_min_us: float
    latency_max_us: float
    ii_cycles: int
    dsp: int
    ff: int
    lut: int
    bram_18k: int
    throughput_eps: float              # events/second = clock / II
    fits: bool
    part: str

    def as_dict(self):
        return self.__dict__.copy()


def _rnn_mults(rnn: RNNConfig) -> Tuple[int, int, int]:
    """(kernel mults, recurrent mults, head mults) per timestep/inference."""
    g = gate_count(rnn.cell)
    mk = rnn.input_size * g * rnn.hidden
    mr = rnn.hidden * g * rnn.hidden
    mh = 0
    prev = rnn.hidden
    for w in rnn.dense_sizes:
        mh += prev * w
        prev = w
    mh += prev * rnn.n_outputs
    return mk, mr, mh


def estimate_design(pt: RNNDesignPoint) -> HLSDesign:
    cfg = pt.cfg
    rnn = cfg.rnn
    assert rnn is not None, "HLS model applies to the RNN tagger family"
    c_pipe, max_off, lat_step = _calib_for(cfg.name)
    cycle_us = 1.0 / pt.clock_mhz
    W = pt.fp.total_bits
    seq = rnn.seq_len

    mk, mr, mh = _rnn_mults(rnn)

    # --- latency / II ------------------------------------------------------
    if pt.strategy == "latency":
        per_step = lat_step
    else:
        per_step = pt.reuse_kernel + c_pipe
    rnn_latency = seq * per_step
    if pt.hoist_input:
        # the hoisted xW GEMM is one extra pipelined front-stage pass
        rnn_latency += max(pt.hoist_reuse, 1) + c_pipe
    latency_min = rnn_latency
    latency_max = rnn_latency + max_off

    if pt.mode == "static":
        ii = rnn_latency
    elif pt.mode == "pipeline":
        # hoisted blocks carry only the hU tiles: a new inference enters at
        # the explicit II target (default: one block's reuse passes)
        ii = max(pt.ii or pt.reuse_kernel, 1)
    else:
        # one block per timestep, state flows block->block: a new inference
        # enters once the first block frees up
        ii = max(per_step if pt.strategy != "latency" else 1, 1)
        if pt.strategy == "latency":
            ii = 1

    # --- resources ----------------------------------------------------------
    rk = 1 if pt.strategy == "latency" else pt.reuse_kernel
    rr = 1 if pt.strategy == "latency" else pt.reuse_recurrent
    # hoisting removes the kernel-GEMM mults from the (per-block, possibly
    # seq_len-replicated) sequential datapath; they come back once below as
    # a shared front stage
    mk_block = 0.0 if pt.hoist_input else mk / rk
    ops_parallel = mk_block + mr / rr + mh / max(rk, 1)
    if W >= 12:
        # multiplications map to DSP48s; packing doubles above 18b inputs
        dsp_one = ops_parallel * mults_per_dsp(W)
        lut_mult = 0.0
    else:
        # hls4ml synthesizes narrow mults into fabric LUTs (paper Fig. 6:
        # non-static at W=10 sits near the LUT line with ~0 DSP growth)
        dsp_one = 0.0
        lut_mult = 0.55 * W * ops_parallel
    import math as _m
    # reuse FSM/mux cost: zero when fully parallel (R=1, no multiplexing)
    reuse_mux = 40.0 * ops_parallel * _m.log2(max(rk, 1))
    ff_one = 0.6 * W * ops_parallel + 12.0 * ops_parallel \
        + 2.0 * W * rnn.hidden                      # pipeline regs
    lut_one = 0.35 * W * ops_parallel + lut_mult + reuse_mux \
        + 25.0 * rnn.hidden * W                     # activations (LUT tables)
    # BRAM: resource strategy keeps weights in BRAM (hoisted kernel weights
    # live in the shared front stage, not in every replicated block)
    n_weights = (0 if pt.hoist_input else mk) + mr + mh
    bram_one = (n_weights * W) / 18432.0 if pt.strategy == "resource" else 0.0

    mult = seq if pt.mode in ("nonstatic", "pipeline") else 1
    dsp = int(dsp_one * mult)
    ff = int(ff_one * mult)
    lut = int(lut_one * mult)
    bram = int(bram_one * mult)

    if pt.hoist_input:
        # shared hoisted front GEMM: mk mults at hoist_reuse, counted ONCE
        # (never replicated across the seq_len blocks)
        hr = max(pt.hoist_reuse, 1)
        hoist_ops = mk / hr
        if W >= 12:
            dsp += int(hoist_ops * mults_per_dsp(W))
        else:
            lut += int(0.55 * W * hoist_ops)
        ff += int(0.6 * W * hoist_ops)
        lut += int(0.35 * W * hoist_ops)
        if pt.strategy == "resource":
            bram += int((mk * W) / 18432.0)

    part = FPGA_PARTS[pt.part]
    # paper Sec 5.2: Vivado synthesis reduces HLS LUT estimates by 20-65%
    # and FF by 10-20%; the fits check uses the post-Vivado expectation.
    VIVADO_LUT, VIVADO_FF = 0.65, 0.85
    fits = (dsp <= part.dsp and ff * VIVADO_FF <= part.ff
            and lut * VIVADO_LUT <= part.lut and bram <= part.bram_18k)

    clock_hz = pt.clock_mhz * 1e6
    return HLSDesign(
        latency_min_us=latency_min * cycle_us,
        latency_max_us=latency_max * cycle_us,
        ii_cycles=int(ii),
        dsp=dsp, ff=ff, lut=lut, bram_18k=bram,
        throughput_eps=clock_hz / max(ii, 1),
        fits=fits,
        part=part.name,
    )


def design_point_for_schedule(cfg: ModelConfig, schedule: KernelSchedule,
                              fp: Optional[FixedPointConfig] = None,
                              **kw) -> RNNDesignPoint:
    """Bridge a kernel schedule to the table-calibrated design-space model:
    the SAME object that executes on TPU (kernels/ops.py) prices out the
    FPGA design, so sweeping schedules sweeps the paper's Fig. 1 curve.

    The reuse factor is clamped to the divisor the kernel actually executes
    (``resolved_axes`` — the SAME resolution ``estimate_schedule`` applies),
    keeping the priced design and the executed schedule in lockstep for
    non-divisor R requests.
    """
    assert cfg.rnn is not None
    r_eff, hr_eff = resolved_axes(schedule, cfg.rnn)
    return RNNDesignPoint(
        cfg, fp if fp is not None else FixedPointConfig(),
        reuse_kernel=r_eff,
        reuse_recurrent=r_eff,
        mode=schedule.mode,
        hoist_input=schedule.hoist_input,
        hoist_reuse=hr_eff,
        ii=schedule.ii, **kw)


def estimate_design_for_schedule(cfg: ModelConfig, schedule: KernelSchedule,
                                 fp: Optional[FixedPointConfig] = None,
                                 **kw) -> HLSDesign:
    return estimate_design(design_point_for_schedule(cfg, schedule, fp, **kw))


def schedule_estimate_for(cfg: ModelConfig, schedule: KernelSchedule,
                          fp: Optional[FixedPointConfig] = None
                          ) -> ScheduleEstimate:
    """Kernel-level (gate matmul) estimate from the same schedule object."""
    assert cfg.rnn is not None
    return estimate_schedule(schedule, cfg.rnn, fp)


# paper Sec. 5.2 GPU reference points (Nvidia V100, QuickDraw LSTM)
V100_THROUGHPUT_EPS = {1: 660.0, 10: 7700.0, 100: 30000.0}
