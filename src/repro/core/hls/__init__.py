from repro.core.hls.design import (  # noqa: F401
    HLSDesign,
    RNNDesignPoint,
    estimate_design,
)
from repro.core.hls.resources import FPGA_PARTS  # noqa: F401
