from repro.core.hls.design import (  # noqa: F401
    HLSDesign,
    RNNDesignPoint,
    design_point_for_schedule,
    estimate_design,
    estimate_design_for_schedule,
    schedule_estimate_for,
)
from repro.core.hls.design_point import (  # noqa: F401
    PARETO_AXES,
    DesignPoint,
    price_decode_point,
    price_point,
)
from repro.core.hls.resources import (  # noqa: F401
    FPGA_PARTS,
    ScheduleEstimate,
    SpeculativeEstimate,
    admission_rate_eps,
    estimate_decode_step,
    estimate_lm_decode,
    estimate_schedule,
    estimate_speculative,
    expected_round_tokens,
    gate_count,
    resolved_axes,
)
