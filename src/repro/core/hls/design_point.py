"""Unified priced design point — ONE record shared by the kernel-level
estimator (resources.py), the table-calibrated FPGA model (design.py), and
the autotune explorer.

Before this module the two pricing paths were bridged separately at every
call site (the serving engine paired ``estimate_schedule`` rows with
``estimate_design`` rows by hand; benchmarks re-derived the gate dimension
and effective reuse).  ``price_point`` now produces a single frozen
:class:`DesignPoint` that carries the schedule, the fixed-point config, the
kernel-level :class:`ScheduleEstimate` AND the table-calibrated
:class:`HLSDesign` — all derived from the SAME schedule object the kernels
execute, with the reuse axes resolved exactly once (``resolved_axes``).

The explorer's Pareto dominance is defined here so that "no returned point
is dominated" means the same thing everywhere: the paper's trade space is
(latency, DSP, BRAM) — Fig. 1's curve plus the Fig. 6 resource axis.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.config import FixedPointConfig, ModelConfig
from repro.core.hls.design import HLSDesign, estimate_design_for_schedule
from repro.core.hls.resources import ScheduleEstimate, estimate_schedule
from repro.kernels.schedule import KernelSchedule, schedule_key

#: the Pareto axes — the paper's latency/resource trade space
PARETO_AXES = ("latency_cycles", "dsp", "bram_18k")


@dataclass(frozen=True)
class DesignPoint:
    """One fully priced (schedule, fixed-point) point of the design space.

    ``estimate`` is the kernel-level price (the structure the Pallas kernels
    execute: grid length, live weight tile); ``design`` is the
    table-calibrated FPGA price (Vivado-shaped FF/LUT, part fit).  Both are
    derived from ``schedule`` — never from parallel hand-kept knobs.
    """

    schedule: KernelSchedule
    fp: Optional[FixedPointConfig]
    estimate: ScheduleEstimate
    design: HLSDesign
    clock_mhz: float = 200.0

    # -- identity -----------------------------------------------------------

    @property
    def key(self) -> str:
        """The serving layer's co-batching key: the queue an auto-picked
        point lands on is exactly this string."""
        return schedule_key(self.schedule, self.fp)

    # -- the Pareto axes ----------------------------------------------------

    @property
    def latency_cycles(self) -> int:
        return self.estimate.latency_cycles

    @property
    def dsp(self) -> int:
        return self.estimate.dsp

    @property
    def bram_18k(self) -> int:
        return self.estimate.bram_18k

    @property
    def ii_cycles(self) -> int:
        return self.estimate.ii_cycles

    def latency_us(self, clock_mhz: Optional[float] = None) -> float:
        return self.estimate.latency_us(clock_mhz or self.clock_mhz)

    def throughput_eps(self, clock_mhz: Optional[float] = None) -> float:
        return self.estimate.throughput_eps(clock_mhz or self.clock_mhz)

    # -- dominance ----------------------------------------------------------

    def dominates(self, other: "DesignPoint") -> bool:
        """Weakly better on every Pareto axis, strictly better on one."""
        mine = (self.latency_cycles, self.dsp, self.bram_18k)
        theirs = (other.latency_cycles, other.dsp, other.bram_18k)
        return (all(a <= b for a, b in zip(mine, theirs))
                and any(a < b for a, b in zip(mine, theirs)))

    # -- reporting ----------------------------------------------------------

    def report_row(self) -> dict:
        row = self.estimate.report_row(self.clock_mhz)
        row.update(key=self.key,
                   fits=self.design.fits,
                   part=self.design.part,
                   design_latency_us=self.design.latency_min_us,
                   design_dsp=self.design.dsp)
        return row


def price_point(cfg: ModelConfig, schedule: KernelSchedule,
                fp: Optional[FixedPointConfig] = None, *,
                clock_mhz: float = 200.0,
                part: str = "xcku115") -> DesignPoint:
    """Price one (schedule, fp) point through BOTH models at once."""
    assert cfg.rnn is not None, "design points apply to the RNN tagger family"
    return DesignPoint(
        schedule=schedule,
        fp=fp,
        estimate=estimate_schedule(schedule, cfg.rnn, fp),
        design=estimate_design_for_schedule(cfg, schedule, fp, part=part,
                                            clock_mhz=clock_mhz),
        clock_mhz=clock_mhz)


def price_decode_point(cfg: ModelConfig, schedule: KernelSchedule,
                       fp: Optional[FixedPointConfig] = None, *,
                       clock_mhz: float = 200.0,
                       part: str = "xcku115") -> DesignPoint:
    """Price one decode-legal point for the SINGLE-STEP path.

    ``estimate`` is :func:`~repro.core.hls.resources.estimate_decode_step`
    — one state update, II ~ R, full weight resident — the structure the
    ``kernels/decode_step.py`` kernels execute.  ``design`` keeps the
    table-calibrated full-model fit (the Vivado tables are calibrated on
    whole-sequence designs; a part that fits the scan fits its single-step
    engine), so part-fit feasibility stays meaningful while the Pareto
    axes price the decode step itself.
    """
    from repro.core.hls.resources import estimate_decode_step

    assert cfg.rnn is not None, "design points apply to the RNN tagger family"
    return DesignPoint(
        schedule=schedule,
        fp=fp,
        estimate=estimate_decode_step(schedule, cfg.rnn, fp),
        design=estimate_design_for_schedule(cfg, schedule, fp, part=part,
                                            clock_mhz=clock_mhz),
        clock_mhz=clock_mhz)
