"""FPGA part catalogue (paper Sec. 5), DSP packing rules, and the
schedule-driven latency/resource estimator.

``estimate_schedule`` consumes the SAME :class:`KernelSchedule` object the
Pallas kernels execute (kernels/ops.py), so the latency-cycle count is by
construction the kernel's sequential grid length and the DSP/BRAM/VMEM
numbers describe the weight tile that schedule actually keeps live.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.kernels.schedule import KernelSchedule


@dataclass(frozen=True)
class FPGAPart:
    name: str
    dsp: int
    ff: int
    lut: int
    bram_18k: int


FPGA_PARTS = {
    # Xilinx Kintex UltraScale (top/flavor tagging target)
    "xcku115": FPGAPart("xcku115-flvb2104-2-i", dsp=5520, ff=1326720,
                        lut=663360, bram_18k=4320),
    # Xilinx Alveo U250 (QuickDraw target)
    "u250": FPGAPart("xcu250-figd2104-2-e", dsp=12288, ff=3456000,
                     lut=1728000, bram_18k=5376),
    # Virtex UltraScale+ VU9P single SLR (CMS L1T phase-2 candidate)
    "vu9p_slr": FPGAPart("xcvu9p (1 SLR)", dsp=2280, ff=788160,
                         lut=394080, bram_18k=1440),
}


def mults_per_dsp(total_bits: int) -> float:
    """DSP48E2 is a 27x18 multiplier: below 18 bits one mult per DSP; the
    paper observes DSP usage flat until the precision exceeds the DSP input
    width, then doubling (Fig. 3)."""
    if total_bits <= 18:
        return 1.0
    if total_bits <= 27:
        return 2.0
    return 4.0


# ---------------------------------------------------------------------------
# Schedule-driven estimates (the software side of the paper's Fig. 1 curve)
# ---------------------------------------------------------------------------

# pipeline depth of one reuse pass (activation LUT + accumulate), cycles
_C_PIPE = 4


@dataclass(frozen=True)
class ScheduleEstimate:
    """What one (cell, schedule) point costs, in paper units.

    latency_cycles  end-to-end cycles for ONE inference — grows with R
    ii_cycles       cycles before the next inference can enter
    dsp             parallel multipliers live at once (x seq_len blocks for
                    non-static) — shrinks with R
    bram_18k        weight storage (non-static replicates per block)
    vmem_bytes      TPU analogue: live weight tile + scratch per kernel step
    """

    schedule: KernelSchedule
    latency_cycles: int
    ii_cycles: int
    dsp: int
    bram_18k: int
    vmem_bytes: int

    def latency_us(self, clock_mhz: float = 200.0) -> float:
        return self.latency_cycles / clock_mhz

    def throughput_eps(self, clock_mhz: float = 200.0) -> float:
        return clock_mhz * 1e6 / max(self.ii_cycles, 1)

    def report_row(self, clock_mhz: float = 200.0) -> dict:
        """The analytical column of the serving layer's measured-vs-
        analytical table, keyed exactly like the measured one."""
        return {
            "schedule_key": self.schedule.key(),
            "latency_cycles": self.latency_cycles,
            "latency_us": self.latency_us(clock_mhz),
            "ii_cycles": self.ii_cycles,
            "throughput_eps": self.throughput_eps(clock_mhz),
            "dsp": self.dsp,
            "bram_18k": self.bram_18k,
            "vmem_bytes": self.vmem_bytes,
        }


def gate_mults(cell: str, input_size: int, hidden: int) -> int:
    """Multiplications of one recurrent step (kernel + recurrent matmul)."""
    g = 4 if cell == "lstm" else 3
    return (input_size + hidden) * g * hidden


def estimate_schedule(schedule: KernelSchedule, rnn, fp=None
                      ) -> ScheduleEstimate:
    """Latency/resource estimate derived from the schedule object itself.

    ``rnn`` is an ``RNNConfig``; ``fp`` an optional ``FixedPointConfig``
    (defaults to the paper's ap_fixed<16,6>).  Monotone by construction:
    latency_cycles rises and dsp falls as reuse_factor grows.
    """
    total_bits = fp.total_bits if fp is not None else 16
    g = 4 if rnn.cell == "lstm" else 3
    # price what EXECUTES: the kernels clamp reuse to a divisor of the gate
    # dim (ops.py), so the estimate must use the same effective R or it
    # would describe a schedule that never runs
    R = schedule.effective_reuse(g * rnn.hidden)
    mults = gate_mults(rnn.cell, rnn.input_size, rnn.hidden)

    # latency/II in kernel sequential steps (exactly the Pallas grid length
    # (B/bt, T, R_eff)), each step costing a pipeline constant
    latency = rnn.seq_len * R + _C_PIPE
    ii = (rnn.seq_len * R if schedule.mode == "static"
          else R + _C_PIPE)

    # parallel multipliers per block = mults / R; non-static has seq_len
    # blocks in silicon (Fig. 6 resource blowup)
    blocks = rnn.seq_len if schedule.mode == "nonstatic" else 1
    dsp = int(-(-mults // R) * mults_per_dsp(total_bits)) * blocks
    weight_bits = mults * total_bits
    bram = int(-(-weight_bits // 18432)) * blocks

    # TPU: live weight column tile + gate scratch + state, f32
    gw = (g * rnn.hidden) // R
    bt = schedule.block_batch
    vmem = 4 * ((rnn.input_size + rnn.hidden) * gw        # weight tile
                + bt * g * rnn.hidden                     # z scratch
                + 2 * bt * rnn.hidden)                    # h, c state
    return ScheduleEstimate(schedule=schedule, latency_cycles=latency,
                            ii_cycles=ii, dsp=dsp, bram_18k=bram,
                            vmem_bytes=vmem)
