"""FPGA part catalogue (paper Sec. 5) and DSP packing rules."""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class FPGAPart:
    name: str
    dsp: int
    ff: int
    lut: int
    bram_18k: int


FPGA_PARTS = {
    # Xilinx Kintex UltraScale (top/flavor tagging target)
    "xcku115": FPGAPart("xcku115-flvb2104-2-i", dsp=5520, ff=1326720,
                        lut=663360, bram_18k=4320),
    # Xilinx Alveo U250 (QuickDraw target)
    "u250": FPGAPart("xcu250-figd2104-2-e", dsp=12288, ff=3456000,
                     lut=1728000, bram_18k=5376),
    # Virtex UltraScale+ VU9P single SLR (CMS L1T phase-2 candidate)
    "vu9p_slr": FPGAPart("xcvu9p (1 SLR)", dsp=2280, ff=788160,
                         lut=394080, bram_18k=1440),
}


def mults_per_dsp(total_bits: int) -> float:
    """DSP48E2 is a 27x18 multiplier: below 18 bits one mult per DSP; the
    paper observes DSP usage flat until the precision exceeds the DSP input
    width, then doubling (Fig. 3)."""
    if total_bits <= 18:
        return 1.0
    if total_bits <= 27:
        return 2.0
    return 4.0
