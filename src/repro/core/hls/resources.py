"""FPGA part catalogue (paper Sec. 5), DSP packing rules, and the
schedule-driven latency/resource estimator.

``estimate_schedule`` consumes the SAME :class:`KernelSchedule` object the
Pallas kernels execute (kernels/ops.py), so the latency-cycle count is by
construction the kernel's sequential grid length and the DSP/BRAM/VMEM
numbers describe the weight tile that schedule actually keeps live.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.quant.fixed_point import is_native_int, packed_weight_bytes
from repro.kernels.schedule import KernelSchedule


def _act_itemsize(fp) -> int:
    """Bytes per live activation/state element: native int datapaths hold
    int8 grid indices (1 byte); float and emulated fp paths hold f32."""
    return 1 if is_native_int(fp) else 4


@dataclass(frozen=True)
class FPGAPart:
    name: str
    dsp: int
    ff: int
    lut: int
    bram_18k: int


FPGA_PARTS = {
    # Xilinx Kintex UltraScale (top/flavor tagging target)
    "xcku115": FPGAPart("xcku115-flvb2104-2-i", dsp=5520, ff=1326720,
                        lut=663360, bram_18k=4320),
    # Xilinx Alveo U250 (QuickDraw target)
    "u250": FPGAPart("xcu250-figd2104-2-e", dsp=12288, ff=3456000,
                     lut=1728000, bram_18k=5376),
    # Virtex UltraScale+ VU9P single SLR (CMS L1T phase-2 candidate)
    "vu9p_slr": FPGAPart("xcvu9p (1 SLR)", dsp=2280, ff=788160,
                         lut=394080, bram_18k=1440),
}


def gate_count(cell: str) -> int:
    """Gates per recurrent cell: LSTM i|f|c|o = 4, GRU r|z|n = 3 — the
    paper's 4:3 LSTM:GRU resource ratio (Sec. 5.2).  The single source of
    truth for the pricing bridge (resources.py, design.py, autotune)."""
    return 4 if cell == "lstm" else 3


def resolved_axes(schedule: KernelSchedule, rnn) -> "tuple[int, int]":
    """(effective reuse, effective hoist reuse) the kernels actually execute.

    The kernels clamp both reuse axes to divisors of the gate dimension
    (ops.py via ``effective_reuse`` / gcd), so every consumer of a schedule's
    price — ``estimate_schedule``, the table-calibrated design bridge, the
    autotune explorer — must resolve the same divisors or it would price a
    schedule that never runs.  This helper is that shared resolution.
    """
    gate_dim = gate_count(rnn.cell) * rnn.hidden
    return (schedule.effective_reuse(gate_dim),
            math.gcd(schedule.hoist_reuse, gate_dim))


def mults_per_dsp(total_bits: int) -> float:
    """DSP48E2 is a 27x18 multiplier: below 18 bits one mult per DSP; the
    paper observes DSP usage flat until the precision exceeds the DSP input
    width, then doubling (Fig. 3)."""
    if total_bits <= 18:
        return 1.0
    if total_bits <= 27:
        return 2.0
    return 4.0


# ---------------------------------------------------------------------------
# Schedule-driven estimates (the software side of the paper's Fig. 1 curve)
# ---------------------------------------------------------------------------

# pipeline depth of one reuse pass (activation LUT + accumulate), cycles
_C_PIPE = 4


@dataclass(frozen=True)
class ScheduleEstimate:
    """What one (cell, schedule) point costs, in paper units.

    latency_cycles  end-to-end cycles for ONE inference — grows with R.
                    The recurrence chain seq_len x R is irreducible (h_t
                    depends on h_{t-1}); hoisting adds the front-stage GEMM
                    cycles but halves the per-step working set, and
                    pipeline mode keeps this chain while dropping II.
    ii_cycles       cycles before the next inference can enter — the
                    II-based throughput axis: seq_len x R (static), one
                    block (nonstatic), or the schedule's explicit ``ii``
                    target (pipeline: slimmed hoisted blocks free up after
                    their hU tiles)
    dsp             parallel multipliers live at once (x seq_len blocks for
                    non-static/pipeline) — shrinks with R, and with
                    hoisting the replicated per-block mults drop from
                    (fin+h)*G*h to h*G*h (the shared hoist GEMM is counted
                    once)
    bram_18k        weight storage (non-static replicates per block; the
                    hoisted input weights are stored once)
    vmem_bytes      TPU analogue: live weight tile + scratch per kernel step
    weight_vmem_bytes  the weight portion of vmem_bytes alone — under a
                    native int fp this is the PACKED layout's bytes
                    (``packed_weight_bytes``: int8 /4, int4 /8 vs f32),
                    identical to what the residency cache measures
    """

    schedule: KernelSchedule
    latency_cycles: int
    ii_cycles: int
    dsp: int
    bram_18k: int
    vmem_bytes: int
    weight_vmem_bytes: int = 0

    def latency_us(self, clock_mhz: float = 200.0) -> float:
        return self.latency_cycles / clock_mhz

    def throughput_eps(self, clock_mhz: float = 200.0) -> float:
        return clock_mhz * 1e6 / max(self.ii_cycles, 1)

    def service_s(self, clock_mhz: float = 200.0) -> float:
        """End-to-end service time of one event, in seconds — the latency
        half of the streaming pipeline's single-server queue model."""
        return self.latency_us(clock_mhz) * 1e-6

    def ii_s(self, clock_mhz: float = 200.0) -> float:
        """Initiation interval in seconds — the server occupancy per event
        (the next event may enter after this, even while the previous one
        is still in flight on a pipelined design)."""
        return max(self.ii_cycles, 1) / (clock_mhz * 1e6)

    def report_row(self, clock_mhz: float = 200.0) -> dict:
        """The analytical column of the serving layer's measured-vs-
        analytical table, keyed exactly like the measured one."""
        return {
            "schedule_key": self.schedule.key(),
            "latency_cycles": self.latency_cycles,
            "latency_us": self.latency_us(clock_mhz),
            "ii_cycles": self.ii_cycles,
            "throughput_eps": self.throughput_eps(clock_mhz),
            "dsp": self.dsp,
            "bram_18k": self.bram_18k,
            "vmem_bytes": self.vmem_bytes,
            "weight_vmem_bytes": self.weight_vmem_bytes,
        }


def gate_mults(cell: str, input_size: int, hidden: int, *,
               hoisted: bool = False) -> int:
    """Multiplications of one recurrent step (kernel + recurrent matmul).

    ``hoisted=True`` counts only the recurrent (hU) half — the sequential
    working set once the input projection leaves the scan.
    """
    g = gate_count(cell)
    fan_in = hidden if hoisted else input_size + hidden
    return fan_in * g * hidden


def estimate_schedule(schedule: KernelSchedule, rnn, fp=None
                      ) -> ScheduleEstimate:
    """Latency/resource estimate derived from the schedule object itself.

    ``rnn`` is an ``RNNConfig``; ``fp`` an optional ``FixedPointConfig``
    (defaults to the paper's ap_fixed<16,6>).  Monotone by construction:
    latency_cycles rises and dsp falls as reuse_factor grows.

    II-based pricing of the hoisted/pipelined variants: the hoisted input
    GEMM is a shared fully-pipelined front stage (its cycles add once to
    latency; its multipliers/weights are NOT replicated per block), the
    sequential blocks carry only hU, and pipeline mode's II is the
    schedule's explicit ``ii`` target — exactly the structure the kernels
    in ops.py execute.
    """
    total_bits = fp.total_bits if fp is not None else 16
    g = gate_count(rnn.cell)
    # price what EXECUTES: the kernels clamp reuse to a divisor of the gate
    # dim (ops.py), so the estimate must use the same effective R or it
    # would describe a schedule that never runs
    R, hr = resolved_axes(schedule, rnn)
    hoist = schedule.hoist_input
    mults_seq = gate_mults(rnn.cell, rnn.input_size, rnn.hidden,
                           hoisted=hoist)
    mults_in = rnn.input_size * g * rnn.hidden            # the hoisted GEMM

    # latency/II in kernel sequential steps (exactly the Pallas grid length
    # (B/bt, T, R_eff)), each step costing a pipeline constant.  The
    # recurrence chain seq_len x R is irreducible; the hoist stage adds its
    # own pipelined pass (hr tiles) up front.
    latency = rnn.seq_len * R + _C_PIPE + (hr + _C_PIPE if hoist else 0)
    if schedule.mode == "static":
        ii = rnn.seq_len * R
    elif schedule.mode == "pipeline":
        # hoisted blocks free up after their R hU-tiles, so the next
        # inference enters at the schedule's ii target
        ii = schedule.initiation_interval(rnn.seq_len)
    else:
        ii = R + _C_PIPE

    # parallel multipliers per block = sequential mults / R; non-static and
    # pipeline have seq_len blocks in silicon (Fig. 6 resource blowup).
    # The hoist GEMM's multipliers are shared across blocks — added once.
    blocks = rnn.seq_len if schedule.mode in ("nonstatic", "pipeline") else 1
    pack = mults_per_dsp(total_bits)
    dsp = int(-(-mults_seq // R) * pack) * blocks
    weight_bits = mults_seq * total_bits
    bram = int(-(-weight_bits // 18432)) * blocks
    if hoist:
        dsp += int(-(-mults_in // hr) * pack)
        bram += int(-(-(mults_in * total_bits) // 18432))

    # TPU: live weight column tile + gate scratch + state; hoisting swaps
    # the (fin+h) x gw tile for h x gw plus the streamed zx tile.  The
    # pipeline kernel unrolls its R passes in-block with the full U
    # resident (the replicated-resources design it executes).  The weight
    # bytes come from packed_weight_bytes — the SAME formula the residency
    # packer realizes (f32, or the native int8/int4 packed layout) — and
    # activations/state shrink to 1 byte on the native datapath.
    gw = (g * rnn.hidden) // R
    bt = schedule.block_batch
    fan_in = rnn.hidden if hoist else rnn.input_size + rnn.hidden
    if schedule.mode == "pipeline":
        weight_vmem = packed_weight_bytes(rnn.hidden, g * rnn.hidden, fp)
    else:
        weight_vmem = packed_weight_bytes(fan_in, gw, fp)
    act = _act_itemsize(fp)
    vmem = weight_vmem + act * (
        bt * g * rnn.hidden                     # z/zh scratch
        + (bt * g * rnn.hidden if hoist else 0)  # zx stream tile
        + 2 * bt * rnn.hidden)                   # h, c state
    return ScheduleEstimate(schedule=schedule, latency_cycles=latency,
                            ii_cycles=ii, dsp=dsp, bram_18k=bram,
                            vmem_bytes=vmem, weight_vmem_bytes=weight_vmem)


# ---------------------------------------------------------------------------
# Throughput -> admission-rate bridge (the streaming pipeline's runtime gate)
# ---------------------------------------------------------------------------


def admission_rate_eps(estimate: ScheduleEstimate,
                       clock_mhz: float = 200.0, *,
                       utilization: float = 1.0) -> float:
    """Events/s an admission gate may let through for one priced schedule.

    This is the bridge that turns a :class:`DesignTarget` budget into a
    RUNTIME guarantee: the analytical initiation-interval throughput of the
    resolved schedule (``estimate.throughput_eps`` — the same number the
    explorer's feasibility check read) becomes the refill rate of the
    streaming pipeline's token bucket, derated by ``utilization``
    (queueing theory: a single-server queue is only stable below 1.0;
    1.0 is exact for deterministic arrivals, bursty traffic should derate).
    Arrivals beyond this rate are shed at ingest instead of growing an
    unbounded queue the design can never drain.
    """
    if not 0.0 < utilization <= 1.0:
        raise ValueError(f"utilization must be in (0, 1]: {utilization}")
    return utilization * estimate.throughput_eps(clock_mhz)


# ---------------------------------------------------------------------------
# Single-step decode estimates (the paper's single-event, II ~ R regime)
# ---------------------------------------------------------------------------


def estimate_decode_step(schedule: KernelSchedule, rnn, fp=None
                         ) -> ScheduleEstimate:
    """What one scheduled RNN decode step costs — the single-event engine.

    The decode kernels (kernels/decode_step.py) run the gate matmuls
    ``[B, d] @ [d, G*h]`` (d = input + hidden) as R column-tile passes
    unrolled in-block with the FULL weight matrix resident, so:

      latency_cycles  one step = the R sequential tile passes + pipe depth
                      (no seq_len factor — the state update IS the step)
      ii_cycles       ~ R: the block frees after its own tile passes, the
                      next event enters immediately (paper II 1-in-R)
      dsp             live multipliers per pass = d x G*h / R (x DSP pack)
      bram_18k        the resident weight store — R tiles storage, not 1/R:
                      residency trades multipliers, not memory
      vmem_bytes      full weight + gate scratch + state (TPU analogue)
    """
    total_bits = fp.total_bits if fp is not None else 16
    g = gate_count(rnn.cell)
    gate_dim = g * rnn.hidden
    R = schedule.effective_reuse(gate_dim)
    d_in = rnn.input_size + rnn.hidden
    mults = d_in * gate_dim
    pack = mults_per_dsp(total_bits)
    bt = schedule.block_batch
    # resident weights = the TWO matrices the decode step actually packs
    # (W: input x G*h, U: hidden x G*h) — per-matrix packed_weight_bytes so
    # the estimate equals the residency cache's measured packed nbytes
    weight_vmem = (packed_weight_bytes(rnn.input_size, gate_dim, fp)
                   + packed_weight_bytes(rnn.hidden, gate_dim, fp))
    act = _act_itemsize(fp)
    return ScheduleEstimate(
        schedule=schedule,
        latency_cycles=R + _C_PIPE,
        ii_cycles=R,
        dsp=int(-(-mults // R) * pack),
        bram_18k=int(-(-(mults * total_bits) // 18432)),
        vmem_bytes=weight_vmem + act * (bt * gate_dim + bt * d_in
                                        + 2 * bt * rnn.hidden),
        weight_vmem_bytes=weight_vmem)


def estimate_lm_decode(schedule: KernelSchedule, cfg, fp=None
                       ) -> ScheduleEstimate:
    """Per-token estimate of the scheduled dense-decoder step (the LM
    serving engine's decode path) from the SAME schedule object the keyed
    decoder executes.

    The scheduled step is a chain of fused matmuls per layer — q|k|v
    (gate-fused), attention out, MLP in (gate-fused), MLP down — each run
    as R in-block column-tile passes over resident weights.  Latency sums
    the chain (each matmul: its effective R passes + pipe depth); II is the
    widest matmul's R (the paper's single-token initiation interval); DSP
    counts every layer's live multipliers (all layers resident, like the
    non-static scan pricing); BRAM/VMEM hold the full resident weights.
    """
    total_bits = fp.total_bits if fp is not None else 16
    d, f = cfg.d_model, cfg.d_ff
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    glu = cfg.mlp_type in ("swiglu", "geglu")
    # (d_in, d_out) of each fused matmul in the per-layer chain
    chain = [(d, (hq + 2 * hk) * hd),            # q|k|v gate-fused
             (hq * hd, d),                       # attention out
             (d, 2 * f if glu else f),           # MLP in (gate|up fused)
             (f, d)]                             # MLP down
    pack = mults_per_dsp(total_bits)
    latency = dsp = bram = vmem_w = 0
    ii = 1
    for d_in, d_out in chain:
        R = schedule.effective_reuse(d_out)
        mults = d_in * d_out
        latency += R + _C_PIPE
        ii = max(ii, R)
        dsp += int(-(-mults // R) * pack)
        bram += int(-(-(mults * total_bits) // 18432))
        vmem_w += packed_weight_bytes(d_in, d_out, fp)
    L = cfg.n_layers
    bt = schedule.block_batch
    act = _act_itemsize(fp)
    return ScheduleEstimate(
        schedule=schedule,
        latency_cycles=L * latency,
        ii_cycles=ii,
        dsp=L * dsp,
        bram_18k=L * bram,
        vmem_bytes=L * vmem_w + act * (bt * max(o for _, o in chain)
                                       + 2 * bt * d),
        weight_vmem_bytes=L * vmem_w)


# ---------------------------------------------------------------------------
# Speculative decode pricing (draft cheap on high R, verify dense on R1)
# ---------------------------------------------------------------------------


def expected_round_tokens(k: int, accept_rate: float) -> float:
    """Expected tokens emitted per speculative round at draft depth ``k``
    and per-draft acceptance probability ``accept_rate`` (independent
    drafts): the truncated geometric sum ``(1 - a^(k+1)) / (1 - a)`` —
    between 1 (reject-all) and ``k + 1`` (accept-all, the bonus token
    included)."""
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1]: {accept_rate}")
    if accept_rate == 1.0:
        return float(k + 1)
    return (1.0 - accept_rate ** (k + 1)) / (1.0 - accept_rate)


@dataclass(frozen=True)
class SpeculativeEstimate:
    """What one speculative (draft, verify, K) triple costs per round.

    ``draft=None`` prices the free n-gram ``CacheTable`` draft (zero
    cycles, zero silicon); a schedule drafts on the model itself — K
    sequential steps at the cheap schedule's latency.  The verify pass is
    ONE batched K+1-position program on the dense schedule: its first
    position costs the full pipeline latency, each further position one
    more initiation interval (the paper's II-limited steady state).

      cycles_per_round = K x draft.latency + verify.latency
                         + K x max(verify.ii, 1)
      tokens_per_cycle = expected_round_tokens(K, accept_rate) / cycles

    ``speedup_vs_sequential`` compares against K=0 sequential decode on
    the SAME verify schedule (one token per verify latency) — exactly 1.0
    at K=0, by construction.  Resources are the sum of both resident
    datapaths: speculation buys its tokens/s with the draft schedule's
    (cheap) silicon, never with accuracy."""

    draft: Optional[ScheduleEstimate]
    verify: ScheduleEstimate
    k: int
    accept_rate: float
    expected_tokens: float
    cycles_per_round: float
    tokens_per_cycle: float
    dsp: int
    bram_18k: int

    def speedup_vs_sequential(self) -> float:
        return self.tokens_per_cycle * float(self.verify.latency_cycles)

    def tokens_per_s(self, clock_mhz: float = 200.0) -> float:
        return self.tokens_per_cycle * clock_mhz * 1e6

    def latency_us_per_token(self, clock_mhz: float = 200.0) -> float:
        return (self.cycles_per_round / max(self.expected_tokens, 1e-12)
                / clock_mhz)

    def report_row(self, clock_mhz: float = 200.0) -> dict:
        return {
            "k": self.k,
            "draft_key": (None if self.draft is None
                          else self.draft.schedule.key()),
            "verify_key": self.verify.schedule.key(),
            "accept_rate": self.accept_rate,
            "expected_tokens": self.expected_tokens,
            "cycles_per_round": self.cycles_per_round,
            "tokens_per_cycle": self.tokens_per_cycle,
            "tokens_per_s": self.tokens_per_s(clock_mhz),
            "speedup_vs_sequential": self.speedup_vs_sequential(),
            "dsp": self.dsp,
            "bram_18k": self.bram_18k,
        }


def estimate_speculative(draft_est: Optional[ScheduleEstimate],
                         verify_est: ScheduleEstimate, k: int,
                         accept_rate: float) -> SpeculativeEstimate:
    """Price a (draft, verify, K) speculative triple analytically.

    ``draft_est=None`` is the n-gram table draft (free); otherwise the
    draft schedule pays K sequential single-step latencies per round.
    The verify pass pays one dense latency plus K extra initiation
    intervals for the batched positions.  At ``k=0`` the round IS the
    sequential step (no drafts, no extra positions): tokens_per_cycle is
    exactly ``1 / verify.latency_cycles`` and the speedup is exactly 1.
    """
    if k < 0:
        raise ValueError(f"k must be >= 0, got {k}")
    exp_tok = expected_round_tokens(k, accept_rate)
    draft_cycles = 0.0 if draft_est is None \
        else float(k * draft_est.latency_cycles)
    cycles = (draft_cycles + float(verify_est.latency_cycles)
              + float(k * max(verify_est.ii_cycles, 1)))
    dsp = verify_est.dsp + (0 if draft_est is None else draft_est.dsp)
    bram = verify_est.bram_18k + (0 if draft_est is None
                                  else draft_est.bram_18k)
    return SpeculativeEstimate(
        draft=draft_est, verify=verify_est, k=k, accept_rate=accept_rate,
        expected_tokens=exp_tok, cycles_per_round=cycles,
        tokens_per_cycle=exp_tok / cycles, dsp=dsp, bram_18k=bram)
