"""Non-static mode across devices: sequence-pipelined RNN inference.

The paper's non-static mode instantiates one RNN block per timestep and
passes state block-to-block, dropping the initiation interval from seq_len
to 1 block (Table 5: II 315 -> 1).  The TPU adaptation maps timestep GROUPS
to devices along a mesh axis: device k owns timesteps [k*spp, (k+1)*spp);
recurrent state hops k -> k+1 via collective_permute.  A software-pipeline
schedule streams a batch of B inferences through P stages in B + P - 1
beats; steady-state II = spp block-steps instead of T — exactly the paper's
throughput argument, with ICI hops playing the role of block-to-block wires.

Run under jax.jit with the mesh active; tests verify bit-equality with the
static scan on 8 host devices.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.config import RNNConfig
from repro.core.rnn.cells import gru_cell, lstm_cell
from repro.kernels.compat import shard_map


def pipelined_rnn(
    rnn: RNNConfig,
    xs: jax.Array,             # [B, T, F]
    W: jax.Array, U: jax.Array, b: jax.Array,
    mesh: Mesh,
    axis: str = "model",
    hoist_input: bool = False,
) -> jax.Array:
    """Returns final hidden state [B, hidden]; T must divide the axis size.

    ``hoist_input`` is the multi-device face of the hoisted-projection
    schedule (KernelSchedule.hoist_input / pipeline mode): zx = xs @ W for
    ALL timesteps is one batched matmul BEFORE the stage pipeline, so each
    stage's blocks carry only the hU recurrence — the per-stage (and thus
    per-beat) latency drops, which is exactly what shrinks the pipeline's
    initiation interval.
    """
    B, T, F = xs.shape
    n_stages = mesh.shape[axis]
    assert T % n_stages == 0, f"T={T} % stages={n_stages}"
    spp = T // n_stages
    H = rnn.hidden
    cell = lstm_cell if rnn.cell == "lstm" else gru_cell
    n_state = 2 if rnn.cell == "lstm" else 1

    if hoist_input:
        # the hoist stage: stream slices of zx (not xs) through the pipe;
        # cells consume the precomputed projection via their zx= injection
        xs = jnp.einsum("btf,fg->btg", xs, W,
                        preferred_element_type=jnp.float32).astype(xs.dtype)
        F = xs.shape[-1]

    def stage_fn(xs_local, W_, U_, b_):
        # xs_local: [B, spp, F] — this device's timestep slice (zx when
        # hoisted: F = G*H and the x-side matmul is skipped in-cell)
        k = jax.lax.axis_index(axis)
        perm = [(i, i + 1) for i in range(n_stages - 1)]

        def run_block(x_blk, state):
            # x_blk: [1, spp, F]; state tuple of [1, H]
            def step(s, x_t):
                st = (s[0], s[1]) if n_state == 2 else s[0]
                _, ns = cell(x_t, st, W_, U_, b_,
                             **({"zx": x_t} if hoist_input else {}))
                ns = ns if n_state == 2 else (ns,)
                return (ns[0],) + ((ns[1],) if n_state == 2 else ()), None
            s0 = tuple(state[i] for i in range(n_state))
            sT, _ = jax.lax.scan(step, s0, jnp.moveaxis(x_blk, 1, 0))
            return jnp.stack(sT)                       # [n_state, 1, H]

        def beat(j, carry):
            out_acc, state_in = carry
            i = j - k                                   # inference handled now
            valid = (i >= 0) & (i < B)
            idx = jnp.clip(i, 0, B - 1)
            x_blk = jax.lax.dynamic_slice(
                xs_local, (idx, 0, 0), (1, spp, F))
            boundary = jnp.where(k == 0,
                                 jnp.zeros_like(state_in), state_in)
            state_out = run_block(x_blk, boundary)
            state_out = jnp.where(valid, state_out,
                                  jnp.zeros_like(state_out))
            # emit: last stage writes the finished inference's hidden state
            emit = valid & (k == n_stages - 1)
            out_acc = jax.lax.dynamic_update_slice(
                out_acc,
                jnp.where(emit, state_out[0],
                          jax.lax.dynamic_slice(out_acc, (idx, 0), (1, H))),
                (idx, 0))
            # pass state rightwards for the next beat
            state_pass = jax.lax.ppermute(state_out, axis, perm)
            return out_acc, state_pass

        out0 = jnp.zeros((B, H), xs_local.dtype)
        s0 = jnp.zeros((n_state, 1, H), xs_local.dtype)
        out, _ = jax.lax.fori_loop(0, B + n_stages - 1, beat, (out0, s0))
        # outputs live on the last stage; share them with everyone
        out = jax.lax.psum(
            jnp.where(k == n_stages - 1, out, jnp.zeros_like(out)), axis)
        return out

    in_specs = (P(None, axis, None), P(), P(), P())
    fn = shard_map(stage_fn, mesh=mesh, in_specs=in_specs,
                       out_specs=P(), check_vma=False)
    return fn(xs, W, U, b)
