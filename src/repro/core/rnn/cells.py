"""LSTM / GRU cells — Keras-compatible math (paper Eq. 1).

Weight layout follows Keras so trained Keras models translate one-to-one
(the hls4ml design flow the paper builds on):

  LSTM: kernel W [in, 4h] (gates i|f|c|o), recurrent U [h, 4h], bias [4h]
  GRU (reset_after): kernel [in, 3h] (z|r|hh), recurrent [h, 3h],
                     bias [2, 3h] (input bias ; recurrent bias)

Each state update = kernel matvec + recurrent matvec + Hadamard products —
the exact op decomposition the paper maps onto hls4ml dense calls plus their
new HLS Hadamard primitive.  The quantized variants apply ap_fixed<W,I>
emulation to every intermediate, mirroring hls4ml's fixed-point datapath.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig, RNNConfig
from repro.core.quant.fixed_point import quantize
from repro.models.init import ParamSpec, ParamSpecs


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def rnn_param_specs(rnn: RNNConfig, prefix: str = "rnn") -> ParamSpecs:
    h, fin = rnn.hidden, rnn.input_size
    g = 4 if rnn.cell == "lstm" else 3
    specs = {
        f"{prefix}/kernel": ParamSpec((fin, g * h), ("rnn_in", "rnn_gates"), "lecun"),
        f"{prefix}/recurrent": ParamSpec((h, g * h), ("rnn_hidden", "rnn_gates"),
                                         "rnn_ortho"),
    }
    if rnn.cell == "lstm":
        specs[f"{prefix}/bias"] = ParamSpec((g * h,), ("rnn_gates",), "zeros")
    else:
        specs[f"{prefix}/bias"] = ParamSpec((2, g * h), (None, "rnn_gates"), "zeros")
    return specs


# ---------------------------------------------------------------------------
# Float cells
# ---------------------------------------------------------------------------


def tiled_matmul(x: jax.Array, w: jax.Array, reuse: int = 1) -> jax.Array:
    """x @ w computed as ``reuse`` sequential column tiles — the cell-level
    realization of the schedule's reuse factor.  Column tiles are
    independent, so any R agrees with R=1 up to fp accumulation order
    (each output column is the same dot product); what changes is the live
    weight working set, which the Pallas kernels and the HLS estimators
    track.  The hot XLA path (layer.py) always uses R=1; this exists for
    explicit schedule emulation and as documentation of the partitioning.
    """
    if reuse <= 1:
        return x @ w
    n = w.shape[-1]
    assert n % reuse == 0, (n, reuse)
    ns = n // reuse
    return jnp.concatenate(
        [x @ w[:, r * ns:(r + 1) * ns] for r in range(reuse)], axis=-1)


def lstm_cell(x_t: jax.Array, state: Tuple[jax.Array, jax.Array],
              W: jax.Array, U: jax.Array, b: jax.Array, *, reuse: int = 1,
              matmul=None, zx=None):
    """One LSTM step.  x_t: [b, in]; state = (h, c): [b, h] each.

    ``matmul`` swaps the gate matmul implementation (the non-static Pallas
    path injects its column-serialized kernel here, so the gate equations
    live in exactly one place); default is ``tiled_matmul`` at ``reuse``.
    ``zx`` injects a PRECOMPUTED input projection x_t @ W (no bias) — the
    hoisted-input schedule: only the hU product remains in the step, and the
    association (xW + hU) + b is unchanged, so hoisted == in-loop bitwise.
    """
    mm = matmul if matmul is not None else (
        lambda a, w: tiled_matmul(a, w, reuse))
    h_prev, c_prev = state
    hdim = h_prev.shape[-1]
    z = (zx if zx is not None else mm(x_t, W)) + mm(h_prev, U) + b
    i, f, g, o = (z[..., :hdim], z[..., hdim:2 * hdim],
                  z[..., 2 * hdim:3 * hdim], z[..., 3 * hdim:])
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c_t = f * c_prev + i * g                         # Hadamard products
    h_t = o * jnp.tanh(c_t)
    return h_t, (h_t, c_t)


def gru_cell(x_t: jax.Array, state: jax.Array,
             W: jax.Array, U: jax.Array, b: jax.Array, *, reuse: int = 1,
             matmul=None, zx=None):
    """One GRU step (reset_after).  x_t: [b, in]; state h: [b, h];
    b: [2, 3h] = (input bias; recurrent bias).  ``matmul`` and ``zx``
    (precomputed x_t @ W, no bias) as in lstm_cell.
    """
    mm = matmul if matmul is not None else (
        lambda a, w: tiled_matmul(a, w, reuse))
    h_prev = state
    hdim = h_prev.shape[-1]
    b_in, b_rec = b[0], b[1]
    zx = (zx if zx is not None else mm(x_t, W)) + b_in   # [b, 3h]
    zh = mm(h_prev, U) + b_rec
    zxz, zxr, zxh = jnp.split(zx, 3, axis=-1)
    zhz, zhr, zhh = jnp.split(zh, 3, axis=-1)
    z = jax.nn.sigmoid(zxz + zhz)
    r = jax.nn.sigmoid(zxr + zhr)
    hh = jnp.tanh(zxh + r * zhh)                     # Hadamard inside tanh
    h_t = z * h_prev + (1.0 - z) * hh                # Hadamard combine
    return h_t, h_t


# ---------------------------------------------------------------------------
# Fixed-point cells (bit-accurate hls4ml datapath emulation)
# ---------------------------------------------------------------------------


def _q(x, fp: Optional[FixedPointConfig]):
    return x if fp is None else quantize(x, fp)


def lstm_cell_quantized(x_t, state, W, U, b, fp: FixedPointConfig, *,
                        matmul=None):
    """LSTM step with every intermediate on the ap_fixed grid.

    Matches hls4ml's datapath: quantized inputs/weights, quantized
    accumulator outputs, LUT-indexed activations (quantized in/out),
    quantized Hadamard products.  ``matmul`` injects the gate matmul
    implementation (the scheduled decode kernel) as in :func:`lstm_cell`;
    it must be value-equal to ``@`` for the datapath to stay bit-accurate.
    """
    mm = matmul if matmul is not None else (lambda a, w: a @ w)
    h_prev, c_prev = state
    hdim = h_prev.shape[-1]
    x_t = _q(x_t, fp)
    z = _q(mm(x_t, W) + mm(h_prev, U) + b, fp)
    i, f, g, o = (z[..., :hdim], z[..., hdim:2 * hdim],
                  z[..., 2 * hdim:3 * hdim], z[..., 3 * hdim:])
    i = _q(jax.nn.sigmoid(i), fp)
    f = _q(jax.nn.sigmoid(f), fp)
    g = _q(jnp.tanh(g), fp)
    o = _q(jax.nn.sigmoid(o), fp)
    c_t = _q(_q(f * c_prev, fp) + _q(i * g, fp), fp)
    h_t = _q(o * _q(jnp.tanh(c_t), fp), fp)
    return h_t, (h_t, c_t)


def gru_cell_quantized(x_t, state, W, U, b, fp: FixedPointConfig, *,
                       matmul=None):
    mm = matmul if matmul is not None else (lambda a, w: a @ w)
    h_prev = state
    x_t = _q(x_t, fp)
    zx = _q(mm(x_t, W) + b[0], fp)
    zh = _q(mm(h_prev, U) + b[1], fp)
    zxz, zxr, zxh = jnp.split(zx, 3, axis=-1)
    zhz, zhr, zhh = jnp.split(zh, 3, axis=-1)
    z = _q(jax.nn.sigmoid(zxz + zhz), fp)
    r = _q(jax.nn.sigmoid(zxr + zhr), fp)
    hh = _q(jnp.tanh(_q(zxh + _q(r * zhh, fp), fp)), fp)
    h_t = _q(_q(z * h_prev, fp) + _q((1.0 - z) * hh, fp), fp)
    return h_t, h_t


def initial_state(cell: str, batch: int, hidden: int, dtype=jnp.float32):
    h0 = jnp.zeros((batch, hidden), dtype)
    if cell == "lstm":
        return (h0, jnp.zeros((batch, hidden), dtype))
    return h0
