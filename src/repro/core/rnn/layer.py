"""RNN layer execution modes — the paper's static / non-static scheduling,
adapted to TPU.

static     — one RNN block processes every timestep; state lives in the block
             (paper Fig. 1 left).  TPU realization: ``lax.scan`` over time —
             weights stay resident (VMEM ≈ BRAM), II = seq_len.  The Pallas
             ``lstm_scan``/``gru_scan`` kernels implement exactly this with
             explicit VMEM residency (impl='pallas').

nonstatic  — one block per timestep, state flows block->block (Fig. 1 right).
             TPU realization: fully unrolled python loop — XLA materializes
             seq_len independent gate computations (≈ seq_len blocks laid out
             in silicon), enabling cross-inference pipelining.  The
             multi-device version (`core.rnn.pipeline`) maps timesteps to
             devices along a mesh axis with collective_permute — a new
             inference enters the pipe every stage latency: II = 1 block.

pipeline   — non-static with the input projection HOISTED out of the blocks
             (schedule.hoist_input, forced): xW for all T runs as one
             batched matmul up front, each unrolled block carries only hU.
             With ``schedule.hoist_input`` the float XLA paths (static scan
             and unrolled) also precompute zx = xs @ W once — the same
             restructuring the Pallas kernels execute.  Quantized (fp)
             paths never hoist: splitting z = q(xW + hU + b) would move the
             quantization points of the hls4ml datapath being emulated.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig, RNNConfig
from repro.core.rnn.cells import (
    gru_cell,
    gru_cell_quantized,
    initial_state,
    lstm_cell,
    lstm_cell_quantized,
)
from repro.kernels.schedule import KernelSchedule


def _cell_fn(cell: str, fp: Optional[FixedPointConfig]):
    if cell == "lstm":
        if fp is None:
            return lstm_cell
        return lambda x, s, W, U, b: lstm_cell_quantized(x, s, W, U, b, fp)
    if fp is None:
        return gru_cell
    return lambda x, s, W, U, b: gru_cell_quantized(x, s, W, U, b, fp)


def rnn_layer(
    rnn: RNNConfig,
    xs: jax.Array,                      # [b, T, in]
    W: jax.Array,
    U: jax.Array,
    b: jax.Array,
    *,
    fp: Optional[FixedPointConfig] = None,
    mode: Optional[str] = None,
    impl: str = "xla",
    schedule: Optional[KernelSchedule] = None,
    lengths: Optional[jax.Array] = None,
) -> jax.Array:
    """Run the recurrent layer; returns the final hidden state [b, h].

    The execution schedule comes from (highest priority first) the
    ``schedule`` argument, the config's ``rnn.kernel_schedule()``, with the
    explicit ``mode`` argument overriding the schedule's mode either way.

    ``lengths`` [b] enables the pad-and-mask ragged path: row i's state
    freezes once t >= lengths[i], so a padded batch of variable-length
    sequences returns each row's state at ITS final true step — bit-identical
    per row to scanning that row unpadded (masked rows compute the same cell
    ops; the freeze is a row-local select).  The masked scan runs on the XLA
    cells for every impl (masking inside the Pallas kernels would change the
    schedule being priced).
    """
    schedule = schedule or rnn.kernel_schedule()
    if mode is not None and mode != schedule.mode:
        schedule = schedule.replace(mode=mode)
    mode = schedule.mode
    batch = xs.shape[0]
    # XLA cells always run reuse=1: column tiling is bit-identical there
    # (cells.tiled_matmul) and only costs graph size; the reuse factor takes
    # physical effect in the Pallas kernels and the HLS estimators
    cell = _cell_fn(rnn.cell, fp)
    s0 = initial_state(rnn.cell, batch, rnn.hidden, xs.dtype)

    if lengths is not None:
        lengths = jnp.asarray(lengths)

        def masked_step(state, inp):
            x_t, t = inp
            _, new = cell(x_t, state, W, U, b)
            keep = (t < lengths)[:, None]
            if rnn.cell == "lstm":
                new = (jnp.where(keep, new[0], state[0]),
                       jnp.where(keep, new[1], state[1]))
            else:
                new = jnp.where(keep, new, state)
            return new, ()

        ts = jnp.arange(xs.shape[1])
        final, _ = jax.lax.scan(masked_step, s0,
                                (jnp.moveaxis(xs, 1, 0), ts))
        return final[0] if rnn.cell == "lstm" else final

    if impl == "pallas":
        from repro.core.quant.fixed_point import is_native_int
        from repro.kernels import ops as kops

        # fp=None: the float kernels (bit-identical to before).  Native
        # integral fp: the int8/int4 kernel bodies.  Emulated fp configs
        # stay on the XLA quantized cells below — emulation IS the
        # reference datapath, there is no Pallas body for it.
        if fp is None or is_native_int(fp):
            if rnn.cell == "lstm":
                return kops.lstm_scan(xs, W, U, b, schedule=schedule, fp=fp)
            return kops.gru_scan(xs, W, U, b, schedule=schedule, fp=fp)

    # hoisted input projection on the float XLA path: one batched
    # [b, T, fin] @ [fin, G*h] matmul up front, cells consume zx slices —
    # same dtype and association (xW + hU) + b as the in-loop cells'
    # per-step x_t @ W, so the carry dtype and numerics are unchanged.
    # Quantized paths keep the in-loop order (hoisting would move the q()
    # points).
    zx_all = None
    if schedule.hoist_input and fp is None:
        zx_all = jnp.einsum("btf,fg->btg", xs, W)

    if mode == "static":
        if zx_all is not None:
            # the cell ignores x_t when zx is injected: stream zx alone
            def step_hoisted(state, zx_t):
                _, new_state = cell(zx_t, state, W, U, b, zx=zx_t)
                return new_state, ()
            final, _ = jax.lax.scan(step_hoisted, s0,
                                    jnp.moveaxis(zx_all, 1, 0))
            return final[0] if rnn.cell == "lstm" else final

        def step(state, x_t):
            h_t, new_state = cell(x_t, state, W, U, b)
            return new_state, ()
        final, _ = jax.lax.scan(step, s0, jnp.moveaxis(xs, 1, 0))
        return final[0] if rnn.cell == "lstm" else final

    # nonstatic / pipeline: fully unrolled — one "block" per timestep
    state = s0
    for t in range(xs.shape[1]):
        if zx_all is not None:
            _, state = cell(zx_all[:, t], state, W, U, b, zx=zx_all[:, t])
        else:
            _, state = cell(xs[:, t], state, W, U, b)
    return state[0] if rnn.cell == "lstm" else state
