from repro.core.rnn.cells import (  # noqa: F401
    lstm_cell,
    gru_cell,
    lstm_cell_quantized,
    gru_cell_quantized,
    rnn_param_specs,
)
from repro.core.rnn.layer import rnn_layer  # noqa: F401
