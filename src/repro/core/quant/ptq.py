"""Post-training quantization + AUC profiling (paper Sec. 5.1, Fig. 2).

The paper quantizes trained Keras models post-training (PTQ) and scans the
AUC ratio (quantized / float) as a function of fractional bits at fixed
integer bits {6, 8, 10, 12}.  ``auc_scan`` reproduces that protocol for our
trained tagger models.
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FixedPointConfig, ModelConfig
from repro.core.quant.fixed_point import quantize_params


def ptq_quantize_model(params: Dict, fp: FixedPointConfig) -> Dict:
    """Quantize all weights/biases to the ap_fixed grid (host-side, exact)."""
    return quantize_params(params, fp)


def binary_auc(scores: np.ndarray, labels: np.ndarray) -> float:
    """AUC via the rank statistic (exact, ties averaged)."""
    scores = np.asarray(scores, np.float64).ravel()
    labels = np.asarray(labels).ravel()
    order = np.argsort(scores, kind="mergesort")
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    # average ranks for ties
    sorted_scores = scores[order]
    i = 0
    while i < len(sorted_scores):
        j = i
        while j + 1 < len(sorted_scores) and sorted_scores[j + 1] == sorted_scores[i]:
            j += 1
        if j > i:
            avg = (ranks[order[i]] + ranks[order[j]]) / 2.0
            ranks[order[i:j + 1]] = avg
        i = j + 1
    n_pos = labels.sum()
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return float("nan")
    return float((ranks[labels > 0].sum() - n_pos * (n_pos + 1) / 2.0)
                 / (n_pos * n_neg))


def multiclass_mean_auc(probs: np.ndarray, labels: np.ndarray) -> float:
    """Mean one-vs-rest AUC (paper's top-1 AUC metric for multiclass)."""
    n_classes = probs.shape[-1]
    aucs = [binary_auc(probs[:, c], (labels == c).astype(np.int32))
            for c in range(n_classes)]
    return float(np.nanmean(aucs))


def model_auc(cfg: ModelConfig, forward_fn: Callable, params: Dict,
              x: np.ndarray, y: np.ndarray,
              fp: Optional[FixedPointConfig] = None) -> float:
    probs = np.asarray(forward_fn(cfg, params, jnp.asarray(x), fp=fp))
    if cfg.rnn.output_activation == "sigmoid":
        return binary_auc(probs[:, 0], y)
    return multiclass_mean_auc(probs, y)


def auc_scan(
    cfg: ModelConfig,
    forward_fn: Callable,
    params: Dict,
    x: np.ndarray,
    y: np.ndarray,
    integer_bits: Iterable[int] = (6, 8, 10, 12),
    fractional_bits: Iterable[int] = tuple(range(0, 17, 2)),
) -> Dict[int, List[Tuple[int, float]]]:
    """Paper Fig. 2: AUC(quantized)/AUC(float) vs fractional bits, one curve
    per integer-bit setting.  Weights are PTQ'd; activations quantized
    in-graph (the full hls4ml datapath)."""
    float_auc = model_auc(cfg, forward_fn, params, x, y, fp=None)
    out: Dict[int, List[Tuple[int, float]]] = {}
    for ib in integer_bits:
        curve = []
        for fb in fractional_bits:
            fp = FixedPointConfig(total_bits=ib + fb, integer_bits=ib)
            qparams = ptq_quantize_model(params, fp)
            auc = model_auc(cfg, forward_fn, qparams, x, y, fp=fp)
            curve.append((fb, auc / float_auc))
        out[ib] = curve
    return out
