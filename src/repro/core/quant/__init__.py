from repro.core.quant.fixed_point import (  # noqa: F401
    quantize,
    quantize_params,
    fixed_point_error_bound,
)
from repro.core.quant.ptq import ptq_quantize_model, auc_scan  # noqa: F401
