"""Bit-accurate ap_fixed<W,I> emulation (the paper's quantization scheme).

hls4ml represents every weight, bias, activation and accumulator as a
fixed-point number with W total bits, I integer bits (signed by default),
round-to-nearest (RND) and saturation (SAT).  We emulate by scaling to the
integer grid, rounding, saturating, and rescaling.

Exactness: the integer grid is exact while |x|*2^F < 2^24 (f32 mantissa).
The paper's scans reach W = 26 (I=10, F=16) where the final rescale can be
off by <= 1 ulp of f32 — negligible against the quantization step itself
(documented tolerance, tested in tests/test_quantization.py).
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FixedPointConfig


def quantize(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Quantize to the ap_fixed grid (returns same dtype, values on grid)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    scale = fp.scale
    y = xf * scale
    if fp.rounding == "rnd":
        y = jnp.round(y)                 # round-half-even (IEEE default)
    else:  # trn: truncate toward -inf (hls4ml AP_TRN)
        y = jnp.floor(y)
    if fp.saturation == "sat":
        lo = fp.min_value * scale
        hi = fp.max_value * scale
        y = jnp.clip(y, lo, hi)
    else:  # wrap (AP_WRAP): modular arithmetic
        span = 2.0 ** fp.total_bits
        y = jnp.mod(y - fp.min_value * scale, span) + fp.min_value * scale
    return (y / scale).astype(dt)


def quantize_np(x: np.ndarray, fp: FixedPointConfig) -> np.ndarray:
    """Exact host-side quantization in float64 (used for PTQ of weights)."""
    scale = fp.scale
    y = np.asarray(x, np.float64) * scale
    if fp.rounding == "rnd":
        y = np.round(y)
    else:
        y = np.floor(y)
    if fp.saturation == "sat":
        y = np.clip(y, fp.min_value * scale, fp.max_value * scale)
    return (y / scale).astype(np.float32)


def quantize_params(params: Dict[str, jax.Array], fp: FixedPointConfig,
                    skip_substrings: tuple = ()) -> Dict[str, jax.Array]:
    """Post-training quantization of a parameter dict (host-side, exact)."""
    out = {}
    for k, v in params.items():
        if any(s in k for s in skip_substrings):
            out[k] = v
        else:
            out[k] = jnp.asarray(quantize_np(np.asarray(v), fp))
    return out


def fixed_point_error_bound(fp: FixedPointConfig) -> float:
    """Max rounding error of a single quantization (half a grid step)."""
    return 0.5 / fp.scale


def saturates(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Fraction of entries that hit the saturation rails (diagnostic)."""
    xf = x.astype(jnp.float32)
    return jnp.mean(((xf > fp.max_value) | (xf < fp.min_value)).astype(jnp.float32))
