"""Bit-accurate ap_fixed<W,I> emulation (the paper's quantization scheme).

hls4ml represents every weight, bias, activation and accumulator as a
fixed-point number with W total bits, I integer bits (signed by default),
round-to-nearest (RND) and saturation (SAT).  We emulate by scaling to the
integer grid, rounding, saturating, and rescaling.

Exactness: the integer grid is exact while |x|*2^F < 2^24 (f32 mantissa).
The paper's scans reach W = 26 (I=10, F=16) where the final rescale can be
off by <= 1 ulp of f32 — negligible against the quantization step itself
(documented tolerance, tested in tests/test_quantization.py).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FixedPointConfig


def grid_constants(fp: FixedPointConfig) -> Tuple[float, float, float]:
    """The single source of the (scale, lo, hi) grid derivation.

    ``q = clamp(round_or_floor(x * scale), lo, hi) / scale``: lo/hi are the
    INTEGER rails of the ap_fixed grid (e.g. signed W=8: [-128, 127]).
    Every quantizer — host (``quantize_np``), device (``quantize``), the
    Pallas kernel (``kernels/fixed_point.py``) and the native-int packers
    (``kernels/quantized.py``) — derives its grid from here, so the clip
    range can never diverge between paths.
    """
    scale = fp.scale
    return scale, fp.min_value * scale, fp.max_value * scale


def _apply_grid(y, fp: FixedPointConfig, xp):
    """Round + saturate/wrap ``y`` (already scaled to the integer grid)
    using the ``xp`` array namespace — the shared core of both quantizers."""
    if fp.rounding == "rnd":
        y = xp.round(y)                  # round-half-even (IEEE default)
    else:  # trn: truncate toward -inf (hls4ml AP_TRN)
        y = xp.floor(y)
    _, lo, hi = grid_constants(fp)
    if fp.saturation == "sat":
        y = xp.clip(y, lo, hi)
    else:  # wrap (AP_WRAP): modular arithmetic
        span = 2.0 ** fp.total_bits
        y = xp.mod(y - lo, span) + lo
    return y


def quantize(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Quantize to the ap_fixed grid (returns same dtype, values on grid)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = _apply_grid(xf * fp.scale, fp, jnp)
    return (y / fp.scale).astype(dt)


def quantize_np(x: np.ndarray, fp: FixedPointConfig) -> np.ndarray:
    """Exact host-side quantization in float64 (used for PTQ of weights)."""
    y = _apply_grid(np.asarray(x, np.float64) * fp.scale, fp, np)
    return (y / fp.scale).astype(np.float32)


# ---------------------------------------------------------------------------
# Native integer execution (the int8/int4 kernel datapath)
# ---------------------------------------------------------------------------


def is_native_int(fp: Optional[FixedPointConfig]) -> bool:
    """True when ``fp`` selects the NATIVE integer kernel bodies.

    The native datapath (kernels/quantized.py) stores weights as int8 /
    nibble-packed int4 and accumulates gate matmuls in int32.  It covers the
    signed round-to-nearest saturating grids up to 8 total bits — exactly
    the configs whose products (<= 2^14) and gate-sum accumulators
    (<= ~2^21 for tagger fan-ins) fit int32 with headroom.  Everything else
    (wider words, trn, wrap, unsigned) runs the f32 emulation path.
    """
    return (fp is not None and fp.total_bits <= 8 and fp.signed
            and fp.rounding == "rnd" and fp.saturation == "sat")


def native_bits(fp: FixedPointConfig) -> int:
    """Storage width of the native path: 4 (nibble-packed) or 8."""
    return 4 if fp.total_bits <= 4 else 8


def to_ints(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Quantize onto the integer grid and return the INT8 grid indices
    (``round(q * scale)``).  Exact (no extra rounding) when ``x`` is already
    on the grid — the native kernels' activation/state representation."""
    scale, lo, hi = grid_constants(fp)
    y = jnp.clip(jnp.round(x.astype(jnp.float32) * scale), lo, hi)
    return y.astype(jnp.int8)


def from_ints(i: jax.Array, fp: FixedPointConfig,
              dtype=jnp.float32) -> jax.Array:
    """Inverse of :func:`to_ints`: grid indices -> on-grid real values."""
    return (i.astype(jnp.float32) / fp.scale).astype(dtype)


def packed_weight_bytes(k: int, n: int,
                        fp: Optional[FixedPointConfig]) -> int:
    """Resident bytes of one [k, n] weight matrix under ``fp`` — the SINGLE
    formula shared by the residency packer (kernels/quantized.py) and the
    analytical vmem pricing (core/hls/resources.py), so measured packing and
    ``estimate_*`` report identical weight bytes.

    float / emulated fp: f32 items (4 bytes).  Native int8: one byte per
    weight.  Native int4: two weights per byte, nibble-packed along k
    (odd k pads one row).
    """
    if not is_native_int(fp):
        return 4 * k * n
    if native_bits(fp) == 8:
        return k * n
    return math.ceil(k / 2) * n


def quantize_params(params: Dict[str, jax.Array], fp: FixedPointConfig,
                    skip_substrings: tuple = ()) -> Dict[str, jax.Array]:
    """Post-training quantization of a parameter dict (host-side, exact)."""
    out = {}
    for k, v in params.items():
        if any(s in k for s in skip_substrings):
            out[k] = v
        else:
            out[k] = jnp.asarray(quantize_np(np.asarray(v), fp))
    return out


def fixed_point_error_bound(fp: FixedPointConfig) -> float:
    """Max rounding error of a single quantization (half a grid step)."""
    return 0.5 / fp.scale


def saturates(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Fraction of entries that hit the saturation rails (diagnostic)."""
    xf = x.astype(jnp.float32)
    return jnp.mean(((xf > fp.max_value) | (xf < fp.min_value)).astype(jnp.float32))
