"""The paper's contribution: ultra-low-latency RNN inference machinery.

  core.rnn    — LSTM/GRU cells, static (scan) and non-static (pipelined)
                execution modes
  core.quant  — ap_fixed<W,I> fixed-point emulation + post-training
                quantization + AUC profiling (paper Fig. 2)
  core.hls    — analytical HLS design-space model (DSP/FF/LUT/BRAM, latency,
                initiation interval) calibrated to the paper's Tables 2-5
"""
