"""Architecture registry: ``--arch <id>`` -> ModelConfig."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.config import ModelConfig

# arch id -> module under repro.configs
ARCHS: Dict[str, str] = {
    # assigned pool (10)
    "gemma-2b": "gemma_2b",
    "nemotron-4-340b": "nemotron_4_340b",
    "stablelm-3b": "stablelm_3b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "mamba2-780m": "mamba2_780m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-medium": "whisper_medium",
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    # the paper's own benchmarks (3 x {lstm,gru})
    "top-tagging-lstm": "top_tagging",
    "top-tagging-gru": "top_tagging",
    "flavor-tagging-lstm": "flavor_tagging",
    "flavor-tagging-gru": "flavor_tagging",
    "quickdraw-lstm": "quickdraw",
    "quickdraw-gru": "quickdraw",
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[name]}")
    if name.endswith("-lstm"):
        return mod.lstm_config()
    if name.endswith("-gru"):
        return mod.gru_config()
    return mod.CONFIG


def list_archs() -> List[str]:
    return sorted(ARCHS)


ASSIGNED_ARCHS = [
    "gemma-2b",
    "nemotron-4-340b",
    "stablelm-3b",
    "deepseek-coder-33b",
    "mamba2-780m",
    "qwen2-moe-a2.7b",
    "qwen3-moe-30b-a3b",
    "recurrentgemma-9b",
    "whisper-medium",
    "phi-3-vision-4.2b",
]
