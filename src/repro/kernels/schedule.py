"""Reuse-factor scheduling layer — ONE object that configures every scan
kernel AND the analytical HLS estimators.

The paper's central knob is the hls4ml reuse factor: with reuse R each DSP
performs R multiplications per matrix product, so DSPs shrink by R while
latency grows by R (Tables 2-4), and the static / non-static mode choice
trades initiation interval against resource replication (Table 5, Fig. 6).
``KernelSchedule`` carries exactly those degrees of freedom plus the TPU
execution backend, and is:

  * hashable / frozen — usable as a ``jax.jit`` static argument;
  * honored by the Pallas kernels: gate matmuls are partitioned into
    ``reuse_factor`` *sequential column tiles* (one extra sequential grid
    dimension), so the kernel's sequential grid length really is
    ``sequential_steps(seq_len)``;
  * the input to ``core.hls.resources.estimate_schedule`` — latency-cycle
    and DSP/BRAM estimates are derived from the same object the kernel
    executes, which is what makes the software sweep of the paper's Fig. 1
    latency–resource curve trustworthy.

Dependency note: this module imports nothing from ``repro`` so that
``repro.config`` can embed schedules in frozen model configs.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, replace
from typing import Iterable, Tuple

MODES = ("static", "nonstatic", "pipeline")
BACKENDS = ("auto", "xla", "pallas_interpret", "pallas_tpu")

#: queue key for requests that carry no schedule at all
DEFAULT_SCHEDULE_KEY = "default"


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@dataclass(frozen=True)
class KernelSchedule:
    """How a scan kernel is scheduled on the latency–resource curve.

    reuse_factor  hls4ml reuse R: gate matmuls run as R sequential column
                  tiles; latency x R, parallel multipliers (DSP analogue,
                  VMEM-resident weight tile on TPU) / R.
    mode          "static" — one weights-resident block scans the whole
                  sequence (paper Fig. 1 left, II = seq_len x R).
                  "nonstatic" — one block per timestep, state flows
                  block-to-block (Fig. 1 right, II = one block latency).
                  "pipeline" — NONSTATIC with the input projection hoisted
                  out of every block (implies ``hoist_input``): the
                  per-timestep blocks carry only the hU recurrence, so the
                  cross-inference initiation interval can shrink to ``ii``
                  sequential steps (paper Table 5's II 315 -> 1, with the
                  xW GEMM as a separate fully-pipelined front stage).
    block_batch   batch tile per kernel invocation (TPU sublane analogue of
                  the paper's "independent inferences in flight").
    backend       "auto" (Pallas; interpret controlled by
                  REPRO_PALLAS_INTERPRET), "pallas_interpret",
                  "pallas_tpu", or "xla" (the lax.scan golden reference).
    hoist_input   compute the input projection xW for ALL timesteps as ONE
                  batched [B*T, fin] @ [fin, G*h] matmul outside the
                  sequential scan (only hU carries the recurrence): the
                  sequential working set drops from (fin+h) x G*h/R to
                  h x G*h/R and the per-step FLOPs roughly halve for
                  fin ~ h.  Bit-identical to the in-loop path (same
                  association order; conformance-enforced).
    ii            pipeline mode only: target initiation interval in
                  sequential steps before the NEXT inference enters the
                  block chain (0 = auto = reuse_factor, one block's column
                  tiles).  Per-inference latency keeps the irreducible
                  seq_len x R recurrence chain; ii is the throughput axis.
    hoist_reuse   reuse factor of the hoisted input GEMM itself (1 = fully
                  parallel, full MXU utilization; >1 runs it as R-tiled
                  sequential column passes — trades the front stage's
                  resources the same way reuse_factor trades the scan's).
    """

    reuse_factor: int = 1
    mode: str = "static"
    block_batch: int = 128
    backend: str = "auto"
    hoist_input: bool = False
    ii: int = 0
    hoist_reuse: int = 1

    def __post_init__(self):
        if self.reuse_factor < 1:
            raise ValueError(f"reuse_factor must be >= 1: {self.reuse_factor}")
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.block_batch < 1:
            raise ValueError(f"block_batch must be >= 1: {self.block_batch}")
        if self.ii < 0:
            raise ValueError(f"ii must be >= 0: {self.ii}")
        if self.hoist_reuse < 1:
            raise ValueError(f"hoist_reuse must be >= 1: {self.hoist_reuse}")
        if self.mode == "pipeline":
            # pipelining the block chain REQUIRES the hoist: only once the
            # xW GEMM leaves the blocks is a block slim enough to free up
            # after its hU tiles, letting the next inference enter at ii
            object.__setattr__(self, "hoist_input", True)
        elif self.ii:
            # ii is a pipeline-mode knob; normalize it away on other modes
            # (instead of raising) so replace(mode=...) — the engine's and
            # rnn_layer's mode-override path — stays total, and the
            # normalized schedule keys/hashes equal the ii-free one
            object.__setattr__(self, "ii", 0)
        if self.hoist_reuse > 1 and not self.hoist_input:
            raise ValueError(
                "hoist_reuse > 1 without hoist_input: there is no hoisted "
                "input GEMM to tile")

    # -- backend resolution -------------------------------------------------

    @property
    def use_pallas(self) -> bool:
        return self.backend != "xla"

    @property
    def interpret(self) -> bool:
        if self.backend == "pallas_interpret":
            return True
        if self.backend == "pallas_tpu":
            return False
        return _env_interpret()

    # -- reuse partitioning -------------------------------------------------

    def effective_reuse(self, dim: int) -> int:
        """Largest divisor of ``dim`` that also divides ``reuse_factor``.

        Column tiles must align with the gate layout (i|f|c|o packed along
        the last axis), so the tiled dimension has to split evenly; ragged
        reuse requests degrade gracefully to the nearest feasible divisor
        instead of erroring (same behavior hls4ml applies to invalid R).
        """
        return math.gcd(self.reuse_factor, dim)

    def sequential_steps(self, seq_len: int) -> int:
        """Sequential kernel grid length — the software latency axis.

        Static: one block serializes time x reuse.  Non-static/pipeline: the
        chain of seq_len blocks still costs seq_len x R end-to-end for one
        inference (each block serializes its R column tiles).  Hoisting does
        NOT change the step count — it shrinks each step's working set and
        FLOPs (the xW half leaves the recurrence).
        """
        return seq_len * self.reuse_factor

    def initiation_interval(self, seq_len: int) -> int:
        """Sequential steps before the NEXT inference can enter (paper II).

        Static re-uses the single block for the whole sequence; non-static
        frees its first block after one block latency (II 315 -> 1 in
        Table 5 terms, scaled by R); pipeline reaches the explicit ``ii``
        target (default one block's R tiles) because the hoisted blocks
        carry only the hU tiles.
        """
        if self.mode == "static":
            return seq_len * self.reuse_factor
        if self.mode == "pipeline":
            return max(self.ii or self.reuse_factor, 1)
        return self.reuse_factor

    # -- stable identity ----------------------------------------------------

    def key(self) -> str:
        """Stable, human-readable hash of the schedule — the co-batching key.

        Two requests with equal keys compile to the SAME kernel (identical
        jit trace), so the serving layer batches them together; the string is
        stable across processes (unlike ``hash()``) and shows up verbatim in
        latency reports and benchmark CSV rows.

        Non-default axes append as suffix tokens (``-hoist``, ``-hrN``,
        ``-iiN``) so default schedules keep their PR 2-era keys and old
        parsers that read only the first four tokens stay correct.
        """
        base = (f"{self.mode}-R{self.reuse_factor}"
                f"-bb{self.block_batch}-{self.backend}")
        if self.hoist_input:
            base += "-hoist"
        if self.hoist_reuse != 1:
            base += f"-hr{self.hoist_reuse}"
        if self.ii:
            base += f"-ii{self.ii}"
        return base

    # -- sweeping -----------------------------------------------------------

    def replace(self, **kw) -> "KernelSchedule":
        return replace(self, **kw)

    @classmethod
    def from_key(cls, key: str) -> "KernelSchedule":
        """Inverse of :meth:`key`; also accepts the fp-suffixed form
        ``schedule_key`` produces (the ``-apW_I_rnd_sat`` tail is ignored).
        Round-trips every valid schedule.

        Forward/backward compatible by construction: the first four tokens
        are positional and REQUIRED (a malformed core raises ValueError);
        every later token is an optional axis — known ones (``hoist``,
        ``hrN``, ``iiN``) parse, unknown ones (axes from a future PR, the
        fp tail) are ignored, so PR 2-era keys still parse after new axes
        land and vice versa.
        """
        parts = key.split("-")
        if len(parts) < 4:
            raise ValueError(f"not a schedule key: {key!r}")
        mode, r, bb, backend = parts[:4]
        if not (r.startswith("R") and r[1:].isdigit()
                and bb.startswith("bb") and bb[2:].isdigit()):
            raise ValueError(f"not a schedule key: {key!r}")
        kw = dict(reuse_factor=int(r[1:]), mode=mode,
                  block_batch=int(bb[2:]), backend=backend)
        for tok in parts[4:]:
            if tok == "hoist":
                kw["hoist_input"] = True
            elif tok.startswith("hr") and tok[2:].isdigit():
                kw["hoist_reuse"] = int(tok[2:])
            elif tok.startswith("ii") and tok[2:].isdigit():
                kw["ii"] = int(tok[2:])
            # anything else: an axis this build does not know (or the
            # schedule_key fp tail) — ignore, do not crash the parser
        return cls(**kw)

    @classmethod
    def sweep(cls, reuse_factors: Iterable[int] = (1, 2, 4, 8),
              modes: Iterable[str] = MODES, *, block_batch: int = 128,
              backend: str = "auto") -> Tuple["KernelSchedule", ...]:
        """The paper's Fig. 1 sweep grid as schedule objects."""
        return tuple(cls(reuse_factor=r, mode=m, block_batch=block_batch,
                         backend=backend)
                     for m in modes for r in reuse_factors)


def cache_meta(schedule: "KernelSchedule | None", fp=None) -> dict:
    """Exhaustive (schedule, fp) identity for the persistent compile cache.

    ``schedule_key`` is the co-batching string and stays forward-compatible
    by IGNORING axes it does not know — the right property for routing, the
    wrong one for naming a serialized executable (two schedules that differ
    in a future axis must never share an artifact).  This derivation is
    exhaustive by construction: every dataclass field of the schedule and
    the fixed-point config lands in the dict, so adding an axis
    automatically invalidates stale cache entries.
    """
    from dataclasses import asdict, is_dataclass

    meta: dict = {"schedule": (None if schedule is None
                               else asdict(schedule))}
    if fp is None:
        meta["fp"] = None
    elif is_dataclass(fp):
        meta["fp"] = asdict(fp)
    else:  # duck-typed fp (no-repro-imports invariant): fall back to repr
        meta["fp"] = repr(fp)
    return meta


def schedule_key(schedule: "KernelSchedule | None", fp=None) -> str:
    """Stable co-batching key for a (schedule, fixed-point config) pair.

    Requests whose key matches execute the same compiled kernel: the same
    column-tile partitioning, mode, backend AND datapath precision.  ``fp``
    is duck-typed (anything with ``total_bits`` / ``integer_bits``) so this
    module keeps its no-repro-imports invariant; ``None`` fp means the float
    datapath.
    """
    base = DEFAULT_SCHEDULE_KEY if schedule is None else schedule.key()
    if fp is None:
        return base
    rounding = getattr(fp, "rounding", "rnd")
    saturation = getattr(fp, "saturation", "sat")
    return (f"{base}-ap{fp.total_bits}_{fp.integer_bits}"
            f"_{rounding}_{saturation}")
