"""Reuse-factor scheduling layer — ONE object that configures every scan
kernel AND the analytical HLS estimators.

The paper's central knob is the hls4ml reuse factor: with reuse R each DSP
performs R multiplications per matrix product, so DSPs shrink by R while
latency grows by R (Tables 2-4), and the static / non-static mode choice
trades initiation interval against resource replication (Table 5, Fig. 6).
``KernelSchedule`` carries exactly those degrees of freedom plus the TPU
execution backend, and is:

  * hashable / frozen — usable as a ``jax.jit`` static argument;
  * honored by the Pallas kernels: gate matmuls are partitioned into
    ``reuse_factor`` *sequential column tiles* (one extra sequential grid
    dimension), so the kernel's sequential grid length really is
    ``sequential_steps(seq_len)``;
  * the input to ``core.hls.resources.estimate_schedule`` — latency-cycle
    and DSP/BRAM estimates are derived from the same object the kernel
    executes, which is what makes the software sweep of the paper's Fig. 1
    latency–resource curve trustworthy.

Dependency note: this module imports nothing from ``repro`` so that
``repro.config`` can embed schedules in frozen model configs.
"""

from __future__ import annotations

import math
import os
from dataclasses import dataclass, replace
from typing import Iterable, Tuple

MODES = ("static", "nonstatic")
BACKENDS = ("auto", "xla", "pallas_interpret", "pallas_tpu")

#: queue key for requests that carry no schedule at all
DEFAULT_SCHEDULE_KEY = "default"


def _env_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@dataclass(frozen=True)
class KernelSchedule:
    """How a scan kernel is scheduled on the latency–resource curve.

    reuse_factor  hls4ml reuse R: gate matmuls run as R sequential column
                  tiles; latency x R, parallel multipliers (DSP analogue,
                  VMEM-resident weight tile on TPU) / R.
    mode          "static" — one weights-resident block scans the whole
                  sequence (paper Fig. 1 left, II = seq_len x R).
                  "nonstatic" — one block per timestep, state flows
                  block-to-block (Fig. 1 right, II = one block latency).
    block_batch   batch tile per kernel invocation (TPU sublane analogue of
                  the paper's "independent inferences in flight").
    backend       "auto" (Pallas; interpret controlled by
                  REPRO_PALLAS_INTERPRET), "pallas_interpret",
                  "pallas_tpu", or "xla" (the lax.scan golden reference).
    """

    reuse_factor: int = 1
    mode: str = "static"
    block_batch: int = 128
    backend: str = "auto"

    def __post_init__(self):
        if self.reuse_factor < 1:
            raise ValueError(f"reuse_factor must be >= 1: {self.reuse_factor}")
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        if self.block_batch < 1:
            raise ValueError(f"block_batch must be >= 1: {self.block_batch}")

    # -- backend resolution -------------------------------------------------

    @property
    def use_pallas(self) -> bool:
        return self.backend != "xla"

    @property
    def interpret(self) -> bool:
        if self.backend == "pallas_interpret":
            return True
        if self.backend == "pallas_tpu":
            return False
        return _env_interpret()

    # -- reuse partitioning -------------------------------------------------

    def effective_reuse(self, dim: int) -> int:
        """Largest divisor of ``dim`` that also divides ``reuse_factor``.

        Column tiles must align with the gate layout (i|f|c|o packed along
        the last axis), so the tiled dimension has to split evenly; ragged
        reuse requests degrade gracefully to the nearest feasible divisor
        instead of erroring (same behavior hls4ml applies to invalid R).
        """
        return math.gcd(self.reuse_factor, dim)

    def sequential_steps(self, seq_len: int) -> int:
        """Sequential kernel grid length — the software latency axis.

        Static: one block serializes time x reuse.  Non-static: the chain of
        seq_len blocks still costs seq_len x R end-to-end for one inference
        (each block serializes its R column tiles).
        """
        return seq_len * self.reuse_factor

    def initiation_interval(self, seq_len: int) -> int:
        """Sequential steps before the NEXT inference can enter (paper II).

        Static re-uses the single block for the whole sequence; non-static
        frees its first block after one block latency (II 315 -> 1 in
        Table 5 terms, scaled by R).
        """
        if self.mode == "static":
            return seq_len * self.reuse_factor
        return self.reuse_factor

    # -- stable identity ----------------------------------------------------

    def key(self) -> str:
        """Stable, human-readable hash of the schedule — the co-batching key.

        Two requests with equal keys compile to the SAME kernel (identical
        jit trace), so the serving layer batches them together; the string is
        stable across processes (unlike ``hash()``) and shows up verbatim in
        latency reports and benchmark CSV rows.
        """
        return (f"{self.mode}-R{self.reuse_factor}"
                f"-bb{self.block_batch}-{self.backend}")

    # -- sweeping -----------------------------------------------------------

    def replace(self, **kw) -> "KernelSchedule":
        return replace(self, **kw)

    @classmethod
    def from_key(cls, key: str) -> "KernelSchedule":
        """Inverse of :meth:`key`; also accepts the fp-suffixed form
        ``schedule_key`` produces (the ``-apW_I_rnd_sat`` tail is ignored).
        Round-trips every valid schedule."""
        mode, r, bb, backend = key.split("-")[:4]
        return cls(reuse_factor=int(r[1:]), mode=mode,
                   block_batch=int(bb[2:]), backend=backend)

    @classmethod
    def sweep(cls, reuse_factors: Iterable[int] = (1, 2, 4, 8),
              modes: Iterable[str] = MODES, *, block_batch: int = 128,
              backend: str = "auto") -> Tuple["KernelSchedule", ...]:
        """The paper's Fig. 1 sweep grid as schedule objects."""
        return tuple(cls(reuse_factor=r, mode=m, block_batch=block_batch,
                         backend=backend)
                     for m in modes for r in reuse_factors)


def schedule_key(schedule: "KernelSchedule | None", fp=None) -> str:
    """Stable co-batching key for a (schedule, fixed-point config) pair.

    Requests whose key matches execute the same compiled kernel: the same
    column-tile partitioning, mode, backend AND datapath precision.  ``fp``
    is duck-typed (anything with ``total_bits`` / ``integer_bits``) so this
    module keeps its no-repro-imports invariant; ``None`` fp means the float
    datapath.
    """
    base = DEFAULT_SCHEDULE_KEY if schedule is None else schedule.key()
    if fp is None:
        return base
    rounding = getattr(fp, "rounding", "rnd")
    saturation = getattr(fp, "saturation", "sat")
    return (f"{base}-ap{fp.total_bits}_{fp.integer_bits}"
            f"_{rounding}_{saturation}")
