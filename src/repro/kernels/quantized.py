"""Native int8/int4 kernel bodies — the datapath `FixedPointConfig` selects.

Before this module every fixed-point config executed the SAME f32 kernels
with quantize() wrapped around each intermediate (emulation).  Here the
integral configs (``core.quant.fixed_point.is_native_int``: signed, rnd,
sat, <= 8 total bits) get genuinely low-precision execution:

  * weights live in the residency cache as int8 grid indices — int4 configs
    nibble-pack two weights per byte along K — so resident bytes drop 4x/8x
    vs the f32 layout (``packed_weight_bytes`` is the shared formula the
    HLS pricing uses, keeping measured and estimated bytes identical);
  * gate matmuls run int8 x int8 -> INT32 accumulation inside a Pallas
    kernel (``quant_matmul_pallas``) whose R reuse passes serialize the
    output column tiles exactly like the float kernels' schedule;
  * requantization happens at the gate boundaries: the int32 accumulator
    (scale 2^2F) is rescaled once and the activation/Hadamard steps apply
    the SAME quantization points as the emulation cells.

Numerical contract (what the conformance suite pins down):

  ``native_matmul`` returns ``(a_int @ w_int) / scale^2`` with the division
  EXACT in f32 — int8 products are <= 2^14 and the K-sums for tagger fan-ins
  stay far below 2^24 (f32's integer-exact range), so the native gate
  pre-activation is bit-identical to the emulation path's f32 matmul of the
  same on-grid operands.  Hence: native == emulation BIT-FOR-BIT whenever
  the weights are already on the fp grid (PTQ'd), and within one grid step
  of the numpy integer golden models (testing.py) otherwise — the weight
  quantization the packer applies is the only divergence.

Quantized datapaths never hoist (splitting z = q(xW + hU + b) would move
the hls4ml quantization points), so every schedule mode runs the same
per-timestep structure; the mode still selects pricing and the reuse factor
still tiles the kernel's output columns.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import (from_ints, grid_constants,
                                          is_native_int, native_bits,
                                          quantize, to_ints)
from repro.kernels.compat import tpu_compiler_params
from repro.kernels.schedule import KernelSchedule, schedule_key


# ---------------------------------------------------------------------------
# Packed integer weight layouts (the residency cache's quantized format)
# ---------------------------------------------------------------------------


def pack_ints(w: jax.Array, fp: FixedPointConfig) -> jax.Array:
    """Quantize a float [K, N] weight matrix to its packed int8 layout.

    int8 grids store one weight per byte.  int4 grids nibble-pack two
    K-adjacent weights per byte (low nibble = even row, high nibble = odd
    row; odd K pads a zero row), so the packed array is [ceil(K/2), N] —
    1/8 the f32 bytes.  ``packed_weight_bytes`` prices exactly this layout.
    """
    q = to_ints(w, fp)
    if native_bits(fp) == 8:
        return q
    k = q.shape[0]
    if k % 2:
        q = jnp.concatenate([q, jnp.zeros((1,) + q.shape[1:], q.dtype)])
    qi = q.astype(jnp.int32) & 0xF          # two's-complement nibbles
    return (qi[0::2] | (qi[1::2] << 4)).astype(jnp.int8)


def unpack_ints(packed: jax.Array, fp: FixedPointConfig,
                k: int) -> jax.Array:
    """Packed layout -> int8 grid indices [k, N] (inverse of pack_ints)."""
    if native_bits(fp) == 8:
        return packed
    b = packed.astype(jnp.int32) & 0xFF
    lo = b & 0xF
    lo = lo - ((lo >= 8) << 4)              # sign-extend the 4-bit field
    hi = (b >> 4) & 0xF
    hi = hi - ((hi >= 8) << 4)
    out = jnp.stack([lo, hi], axis=1).reshape((-1,) + packed.shape[1:])
    return out[:k].astype(jnp.int8)


def packed_nbytes(packed) -> int:
    """Measured bytes of a packed layout (what the LRU accounting sees)."""
    return sum(getattr(a, "nbytes", 0)
               for a in jax.tree_util.tree_leaves(packed))


# ---------------------------------------------------------------------------
# The int32-accumulating scheduled matmul kernel
# ---------------------------------------------------------------------------


def _quant_mm_kernel(x_ref, w_ref, o_ref, *, reuse: int, ns: int):
    """One batch-tile cell: int8 operands, INT32 accumulation, the R output
    column tiles serialized in-block (the decode kernels' reuse structure —
    column tiles never split the K reduction, so every output element is
    the full-K integer dot product)."""
    x = x_ref[...].astype(jnp.int32)
    for r in range(reuse):
        w = w_ref[:, r * ns:(r + 1) * ns].astype(jnp.int32)
        o_ref[:, r * ns:(r + 1) * ns] = jax.lax.dot_general(
            x, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)


def quant_matmul_pallas(x: jax.Array, w: jax.Array, *, reuse: int = 1,
                        block_m: int = 8, interpret: bool = True
                        ) -> jax.Array:
    """x: [M, K] int8 @ w: [K, N] int8 -> [M, N] int32, with the N columns
    computed in ``reuse`` sequential in-block passes over the resident
    integer weight block.  N must divide by reuse; M by block_m."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % reuse == 0 and M % block_m == 0
    kernel = partial(_quant_mm_kernel, reuse=reuse, ns=N // reuse)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.int32),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)


def _residency_key(schedule: Optional[KernelSchedule],
                   fp: FixedPointConfig, tag: str) -> str:
    """Quantized layouts key on (schedule key, fp token): a precision change
    can never serve a stale float — or other-precision — layout."""
    return f"quant/{tag}/{schedule_key(schedule, fp)}"


def resident_quantized(w: jax.Array, fp: FixedPointConfig, *,
                       schedule: Optional[KernelSchedule] = None,
                       tag: str = "w") -> jax.Array:
    """The packed integer layout of one weight matrix, cached ONCE per
    (array identity, schedule key, fp) in RESIDENT_WEIGHTS.  The cache's
    byte accounting sees the PACKED nbytes (int4: 1/8 of f32)."""
    from repro.kernels.ops import resident

    return resident(w, _residency_key(schedule, fp, tag),
                    lambda: pack_ints(w, fp))


def _int_matmul(ai: jax.Array, wq: jax.Array,
                schedule: Optional[KernelSchedule]) -> jax.Array:
    """int8 [M, K] @ int8 [K, N] -> int32, scheduled.  Pallas backends run
    the in-block reuse-tiled kernel; the xla backend (and schedule=None)
    keep the same int32 dot as the golden integer reference."""
    if schedule is None or not schedule.use_pallas:
        return jax.lax.dot_general(
            ai.astype(jnp.int32), wq.astype(jnp.int32),
            (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    from repro.kernels.ops import _pad_axis, check_tpu_alignment

    M = ai.shape[0]
    re = schedule.effective_reuse(wq.shape[-1])
    bm = min(schedule.block_batch, max(8, M))
    check_tpu_alignment(schedule, tile_width=wq.shape[-1] // re,
                        block_batch=bm, kernel="quant_matmul")
    a_p = _pad_axis(ai, 0, bm)
    out = quant_matmul_pallas(a_p, wq, reuse=re, block_m=bm,
                              interpret=schedule.interpret)
    return out[:M]


def native_matmul(a: jax.Array, w: jax.Array, fp: FixedPointConfig, *,
                  schedule: Optional[KernelSchedule] = None,
                  tag: str = "w") -> jax.Array:
    """The native gate matmul: quantize-to-ints, int32-accumulate, rescale.

    ``a`` [M, K] holds on-grid activations (the quantized cells quantize
    every input before the matmul, so ``to_ints`` is exact); ``w`` is the
    float weight matrix, PTQ'd to ints at residency-pack time.  Returns
    ``(a_int @ w_int) / scale^2`` as f32 — EXACT for int8/int4 ranges, i.e.
    bit-identical to the emulation path's f32 ``a @ quantize(w)``.
    """
    packed = resident_quantized(w, fp, schedule=schedule, tag=tag)
    wq = unpack_ints(packed, fp, w.shape[0])
    acc = _int_matmul(to_ints(a, fp), wq, schedule)
    scale, _, _ = grid_constants(fp)
    return acc.astype(jnp.float32) * (1.0 / (scale * scale))


# ---------------------------------------------------------------------------
# Native quantized cells (same quantization points as core.rnn.cells)
# ---------------------------------------------------------------------------
#
# The steps below mirror lstm_cell_quantized / gru_cell_quantized LINE FOR
# LINE — same q() placement, same float association order — with the gate
# matmuls swapped for native_matmul.  Because native_matmul's rescaled
# accumulator equals the emulation's f32 matmul exactly (see module doc),
# the two datapaths are bit-identical for PTQ'd weights; the conformance
# suite asserts this, which is what lets the cell math live in two places.


def _native_lstm_step(x_t, state, W, U, b, fp, schedule):
    q = lambda v: quantize(v, fp)                          # noqa: E731
    mm = lambda a, w, tag: native_matmul(a, w, fp, schedule=schedule,
                                         tag=tag)          # noqa: E731
    h_prev, c_prev = state
    hdim = h_prev.shape[-1]
    x_t = q(x_t)
    z = q(mm(x_t, W, "lstm-W") + mm(h_prev, U, "lstm-U") + b)
    i, f, g, o = (z[..., :hdim], z[..., hdim:2 * hdim],
                  z[..., 2 * hdim:3 * hdim], z[..., 3 * hdim:])
    i = q(jax.nn.sigmoid(i))
    f = q(jax.nn.sigmoid(f))
    g = q(jnp.tanh(g))
    o = q(jax.nn.sigmoid(o))
    c_t = q(q(f * c_prev) + q(i * g))
    h_t = q(o * q(jnp.tanh(c_t)))
    return h_t, (h_t, c_t)


def _native_gru_step(x_t, state, W, U, b, fp, schedule):
    q = lambda v: quantize(v, fp)                          # noqa: E731
    mm = lambda a, w, tag: native_matmul(a, w, fp, schedule=schedule,
                                         tag=tag)          # noqa: E731
    h_prev = state
    x_t = q(x_t)
    zx = q(mm(x_t, W, "gru-W") + b[0])
    zh = q(mm(h_prev, U, "gru-U") + b[1])
    zxz, zxr, zxh = jnp.split(zx, 3, axis=-1)
    zhz, zhr, zhh = jnp.split(zh, 3, axis=-1)
    z = q(jax.nn.sigmoid(zxz + zhz))
    r = q(jax.nn.sigmoid(zxr + zhr))
    hh = q(jnp.tanh(q(zxh + q(r * zhh))))
    h_t = q(q(z * h_prev) + q((1.0 - z) * hh))
    return h_t, h_t


NATIVE_STEPS = {"lstm": _native_lstm_step, "gru": _native_gru_step}


# ---------------------------------------------------------------------------
# Scheduled entry points (what ops.py dispatches to for integral fp)
# ---------------------------------------------------------------------------


def quantized_scan(cell: str, xs, W, U, b, *, fp: FixedPointConfig,
                   schedule: KernelSchedule):
    """[B, T, in] -> final hidden [B, h] on the native integer datapath.

    Weights pack ONCE per (identity, schedule key, fp) in the residency
    cache (eager call path; tracers pack in-trace as usual), then every
    timestep runs the native cell: int8 state/activations at the gate
    boundaries, int32-accumulated gate matmuls through the Pallas kernel.
    All modes share the per-timestep structure — quantized datapaths never
    hoist (it would move the q points), and a "static"-mode schedule still
    means weights-resident + R column tiles per step.
    """
    assert is_native_int(fp), fp
    # warm the residency cache eagerly (concrete weights only)
    for w, tag in ((W, f"{cell}-W"), (U, f"{cell}-U")):
        if isinstance(w, jax.Array) and not isinstance(w, jax.core.Tracer):
            resident_quantized(w, fp, schedule=schedule, tag=tag)
    return _quantized_scan_jit(xs, W, U, b, cell=cell, fp=fp,
                               schedule=schedule)


@partial(jax.jit, static_argnames=("cell", "fp", "schedule"))
def _quantized_scan_jit(xs, W, U, b, *, cell: str, fp: FixedPointConfig,
                        schedule: KernelSchedule):
    from repro.core.rnn.cells import initial_state

    B, T, _ = xs.shape
    H = U.shape[0]
    step = NATIVE_STEPS[cell]
    state = initial_state(cell, B, H, jnp.float32)
    bf = b.astype(jnp.float32)
    for t in range(T):
        _, state = step(xs[:, t].astype(jnp.float32), state, W, U, bf,
                        fp, schedule)
    h = state[0] if cell == "lstm" else state
    return h.astype(xs.dtype)


def quantized_decode_step(cell: str, x_t, state, W, U, b, *,
                          fp: FixedPointConfig,
                          schedule: Optional[KernelSchedule] = None):
    """One native single-event state update (kernels/decode_step.py's fp
    route for integral configs): same cell math, one step."""
    assert is_native_int(fp), fp
    step = NATIVE_STEPS[cell]
    return step(x_t, state, W, U, b, fp, schedule)


@partial(jax.jit, static_argnames=("fp", "schedule"))
def _quantized_rglru_jit(a, bx, *, fp: FixedPointConfig,
                         schedule: KernelSchedule):
    B, T, Wd = a.shape
    scale, lo, hi = grid_constants(fp)
    F = fp.fractional_bits
    ai = to_ints(a, fp).astype(jnp.int32)        # grid indices, scale 2^F
    bi = to_ints(bx, fp).astype(jnp.int32)
    h = jnp.zeros((B, Wd), jnp.int32)
    hs = []
    for t in range(T):
        # a*h products land on the 2^2F grid; fold bx up and requantize the
        # sum back to 2^F — integer round-half-even via the exact f32 round
        # (|acc| <= 2^15 << 2^24)
        acc = ai[:, t] * h + (bi[:, t] << F)
        h = jnp.clip(jnp.round(acc.astype(jnp.float32) * (1.0 / scale)),
                     lo, hi).astype(jnp.int32)
        hs.append(h)
    out = jnp.stack(hs, axis=1)
    return from_ints(out, fp, a.dtype)


def quantized_rglru_scan(a, bx, *, fp: FixedPointConfig,
                         schedule: KernelSchedule):
    """Native RG-LRU: matmul-free, so the whole recurrence runs on INTEGER
    grid indices (int32 elementwise products — scale 2^2F — requantized to
    the 2^F grid each step).  Bit-identical to the numpy integer golden
    model by construction: every op is exact integer arithmetic.
    """
    assert is_native_int(fp), fp
    return _quantized_rglru_jit(a, bx, fp=fp, schedule=schedule)


def quantized_reuse_matmul(x, w, *, fp: FixedPointConfig,
                           schedule: Optional[KernelSchedule] = None):
    """Native scheduled matmul: q(x) and PTQ'd w multiply as integers, the
    int32 accumulator requantizes ONCE to the fp grid (z = q(xW) — the
    dense-layer gate boundary).  The reuse factor serializes output column
    tiles in-block (kernels' N-tiling; the float kernel's K-split reuse has
    no integer analogue without double-rounding the accumulator)."""
    assert is_native_int(fp), fp
    xq = quantize(x.astype(jnp.float32), fp)
    out = native_matmul(xq, w, fp, schedule=schedule, tag="mm")
    return quantize(out, fp).astype(x.dtype)
