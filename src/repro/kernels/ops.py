"""Jit'd public wrappers around the Pallas kernels: padding to hardware-
aligned tiles, dtype handling, and — the scheduling layer — dispatch of every
scan kernel through a single :class:`KernelSchedule`.

A schedule carries (reuse_factor, mode, block_batch, backend) and selects:

  backend "xla"             the lax.scan golden reference (ref.py) — the
                            bit-for-bit ground truth of the conformance
                            harness;
  backend "pallas_*"/"auto" the Pallas kernels.  Static mode runs the
                            weights-resident scan kernel with the gate
                            matmuls partitioned into reuse_factor sequential
                            column tiles; non-static mode unrolls one block
                            per timestep, each block built from the
                            column-serialized ``col_matmul`` kernel (paper
                            Fig. 1 right); pipeline mode is non-static with
                            the input projection hoisted (NONSTATIC in paper
                            terms: slimmed blocks, II = schedule.ii).

Hoisted input projection (``schedule.hoist_input``): of the gate matmul
z = x W + h U + b only the hU half carries a sequential dependency — xW for
all T timesteps is embarrassingly parallel, so the hoist stage computes it
as ONE batched [B*T, fin] @ [fin, G*h] matmul outside the scan (full MXU
utilization; R-tiled through ``col_matmul`` only when ``hoist_reuse`` > 1)
and the sequential kernel consumes the precomputed zx.  The hoisted and
in-loop paths are bit-identical: the pre-activation keeps the association
(xW + hU) + b, and the conformance suite enforces the bit-match.

The same schedule object drives ``core.hls.resources.estimate_schedule`` so
software latency/resource numbers describe exactly what executes here.

TPU lane alignment (ROADMAP open item): on ``backend="pallas_tpu"`` the
per-reuse column tile is a lane-dimension block — Mosaic requires its width
to be a multiple of 128 (and the batch tile a multiple of 8 sublanes).  The
dispatch validates this at schedule-application time and raises a clear
ValueError instead of miscompiling on hardware.

CPU containers run interpret=True; on a real TPU either set
REPRO_PALLAS_INTERPRET=0 or use backend="pallas_tpu".
"""

from __future__ import annotations

from collections import OrderedDict
from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

import math

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import is_native_int
from repro.kernels import ref
from repro.kernels.fixed_point import fixed_point_pallas
from repro.kernels.gru_scan import (gru_scan_hoisted_pallas, gru_scan_pallas,
                                    gru_scan_pipeline_pallas)
from repro.kernels.hadamard import hadamard_pallas
from repro.kernels.lstm_scan import (lstm_scan_hoisted_pallas,
                                     lstm_scan_pallas,
                                     lstm_scan_pipeline_pallas)
from repro.kernels.reuse_matmul import col_matmul_pallas, reuse_matmul_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.schedule import KernelSchedule
from repro.kernels.schedule import _env_interpret as _interpret

#: Mosaic tiling floors for f32 blocks — last dim lanes, second-to-last
#: sublanes; a column tile off these boundaries miscompiles on hardware
TPU_LANES = 128
TPU_SUBLANES = 8


def check_tpu_alignment(schedule: KernelSchedule, *, tile_width: int,
                        block_batch: int, kernel: str) -> None:
    """Validate Mosaic lane alignment for a real-hardware schedule.

    ROADMAP open item: on ``backend="pallas_tpu"`` the per-reuse column tile
    of width ``tile_width`` is a lane-dim block and the batch tile spans
    sublanes.  Interpret/XLA backends have no such constraint, so the check
    only fires for the hardware backend — raising at schedule-application
    time with an actionable message instead of miscompiling.
    """
    if schedule.backend != "pallas_tpu":
        return
    if tile_width % TPU_LANES != 0:
        raise ValueError(
            f"{kernel}: pallas_tpu column tile width {tile_width} is not a "
            f"multiple of {TPU_LANES} lanes (schedule {schedule.key()}). "
            f"Pick a reuse factor so the per-reuse tile width is "
            f"128-aligned, or pad the gate dimension, or use "
            f"backend='pallas_interpret' off-hardware.")
    if block_batch % TPU_SUBLANES != 0:
        raise ValueError(
            f"{kernel}: pallas_tpu batch tile {block_batch} is not a "
            f"multiple of {TPU_SUBLANES} sublanes (schedule "
            f"{schedule.key()}). Use a block_batch that is 8-aligned.")


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve(schedule: Optional[KernelSchedule],
             block_batch: Optional[int], default_bb: int = 128
             ) -> KernelSchedule:
    if schedule is None:
        return KernelSchedule(block_batch=block_batch or default_bb)
    if block_batch is not None:
        return schedule.replace(block_batch=block_batch)
    return schedule


# ---------------------------------------------------------------------------
# Weight residency: pack each weight ONCE per (weights identity, schedule key)
# ---------------------------------------------------------------------------


class WeightResidency:
    """Host-side cache of packed/padded weight layouts.

    The kernels' weight transformations (compute-dtype cast, gate fusion,
    R-tile layout) are pure functions of the weight arrays and the schedule
    key, yet before this cache they re-ran inside every call's compiled
    program.  ``get`` runs the pack function ONCE per (source identity,
    schedule key) and returns the resident result on every later call — the
    software analogue of the paper's weights-stay-on-chip static mode.

    Safety: only IMMUTABLE sources are cacheable — every source must be a
    ``jax.Array`` (in-place mutation is impossible, so an identity hit
    implies value equality); numpy or other mutable buffers pack uncached,
    exactly like the pre-cache behavior.  An entry stores a strong
    reference to every source array, so CPython cannot recycle an ``id``
    while the entry lives, and a hit additionally verifies each source
    ``is`` the remembered object.  Tracers never reach the cache — callers
    bypass it in-trace, where packing stays a traced (and XLA-CSE'd)
    computation.  Eviction is LRU, bounded BOTH by entry count and by the
    packed payload's total bytes (LM-scale packs would otherwise pin many
    model-sized copies in a count-only cache).
    """

    def __init__(self, max_entries: int = 128,
                 max_bytes: int = 512 * 1024 * 1024):
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self.bytes = 0
        self._entries: "OrderedDict[Tuple, Tuple[Tuple, object, int]]" = \
            OrderedDict()

    @staticmethod
    def _nbytes(packed) -> int:
        return sum(getattr(a, "nbytes", 0)
                   for a in jax.tree_util.tree_leaves(packed))

    def get(self, srcs, key: str, pack: Callable[[], object]):
        """Packed layout for ``srcs`` (one array or a tuple) under ``key``."""
        if not isinstance(srcs, tuple):
            srcs = (srcs,)
        if not all(isinstance(a, jax.Array)
                   and not isinstance(a, jax.core.Tracer) for a in srcs):
            return pack()       # tracer or mutable buffer: never cache
        ck = (key,) + tuple(id(a) for a in srcs)
        ent = self._entries.get(ck)
        if ent is not None and all(a is b for a, b in zip(ent[0], srcs)):
            self.hits += 1
            self._entries.move_to_end(ck)
            return ent[1]
        self.misses += 1
        packed = pack()
        nb = self._nbytes(packed)
        self._entries[ck] = (srcs, packed, nb)
        self.bytes += nb
        while self._entries and (len(self._entries) > self.max_entries
                                 or self.bytes > self.max_bytes):
            _, (_, _, old_nb) = self._entries.popitem(last=False)
            self.bytes -= old_nb
        return packed

    def __len__(self) -> int:
        return len(self._entries)

    def clear(self) -> None:
        self._entries.clear()
        self.bytes = 0


#: module-level residency cache shared by the scan wrappers and the decode
#: kernels (kernels/decode_step.py, models/decode.py pack through it too)
RESIDENT_WEIGHTS = WeightResidency()


def resident(srcs, key: str, pack: Callable[[], object]):
    """Module-level convenience over :data:`RESIDENT_WEIGHTS`."""
    return RESIDENT_WEIGHTS.get(srcs, key, pack)


def _scan_weights_resident(cell: str, W, U, b, schedule: KernelSchedule):
    """The Pallas scan kernels compute every gate matmul in f32
    (``preferred_element_type``/explicit casts in ``_gate_mm`` and
    ``_hoist_stage``), so the f32 weight layout is schedule-invariant data —
    pre-cast it once per weights identity instead of re-casting inside every
    compiled call.  bf16 -> f32 is exact, hence bit-identical to the in-call
    cast.  The XLA golden path computes in the caller's dtype and is left
    untouched."""
    if not schedule.use_pallas:
        return W, U, b

    def pack():
        return (jnp.asarray(W, jnp.float32), jnp.asarray(U, jnp.float32),
                jnp.asarray(b, jnp.float32))

    return resident((W, U, b), f"{cell}-scan-f32", pack)


# ---------------------------------------------------------------------------
# Hoisted input-projection stage + per-timestep unrolled blocks
# ---------------------------------------------------------------------------


def _gate_mm(x: jax.Array, w: jax.Array, reuse: int,
             interpret: bool) -> jax.Array:
    """f32 x @ w through the column-tiled Pallas kernel (one per-timestep
    'block' of the non-static pipeline)."""
    M = x.shape[0]
    bm = min(128, max(8, M))
    x_p = _pad_axis(x.astype(jnp.float32), 0, bm)
    out = col_matmul_pallas(x_p, w.astype(jnp.float32), reuse=reuse,
                            block_m=bm, interpret=interpret)
    return out[:M]


def _hoist_stage(xs: jax.Array, W: jax.Array,
                 schedule: KernelSchedule) -> jax.Array:
    """The hoisted input projection: ONE batched [B*T, fin] @ [fin, G*h]
    matmul outside the sequential scan (f32 accumulate, no bias) — the
    embarrassingly parallel half of the gate pre-activation, previously
    recomputed inside every sequential grid cell.

    Fully parallel (one full-MXU pass) unless the schedule asks for R-tiling
    via ``hoist_reuse``, in which case it runs as sequential column tiles
    through the same ``col_matmul`` kernel the non-static blocks use.
    """
    B, T, fin = xs.shape
    flat = xs.reshape(B * T, fin)
    hr = math.gcd(schedule.hoist_reuse, W.shape[-1])
    if hr > 1:
        check_tpu_alignment(schedule, tile_width=W.shape[-1] // hr,
                            block_batch=min(128, max(8, flat.shape[0])),
                            kernel="hoist_stage")
        zx = _gate_mm(flat, W, hr, schedule.interpret)
    else:
        zx = jnp.dot(flat, W, preferred_element_type=jnp.float32)
    return zx.reshape(B, T, W.shape[-1])


def _cell_pipeline(cell: str, xs, W, U, b,
                   schedule: KernelSchedule) -> jax.Array:
    """The fused pipelined-NONSTATIC executor: hoist stage + ONE Pallas
    kernel whose grid carries only (batch, time) and whose block unrolls
    the R reuse passes of the hU product in-silicon (Fig. 1 right) — the
    schedule estimate_schedule prices with blocks = seq_len and
    II = schedule.ii."""
    B, T, _ = xs.shape
    H = U.shape[0]
    g = 4 if cell == "lstm" else 3
    re = schedule.effective_reuse(g * H)
    bt = min(schedule.block_batch, max(8, B))
    check_tpu_alignment(schedule, tile_width=g * H // re, block_batch=bt,
                        kernel=f"{cell}_scan")
    xs_p = _pad_axis(xs, 0, bt)
    zx = _hoist_stage(xs_p, W, schedule)
    if cell == "lstm":
        out = lstm_scan_pipeline_pallas(zx, U, b, block_batch=bt, reuse=re,
                                        interpret=schedule.interpret,
                                        out_dtype=xs.dtype)
    else:
        out = gru_scan_pipeline_pallas(zx + b[0], U, b[1], block_batch=bt,
                                       reuse=re,
                                       interpret=schedule.interpret,
                                       out_dtype=xs.dtype)
    return out[:B]


def _cell_unrolled(cell: str, xs, W, U, b,
                   schedule: KernelSchedule) -> jax.Array:
    """One block per timestep (Fig. 1 right): the cell equations come from
    core.rnn.cells with the gate matmul swapped for the column-serialized
    Pallas kernel — the math lives in exactly one place.

    With ``schedule.hoist_input`` the xW projections for ALL timesteps come
    from the hoist stage and each block computes only its hU tiles — the
    same restructuring the fused pipeline kernel executes in one call.
    """
    from repro.core.rnn.cells import gru_cell, initial_state, lstm_cell

    B, T, _ = xs.shape
    H = U.shape[0]
    g = 4 if cell == "lstm" else 3
    re = schedule.effective_reuse(g * H)
    itp = schedule.interpret
    check_tpu_alignment(schedule, tile_width=g * H // re,
                        block_batch=min(128, max(8, B)),
                        kernel=f"{cell}_scan")

    def mm(a, w):
        return _gate_mm(a, w, re, itp)

    zx_all = None
    if schedule.hoist_input:
        flat = xs.reshape(B * T, -1)
        hr = math.gcd(schedule.hoist_reuse, g * H)
        check_tpu_alignment(schedule, tile_width=g * H // hr,
                            block_batch=min(128, max(8, flat.shape[0])),
                            kernel="hoist_stage")
        # same col-serialized kernel as the in-loop blocks -> bit-identical
        zx_all = _gate_mm(flat, W, max(hr, 1), itp).reshape(B, T, g * H)

    state = initial_state(cell, B, H, jnp.float32)
    bf = b.astype(jnp.float32)
    step = lstm_cell if cell == "lstm" else gru_cell
    for t in range(T):
        _, state = step(xs[:, t], state, W, U, bf, matmul=mm,
                        zx=None if zx_all is None else zx_all[:, t])
    h = state[0] if cell == "lstm" else state
    return h.astype(xs.dtype)


# ---------------------------------------------------------------------------
# Fixed-point dispatch: native int bodies vs ap_fixed emulation
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("cell", "fp"))
def _emulated_scan_jit(xs, W, U, b, *, cell: str,
                       fp: FixedPointConfig):
    """The ap_fixed EMULATION scan: the quantized cells from core.rnn.cells
    (f32 compute, quantize() at every hls4ml datapath point) unrolled over
    T — the fallback body for every fp ``is_native_int`` does not cover
    (wide words, trn rounding, wrap saturation, unsigned)."""
    from repro.core.rnn.cells import (gru_cell_quantized, initial_state,
                                      lstm_cell_quantized)

    B, T, _ = xs.shape
    H = U.shape[0]
    step = lstm_cell_quantized if cell == "lstm" else gru_cell_quantized
    state = initial_state(cell, B, H, jnp.float32)
    bf = b.astype(jnp.float32)
    for t in range(T):
        _, state = step(xs[:, t].astype(jnp.float32), state, W, U, bf, fp)
    h = state[0] if cell == "lstm" else state
    return h.astype(xs.dtype)


def _scan_fp_dispatch(cell: str, xs, W, U, b, schedule: KernelSchedule,
                      fp: FixedPointConfig):
    """Route a quantized scan: native int bodies for integral fp on a
    Pallas backend, the f32 emulation otherwise (incl. backend="xla" —
    the quantized golden reference stays the emulation cells)."""
    from repro.kernels.quantized import quantized_scan

    if is_native_int(fp) and schedule.use_pallas:
        return quantized_scan(cell, xs, W, U, b, fp=fp, schedule=schedule)
    return _emulated_scan_jit(xs, W, U, b, cell=cell, fp=fp)


# ---------------------------------------------------------------------------
# Scheduled scan kernels
# ---------------------------------------------------------------------------


def lstm_scan(xs, W, U, b, *, schedule: Optional[KernelSchedule] = None,
              block_batch: Optional[int] = None,
              fp: Optional[FixedPointConfig] = None):
    """[B, T, in] -> final hidden [B, h], scheduled by ``schedule``.

    Eager wrapper: resolves the schedule and fetches the weights' resident
    f32 layout from :data:`RESIDENT_WEIGHTS` (packed once per weights
    identity) before entering the jitted kernel body — repeated calls with
    the same weight arrays stop re-casting them in-program.  Under an outer
    jit the inputs are tracers, the cache bypasses itself, and the packing
    stays in-trace exactly as before.

    ``fp`` selects the fixed-point datapath: None is today's float route
    (bit-identical), an ``is_native_int`` config runs the int8/int4 kernel
    bodies (kernels/quantized.py) on Pallas backends, any other config runs
    the ap_fixed emulation cells.
    """
    schedule = _resolve(schedule, block_batch)
    if fp is not None:
        return _scan_fp_dispatch("lstm", xs, W, U, b, schedule, fp)
    W, U, b = _scan_weights_resident("lstm", W, U, b, schedule)
    return _lstm_scan_jit(xs, W, U, b, schedule=schedule)


@partial(jax.jit, static_argnames=("schedule",))
def _lstm_scan_jit(xs, W, U, b, *, schedule: KernelSchedule):
    if not schedule.use_pallas:
        return ref.lstm_scan_ref(xs, W, U, b)
    if schedule.mode == "pipeline":
        return _cell_pipeline("lstm", xs, W, U, b, schedule)
    if schedule.mode == "nonstatic":
        return _cell_unrolled("lstm", xs, W, U, b, schedule)
    B = xs.shape[0]
    bt = min(schedule.block_batch, max(8, B))
    reuse = schedule.effective_reuse(4 * U.shape[0])
    check_tpu_alignment(schedule, tile_width=4 * U.shape[0] // reuse,
                        block_batch=bt, kernel="lstm_scan")
    xs_p = _pad_axis(xs, 0, bt)
    if schedule.hoist_input:
        zx = _hoist_stage(xs_p, W, schedule)
        out = lstm_scan_hoisted_pallas(zx, U, b, block_batch=bt, reuse=reuse,
                                       interpret=schedule.interpret,
                                       out_dtype=xs.dtype)
    else:
        out = lstm_scan_pallas(xs_p, W, U, b, block_batch=bt, reuse=reuse,
                               interpret=schedule.interpret)
    return out[:B]


def gru_scan(xs, W, U, b, *, schedule: Optional[KernelSchedule] = None,
             block_batch: Optional[int] = None,
             fp: Optional[FixedPointConfig] = None):
    """GRU counterpart of :func:`lstm_scan` (same eager wrapper + resident
    f32 weight layout + jitted body split + fp dispatch)."""
    schedule = _resolve(schedule, block_batch)
    if fp is not None:
        return _scan_fp_dispatch("gru", xs, W, U, b, schedule, fp)
    W, U, b = _scan_weights_resident("gru", W, U, b, schedule)
    return _gru_scan_jit(xs, W, U, b, schedule=schedule)


@partial(jax.jit, static_argnames=("schedule",))
def _gru_scan_jit(xs, W, U, b, *, schedule: KernelSchedule):
    if not schedule.use_pallas:
        return ref.gru_scan_ref(xs, W, U, b)
    if schedule.mode == "pipeline":
        return _cell_pipeline("gru", xs, W, U, b, schedule)
    if schedule.mode == "nonstatic":
        return _cell_unrolled("gru", xs, W, U, b, schedule)
    B = xs.shape[0]
    bt = min(schedule.block_batch, max(8, B))
    reuse = schedule.effective_reuse(3 * U.shape[0])
    check_tpu_alignment(schedule, tile_width=3 * U.shape[0] // reuse,
                        block_batch=bt, kernel="gru_scan")
    xs_p = _pad_axis(xs, 0, bt)
    if schedule.hoist_input:
        # GRU keeps input- and recurrent-side pre-activations separate, so
        # the input bias folds into the hoisted zx (same add order as the
        # in-loop kernel's dot + b_in)
        zx = _hoist_stage(xs_p, W, schedule) + b[0]
        out = gru_scan_hoisted_pallas(zx, U, b[1], block_batch=bt,
                                      reuse=reuse,
                                      interpret=schedule.interpret,
                                      out_dtype=xs.dtype)
    else:
        out = gru_scan_pallas(xs_p, W, U, b, block_batch=bt, reuse=reuse,
                              interpret=schedule.interpret)
    return out[:B]


@jax.jit
def hadamard(a, b):
    shape = a.shape
    rows = a.size // shape[-1]
    a2 = a.reshape(rows, shape[-1])
    b2 = b.reshape(rows, shape[-1])
    bn = min(1024, rows)
    a2 = _pad_axis(a2, 0, bn)
    b2 = _pad_axis(b2, 0, bn)
    out = hadamard_pallas(a2, b2, block=bn, interpret=_interpret())
    return out[:rows].reshape(shape)


def fixed_point(x, fp: FixedPointConfig):
    @jax.jit
    def run(x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        bn = min(1024, x2.shape[0])
        x2 = _pad_axis(x2, 0, bn)
        out = fixed_point_pallas(x2, fp, block=bn, interpret=_interpret())
        return out[: (x.size // shape[-1])].reshape(shape)
    return run(x)


@partial(jax.jit, static_argnames=("fp",))
def _rglru_emulated_jit(a, bx, *, fp: FixedPointConfig):
    """ap_fixed emulation of the RG-LRU recurrence: gates and state on the
    grid, one requantization per step (h = q(q(a)*h + q(bx)))."""
    from repro.core.quant.fixed_point import quantize

    B, T, W = a.shape
    aq = quantize(a.astype(jnp.float32), fp)
    bq = quantize(bx.astype(jnp.float32), fp)
    h = jnp.zeros((B, W), jnp.float32)
    hs = []
    for t in range(T):
        h = quantize(aq[:, t] * h + bq[:, t], fp)
        hs.append(h)
    return jnp.stack(hs, axis=1).astype(a.dtype)


def rglru_scan(a, bx, *, schedule: Optional[KernelSchedule] = None,
               block_batch: Optional[int] = None, block_width: int = 128,
               fp: Optional[FixedPointConfig] = None):
    """a, bx: [B, T, W] -> all recurrence states [B, T, W].

    Reuse for this matmul-free kernel serializes the width tiles: per
    sequential step one W/R-wide tile of VPU lanes is live.

    ``hoist_input`` is accepted as a no-op: the RG-LRU kernel consumes a
    PRECOMPUTED gated input bx (the caller's dense gates are the hoist
    stage), i.e. the kernel is already in hoisted form — only the
    elementwise a_t * h recurrence is sequential.  Pipeline mode unrolls
    one block per timestep like nonstatic (slim elementwise blocks).

    ``fp`` as in :func:`lstm_scan`: integral configs run the all-integer
    recurrence (kernels/quantized.py), others the f32 emulation.
    """
    schedule = _resolve(schedule, block_batch, default_bb=8)
    if fp is not None:
        if is_native_int(fp) and schedule.use_pallas:
            from repro.kernels.quantized import quantized_rglru_scan

            return quantized_rglru_scan(a, bx, fp=fp, schedule=schedule)
        return _rglru_emulated_jit(a, bx, fp=fp)
    return _rglru_scan_jit(a, bx, schedule=schedule,
                           block_width=block_width)


@partial(jax.jit, static_argnames=("schedule", "block_width"))
def _rglru_scan_jit(a, bx, *, schedule: KernelSchedule,
                    block_width: int = 128):
    B, T, W = a.shape
    if not schedule.use_pallas:
        return ref.rglru_scan_ref(a, bx)
    if schedule.mode in ("nonstatic", "pipeline"):
        h = jnp.zeros((B, W), jnp.float32)
        hs = []
        for t in range(T):                 # one block per timestep
            h = a[:, t].astype(jnp.float32) * h + bx[:, t].astype(jnp.float32)
            hs.append(h)
        return jnp.stack(hs, axis=1).astype(a.dtype)
    reuse = schedule.reuse_factor
    bb = min(schedule.block_batch, max(1, B))
    bw = min(block_width, -(-W // reuse))  # ceil: R sequential width tiles
    check_tpu_alignment(schedule, tile_width=bw, block_batch=bb,
                        kernel="rglru_scan")
    a_p = _pad_axis(_pad_axis(a, 0, bb), 2, bw)
    b_p = _pad_axis(_pad_axis(bx, 0, bb), 2, bw)
    out = rglru_scan_pallas(a_p, b_p, block_batch=bb, block_width=bw,
                            serial_width=reuse > 1,
                            interpret=schedule.interpret)
    return out[:B, :, :W]


def reuse_matmul(x, w, *, reuse: int = 1, block_m: int = 128,
                 schedule: Optional[KernelSchedule] = None,
                 fp: Optional[FixedPointConfig] = None):
    """[M, K] @ [K, N] with K serialized into `reuse` passes (a schedule's
    reuse_factor overrides the bare ``reuse`` argument).

    ``fp``: integral configs on a Pallas schedule run the int8/int4
    column-tiled kernel (z = q(q(x) @ q(w)) with int32 accumulation);
    other fp configs emulate the same quantization points in f32.
    """
    if fp is not None:
        if (is_native_int(fp) and schedule is not None
                and schedule.use_pallas):
            from repro.kernels.quantized import quantized_reuse_matmul

            return quantized_reuse_matmul(x, w, fp=fp, schedule=schedule)
        from repro.core.quant.fixed_point import quantize

        xq = quantize(x.astype(jnp.float32), fp)
        wq = quantize(w.astype(jnp.float32), fp)
        out = _reuse_matmul_jit(xq, wq, reuse=reuse, block_m=block_m,
                                schedule=schedule)
        return quantize(out, fp).astype(x.dtype)
    return _reuse_matmul_jit(x, w, reuse=reuse, block_m=block_m,
                             schedule=schedule)


@partial(jax.jit, static_argnames=("reuse", "block_m", "schedule"))
def _reuse_matmul_jit(x, w, *, reuse: int = 1, block_m: int = 128,
                      schedule: Optional[KernelSchedule] = None):
    if schedule is not None:
        if not schedule.use_pallas:
            return ref.reuse_matmul_ref(x, w)
        reuse = schedule.effective_reuse(x.shape[1])
        interpret = schedule.interpret
        check_tpu_alignment(schedule, tile_width=x.shape[1] // reuse,
                            block_batch=min(block_m, max(8, x.shape[0])),
                            kernel="reuse_matmul")
    else:
        interpret = _interpret()
    M, K = x.shape
    bm = min(block_m, max(8, M))
    x_p = _pad_axis(x, 0, bm)
    out = reuse_matmul_pallas(x_p, w, reuse=reuse, block_m=bm,
                              interpret=interpret)
    return out[:M]


# kernel name -> (scheduled entry point, golden reference) — the conformance
# harness and benchmarks enumerate this
SCHEDULED_KERNELS = {
    "lstm": (lstm_scan, ref.lstm_scan_ref),
    "gru": (gru_scan, ref.gru_scan_ref),
    "rglru": (rglru_scan, ref.rglru_scan_ref),
    "reuse_matmul": (reuse_matmul, ref.reuse_matmul_ref),
}
