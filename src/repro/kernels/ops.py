"""Jit'd public wrappers around the Pallas kernels: padding to hardware-
aligned tiles, dtype handling, and — the scheduling layer — dispatch of every
scan kernel through a single :class:`KernelSchedule`.

A schedule carries (reuse_factor, mode, block_batch, backend) and selects:

  backend "xla"             the lax.scan golden reference (ref.py) — the
                            bit-for-bit ground truth of the conformance
                            harness;
  backend "pallas_*"/"auto" the Pallas kernels.  Static mode runs the
                            weights-resident scan kernel with the gate
                            matmuls partitioned into reuse_factor sequential
                            column tiles; non-static mode unrolls one block
                            per timestep, each block built from the
                            column-serialized ``col_matmul`` kernel (paper
                            Fig. 1 right).

The same schedule object drives ``core.hls.resources.estimate_schedule`` so
software latency/resource numbers describe exactly what executes here.

CPU containers run interpret=True; on a real TPU either set
REPRO_PALLAS_INTERPRET=0 or use backend="pallas_tpu".
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig
from repro.kernels import ref
from repro.kernels.fixed_point import fixed_point_pallas
from repro.kernels.gru_scan import gru_scan_pallas
from repro.kernels.hadamard import hadamard_pallas
from repro.kernels.lstm_scan import lstm_scan_pallas
from repro.kernels.reuse_matmul import col_matmul_pallas, reuse_matmul_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas
from repro.kernels.schedule import KernelSchedule
from repro.kernels.schedule import _env_interpret as _interpret


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve(schedule: Optional[KernelSchedule],
             block_batch: Optional[int], default_bb: int = 128
             ) -> KernelSchedule:
    if schedule is None:
        return KernelSchedule(block_batch=block_batch or default_bb)
    if block_batch is not None:
        return schedule.replace(block_batch=block_batch)
    return schedule


# ---------------------------------------------------------------------------
# Non-static building block: per-timestep column-serialized gate matmul
# ---------------------------------------------------------------------------


def _gate_mm(x: jax.Array, w: jax.Array, reuse: int,
             interpret: bool) -> jax.Array:
    """f32 x @ w through the column-tiled Pallas kernel (one per-timestep
    'block' of the non-static pipeline)."""
    M = x.shape[0]
    bm = min(128, max(8, M))
    x_p = _pad_axis(x.astype(jnp.float32), 0, bm)
    out = col_matmul_pallas(x_p, w.astype(jnp.float32), reuse=reuse,
                            block_m=bm, interpret=interpret)
    return out[:M]


def _cell_nonstatic(cell: str, xs, W, U, b,
                    schedule: KernelSchedule) -> jax.Array:
    """One block per timestep (Fig. 1 right): the cell equations come from
    core.rnn.cells with the gate matmul swapped for the column-serialized
    Pallas kernel — the math lives in exactly one place."""
    from repro.core.rnn.cells import gru_cell, initial_state, lstm_cell

    B, T, _ = xs.shape
    H = U.shape[0]
    g = 4 if cell == "lstm" else 3
    re = schedule.effective_reuse(g * H)
    itp = schedule.interpret

    def mm(a, w):
        return _gate_mm(a, w, re, itp)

    state = initial_state(cell, B, H, jnp.float32)
    bf = b.astype(jnp.float32)
    step = lstm_cell if cell == "lstm" else gru_cell
    for t in range(T):
        _, state = step(xs[:, t], state, W, U, bf, matmul=mm)
    h = state[0] if cell == "lstm" else state
    return h.astype(xs.dtype)


# ---------------------------------------------------------------------------
# Scheduled scan kernels
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("schedule", "block_batch"))
def lstm_scan(xs, W, U, b, *, schedule: Optional[KernelSchedule] = None,
              block_batch: Optional[int] = None):
    """[B, T, in] -> final hidden [B, h], scheduled by ``schedule``."""
    schedule = _resolve(schedule, block_batch)
    if not schedule.use_pallas:
        return ref.lstm_scan_ref(xs, W, U, b)
    if schedule.mode == "nonstatic":
        return _cell_nonstatic("lstm", xs, W, U, b, schedule)
    B = xs.shape[0]
    bt = min(schedule.block_batch, max(8, B))
    xs_p = _pad_axis(xs, 0, bt)
    out = lstm_scan_pallas(xs_p, W, U, b, block_batch=bt,
                           reuse=schedule.effective_reuse(4 * U.shape[0]),
                           interpret=schedule.interpret)
    return out[:B]


@partial(jax.jit, static_argnames=("schedule", "block_batch"))
def gru_scan(xs, W, U, b, *, schedule: Optional[KernelSchedule] = None,
             block_batch: Optional[int] = None):
    schedule = _resolve(schedule, block_batch)
    if not schedule.use_pallas:
        return ref.gru_scan_ref(xs, W, U, b)
    if schedule.mode == "nonstatic":
        return _cell_nonstatic("gru", xs, W, U, b, schedule)
    B = xs.shape[0]
    bt = min(schedule.block_batch, max(8, B))
    xs_p = _pad_axis(xs, 0, bt)
    out = gru_scan_pallas(xs_p, W, U, b, block_batch=bt,
                          reuse=schedule.effective_reuse(3 * U.shape[0]),
                          interpret=schedule.interpret)
    return out[:B]


@jax.jit
def hadamard(a, b):
    shape = a.shape
    rows = a.size // shape[-1]
    a2 = a.reshape(rows, shape[-1])
    b2 = b.reshape(rows, shape[-1])
    bn = min(1024, rows)
    a2 = _pad_axis(a2, 0, bn)
    b2 = _pad_axis(b2, 0, bn)
    out = hadamard_pallas(a2, b2, block=bn, interpret=_interpret())
    return out[:rows].reshape(shape)


def fixed_point(x, fp: FixedPointConfig):
    @jax.jit
    def run(x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        bn = min(1024, x2.shape[0])
        x2 = _pad_axis(x2, 0, bn)
        out = fixed_point_pallas(x2, fp, block=bn, interpret=_interpret())
        return out[: (x.size // shape[-1])].reshape(shape)
    return run(x)


@partial(jax.jit, static_argnames=("schedule", "block_batch", "block_width"))
def rglru_scan(a, bx, *, schedule: Optional[KernelSchedule] = None,
               block_batch: Optional[int] = None, block_width: int = 128):
    """a, bx: [B, T, W] -> all recurrence states [B, T, W].

    Reuse for this matmul-free kernel serializes the width tiles: per
    sequential step one W/R-wide tile of VPU lanes is live.
    """
    schedule = _resolve(schedule, block_batch, default_bb=8)
    B, T, W = a.shape
    if not schedule.use_pallas:
        return ref.rglru_scan_ref(a, bx)
    if schedule.mode == "nonstatic":
        h = jnp.zeros((B, W), jnp.float32)
        hs = []
        for t in range(T):                 # one block per timestep
            h = a[:, t].astype(jnp.float32) * h + bx[:, t].astype(jnp.float32)
            hs.append(h)
        return jnp.stack(hs, axis=1).astype(a.dtype)
    reuse = schedule.reuse_factor
    bb = min(schedule.block_batch, max(1, B))
    bw = min(block_width, -(-W // reuse))  # ceil: R sequential width tiles
    a_p = _pad_axis(_pad_axis(a, 0, bb), 2, bw)
    b_p = _pad_axis(_pad_axis(bx, 0, bb), 2, bw)
    out = rglru_scan_pallas(a_p, b_p, block_batch=bb, block_width=bw,
                            serial_width=reuse > 1,
                            interpret=schedule.interpret)
    return out[:B, :, :W]


@partial(jax.jit, static_argnames=("reuse", "block_m", "schedule"))
def reuse_matmul(x, w, *, reuse: int = 1, block_m: int = 128,
                 schedule: Optional[KernelSchedule] = None):
    """[M, K] @ [K, N] with K serialized into `reuse` passes (a schedule's
    reuse_factor overrides the bare ``reuse`` argument)."""
    if schedule is not None:
        if not schedule.use_pallas:
            return ref.reuse_matmul_ref(x, w)
        reuse = schedule.effective_reuse(x.shape[1])
        interpret = schedule.interpret
    else:
        interpret = _interpret()
    M, K = x.shape
    bm = min(block_m, max(8, M))
    x_p = _pad_axis(x, 0, bm)
    out = reuse_matmul_pallas(x_p, w, reuse=reuse, block_m=bm,
                              interpret=interpret)
    return out[:M]


# kernel name -> (scheduled entry point, golden reference) — the conformance
# harness and benchmarks enumerate this
SCHEDULED_KERNELS = {
    "lstm": (lstm_scan, ref.lstm_scan_ref),
    "gru": (gru_scan, ref.gru_scan_ref),
    "rglru": (rglru_scan, ref.rglru_scan_ref),
    "reuse_matmul": (reuse_matmul, ref.reuse_matmul_ref),
}
