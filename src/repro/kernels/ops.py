"""Jit'd public wrappers around the Pallas kernels: padding to hardware-
aligned tiles, dtype handling, interpret-mode selection (CPU container runs
interpret=True; on a real TPU set REPRO_PALLAS_INTERPRET=0)."""

from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig
from repro.kernels.fixed_point import fixed_point_pallas
from repro.kernels.gru_scan import gru_scan_pallas
from repro.kernels.hadamard import hadamard_pallas
from repro.kernels.lstm_scan import lstm_scan_pallas
from repro.kernels.reuse_matmul import reuse_matmul_pallas
from repro.kernels.rglru_scan import rglru_scan_pallas


def _interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("block_batch",))
def lstm_scan(xs, W, U, b, *, block_batch: int = 128):
    """[B, T, in] -> final hidden [B, h]. Pads batch to the block size."""
    B = xs.shape[0]
    bt = min(block_batch, max(8, B))
    xs_p = _pad_axis(xs, 0, bt)
    out = lstm_scan_pallas(xs_p, W, U, b, block_batch=bt,
                           interpret=_interpret())
    return out[:B]


@partial(jax.jit, static_argnames=("block_batch",))
def gru_scan(xs, W, U, b, *, block_batch: int = 128):
    B = xs.shape[0]
    bt = min(block_batch, max(8, B))
    xs_p = _pad_axis(xs, 0, bt)
    out = gru_scan_pallas(xs_p, W, U, b, block_batch=bt,
                          interpret=_interpret())
    return out[:B]


@jax.jit
def hadamard(a, b):
    shape = a.shape
    rows = a.size // shape[-1]
    a2 = a.reshape(rows, shape[-1])
    b2 = b.reshape(rows, shape[-1])
    bn = min(1024, rows)
    a2 = _pad_axis(a2, 0, bn)
    b2 = _pad_axis(b2, 0, bn)
    out = hadamard_pallas(a2, b2, block=bn, interpret=_interpret())
    return out[:rows].reshape(shape)


def fixed_point(x, fp: FixedPointConfig):
    @jax.jit
    def run(x):
        shape = x.shape
        x2 = x.reshape(-1, shape[-1])
        bn = min(1024, x2.shape[0])
        x2 = _pad_axis(x2, 0, bn)
        out = fixed_point_pallas(x2, fp, block=bn, interpret=_interpret())
        return out[: (x.size // shape[-1])].reshape(shape)
    return run(x)


@partial(jax.jit, static_argnames=("block_batch", "block_width"))
def rglru_scan(a, bx, *, block_batch: int = 8, block_width: int = 128):
    """a, bx: [B, T, W] -> all recurrence states [B, T, W]."""
    B, T, W = a.shape
    bb = min(block_batch, max(1, B))
    bw = min(block_width, W)
    a_p = _pad_axis(_pad_axis(a, 0, bb), 2, bw)
    b_p = _pad_axis(_pad_axis(bx, 0, bb), 2, bw)
    out = rglru_scan_pallas(a_p, b_p, block_batch=bb, block_width=bw,
                            interpret=_interpret())
    return out[:B, :, :W]


@partial(jax.jit, static_argnames=("reuse", "block_m"))
def reuse_matmul(x, w, *, reuse: int = 1, block_m: int = 128):
    """[M, K] @ [K, N] with K serialized into `reuse` passes."""
    M, K = x.shape
    bm = min(block_m, max(8, M))
    x_p = _pad_axis(x, 0, bm)
    out = reuse_matmul_pallas(x_p, w, reuse=reuse, block_m=bm,
                              interpret=_interpret())
    return out[:M]
