"""JAX version-drift shims shared by every Pallas kernel.

The Pallas TPU compiler-params dataclass was renamed across JAX releases
(``pltpu.TPUCompilerParams`` -> ``pltpu.CompilerParams``); kernels import the
resolved symbol from here instead of guessing.  Same for the optional
``jax.sharding.AxisType`` enum used by the mesh builders.
"""

from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # noqa: F401
    HAS_AXIS_TYPE = True
except ImportError:  # older jax: meshes are implicitly "auto"
    AxisType = None
    HAS_AXIS_TYPE = False

try:  # jax >= 0.6: top-level export, replication check kwarg is check_vma
    shard_map = jax.shard_map
except AttributeError:  # older jax: experimental home, kwarg is check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *args, check_vma=None, **kw):
        if check_vma is not None:
            kw.setdefault("check_rep", check_vma)
        return _shard_map(f, *args, **kw)


def tpu_compiler_params(*, dimension_semantics) -> "CompilerParams":
    """Build compiler params with per-grid-dim semantics, any JAX version."""
    return CompilerParams(dimension_semantics=tuple(dimension_semantics))
