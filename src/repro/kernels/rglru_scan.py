"""Pallas RG-LRU scan kernel (recurrentgemma's recurrent core).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)  with
a_t = exp(log_a_t) precomputed by the caller (gates are dense matmuls that
XLA already fuses well; the kernel owns the sequential elementwise
recurrence, which is the part XLA serializes poorly at long T).

This kernel is ALREADY in hoisted form in the sense of
``KernelSchedule.hoist_input``: its entire input side (the gated input bx
and the decay a) is precomputed by the caller — the dense gate matmuls are
the hoist stage — and only the elementwise a_t * h recurrence is
sequential.  The scheduling layer (ops.py) therefore accepts
``hoist_input`` as a no-op for rglru and runs pipeline mode as the unrolled
per-timestep elementwise chain.

Grid: (B/bt, W/wt, T) — batch and width tiles parallel, time sequential and
INNERMOST (fastest-varying) so the state scratch persists across t for each
(batch, width) tile.  State scratch: [bt, wt] f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _rglru_kernel(a_ref, bx_ref, out_ref, h_scr, *, seq_len: int):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a_t = a_ref[:, 0, :].astype(jnp.float32)
    b_t = bx_ref[:, 0, :].astype(jnp.float32)
    h = a_t * h_scr[...] + b_t
    h_scr[...] = h
    out_ref[:, 0, :] = h.astype(out_ref.dtype)


def rglru_scan_pallas(a: jax.Array, bx: jax.Array, *,
                      block_batch: int = 8, block_width: int = 128,
                      serial_width: bool = False,
                      interpret: bool = True) -> jax.Array:
    """a, bx: [B, T, W] (decay and gated input) -> all states h [B, T, W].

    ``serial_width=True`` is the reuse-factor schedule for this (matmul-free)
    recurrence: the width tiles execute sequentially instead of in parallel,
    so one tile's worth of VPU lanes (the DSP analogue) is reused W/wt times
    per step — resources / R, sequential grid length x R.
    """
    B, T, Wd = a.shape
    assert B % block_batch == 0 and Wd % block_width == 0
    width_sem = "arbitrary" if serial_width else "parallel"

    kernel = functools.partial(_rglru_kernel, seq_len=T)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, Wd // block_width, T),
        in_specs=[
            pl.BlockSpec((block_batch, 1, block_width),
                         lambda i, j, t: (i, t, j)),
            pl.BlockSpec((block_batch, 1, block_width),
                         lambda i, j, t: (i, t, j)),
        ],
        out_specs=pl.BlockSpec((block_batch, 1, block_width),
                               lambda i, j, t: (i, t, j)),
        out_shape=jax.ShapeDtypeStruct((B, T, Wd), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_batch, block_width), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", width_sem, "arbitrary")),
        interpret=interpret,
    )(a, bx)
