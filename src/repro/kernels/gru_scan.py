"""Pallas GRU static-mode scan kernel (reset_after, Keras-compatible) with
reuse-factor column tiling.

Same schedule as lstm_scan: weights VMEM-resident, h state in scratch,
sequential time grid.  GRU has 3 gate groups (z|r|hh) and the Hadamard
product sits inside the candidate tanh (r * (h U_h + b_rec)), so the kernel
accumulates the input-side (zx) and recurrent-side (zh) pre-activations in
separate scratches across the R sequential column tiles and combines them at
the last tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _gru_kernel(x_ref, w_ref, u_ref, b_ref, out_ref, zx_scr, zh_scr, h_scr,
                *, hidden: int, seq_len: int, reuse: int):
    t = pl.program_id(1)
    r = pl.program_id(2)
    gw = (3 * hidden) // reuse

    @pl.when(jnp.logical_and(t == 0, r == 0))
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x_t = x_ref[:, 0, :]
    h = h_scr[...]
    b_in = b_ref[0]                                        # [gw]
    b_rec = b_ref[1]

    zx_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32) + b_in)
    zh_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32) + b_rec)

    @pl.when(r == reuse - 1)
    def _update():
        zx = zx_scr[...]                                   # [bt, 3h]
        zh = zh_scr[...]
        z = jax.nn.sigmoid(zx[:, :hidden] + zh[:, :hidden])
        rg = jax.nn.sigmoid(zx[:, hidden:2 * hidden]
                            + zh[:, hidden:2 * hidden])
        hh = jnp.tanh(zx[:, 2 * hidden:] + rg * zh[:, 2 * hidden:])
        h_new = z * h_scr[...] + (1.0 - z) * hh
        h_scr[...] = h_new

        @pl.when(t == seq_len - 1)
        def _emit():
            out_ref[...] = h_new.astype(out_ref.dtype)


def gru_scan_pallas(xs: jax.Array, W: jax.Array, U: jax.Array,
                    b: jax.Array, *, block_batch: int = 128,
                    reuse: int = 1, interpret: bool = True) -> jax.Array:
    """xs: [B, T, in]; W: [in, 3h]; U: [h, 3h]; b: [2, 3h] -> h [B, h]."""
    B, T, fin = xs.shape
    hidden = U.shape[0]
    assert B % block_batch == 0
    assert (3 * hidden) % reuse == 0
    gw = (3 * hidden) // reuse

    kernel = functools.partial(_gru_kernel, hidden=hidden, seq_len=T,
                               reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T, reuse),
        in_specs=[
            pl.BlockSpec((block_batch, 1, fin), lambda i, t, r: (i, t, 0)),
            pl.BlockSpec((fin, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((hidden, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((2, gw), lambda i, t, r: (0, r)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), xs.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xs, W, U, b)
