"""Pallas GRU static-mode scan kernel (reset_after, Keras-compatible) with
reuse-factor column tiling.

Same schedule as lstm_scan: weights VMEM-resident, h state in scratch,
sequential time grid.  GRU has 3 gate groups (z|r|hh) and the Hadamard
product sits inside the candidate tanh (r * (h U_h + b_rec)), so the kernel
accumulates the input-side (zx) and recurrent-side (zh) pre-activations in
separate scratches across the R sequential column tiles and combines them at
the last tile.

Hoisted variant (``gru_scan_hoisted_pallas``): zx = x W + b_in for ALL
timesteps is computed outside the scan (ops.py's hoist stage) — the GRU is
the ideal hoist target because its input-side pre-activation is ALREADY kept
separate from the recurrent side in-kernel, so hoisting removes the zx dot
and scratch wholesale without touching the gate math.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _gate_update(zx, zh, h, hidden: int):
    """zx, zh: [bt, 3h] input-/recurrent-side pre-activations (z|r|hh
    packed), h: [bt, h] -> h_new.  The single home of the GRU gate math for
    all three kernel variants (in-loop / hoisted / pipeline)."""
    z = jax.nn.sigmoid(zx[:, :hidden] + zh[:, :hidden])
    rg = jax.nn.sigmoid(zx[:, hidden:2 * hidden] + zh[:, hidden:2 * hidden])
    hh = jnp.tanh(zx[:, 2 * hidden:] + rg * zh[:, 2 * hidden:])
    return z * h + (1.0 - z) * hh


def _gru_kernel(x_ref, w_ref, u_ref, b_ref, out_ref, zx_scr, zh_scr, h_scr,
                *, hidden: int, seq_len: int, reuse: int):
    t = pl.program_id(1)
    r = pl.program_id(2)
    gw = (3 * hidden) // reuse

    @pl.when(jnp.logical_and(t == 0, r == 0))
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x_t = x_ref[:, 0, :]
    h = h_scr[...]
    b_in = b_ref[0]                                        # [gw]
    b_rec = b_ref[1]

    zx_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32) + b_in)
    zh_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32) + b_rec)

    @pl.when(r == reuse - 1)
    def _update():
        h_new = _gate_update(zx_scr[...], zh_scr[...], h_scr[...], hidden)
        h_scr[...] = h_new

        @pl.when(t == seq_len - 1)
        def _emit():
            out_ref[...] = h_new.astype(out_ref.dtype)


def _gru_hoisted_kernel(zx_ref, u_ref, b_ref, out_ref, zx_scr, zh_scr, h_scr,
                        *, hidden: int, seq_len: int, reuse: int):
    """Hoisted grid cell: zx (input side, bias folded) is precomputed; only
    the recurrent-side zh = h U + b_rec accumulates across column tiles.
    Block movement mirrors the in-loop kernel — the zx tile copy replaces
    the (x_t, W-tile) dot."""
    t = pl.program_id(1)
    r = pl.program_id(2)
    gw = (3 * hidden) // reuse

    @pl.when(jnp.logical_and(t == 0, r == 0))
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    zx_scr[:, pl.ds(r * gw, gw)] = zx_ref[:, 0, :]
    zh_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(h_scr[...], u_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...])

    @pl.when(r == reuse - 1)
    def _update():
        h_new = _gate_update(zx_scr[...], zh_scr[...], h_scr[...], hidden)
        h_scr[...] = h_new

        @pl.when(t == seq_len - 1)
        def _emit():
            out_ref[...] = h_new.astype(out_ref.dtype)


def gru_scan_pallas(xs: jax.Array, W: jax.Array, U: jax.Array,
                    b: jax.Array, *, block_batch: int = 128,
                    reuse: int = 1, interpret: bool = True) -> jax.Array:
    """xs: [B, T, in]; W: [in, 3h]; U: [h, 3h]; b: [2, 3h] -> h [B, h]."""
    B, T, fin = xs.shape
    hidden = U.shape[0]
    assert B % block_batch == 0
    assert (3 * hidden) % reuse == 0
    gw = (3 * hidden) // reuse

    kernel = functools.partial(_gru_kernel, hidden=hidden, seq_len=T,
                               reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T, reuse),
        in_specs=[
            pl.BlockSpec((block_batch, 1, fin), lambda i, t, r: (i, t, 0)),
            pl.BlockSpec((fin, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((hidden, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((2, gw), lambda i, t, r: (0, r)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), xs.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xs, W, U, b)


def _gru_pipeline_kernel(zx_ref, u_ref, b_ref, out_ref, h_scr, *,
                         hidden: int, seq_len: int, reuse: int):
    """One PIPELINED block (Fig. 1 right): R reuse passes of the hU product
    unrolled in-block, full U resident (resources replicate x seq_len as
    priced), sequential grid carries only time."""
    t = pl.program_id(1)
    gw = (3 * hidden) // reuse

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    h = h_scr[...]
    zx = zx_ref[:, 0, :]                                   # [bt, 3h], b_in in
    u = u_ref[...]
    b_rec = b_ref[...]
    parts = [
        jnp.dot(h, u[:, r * gw:(r + 1) * gw],
                preferred_element_type=jnp.float32)
        + b_rec[r * gw:(r + 1) * gw]
        for r in range(reuse)
    ]
    zh = parts[0] if reuse == 1 else jnp.concatenate(parts, axis=-1)
    h_new = _gate_update(zx, zh, h, hidden)
    h_scr[...] = h_new

    @pl.when(t == seq_len - 1)
    def _emit():
        out_ref[...] = h_new.astype(out_ref.dtype)


def gru_scan_pipeline_pallas(zx: jax.Array, U: jax.Array, b_rec: jax.Array,
                             *, block_batch: int = 128, reuse: int = 1,
                             interpret: bool = True,
                             out_dtype=None) -> jax.Array:
    """zx: [B, T, 3h] precomputed x W + b_in (f32); U: [h, 3h]; b_rec: [3h]
    -> final h [B, h].  Grid (B/bt, T): the pipelined NONSTATIC executor."""
    B, T, gh = zx.shape
    hidden = U.shape[0]
    assert gh == 3 * hidden
    assert B % block_batch == 0
    assert (3 * hidden) % reuse == 0

    kernel = functools.partial(_gru_pipeline_kernel, hidden=hidden,
                               seq_len=T, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T),
        in_specs=[
            pl.BlockSpec((block_batch, 1, 3 * hidden),
                         lambda i, t: (i, t, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((3 * hidden,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden),
                                       out_dtype if out_dtype is not None
                                       else zx.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(zx, U, b_rec)


def gru_scan_hoisted_pallas(zx: jax.Array, U: jax.Array, b_rec: jax.Array,
                            *, block_batch: int = 128, reuse: int = 1,
                            interpret: bool = True,
                            out_dtype=None) -> jax.Array:
    """zx: [B, T, 3h] precomputed x W + b_in (f32); U: [h, 3h];
    b_rec: [3h] recurrent bias -> final h [B, h].

    Same (B/bt, T, R) sequential grid as ``gru_scan_pallas``; the live
    weight tile per step shrinks from (fin + h) x gw to h x gw.
    """
    B, T, gh = zx.shape
    hidden = U.shape[0]
    assert gh == 3 * hidden
    assert B % block_batch == 0
    assert (3 * hidden) % reuse == 0
    gw = (3 * hidden) // reuse

    kernel = functools.partial(_gru_hoisted_kernel, hidden=hidden,
                               seq_len=T, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T, reuse),
        in_specs=[
            pl.BlockSpec((block_batch, 1, gw), lambda i, t, r: (i, t, r)),
            pl.BlockSpec((hidden, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((gw,), lambda i, t, r: (r,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden),
                                       out_dtype if out_dtype is not None
                                       else zx.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, 3 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(zx, U, b_rec)
