"""Pallas GRU static-mode scan kernel (reset_after, Keras-compatible).

Same schedule as lstm_scan: weights VMEM-resident, h state in scratch,
sequential time grid.  GRU has 3 gate groups (z|r|hh) and the Hadamard
product sits inside the candidate tanh (r * (h U_h + b_rec)).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gru_kernel(x_ref, w_ref, u_ref, b_ref, out_ref, h_scr, *,
                hidden: int, seq_len: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x_t = x_ref[:, 0, :]
    h = h_scr[...]
    b_in = b_ref[0]                                        # [3h]
    b_rec = b_ref[1]

    zx = jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32) + b_in
    zh = jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32) + b_rec

    z = jax.nn.sigmoid(zx[:, :hidden] + zh[:, :hidden])
    r = jax.nn.sigmoid(zx[:, hidden:2 * hidden] + zh[:, hidden:2 * hidden])
    hh = jnp.tanh(zx[:, 2 * hidden:] + r * zh[:, 2 * hidden:])
    h_new = z * h + (1.0 - z) * hh
    h_scr[...] = h_new

    @pl.when(t == seq_len - 1)
    def _emit():
        out_ref[...] = h_new.astype(out_ref.dtype)


def gru_scan_pallas(xs: jax.Array, W: jax.Array, U: jax.Array,
                    b: jax.Array, *, block_batch: int = 128,
                    interpret: bool = True) -> jax.Array:
    """xs: [B, T, in]; W: [in, 3h]; U: [h, 3h]; b: [2, 3h] -> h [B, h]."""
    B, T, fin = xs.shape
    hidden = U.shape[0]
    assert B % block_batch == 0

    kernel = functools.partial(_gru_kernel, hidden=hidden, seq_len=T)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T),
        in_specs=[
            pl.BlockSpec((block_batch, 1, fin), lambda i, t: (i, t, 0)),
            pl.BlockSpec((fin, 3 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((2, 3 * hidden), lambda i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), xs.dtype),
        scratch_shapes=[pltpu.VMEM((block_batch, hidden), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xs, W, U, b)
