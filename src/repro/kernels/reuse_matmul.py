"""Reuse-factor matmul kernel — the TPU analogue of hls4ml's `reuse` knob.

On the FPGA, reuse R means each DSP performs R multiplications per matrix
product: DSP count shrinks by R, latency grows by R.  On TPU the analogous
serialization is K-dimension splitting: the kernel performs the matmul in R
sequential passes over K-slices, accumulating in a VMEM scratch.  The VMEM
working set for the weight operand shrinks by R (K/R x N resident at a time)
while the sequential grid length — the latency — grows by R.  This gives the
same resource/latency Pareto the paper sweeps in Tables 2-4, with VMEM bytes
playing the role of DSPs/BRAM.

Grid: (M/bm, R) — R sequential K-passes (innermost), M tiles parallel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _reuse_mm_kernel(x_ref, w_ref, o_ref, acc_scr, *, reuse: int):
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    acc_scr[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(r == reuse - 1)
    def _emit():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


def reuse_matmul_pallas(x: jax.Array, w: jax.Array, *, reuse: int = 1,
                        block_m: int = 128, interpret: bool = True
                        ) -> jax.Array:
    """x: [M, K] @ w: [K, N] in `reuse` sequential K-passes.

    K must divide by reuse; M by block_m (ops.py pads).
    VMEM per step: block_m*K/R (x) + (K/R)*N (w) + block_m*N (acc).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and K % reuse == 0 and M % block_m == 0
    ks = K // reuse

    kernel = functools.partial(_reuse_mm_kernel, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m, reuse),
        in_specs=[
            pl.BlockSpec((block_m, ks), lambda i, r: (i, r)),
            pl.BlockSpec((ks, N), lambda i, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda i, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, N), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)


def _col_mm_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...],
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


def col_matmul_pallas(x: jax.Array, w: jax.Array, *, reuse: int = 1,
                      block_m: int = 128, interpret: bool = True
                      ) -> jax.Array:
    """x @ w with the OUTPUT columns serialized into `reuse` sequential tiles.

    This is the gate-matmul schedule of the scan kernels exposed standalone:
    per sequential step only a K x N/R weight tile is live (the DSP/BRAM
    working set shrinks by R) and the grid runs R sequential passes.  The
    non-static execution mode builds each per-timestep block out of these.
    N must divide by reuse; M by block_m (ops.py pads / clamps).
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % reuse == 0 and M % block_m == 0
    ns = N // reuse

    return pl.pallas_call(
        _col_mm_kernel,
        grid=(M // block_m, reuse),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i, r: (i, 0)),
            pl.BlockSpec((K, ns), lambda i, r: (0, r)),
        ],
        out_specs=pl.BlockSpec((block_m, ns), lambda i, r: (i, r)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, w)


def vmem_bytes(M: int, K: int, N: int, reuse: int, block_m: int = 128,
               itemsize: int = 4) -> int:
    """Analytical VMEM working set — the 'resource' axis of the Pareto."""
    ks = K // reuse
    return (block_m * ks + ks * N + block_m * N) * itemsize
