"""Pallas TPU kernels for the paper's compute hot spots.

  lstm_scan / gru_scan — the paper's STATIC MODE on TPU: one weights-resident
      block (VMEM ~ BRAM) scans the sequence, state lives in VMEM scratch.
  hadamard             — the elementwise product the paper added to hls4ml.
  fixed_point          — ap_fixed<W,I> quantization on-chip.
  rglru_scan           — the RG-LRU gated linear recurrence (recurrentgemma).
  reuse_matmul         — reuse-factor analogue: K-serialized matmul whose
      VMEM working set shrinks by R while latency grows by R.
  col_matmul           — column-serialized matmul: the non-static per-
      timestep block with the gate matmul split into R sequential tiles.

Every scan kernel dispatches through the reuse-factor scheduling layer
(schedule.KernelSchedule via ops.py): reuse_factor partitions gate matmuls
into sequential column tiles, mode selects static (one weights-resident
block) vs non-static (one block per timestep), and the same schedule object
feeds core.hls's latency/DSP estimators.  compat.py absorbs JAX API drift
(TPUCompilerParams/CompilerParams, sharding.AxisType).

Kernels target TPU (Mosaic); this container is CPU-only so tests run them
with interpret=True against the pure-jnp oracles in ref.py.  The XLA model
paths are used for dry-run lowering (DESIGN.md Sec. 3).
"""
