"""Pallas Hadamard-product kernel — the one op the paper had to add to
hls4ml's library (Sec. 3).  Elementwise a*b with VMEM tiling; trivially
VPU-bound, included for paper fidelity and as the simplest BlockSpec example.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hadamard_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] * b_ref[...]


def hadamard_pallas(a: jax.Array, b: jax.Array, *, block: int = 1024,
                    interpret: bool = True) -> jax.Array:
    """a, b: [N, M] (caller pads rows to the block)."""
    assert a.shape == b.shape and a.ndim == 2
    n, m = a.shape
    bn = min(block, n)
    assert n % bn == 0
    return pl.pallas_call(
        _hadamard_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0)),
                  pl.BlockSpec((bn, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), a.dtype),
        interpret=interpret,
    )(a, b)
