"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig


def lstm_scan_ref(xs: jax.Array, W: jax.Array, U: jax.Array,
                  b: jax.Array) -> jax.Array:
    """xs: [B, T, in] -> final h [B, h] (Keras gate order i|f|c|o)."""
    B, T, _ = xs.shape
    h = U.shape[0]

    def step(carry, x_t):
        hp, cp = carry
        z = (x_t @ W + hp @ U + b).astype(jnp.float32)
        i = jax.nn.sigmoid(z[:, :h])
        f = jax.nn.sigmoid(z[:, h:2 * h])
        g = jnp.tanh(z[:, 2 * h:3 * h])
        o = jax.nn.sigmoid(z[:, 3 * h:])
        c = f * cp + i * g
        hn = o * jnp.tanh(c)
        return (hn, c), None

    init = (jnp.zeros((B, h), jnp.float32), jnp.zeros((B, h), jnp.float32))
    (hf, _), _ = jax.lax.scan(step, init, jnp.moveaxis(xs, 1, 0))
    return hf.astype(xs.dtype)


def gru_scan_ref(xs: jax.Array, W: jax.Array, U: jax.Array,
                 b: jax.Array) -> jax.Array:
    """xs: [B, T, in] -> final h [B, h] (reset_after; b: [2, 3h])."""
    B, T, _ = xs.shape
    h = U.shape[0]

    def step(hp, x_t):
        zx = (x_t @ W + b[0]).astype(jnp.float32)
        zh = (hp @ U + b[1]).astype(jnp.float32)
        z = jax.nn.sigmoid(zx[:, :h] + zh[:, :h])
        r = jax.nn.sigmoid(zx[:, h:2 * h] + zh[:, h:2 * h])
        hh = jnp.tanh(zx[:, 2 * h:] + r * zh[:, 2 * h:])
        hn = z * hp + (1.0 - z) * hh
        return hn, None

    hf, _ = jax.lax.scan(step, jnp.zeros((B, h), jnp.float32),
                         jnp.moveaxis(xs, 1, 0))
    return hf.astype(xs.dtype)


def hadamard_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return a * b


def fixed_point_ref(x: jax.Array, fp: FixedPointConfig) -> jax.Array:
    from repro.core.quant.fixed_point import quantize
    return quantize(x, fp)


def rglru_scan_ref(a: jax.Array, bx: jax.Array) -> jax.Array:
    """h_t = a_t h_{t-1} + bx_t over axis 1 -> all states [B, T, W]."""
    def step(hp, inp):
        a_t, b_t = inp
        hn = a_t.astype(jnp.float32) * hp + b_t.astype(jnp.float32)
        return hn, hn

    B, T, W = a.shape
    _, hs = jax.lax.scan(step, jnp.zeros((B, W), jnp.float32),
                         (jnp.moveaxis(a, 1, 0), jnp.moveaxis(bx, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).astype(a.dtype)


def reuse_matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
