"""Pallas LSTM static-mode scan kernel.

TPU adaptation of the paper's STATIC mode (Fig. 1 left): ONE physical block —
the gate weights stay resident in VMEM across the whole sequence (the BRAM
analogue), the (h, c) state lives in VMEM scratch, and the sequential grid
dimension walks timesteps.  HBM traffic: weights read once (not T times),
x_t streamed in, final h written out — exactly the paper's resource-minimal
schedule.

Grid: (B/bt, T) — the batch-tile dim is parallel ("independent inferences"),
the time dim is sequential ("arbitrary": carries scratch state).
Block shapes are padded to (8, 128) lane/sublane multiples by the caller
(ops.py) so the MXU sees aligned tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lstm_kernel(x_ref, w_ref, u_ref, b_ref, out_ref, h_scr, c_scr, *,
                 hidden: int, seq_len: int):
    """One (batch-tile, timestep) grid cell."""
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)

    x_t = x_ref[:, 0, :]                                   # [bt, in]
    h = h_scr[...]
    c = c_scr[...]

    z = (jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32)
         + jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32)
         + b_ref[...][None, :])                            # [bt, 4h]

    i = jax.nn.sigmoid(z[:, :hidden])
    f = jax.nn.sigmoid(z[:, hidden:2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden:])

    c_new = f * c + i * g                                  # Hadamard products
    h_new = o * jnp.tanh(c_new)
    h_scr[...] = h_new
    c_scr[...] = c_new

    @pl.when(t == seq_len - 1)
    def _emit():
        out_ref[...] = h_new.astype(out_ref.dtype)


def lstm_scan_pallas(xs: jax.Array, W: jax.Array, U: jax.Array,
                     b: jax.Array, *, block_batch: int = 128,
                     interpret: bool = True) -> jax.Array:
    """xs: [B, T, in]; W: [in, 4h]; U: [h, 4h]; b: [4h] -> final h [B, h].

    The caller (ops.py) pads B to block_batch and hidden/in to lane
    multiples; this function assumes aligned shapes.
    """
    B, T, fin = xs.shape
    hidden = U.shape[0]
    assert B % block_batch == 0

    kernel = functools.partial(_lstm_kernel, hidden=hidden, seq_len=T)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T),
        in_specs=[
            pl.BlockSpec((block_batch, 1, fin), lambda i, t: (i, t, 0)),
            pl.BlockSpec((fin, 4 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((4 * hidden,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), xs.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xs, W, U, b)
