"""Pallas LSTM static-mode scan kernel with reuse-factor column tiling.

TPU adaptation of the paper's STATIC mode (Fig. 1 left): ONE physical block —
the gate weights stay resident in VMEM across the whole sequence (the BRAM
analogue), the (h, c) state lives in VMEM scratch, and the sequential grid
dimension walks timesteps.  HBM traffic: weights read once (not T times),
x_t streamed in, final h written out — exactly the paper's resource-minimal
schedule.

Reuse factor R (hls4ml's central knob): the gate matmul z = x W + h U + b is
partitioned into R *sequential column tiles* of width 4h/R.  Per sequential
step only a (fin + h) x 4h/R weight tile is live — the parallel-multiplier
working set (DSP analogue) shrinks by R — while the sequential grid grows to
T x R steps (latency x R).  R = 1 degenerates to the fully parallel kernel.

Grid: (B/bt, T, R) — batch tiles parallel ("independent inferences"), time
and reuse sequential ("arbitrary": they carry scratch state).  Block shapes
are padded to (8, 128) lane/sublane multiples by the caller (ops.py) so the
MXU sees aligned tiles.

Hoisted variant (``lstm_scan_hoisted_pallas``): the input projection
zx = x W for ALL timesteps is computed OUTSIDE the scan as one batched
matmul (ops.py's hoist stage — full MXU utilization; only hU carries a
sequential dependency), and the sequential kernel consumes zx: per grid
cell ONE [bt, h] x [h, gw] dot instead of two, live weight tile h x gw
instead of (fin + h) x gw.  Bit-identical to the in-loop kernel: the final
pre-activation keeps the exact association (xW + hU) + b.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import tpu_compiler_params


def _gate_update(z, c, hidden: int):
    """z: [bt, 4h] pre-activations, c: [bt, h] -> (h_new, c_new)."""
    i = jax.nn.sigmoid(z[:, :hidden])
    f = jax.nn.sigmoid(z[:, hidden:2 * hidden])
    g = jnp.tanh(z[:, 2 * hidden:3 * hidden])
    o = jax.nn.sigmoid(z[:, 3 * hidden:])
    c_new = f * c + i * g                                  # Hadamard products
    h_new = o * jnp.tanh(c_new)
    return h_new, c_new


def _lstm_kernel(x_ref, w_ref, u_ref, b_ref, out_ref, z_scr, h_scr, c_scr, *,
                 hidden: int, seq_len: int, reuse: int):
    """One (batch-tile, timestep, column-tile) grid cell."""
    t = pl.program_id(1)
    r = pl.program_id(2)
    gw = (4 * hidden) // reuse

    @pl.when(jnp.logical_and(t == 0, r == 0))
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)

    x_t = x_ref[:, 0, :]                                   # [bt, in]
    h = h_scr[...]                                         # pre-update state

    # column tile r of the gate pre-activations: a (fin+h) x gw weight slice
    # is the only weight data live this step — the reuse resource saving
    z_scr[:, pl.ds(r * gw, gw)] = (
        jnp.dot(x_t, w_ref[...], preferred_element_type=jnp.float32)
        + jnp.dot(h, u_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :])

    @pl.when(r == reuse - 1)
    def _update():
        h_new, c_new = _gate_update(z_scr[...], c_scr[...], hidden)
        h_scr[...] = h_new
        c_scr[...] = c_new

        @pl.when(t == seq_len - 1)
        def _emit():
            out_ref[...] = h_new.astype(out_ref.dtype)


def _lstm_hoisted_kernel(zx_ref, u_ref, b_ref, out_ref, z_scr, h_scr, c_scr,
                         *, hidden: int, seq_len: int, reuse: int):
    """Hoisted grid cell: zx = x W is precomputed for every timestep, so the
    only weight data live per step is the h x gw recurrent tile and the body
    runs ONE dot instead of two (the per-step FLOPs halve for fin ~ h).
    Block movement mirrors the in-loop kernel tile-for-tile — the zx tile
    replaces the (x_t, W-tile) pair."""
    t = pl.program_id(1)
    r = pl.program_id(2)
    gw = (4 * hidden) // reuse

    @pl.when(jnp.logical_and(t == 0, r == 0))
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)

    # (zx + zh) + b — elementwise the same association as the in-loop
    # (dot_x + dot_h) + b, so the two paths are bit-identical
    z_scr[:, pl.ds(r * gw, gw)] = (
        zx_ref[:, 0, :]
        + jnp.dot(h_scr[...], u_ref[...], preferred_element_type=jnp.float32)
        + b_ref[...][None, :])

    @pl.when(r == reuse - 1)
    def _update():
        h_new, c_new = _gate_update(z_scr[...], c_scr[...], hidden)
        h_scr[...] = h_new
        c_scr[...] = c_new

        @pl.when(t == seq_len - 1)
        def _emit():
            out_ref[...] = h_new.astype(out_ref.dtype)


def lstm_scan_pallas(xs: jax.Array, W: jax.Array, U: jax.Array,
                     b: jax.Array, *, block_batch: int = 128,
                     reuse: int = 1, interpret: bool = True) -> jax.Array:
    """xs: [B, T, in]; W: [in, 4h]; U: [h, 4h]; b: [4h] -> final h [B, h].

    The caller (ops.py) pads B to block_batch, clamps ``reuse`` to a divisor
    of 4h, and pads hidden/in to lane multiples; this function assumes
    aligned shapes.
    """
    B, T, fin = xs.shape
    hidden = U.shape[0]
    assert B % block_batch == 0
    assert (4 * hidden) % reuse == 0
    gw = (4 * hidden) // reuse

    kernel = functools.partial(_lstm_kernel, hidden=hidden, seq_len=T,
                               reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T, reuse),
        in_specs=[
            pl.BlockSpec((block_batch, 1, fin), lambda i, t, r: (i, t, 0)),
            pl.BlockSpec((fin, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((hidden, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((gw,), lambda i, t, r: (r,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden), xs.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, 4 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(xs, W, U, b)


def _lstm_pipeline_kernel(zx_ref, u_ref, b_ref, out_ref, h_scr, c_scr, *,
                          hidden: int, seq_len: int, reuse: int):
    """One PIPELINED block (paper Fig. 1 right): the R reuse passes of this
    timestep's hU product are unrolled INSIDE the block — resources
    replicate (the full U stays resident, as priced by estimate_schedule's
    blocks = seq_len) and the sequential grid carries only time, so the
    block frees up after its own R passes: II = schedule.ii, not T x R."""
    t = pl.program_id(1)
    gw = (4 * hidden) // reuse

    @pl.when(t == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)
        c_scr[...] = jnp.zeros_like(c_scr)

    h = h_scr[...]
    zx = zx_ref[:, 0, :]
    u = u_ref[...]
    b = b_ref[...]
    # the R sequential column-tile passes, unrolled in-block; each keeps
    # the association (xW + hU) + b of the in-loop kernels -> bit-identical
    parts = [
        zx[:, r * gw:(r + 1) * gw]
        + jnp.dot(h, u[:, r * gw:(r + 1) * gw],
                  preferred_element_type=jnp.float32)
        + b[r * gw:(r + 1) * gw][None, :]
        for r in range(reuse)
    ]
    z = parts[0] if reuse == 1 else jnp.concatenate(parts, axis=-1)
    h_new, c_new = _gate_update(z, c_scr[...], hidden)
    h_scr[...] = h_new
    c_scr[...] = c_new

    @pl.when(t == seq_len - 1)
    def _emit():
        out_ref[...] = h_new.astype(out_ref.dtype)


def lstm_scan_pipeline_pallas(zx: jax.Array, U: jax.Array, b: jax.Array, *,
                              block_batch: int = 128, reuse: int = 1,
                              interpret: bool = True,
                              out_dtype=None) -> jax.Array:
    """zx: [B, T, 4h] precomputed x W (f32, NO bias) -> final h [B, h].

    The pipelined NONSTATIC executor: grid (B/bt, T) with the R reuse
    passes unrolled in-block (one 'block per timestep' in paper terms —
    seq_len x R sequential steps total, T grid cells).
    """
    B, T, gh = zx.shape
    hidden = U.shape[0]
    assert gh == 4 * hidden
    assert B % block_batch == 0
    assert (4 * hidden) % reuse == 0

    kernel = functools.partial(_lstm_pipeline_kernel, hidden=hidden,
                               seq_len=T, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T),
        in_specs=[
            pl.BlockSpec((block_batch, 1, 4 * hidden),
                         lambda i, t: (i, t, 0)),
            pl.BlockSpec((hidden, 4 * hidden), lambda i, t: (0, 0)),
            pl.BlockSpec((4 * hidden,), lambda i, t: (0,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden),
                                       out_dtype if out_dtype is not None
                                       else zx.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(zx, U, b)


def lstm_scan_hoisted_pallas(zx: jax.Array, U: jax.Array, b: jax.Array, *,
                             block_batch: int = 128, reuse: int = 1,
                             interpret: bool = True,
                             out_dtype=None) -> jax.Array:
    """zx: [B, T, 4h] precomputed x W (f32, NO bias); U: [h, 4h]; b: [4h]
    -> final h [B, h].

    The sequential grid is identical to ``lstm_scan_pallas`` — (B/bt, T, R)
    — but each cell's live weight tile is h x gw (the xW half left the
    recurrence with the hoist stage in ops.py).
    """
    B, T, gh = zx.shape
    hidden = U.shape[0]
    assert gh == 4 * hidden
    assert B % block_batch == 0
    assert (4 * hidden) % reuse == 0
    gw = (4 * hidden) // reuse

    kernel = functools.partial(_lstm_hoisted_kernel, hidden=hidden,
                               seq_len=T, reuse=reuse)
    return pl.pallas_call(
        kernel,
        grid=(B // block_batch, T, reuse),
        in_specs=[
            pl.BlockSpec((block_batch, 1, gw), lambda i, t, r: (i, t, r)),
            pl.BlockSpec((hidden, gw), lambda i, t, r: (0, r)),
            pl.BlockSpec((gw,), lambda i, t, r: (r,)),
        ],
        out_specs=pl.BlockSpec((block_batch, hidden), lambda i, t, r: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, hidden),
                                       out_dtype if out_dtype is not None
                                       else zx.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_batch, 4 * hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
            pltpu.VMEM((block_batch, hidden), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(zx, U, b)
