"""Fused single-step decode kernels — reuse-tiled, weight-resident.

The paper's headline engine is the SINGLE-EVENT regime: state resident, one
block processes each new element, initiation interval = one block latency.
This module is that regime's software kernel family, built so the
:class:`~repro.kernels.schedule.KernelSchedule` changes what the per-token
hot path EXECUTES (not just how it is priced or routed):

``decode_matmul``
    The scheduled gate matmul ``[B, d] @ [d, N]`` of one decode step.  The
    R reuse passes are *unrolled in-block*: the grid carries only the batch
    tiles, the whole weight matrix stays resident in VMEM for the step (the
    paper's static-mode "weights live on-chip" discipline), and each pass
    produces one ``N/R``-wide column tile.  Column tiles never split the K
    reduction, so every output element is the same full-K dot product as
    the unscheduled ``x @ w`` — the scheduled path is bit-identical to the
    einsum golden path, which the conformance tests assert exactly.

``rnn_decode_step``
    One scheduled LSTM/GRU state update (the paper's Eq. 1 as a single
    step): the cell equations come from ``core.rnn.cells`` with the gate
    matmul swapped for ``decode_matmul``, so the math lives in one place
    and scheduled == golden bitwise.  ``fp`` routes through the quantized
    cells (hls4ml ap_fixed datapath) with the same matmul injection.

Weight residency rides :data:`repro.kernels.ops.RESIDENT_WEIGHTS`: callers
pack each weight matrix ONCE per (weights identity, schedule key) into the
compute-ready layout (dtype cast, gate fusion, tile-aligned padding) via
:func:`resident_matrix` instead of re-deriving it inside every call's
compiled program — ``models/decode.py`` packs whole decoder layers through
the same cache.

Backend discipline matches ops.py: ``backend="xla"`` is the plain-dot
reference; Pallas backends run the in-block unrolled kernel (interpret on
CPU, compiled on TPU with the usual 128-lane tile checks).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import tpu_compiler_params
from repro.kernels.ops import _pad_axis, check_tpu_alignment, resident
from repro.kernels.schedule import KernelSchedule


# ---------------------------------------------------------------------------
# The reuse-tiled, weight-resident single-step matmul
# ---------------------------------------------------------------------------


def _decode_mm_kernel(x_ref, w_ref, o_ref, *, reuse: int, ns: int):
    """One batch-tile cell: the R column-tile passes unrolled in-block.

    The full [K, N] weight block is resident for the step; pass ``r``
    reads only its K x ns column slice — the live-multiplier working set
    of the paper's reuse factor — and the passes serialize in-block, so
    the step's II is R passes, not R grid cells."""
    x = x_ref[...]
    for r in range(reuse):
        o_ref[:, r * ns:(r + 1) * ns] = jnp.dot(x, w_ref[:, r * ns:(r + 1) * ns])


def decode_matmul_pallas(x: jax.Array, w: jax.Array, *, reuse: int = 1,
                         block_m: int = 8, interpret: bool = True
                         ) -> jax.Array:
    """x: [M, K] @ w: [K, N] with the N columns computed in ``reuse``
    sequential in-block passes.  N must divide by reuse; M by block_m
    (``decode_matmul`` pads)."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2 and N % reuse == 0 and M % block_m == 0
    kernel = functools.partial(_decode_mm_kernel, reuse=reuse, ns=N // reuse)
    return pl.pallas_call(
        kernel,
        grid=(M // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i: (i, 0)),
            pl.BlockSpec((K, N), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w)


def decode_matmul(x: jax.Array, w: jax.Array, *,
                  schedule: Optional[KernelSchedule] = None) -> jax.Array:
    """The scheduled single-step matmul: [M, K] @ [K, N] -> [M, N].

    ``schedule=None`` or ``backend="xla"`` is the golden plain dot; Pallas
    backends run :func:`decode_matmul_pallas` with the schedule's effective
    reuse (clamped to a divisor of N, hls4ml-style).  Bit-identical to the
    golden path for every R: column tiling never splits the K reduction.
    """
    if schedule is None or not schedule.use_pallas:
        return jnp.dot(x, w)
    re = schedule.effective_reuse(w.shape[-1])
    M = x.shape[0]
    bm = min(schedule.block_batch, max(8, M))
    check_tpu_alignment(schedule, tile_width=w.shape[-1] // re,
                        block_batch=bm, kernel="decode_matmul")
    x_p = _pad_axis(x, 0, bm)
    out = decode_matmul_pallas(x_p, w, reuse=re, block_m=bm,
                               interpret=schedule.interpret)
    return out[:M]


# ---------------------------------------------------------------------------
# Weight residency helpers (pack once per (weights identity, schedule key))
# ---------------------------------------------------------------------------


def _residency_key(schedule: Optional[KernelSchedule], tag: str) -> str:
    base = "none" if schedule is None else schedule.key()
    return f"decode/{tag}/{base}"


def resident_matrix(w, *, schedule: Optional[KernelSchedule],
                    dtype=None, tag: str = "w") -> jax.Array:
    """The compute-ready 2D layout of one weight matrix, cached per
    (array identity, schedule key): trailing dims flattened to the matmul's
    N axis, optional dtype cast.  Tracers pack in-trace (no host cache)."""

    def pack():
        m = w.reshape(w.shape[0], -1)
        return m if dtype is None else m.astype(dtype)

    return resident(w, _residency_key(schedule, tag), pack)


def resident_fused(ws: Tuple[jax.Array, ...], *,
                   schedule: Optional[KernelSchedule], dtype=None,
                   tag: str = "fused") -> jax.Array:
    """Gate-fuse several same-K weight matrices into ONE [K, sum(N_i)]
    matrix (q|k|v, gate|up — the LSTM i|f|c|o packing at LM scale), cached
    per (identities, schedule key).  The fused dot is bit-identical to the
    separate dots: each output column keeps its own full-K reduction."""

    def pack():
        flat = [w.reshape(w.shape[0], -1) for w in ws]
        m = jnp.concatenate(flat, axis=-1) if len(flat) > 1 else flat[0]
        return m if dtype is None else m.astype(dtype)

    return resident(tuple(ws), _residency_key(schedule, tag), pack)


# ---------------------------------------------------------------------------
# Scheduled single-step RNN decode (the paper's single-event engine)
# ---------------------------------------------------------------------------


def rnn_decode_step(cell: str, x_t: jax.Array, state,
                    W: jax.Array, U: jax.Array, b: jax.Array, *,
                    schedule: Optional[KernelSchedule] = None,
                    fp=None):
    """One scheduled recurrent state update.  x_t: [B, in]; state as in
    ``core.rnn.cells`` ((h, c) for LSTM, h for GRU).  Returns (h_t, state).

    The gate matmuls ``[B, d] @ [d, G*h]`` run through
    :func:`decode_matmul` under ``schedule`` — R sequential column-tile
    passes, weights resident — and are bit-identical to the golden cells
    for every (cell, R, dtype, fp): the cell equations ARE the golden
    cells', only the matmul implementation is injected.

    Native integral fp on a Pallas schedule runs the int8/int4 step from
    ``kernels/quantized.py`` instead: the weights' nibble-/byte-packed
    layout comes from the fp-keyed residency cache and the gate matmuls
    accumulate in int32 — bit-identical to the emulation cells when the
    weights are PTQ'd (on-grid), which the conformance suite asserts.
    """
    from repro.core.quant.fixed_point import is_native_int
    from repro.core.rnn.cells import (gru_cell, gru_cell_quantized, lstm_cell,
                                      lstm_cell_quantized)

    use_pallas = schedule is not None and schedule.use_pallas
    if fp is not None and is_native_int(fp) and use_pallas:
        from repro.kernels.quantized import quantized_decode_step

        return quantized_decode_step(cell, x_t, state, W, U, b, fp=fp,
                                     schedule=schedule)
    if use_pallas:
        mm = lambda a, w: decode_matmul(a, w, schedule=schedule)  # noqa: E731
    else:
        mm = None
    if fp is not None:
        step = lstm_cell_quantized if cell == "lstm" else gru_cell_quantized
        return step(x_t, state, W, U, b, fp, matmul=mm)
    step = lstm_cell if cell == "lstm" else gru_cell
    return step(x_t, state, W, U, b, matmul=mm)
