"""Pallas ap_fixed<W,I> quantization kernel (hls4ml's fixed-point datapath
stage, fused on-chip).

The grid math is NOT derived here: the kernel body calls
``core.quant.fixed_point.quantize`` — the same scale/round/clip/wrap
derivation as the host and XLA quantizers (one source of truth), so every
rounding ("rnd"/"trn") and saturation ("sat"/"wrap") mode behaves
identically across the three paths (cross-checked in
tests/test_quantization.py)."""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import quantize


def _quant_kernel(x_ref, o_ref, *, fp: FixedPointConfig):
    o_ref[...] = quantize(x_ref[...], fp).astype(o_ref.dtype)


def fixed_point_pallas(x: jax.Array, fp: FixedPointConfig, *,
                       block: int = 1024, interpret: bool = True) -> jax.Array:
    """x: [N, M] -> quantized to the ap_fixed<total, integer> grid."""
    assert x.ndim == 2
    n, m = x.shape
    bn = min(block, n)
    assert n % bn == 0
    kernel = functools.partial(_quant_kernel, fp=fp)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x)
