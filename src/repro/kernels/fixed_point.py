"""Pallas ap_fixed<W,I> quantization kernel: scale -> round-half-even ->
saturate -> rescale, fused on-chip (hls4ml's fixed-point datapath stage)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.config import FixedPointConfig


def _quant_kernel(x_ref, o_ref, *, scale: float, lo: float, hi: float):
    x = x_ref[...].astype(jnp.float32) * scale
    # round-half-even == jnp.round semantics
    y = jnp.clip(jnp.round(x), lo, hi)
    o_ref[...] = (y * (1.0 / scale)).astype(o_ref.dtype)


def fixed_point_pallas(x: jax.Array, fp: FixedPointConfig, *,
                       block: int = 1024, interpret: bool = True) -> jax.Array:
    """x: [N, M] -> quantized to the ap_fixed<total, integer> grid."""
    assert x.ndim == 2
    n, m = x.shape
    bn = min(block, n)
    assert n % bn == 0
    kernel = functools.partial(
        _quant_kernel, scale=fp.scale,
        lo=fp.min_value * fp.scale, hi=fp.max_value * fp.scale)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bn, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x)
