"""Fault-tolerant checkpointing: atomic writes, manifest integrity, restore
onto a DIFFERENT mesh (elastic restart after node loss).

Layout (one directory per step):
  <dir>/step_000123/
    manifest.json     — step, param paths, shapes, dtypes, sha-lite checksums
    <flatkey>.npy     — full (unsharded) arrays, written once by process 0

Multi-host note: this container is single-process; in a real multi-host pod
each host writes only the shards it owns (jax.experimental .multihost_utils
/ array_serialization) — the manager's API (save/restore/latest_step) and
the atomicity protocol (write temp dir -> fsync -> rename) are exactly what
the distributed writer plugs into.  Restore rebuilds arrays with
jax.device_put against whatever sharding the NEW mesh prescribes, so a
checkpoint taken on (16,16) restores cleanly on (2,16,16), (8,8) or 1
device — tests/test_checkpoint.py exercises mesh-shape changes.
"""

from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flat(k: str) -> str:
    return k.replace("/", "__")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:09d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    steps.append(int(name[5:]))
        return max(steps) if steps else None

    # -- save ------------------------------------------------------------------
    def save(self, step: int, params: Dict, opt_state=None,
             extra: Optional[Dict] = None) -> str:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        manifest = {"step": step, "arrays": {}, "extra": extra or {}}
        trees = {"params": params}
        if opt_state is not None:
            trees["opt_m"] = opt_state.m
            trees["opt_v"] = opt_state.v
            manifest["opt_step"] = int(opt_state.step)

        for tree_name, tree in trees.items():
            for k, v in tree.items():
                arr = np.asarray(jax.device_get(v))
                logical_dtype = str(arr.dtype)
                if arr.dtype.kind == "V" or "bfloat16" in logical_dtype:
                    # numpy cannot persist bfloat16 natively: store the raw
                    # bits as uint16 and record the logical dtype
                    logical_dtype = "bfloat16"
                    arr = arr.view(np.uint16)
                key = f"{tree_name}__{_flat(k)}"
                np.save(os.path.join(tmp, key + ".npy"), arr)
                manifest["arrays"][key] = {
                    "tree": tree_name, "key": k,
                    "shape": list(arr.shape), "dtype": logical_dtype,
                    "crc": zlib.crc32(arr.tobytes()) & 0xFFFFFFFF,
                }

        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, final)          # atomic publish
        self._gc()
        return final

    def _gc(self):
        steps = sorted(
            int(n[5:]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def restore(self, step: Optional[int] = None,
                shardings: Optional[Dict] = None,
                verify: bool = True) -> Tuple[int, Dict, Optional[Dict]]:
        """Returns (step, params, opt dict or None).  `shardings` maps param
        key -> Sharding for the (possibly different) restore mesh."""
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.load(open(os.path.join(d, "manifest.json")))

        trees: Dict[str, Dict] = {"params": {}, "opt_m": {}, "opt_v": {}}
        for key, info in manifest["arrays"].items():
            arr = np.load(os.path.join(d, key + ".npy"))
            if verify:
                crc = zlib.crc32(arr.tobytes()) & 0xFFFFFFFF
                if crc != info["crc"]:
                    raise IOError(f"checksum mismatch for {key} "
                                  f"(corrupt checkpoint {d})")
            if info["dtype"] == "bfloat16" and arr.dtype == np.uint16:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            k = info["key"]
            sh = (shardings or {}).get(k) if info["tree"] == "params" else \
                 (shardings or {}).get(k)
            if sh is not None:
                v = jax.device_put(arr, sh)
            else:
                v = jnp.asarray(arr)
            trees[info["tree"]][k] = v

        opt = None
        if trees["opt_m"]:
            opt = {"m": trees["opt_m"], "v": trees["opt_v"],
                   "step": manifest.get("opt_step", step)}
        return step, trees["params"], opt
