"""AdamW with warmup-cosine schedule and global-norm clipping (from scratch —
no optax in this container).  Optimizer state shards exactly like params
(moments inherit the param logical axes), i.e. fully-sharded (ZeRO-ish) by
construction under FSDP rules.
"""

from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import OptimizerConfig


class OptState(NamedTuple):
    step: jax.Array                     # scalar int32
    m: Dict[str, jax.Array]
    v: Dict[str, jax.Array]


def lr_schedule(opt: OptimizerConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = opt.lr * (s + 1.0) / max(opt.warmup_steps, 1)
    total = max(opt.total_steps - opt.warmup_steps, 1)
    t = jnp.clip((s - opt.warmup_steps) / total, 0.0, 1.0)
    cos = 0.5 * opt.lr * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(s < opt.warmup_steps, warm, cos)


def adamw_init(params: Dict[str, jax.Array],
               opt: OptimizerConfig) -> OptState:
    dt = jnp.dtype(opt.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m={k: zeros(p) for k, p in params.items()},
        v={k: zeros(p) for k, p in params.items()},
    )


def global_norm(tree: Dict[str, jax.Array]) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in tree.values()))


_NO_DECAY = ("bias", "norm", "scale", "a_log", "dt_bias", "lambda", "d_skip")


def adamw_update(
    params: Dict[str, jax.Array],
    grads: Dict[str, jax.Array],
    state: OptState,
    opt: OptimizerConfig,
) -> Tuple[Dict[str, jax.Array], OptState, Dict[str, jax.Array]]:
    step = state.step + 1
    lr = lr_schedule(opt, state.step)

    gn = global_norm(grads)
    clip = jnp.minimum(1.0, opt.grad_clip / jnp.maximum(gn, 1e-9)) \
        if opt.grad_clip > 0 else jnp.float32(1.0)

    b1, b2, eps = opt.b1, opt.b2, opt.eps
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_p, new_m, new_v = {}, {}, {}
    for k, p in params.items():
        g = grads[k].astype(jnp.float32) * clip
        m = state.m[k].astype(jnp.float32) * b1 + (1 - b1) * g
        v = state.v[k].astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        if opt.weight_decay > 0 and not any(s in k for s in _NO_DECAY):
            update = update + opt.weight_decay * p.astype(jnp.float32)
        new_p[k] = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        new_m[k] = m.astype(state.m[k].dtype)
        new_v[k] = v.astype(state.v[k].dtype)

    metrics = {"grad_norm": gn, "lr": lr}
    return new_p, OptState(step, new_m, new_v), metrics
