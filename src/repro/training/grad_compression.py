"""int8 gradient compression with error feedback — cross-pod reduction trick.

At multi-pod scale the pod-to-pod links are the scarcest bandwidth; 4x
compression of the gradient all-reduce is a standard lever.  We quantize
per-tensor to int8 with a dynamic scale and carry the quantization error
into the next step (error feedback keeps SGD/Adam convergence, Seide et al.
1-bit SGD lineage).

Under jit the quantize-dequantize pair shrinks the all-reduced payload when
XLA schedules the reduction after quantization; `compress_decompress` is
also usable as a plain drop-in to measure convergence impact in tests.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(g)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(grads: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    out = {}
    for k, g in grads.items():
        q, s = quantize_int8(g.astype(jnp.float32))
        out[k] = dequantize_int8(q, s).astype(g.dtype)
    return out


def compress_with_error_feedback(
    grads: Dict[str, jax.Array],
    error: Optional[Dict[str, jax.Array]],
) -> Tuple[Dict[str, jax.Array], Dict[str, jax.Array]]:
    """Returns (compressed grads, new error residual)."""
    new_g, new_e = {}, {}
    for k, g in grads.items():
        gf = g.astype(jnp.float32)
        if error is not None:
            gf = gf + error[k]
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        new_g[k] = deq.astype(g.dtype)
        new_e[k] = gf - deq
    return new_g, new_e
