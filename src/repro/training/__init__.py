from repro.training.optimizer import (  # noqa: F401
    OptState,
    adamw_init,
    adamw_update,
    lr_schedule,
)
from repro.training.train_step import make_train_step  # noqa: F401
