"""Train step builder: grad accumulation (microbatch scan) + AdamW update +
optional int8 cross-replica gradient compression.

The returned function is pure (params, opt_state, batch) -> (params,
opt_state, metrics) and is what launch/dryrun.py lowers for the roofline.
"""

from __future__ import annotations

from functools import partial
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, OptimizerConfig, TrainConfig
from repro.models.model import Model
from repro.sharding.api import constrain
from repro.training.optimizer import OptState, adamw_update


def _split_microbatches(batch: Dict, accum: int) -> Dict:
    """[B, ...] -> [accum, B/accum, ...] (microbatch dim is scanned)."""
    def r(x):
        b = x.shape[0]
        assert b % accum == 0, f"batch {b} % accum {accum}"
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_train_step(
    model: Model,
    train_cfg: TrainConfig,
    grad_accum: Optional[int] = None,
    accum_dtype: str = "float32",
    grad_shardings: Optional[Dict] = None,
) -> Callable:
    cfg = model.cfg
    accum = grad_accum if grad_accum is not None else max(cfg.grad_accum, 1)
    opt = train_cfg.optimizer
    acc_dt = jnp.dtype(accum_dtype)

    def _shard_grads(g):
        """Pin gradients to the parameter shardings: without this GSPMD
        keeps grads replicated and ALL-REDUCES them (measured: 5.4 TiB/dev
        on nemotron train_4k); with it backward emits reduce-scatters into
        the sharded accumulation buffer."""
        if grad_shardings is None:
            return g
        return {k: jax.lax.with_sharding_constraint(v, grad_shardings[k])
                if k in grad_shardings else v for k, v in g.items()}

    def loss_fn(params, mb):
        loss, metrics = model.loss(params, mb)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch: Dict):
        if accum > 1:
            mbs = _split_microbatches(batch, accum)

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g = _shard_grads(g)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g0 = _shard_grads(jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params))
            (grads, loss_sum), ms = jax.lax.scan(
                micro, (g0, jnp.float32(0.0)), mbs)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _shard_grads(grads)

        if train_cfg.compress_grads:
            from repro.training.grad_compression import compress_decompress
            grads = compress_decompress(grads)

        params, opt_state, opt_metrics = adamw_update(
            params, grads, opt_state, opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
