"""repro: hls4ml-RNN paper reproduction as a multi-pod JAX/TPU framework.

Layers:
  core/      — the paper's contribution (RNN cells, static/non-static modes,
               fixed-point quantization, HLS design-space model)
  models/    — model zoo covering the 10 assigned architectures
  kernels/   — Pallas TPU kernels (validated in interpret mode on CPU)
  sharding/  — logical-axis partitioning rules (FSDP x TP x EP x SP)
  training/  — optimizers, grad accumulation, compression
  serving/   — KV caches, flash-decode, batching engines
  checkpoint — fault-tolerant save/restore with elastic resharding
  launch/    — production mesh, dry-run, train/serve drivers
"""

__version__ = "0.1.0"
