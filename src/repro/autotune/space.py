"""Legal KernelSchedule space enumeration — the paper's hand-built sweep
grid, generated and pruned mechanically.

The axes are exactly ``KernelSchedule``'s: reuse factor x mode x hoist x
hoist_reuse x ii x block_batch x backend.  Legality pruning applies the same
rules the kernels enforce at dispatch:

  * reuse factors must divide the gate dimension ``G x hidden`` (the kernels
    clamp non-divisors via ``effective_reuse`` — enumerating them would only
    alias already-enumerated points under a different name);
  * ``hoist_reuse > 1`` requires the hoist; pipeline mode implies it
    (``KernelSchedule.__post_init__``); ``ii`` is a pipeline-only axis;
  * ``backend="pallas_tpu"`` points must pass ``ops.check_tpu_alignment``
    (128-lane column tiles, 8-sublane batch tiles) — misaligned points are
    pruned, not clamped, because they would raise at dispatch;
  * duplicates (same ``schedule.key()``) collapse to one point.

The result is deterministic (sorted by key) so Pareto frontiers and selected
schedules are reproducible across runs.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

from repro.config import ModelConfig
from repro.core.hls.resources import gate_count
from repro.kernels.schedule import MODES, KernelSchedule


def divisors(n: int) -> Tuple[int, ...]:
    """All divisors of n, ascending — the legal reuse factors of a gate
    dimension (hls4ml restricts R the same way)."""
    small, large = [], []
    d = 1
    while d * d <= n:
        if n % d == 0:
            small.append(d)
            if d != n // d:
                large.append(n // d)
        d += 1
    return tuple(small + large[::-1])


@dataclass(frozen=True)
class SpaceSpec:
    """Which slice of the schedule space to enumerate.

    ``reuse_factors=None`` means every divisor of the gate dimension — the
    full hls4ml-legal R axis.  The defaults describe the container-friendly
    slice (interpret backend, one block_batch); hardware sweeps pass
    ``backends=("pallas_tpu",)`` and get alignment-pruned automatically.
    """

    reuse_factors: Optional[Tuple[int, ...]] = None
    modes: Tuple[str, ...] = MODES
    hoist: Tuple[bool, ...] = (False, True)
    hoist_reuses: Tuple[int, ...] = (1,)
    iis: Tuple[int, ...] = (0,)
    block_batches: Tuple[int, ...] = (8,)
    backends: Tuple[str, ...] = ("pallas_interpret",)
    max_points: int = 4096

    def __post_init__(self):
        for m in self.modes:
            if m not in MODES:
                raise ValueError(f"mode {m!r} not in {MODES}")


def _tpu_aligned(schedule: KernelSchedule, gate_dim: int) -> bool:
    """True when a pallas_tpu schedule passes the Mosaic alignment rules
    (non-TPU backends are unconstrained)."""
    if schedule.backend != "pallas_tpu":
        return True
    import math

    from repro.kernels.ops import check_tpu_alignment
    try:
        r = schedule.effective_reuse(gate_dim)
        check_tpu_alignment(schedule, tile_width=gate_dim // r,
                            block_batch=schedule.block_batch, kernel="space")
        if schedule.hoist_reuse > 1:
            hr = math.gcd(schedule.hoist_reuse, gate_dim)
            check_tpu_alignment(schedule, tile_width=gate_dim // hr,
                                block_batch=schedule.block_batch,
                                kernel="space")
    except ValueError:
        return False
    return True


def _raw_points(gate_dim: int, spec: SpaceSpec) -> Iterator[KernelSchedule]:
    rfs = spec.reuse_factors if spec.reuse_factors is not None \
        else divisors(gate_dim)
    for backend in spec.backends:
        for bb in spec.block_batches:
            for r in rfs:
                if gate_dim % r != 0:
                    continue            # aliases the gcd point — prune
                for mode in spec.modes:
                    base = dict(reuse_factor=r, mode=mode, block_batch=bb,
                                backend=backend)
                    if mode == "pipeline":
                        # hoist is implied; ii and hoist_reuse are live axes
                        for ii in spec.iis:
                            for hr in spec.hoist_reuses:
                                if hr > 1 and gate_dim % hr != 0:
                                    continue
                                yield KernelSchedule(ii=ii, hoist_reuse=hr,
                                                     **base)
                        continue
                    for hoist in spec.hoist:
                        if not hoist:
                            yield KernelSchedule(**base)
                            continue
                        for hr in spec.hoist_reuses:
                            if hr > 1 and gate_dim % hr != 0:
                                continue
                            yield KernelSchedule(hoist_input=True,
                                                 hoist_reuse=hr, **base)


def enumerate_space(cfg: ModelConfig,
                    spec: Optional[SpaceSpec] = None
                    ) -> Tuple[KernelSchedule, ...]:
    """The legal, deduplicated, deterministic schedule space for one model."""
    assert cfg.rnn is not None, "the schedule space is an RNN-family concept"
    spec = spec or SpaceSpec()
    gate_dim = gate_count(cfg.rnn.cell) * cfg.rnn.hidden
    seen = {}
    for s in _raw_points(gate_dim, spec):
        if not _tpu_aligned(s, gate_dim):
            continue
        seen.setdefault(s.key(), s)
        if len(seen) >= spec.max_points:
            break
    return tuple(seen[k] for k in sorted(seen))


# ---------------------------------------------------------------------------
# Decode-legal slice (the single-step kernels of kernels/decode_step.py)
# ---------------------------------------------------------------------------


def decode_legal(schedule: KernelSchedule) -> bool:
    """True when the single-step decode kernels can execute ``schedule``.

    A decode step has no time axis, so the scan-only degrees of freedom are
    illegal: mode must be ``"static"`` (ONE weights-resident block serves
    the step; non-static/pipeline describe per-timestep block chains that
    do not exist here), and the hoist axes (``hoist_input``,
    ``hoist_reuse``) and pipeline ``ii`` must be off — there is no input
    projection to hoist out of a single step.  The reuse factor and
    backend axes carry over unchanged.
    """
    return (schedule.mode == "static" and not schedule.hoist_input
            and schedule.hoist_reuse == 1 and schedule.ii == 0)


def native_int_legal(schedule: KernelSchedule) -> bool:
    """True when the NATIVE int8/int4 kernel bodies can execute
    ``schedule``.

    Quantized datapaths never hoist — splitting z = q(xW + hU + b) into a
    precomputed zx plus an in-loop hU would move the hls4ml quantization
    points — so ``hoist_input``/``hoist_reuse`` and pipeline mode (which
    implies the hoist) are illegal, as is a pipeline ``ii``.  Reuse factor,
    mode static/nonstatic, block_batch and backend carry over: the native
    scan runs the same per-timestep structure either way, with R column
    tiles per gate matmul.
    """
    return (not schedule.hoist_input and schedule.mode != "pipeline"
            and schedule.hoist_reuse == 1 and schedule.ii == 0)


def enumerate_decode_space(cfg: ModelConfig,
                           spec: Optional[SpaceSpec] = None
                           ) -> Tuple[KernelSchedule, ...]:
    """The decode-legal slice of the schedule space (deduped, sorted) —
    what ``autotune.select_decode`` and the decode estimators price."""
    return tuple(s for s in enumerate_space(cfg, spec) if decode_legal(s))


# ---------------------------------------------------------------------------
# Speculative slice: legal (draft, verify, K) triples over the decode space
# ---------------------------------------------------------------------------


def lm_decode_schedules(cfg: ModelConfig,
                        spec: Optional[SpaceSpec] = None
                        ) -> Tuple[KernelSchedule, ...]:
    """The decode-legal schedule slice for a DENSE-stack LM config — the
    reuse factors are divisors of the gcd of the scheduled step's fused
    matmul output widths (q|k|v, attn out, MLP in, MLP down), so every
    enumerated R is what ``effective_reuse`` resolves on EVERY matmul in
    the chain: the point priced is the point executed, chain-wide.
    """
    import math

    spec = spec or SpaceSpec()
    d, f = cfg.d_model, cfg.d_ff
    hq, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    glu = cfg.mlp_type in ("swiglu", "geglu")
    widths = [(hq + 2 * hk) * hd, d, 2 * f if glu else f, d]
    g = 0
    for w in widths:
        g = math.gcd(g, w)
    rfs = spec.reuse_factors if spec.reuse_factors is not None \
        else divisors(g)
    seen = {}
    for backend in spec.backends:
        for bb in spec.block_batches:
            for r in rfs:
                if g % r != 0:
                    continue
                s = KernelSchedule(reuse_factor=r, mode="static",
                                   block_batch=bb, backend=backend)
                if not _tpu_aligned(s, g):
                    continue
                seen.setdefault(s.key(), s)
                if len(seen) >= spec.max_points:
                    break
    return tuple(seen[k] for k in sorted(seen))


def speculative_draft_legal(draft: Optional[KernelSchedule],
                            verify: KernelSchedule) -> bool:
    """True when ``draft`` may propose tokens for ``verify`` to check.

    ``None`` (the n-gram CacheTable) is always legal — free drafts cost
    nothing to be wrong.  A model draft must itself be decode-legal
    (it runs the same single-step kernels) and STRICTLY cheaper than the
    verify schedule — reuse_factor strictly higher, the cheap side of the
    paper's R asymmetry.  Equal-or-denser drafts would pay more per draft
    than verification recovers; they are pruned, not penalized.
    """
    if draft is None:
        return True
    return (decode_legal(draft)
            and draft.reuse_factor > verify.reuse_factor)


def enumerate_speculative_space(cfg: ModelConfig,
                                spec: Optional[SpaceSpec] = None, *,
                                ks: Tuple[int, ...] = (1, 2, 4, 8),
                                include_ngram: bool = True
                                ) -> Tuple[Tuple[Optional[KernelSchedule],
                                                 KernelSchedule, int], ...]:
    """Every legal (draft, verify, K) triple: verify ranges over the
    decode-legal slice (RNN families via ``enumerate_decode_space``,
    dense stacks via ``lm_decode_schedules``), drafts over the same slice
    restricted by ``speculative_draft_legal`` plus the free n-gram draft
    (``None``) when ``include_ngram``.  Deterministic order: sorted by
    (verify key, draft key or '', K)."""
    if cfg.rnn is not None:
        pool = enumerate_decode_space(cfg, spec)
    else:
        pool = lm_decode_schedules(cfg, spec)
    triples = []
    for verify in pool:
        drafts: Tuple[Optional[KernelSchedule], ...] = tuple(
            d for d in pool if speculative_draft_legal(d, verify))
        if include_ngram:
            drafts = (None,) + drafts
        for draft in drafts:
            for k in ks:
                if k < 1:
                    continue        # K=0 is "speculation off", not a point
                triples.append((draft, verify, k))
    triples.sort(key=lambda t: (t[1].key(),
                                "" if t[0] is None else t[0].key(), t[2]))
    return tuple(triples)
