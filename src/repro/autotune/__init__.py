"""Auto-scheduler: Pareto design-space exploration over KernelSchedule.

``explore(cfg, target)`` prices the legal schedule space and reduces it to
a Pareto frontier; ``select(cfg, target)`` returns the single point a
serving engine should run — the paper's hand-enumerated latency/resource
tables, turned into a solver.
"""

from repro.autotune.explorer import (  # noqa: F401
    Exploration,
    InfeasibleTargetError,
    SpeculativePoint,
    degradation_ladder,
    explore,
    explore_decode,
    explore_speculative,
    is_feasible,
    measure_points,
    pareto,
    select,
    select_decode,
    select_speculative,
    suggest_replicas,
    violation,
)
from repro.autotune.space import (  # noqa: F401
    SpaceSpec,
    decode_legal,
    divisors,
    enumerate_decode_space,
    enumerate_space,
    enumerate_speculative_space,
    lm_decode_schedules,
    speculative_draft_legal,
)
from repro.autotune.target import OBJECTIVES, DesignTarget  # noqa: F401
