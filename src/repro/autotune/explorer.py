"""Pareto design-space explorer over KernelSchedule — the component that
*chooses* a point on the paper's latency/resource curve.

The paper's tables are hand-enumerated sweeps; this module closes the loop:

  1. ``enumerate_space`` yields every legal schedule (space.py);
  2. every point is priced analytically through the unified
     ``core.hls.price_point`` bridge — the SAME object the kernels execute;
  3. the space reduces to a Pareto frontier over (latency_cycles, dsp,
     bram_18k) — no returned point is dominated by any legal point;
  4. a :class:`~repro.autotune.target.DesignTarget` filters the space to the
     feasible region and ``select`` picks the objective-optimal point —
     optionally re-ranked by measured wall-clock of the top-k candidates
     (the bench harness's steady-state timing, ``measure_points``).

An infeasible target raises :class:`InfeasibleTargetError` naming the
nearest-to-feasible point (smallest summed relative constraint violation), so
the error message tells the designer exactly how far their budget is from
the achievable curve.
"""

from __future__ import annotations

import dataclasses
import math
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.config import ModelConfig
from repro.core.hls.design_point import (DesignPoint, price_decode_point,
                                         price_point)
from repro.autotune.space import (SpaceSpec, enumerate_decode_space,
                                  enumerate_space, native_int_legal)
from repro.core.quant.fixed_point import is_native_int
from repro.autotune.target import DesignTarget


# ---------------------------------------------------------------------------
# Feasibility
# ---------------------------------------------------------------------------


def violation(point: DesignPoint, target: DesignTarget) -> float:
    """Summed relative constraint violation; 0.0 iff feasible.

    Each violated constraint contributes its fractional excess (e.g. a point
    at 12 µs against a 10 µs budget adds 0.2), so "nearest to feasible" is
    scale-free across latency/DSP/BRAM/throughput axes.
    """
    v = 0.0
    c = target.clock_mhz
    if target.max_latency_us is not None:
        v += max(0.0, point.latency_us(c) / target.max_latency_us - 1.0)
    if target.min_throughput_eps is not None:
        # the throughput floor is read against the target's data-parallel
        # replica count: K replicas of one design sustain K x its events/s
        v += max(0.0,
                 target.min_throughput_eps
                 / (point.throughput_eps(c) * target.replicas) - 1.0)
    if target.max_dsp is not None:
        v += max(0.0, point.dsp / target.max_dsp - 1.0)
    if target.max_bram_18k is not None:
        v += max(0.0, point.bram_18k / target.max_bram_18k - 1.0)
    if target.part is not None and not point.design.fits:
        v += 1.0
    return v


def is_feasible(point: DesignPoint, target: DesignTarget) -> bool:
    return violation(point, target) == 0.0


def suggest_replicas(points: Sequence[DesignPoint], target: DesignTarget
                     ) -> Optional[Tuple[int, DesignPoint]]:
    """Smallest data-parallel replica count that would clear the target's
    throughput floor, and the point to replicate.

    Only an aggregate-throughput shortfall is fixable by replication:
    among points feasible on every NON-throughput constraint, take the
    highest-throughput one and size the pool as
    ``ceil(min_throughput_eps / point_eps)``.  None when no throughput
    floor is set, when no point clears the other constraints (replication
    cannot fix a latency or resource bust), or when the suggestion would
    not exceed the replicas the target already has."""
    if target.min_throughput_eps is None or not points:
        return None
    relaxed = dataclasses.replace(target, min_throughput_eps=None)
    ok = [p for p in points if is_feasible(p, relaxed)]
    if not ok:
        return None
    c = target.clock_mhz
    best = max(ok, key=lambda p: (p.throughput_eps(c), -p.dsp, p.key))
    k = max(1, math.ceil(target.min_throughput_eps / best.throughput_eps(c)
                         - 1e-9))
    if k <= target.replicas:
        return None
    return k, best


class InfeasibleTargetError(ValueError):
    """No enumerated schedule meets the target; carries the nearest point
    and, when the shortfall is pure throughput, the smallest replica count
    that would clear it (``suggested_replicas`` / ``suggested_point``)."""

    def __init__(self, target: DesignTarget, nearest: DesignPoint,
                 n_points: int,
                 replica_hint: Optional[Tuple[int, DesignPoint]] = None):
        self.target = target
        self.nearest = nearest
        self.suggested_replicas = (replica_hint[0] if replica_hint
                                   else None)
        self.suggested_point = replica_hint[1] if replica_hint else None
        c = target.clock_mhz
        msg = (
            f"no schedule among {n_points} legal points meets target "
            f"{target.describe()}; nearest-to-feasible point is "
            f"{nearest.key} (latency {nearest.latency_us(c):.2f}us, "
            f"dsp {nearest.dsp}, bram {nearest.bram_18k}, "
            f"throughput {nearest.throughput_eps(c):.0f}ev/s, "
            f"violation {violation(nearest, target):.1%}) — relax the "
            f"budget at least that far or widen the space spec")
        if replica_hint is not None:
            k, pt = replica_hint
            msg += (
                f"; or scale out: {k} data-parallel replicas of {pt.key} "
                f"({pt.throughput_eps(c):.0f}ev/s each, "
                f"{k * pt.throughput_eps(c):.0f}ev/s aggregate) clear the "
                f"throughput floor — set replicas={k} on the target and "
                f"serve through a ReplicaPool/Router of that size")
        super().__init__(msg)


# ---------------------------------------------------------------------------
# Pareto reduction
# ---------------------------------------------------------------------------


def pareto(points: Sequence[DesignPoint]) -> Tuple[DesignPoint, ...]:
    """Non-dominated subset under DesignPoint.dominates, sorted by latency
    (ties by DSP then BRAM then key, for determinism).

    Sort-then-scan: after sorting by (latency, dsp, bram), any dominator of
    a point precedes it, so one pass keeping the running non-dominated set
    is O(n·k) with k = frontier size.
    """
    ordered = sorted(points, key=lambda p: (p.latency_cycles, p.dsp,
                                            p.bram_18k, p.key))
    front: List[DesignPoint] = []
    for p in ordered:
        if not any(q.dominates(p) for q in front):
            front.append(p)
    return tuple(front)


# ---------------------------------------------------------------------------
# Exploration
# ---------------------------------------------------------------------------


_OBJECTIVE_RANK = {
    "latency": lambda p: (p.latency_cycles, p.dsp, p.bram_18k, p.key),
    "resources": lambda p: (p.dsp, p.bram_18k, p.latency_cycles, p.key),
    "throughput": lambda p: (p.ii_cycles, p.latency_cycles, p.dsp, p.key),
}


@dataclass(frozen=True)
class Exploration:
    """Everything ``explore`` learned about one (config, target) pair."""

    cfg: ModelConfig
    target: Optional[DesignTarget]
    points: Tuple[DesignPoint, ...]      # every legal priced point
    frontier: Tuple[DesignPoint, ...]    # Pareto over (latency, dsp, bram)
    feasible: Tuple[DesignPoint, ...]    # target-feasible, objective-ranked

    @property
    def best(self) -> Optional[DesignPoint]:
        return self.feasible[0] if self.feasible else None

    def frontier_table(self) -> List[dict]:
        return [p.report_row() for p in self.frontier]

    def prewarm(self, engine, k: Optional[int] = None) -> Dict[str, dict]:
        """Zero-warmup hook: pre-compile the engine's serving executables
        for the top-``k`` feasible points (the whole Pareto frontier when
        the exploration had no target, or ``k=None`` for all of them).

        An engine started over a warm ``cache_dir`` deserializes every
        frontier artifact instead of compiling — the first request on ANY
        frontier queue then pays zero jit compiles, which is what makes a
        target re-resolve (new tenant, redeploy) a routing decision instead
        of a latency cliff.  Returns the engine's per-key
        ``{"status", "compile_s"}`` prewarm report."""
        pts = list(self.feasible if self.feasible else self.frontier)
        if k is not None:
            pts = pts[:k]
        return engine.prewarm(schedules=[p.schedule for p in pts],
                              fps=[p.fp for p in pts])


def _finish(cfg: ModelConfig, target: Optional[DesignTarget],
            points: Tuple[DesignPoint, ...]) -> Exploration:
    """Pareto-reduce priced points and rank the target-feasible region —
    shared by the scan-path and decode-path explorations."""
    front = pareto(points)
    if target is None:
        feas = tuple(sorted(points, key=_OBJECTIVE_RANK["latency"]))
    else:
        feas = tuple(sorted((p for p in points if is_feasible(p, target)),
                            key=_OBJECTIVE_RANK[target.objective]))
    return Exploration(cfg=cfg, target=target, points=points,
                       frontier=front, feasible=feas)


def _pricing_axes(target: Optional[DesignTarget]):
    fp = target.fp if target is not None else None
    clock = target.clock_mhz if target is not None else 200.0
    part = (target.part if target is not None and target.part is not None
            else "xcku115")
    return fp, clock, part


def explore(cfg: ModelConfig, target: Optional[DesignTarget] = None,
            spec: Optional[SpaceSpec] = None) -> Exploration:
    """Enumerate, price, and Pareto-reduce the legal schedule space.

    The fixed-point axis comes from the target (``target.fp``); pricing and
    the eventual serving queue both use that config, so the explored curve
    is the one the engine will execute.
    """
    schedules = enumerate_space(cfg, spec)
    fp, clock, part = _pricing_axes(target)
    if is_native_int(fp):
        # the native int bodies cannot hoist/pipeline — prune the points
        # the quantized kernels would refuse to execute
        schedules = tuple(s for s in schedules if native_int_legal(s))
    points = tuple(price_point(cfg, s, fp, clock_mhz=clock, part=part)
                   for s in schedules)
    return _finish(cfg, target, points)


def explore_decode(cfg: ModelConfig, target: Optional[DesignTarget] = None,
                   spec: Optional[SpaceSpec] = None) -> Exploration:
    """The decode-path exploration: the DECODE-LEGAL slice of the space
    (static, un-hoisted — see ``space.decode_legal``), every point priced
    with the single-step estimate (``price_decode_point``: II ~ R, full
    weight resident) instead of the whole-sequence scan estimate.  The
    same DesignTarget constraints and objectives apply — a latency budget
    now reads "per state update" rather than "per sequence"."""
    schedules = enumerate_decode_space(cfg, spec)
    fp, clock, part = _pricing_axes(target)
    points = tuple(price_decode_point(cfg, s, fp, clock_mhz=clock, part=part)
                   for s in schedules)
    return _finish(cfg, target, points)


def select(cfg: ModelConfig, target: DesignTarget,
           spec: Optional[SpaceSpec] = None, *,
           measure_top_k: int = 0,
           measure_batch: int = 32) -> DesignPoint:
    """The auto-scheduler entry point: target -> the schedule to serve.

    Raises :class:`InfeasibleTargetError` (naming the nearest-to-feasible
    point) when nothing in the space meets the target, and a plain
    ``ValueError`` when the spec pruned the space to nothing (there is no
    nearest point to name).  With ``measure_top_k > 0`` the top-k feasible
    candidates (by predicted objective) are re-ranked by measured
    steady-state wall-clock — analytic pricing proposes, measurement
    disposes.  Measurement carries no resource information, so the
    ``"resources"`` objective keeps the analytic ranking (its optimum is a
    DSP count, not a wall-clock).
    """
    ex = explore(cfg, target, spec)
    _check_selectable(ex, target)
    if measure_top_k <= 0 or target.objective == "resources":
        return ex.feasible[0]
    top = list(ex.feasible[:measure_top_k])
    walls = measure_points(cfg, top, batch=measure_batch)
    return min(top, key=lambda p: (walls[p.key], p.dsp, p.key))


def _check_selectable(ex: Exploration, target: DesignTarget) -> None:
    if not ex.points:
        raise ValueError(
            f"enumerated schedule space is empty for target "
            f"{target.describe()}: the space spec pruned every point "
            f"(e.g. pallas_tpu lane alignment, or reuse factors that do "
            f"not divide the gate dimension) — widen the SpaceSpec")
    if not ex.feasible:
        nearest = min(ex.points, key=lambda p: (violation(p, target),
                                                p.latency_cycles, p.key))
        raise InfeasibleTargetError(target, nearest, len(ex.points),
                                    replica_hint=suggest_replicas(ex.points,
                                                                  target))


def select_decode(cfg: ModelConfig, target: DesignTarget,
                  spec: Optional[SpaceSpec] = None) -> DesignPoint:
    """Target -> the schedule the single-step decode path should run.

    Decode counterpart of :func:`select`: same constraint/objective
    machinery over the decode-legal space priced per state update.
    Analytic-only — the decode wall clock is tracked by the benchmark
    record (BENCH_rnn_kernels.json), not re-measured here.
    """
    ex = explore_decode(cfg, target, spec)
    _check_selectable(ex, target)
    return ex.feasible[0]


# ---------------------------------------------------------------------------
# Degradation ladder (overload control's pre-warmed fallback schedules)
# ---------------------------------------------------------------------------


def degradation_ladder(cfg: ModelConfig, base: DesignPoint, *,
                       spec: Optional[SpaceSpec] = None,
                       fp=None,
                       max_rungs: int = 4,
                       min_gain: float = 1.5) -> Tuple[DesignPoint, ...]:
    """Pre-warmable fallback schedules for graceful degradation under
    overload — rung 0 is the resolved ``base`` point, every later rung
    buys at least ``min_gain``x more priced throughput than the rung
    before it.

    When a streaming pipeline's sustained queue depth crosses its
    high-water mark it steps DOWN this ladder (and back up on low water):
    each step raises the admission rate (``admission_rate_eps`` of the
    rung's estimate) the same way the paper trades ``reuse_factor`` —
    giving up latency/resource headroom for initiation-interval
    throughput, accuracy-neutral because every rung executes the same
    trained weights, just under a different schedule.

    Candidates come from the Pareto frontier of the float space, plus —
    when ``fp`` is a native-int config — the native-legal quantized slice
    (``space.native_int_legal``), priced WITH that fp, so an int8 rung can
    appear where float pricing has no headroom left.  The result is
    deterministic: throughput strictly ascends along the ladder, ties
    broken toward fewer resources, deduped by serving key.
    """
    if max_rungs < 1:
        raise ValueError(f"max_rungs must be >= 1: {max_rungs}")
    if min_gain <= 1.0:
        raise ValueError(f"min_gain must be > 1.0: {min_gain}")
    clock = base.clock_mhz
    candidates: List[DesignPoint] = list(explore(cfg, None, spec).frontier)
    if is_native_int(fp):
        qt = DesignTarget(fp=fp, objective="throughput", clock_mhz=clock)
        candidates.extend(explore(cfg, qt, spec).frontier)
    ladder: List[DesignPoint] = [base]
    seen = {base.key}
    # descending ii = ascending throughput: each accepted rung is the
    # SMALLEST gain >= min_gain, keeping later rungs available for later
    pool = sorted((p for p in candidates if p.key not in seen),
                  key=lambda p: (-p.ii_cycles, p.dsp, p.bram_18k, p.key))
    for p in pool:                       # ascending throughput order
        if len(ladder) >= max_rungs:
            break
        if p.key in seen:
            continue
        if p.throughput_eps(clock) >= min_gain * ladder[-1].throughput_eps(
                clock):
            ladder.append(p)
            seen.add(p.key)
    return tuple(ladder)


# ---------------------------------------------------------------------------
# Measured refinement (the bench harness's steady-state timing)
# ---------------------------------------------------------------------------


def measure_points(cfg: ModelConfig, points: Sequence[DesignPoint], *,
                   batch: int = 32, iters: int = 3,
                   seed: int = 0) -> Dict[str, float]:
    """Steady-state seconds/call of the scan kernel under each point's
    schedule (min over iters, first call compiles) — keyed by point.key.

    Measures the float kernel datapath (the quantizer wraps it uniformly,
    so fixed-point configs do not reorder schedules).
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.hls.resources import gate_count
    from repro.kernels import ops

    rnn = cfg.rnn
    assert rnn is not None
    g = gate_count(rnn.cell)
    rng = np.random.RandomState(seed)
    xs = jnp.asarray(rng.randn(batch, rnn.seq_len, rnn.input_size)
                     .astype(np.float32))
    W = jnp.asarray(rng.randn(rnn.input_size, g * rnn.hidden)
                    .astype(np.float32) * .3)
    U = jnp.asarray(rng.randn(rnn.hidden, g * rnn.hidden)
                    .astype(np.float32) * .3)
    bshape = (g * rnn.hidden,) if rnn.cell == "lstm" else (2, g * rnn.hidden)
    b = jnp.asarray(rng.randn(*bshape).astype(np.float32) * .1)
    op = ops.SCHEDULED_KERNELS["lstm" if rnn.cell == "lstm" else "gru"][0]

    walls: Dict[str, float] = {}
    for p in points:
        op(xs, W, U, b, schedule=p.schedule).block_until_ready()  # compile
        best = float("inf")
        for _ in range(iters):
            t0 = time.perf_counter()
            op(xs, W, U, b, schedule=p.schedule).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        walls[p.key] = best
    return walls


# ---------------------------------------------------------------------------
# Speculative exploration: price (draft, verify, K) triples analytically
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SpeculativePoint:
    """One priced (draft, verify, K) speculative triple."""

    draft: Optional[object]              # KernelSchedule | None (n-gram)
    verify: object                       # KernelSchedule
    k: int
    estimate: object                     # core.hls.SpeculativeEstimate

    @property
    def key(self) -> str:
        d = "ngram" if self.draft is None else self.draft.key()
        return f"spec(k={self.k}, draft={d}, verify={self.verify.key()})"

    def report_row(self, clock_mhz: float = 200.0) -> dict:
        return {"key": self.key, **self.estimate.report_row(clock_mhz)}


def _estimate_for(cfg: ModelConfig, schedule, fp):
    """Single-step estimate of one schedule on this config's decode path:
    the RNN step for recurrent families, the dense-stack LM step
    otherwise — the same split the serving engines execute."""
    from repro.core.hls.resources import (estimate_decode_step,
                                          estimate_lm_decode)
    if cfg.rnn is not None:
        return estimate_decode_step(schedule, cfg.rnn, fp)
    return estimate_lm_decode(schedule, cfg, fp)


def _spec_feasible(est, target: Optional[DesignTarget]) -> bool:
    """Target feasibility for a speculative estimate: resource caps apply
    to the SUM of both resident datapaths, the latency budget to the
    expected per-token latency of the round, the throughput floor to the
    expected tokens/s."""
    if target is None:
        return True
    c = target.clock_mhz
    if target.max_dsp is not None and est.dsp > target.max_dsp:
        return False
    if target.max_bram_18k is not None and est.bram_18k > target.max_bram_18k:
        return False
    if (target.max_latency_us is not None
            and est.latency_us_per_token(c) > target.max_latency_us):
        return False
    if (target.min_throughput_eps is not None
            and est.tokens_per_s(c) < target.min_throughput_eps):
        return False
    return True


def explore_speculative(cfg: ModelConfig,
                        target: Optional[DesignTarget] = None,
                        spec: Optional[SpaceSpec] = None, *,
                        ks: Sequence[int] = (1, 2, 4, 8),
                        accept_rate: float = 0.75,
                        include_ngram: bool = True
                        ) -> Tuple[SpeculativePoint, ...]:
    """Price every legal (draft, verify, K) triple and rank by expected
    tokens/cycle (ties toward fewer DSPs, then key — deterministic).

    ``accept_rate`` is the ASSUMED per-draft acceptance probability; the
    bench harness records the measured rate next to it, the same
    predicted-vs-measured discipline as every other estimator here.
    Target constraints prune on the summed-resource / per-token-latency
    axes (``_spec_feasible``)."""
    from repro.autotune.space import enumerate_speculative_space
    from repro.core.hls.resources import estimate_speculative

    triples = enumerate_speculative_space(cfg, spec, ks=tuple(ks),
                                          include_ngram=include_ngram)
    fp, _clock, _part = _pricing_axes(target)
    cache: Dict[str, object] = {}

    def est_of(schedule):
        key = schedule.key()
        if key not in cache:
            cache[key] = _estimate_for(cfg, schedule, fp)
        return cache[key]

    points = []
    for draft, verify, k in triples:
        est = estimate_speculative(
            None if draft is None else est_of(draft), est_of(verify), k,
            accept_rate)
        if _spec_feasible(est, target):
            points.append(SpeculativePoint(draft=draft, verify=verify, k=k,
                                           estimate=est))
    points.sort(key=lambda p: (-p.estimate.tokens_per_cycle,
                               p.estimate.dsp, p.key))
    return tuple(points)


def select_speculative(cfg: ModelConfig,
                       target: Optional[DesignTarget] = None,
                       spec: Optional[SpaceSpec] = None, *,
                       ks: Sequence[int] = (1, 2, 4, 8),
                       accept_rate: float = 0.75,
                       include_ngram: bool = True,
                       measure_fn=None,
                       measure_top_k: int = 3) -> SpeculativePoint:
    """Pick the speculative triple to serve: the analytically best point,
    optionally re-ranked by measurement — ``measure_fn(point) ->
    tokens/s`` runs the top-k predicted candidates through the real
    engine and the HIGHEST measured rate wins (ties toward fewer DSPs).
    Raises ValueError when the target prunes the space to nothing."""
    points = explore_speculative(cfg, target, spec, ks=ks,
                                 accept_rate=accept_rate,
                                 include_ngram=include_ngram)
    if not points:
        raise ValueError(
            "no speculative (draft, verify, K) triple is feasible: the "
            "target pruned every point — relax the resource/latency "
            "budget, widen the SpaceSpec, or allow the n-gram draft")
    if measure_fn is None or measure_top_k <= 0:
        return points[0]
    top = list(points[:measure_top_k])
    walls = {p.key: float(measure_fn(p)) for p in top}
    return max(top, key=lambda p: (walls[p.key], -p.estimate.dsp))
