"""Design targets — WHAT the user wants, stated in paper units.

The paper's tables are hand-enumerated sweeps over reuse factor and
static/non-static mode, read backwards by a designer holding a latency
budget ("the L1 trigger gives you ~1 µs") or a resource budget ("this
algorithm gets 30% of the SLR's DSPs").  :class:`DesignTarget` states that
budget directly; the explorer (``repro.autotune.explorer``) turns it into a
:class:`~repro.core.hls.DesignPoint` — i.e. into the ``KernelSchedule`` the
serving engine then executes.

Frozen/hashable so engines can memoize target -> schedule resolution and
use targets as queue-policy keys.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.config import FixedPointConfig

OBJECTIVES = ("latency", "resources", "throughput")


@dataclass(frozen=True)
class DesignTarget:
    """Constraints + objective for the design-space search.

    max_latency_us      end-to-end inference latency budget at ``clock_mhz``
                        (the trigger budget; None = unconstrained)
    min_throughput_eps  initiation-interval-derived events/s floor (the
                        coprocessor budget; None = unconstrained)
    max_dsp             parallel-multiplier (DSP) budget, kernel-level units
    max_bram_18k        weight-storage budget, 18 kb BRAM blocks
    fp                  fixed-point constraint: price AND serve with this
                        ap_fixed config (None = float datapath)
    part                when set, the table-calibrated design must fit this
                        FPGA part (``core.hls.FPGA_PARTS`` key)
    replicas            data-parallel replica count the throughput floor is
                        read against: K replicas of one design sustain K x
                        its priced events/s (``serving.replica`` /
                        ``serving.router`` is the layer that provides them),
                        so ``min_throughput_eps`` resolves to the design
                        whose throughput x replicas clears the floor
    clock_mhz           clock the latency/throughput constraints are read at
    objective           what to minimize among feasible points:
                        "latency"    latency_cycles, then DSP, then BRAM
                        "resources"  DSP, then BRAM, then latency
                        "throughput" II (max events/s), then latency, DSP
    """

    max_latency_us: Optional[float] = None
    min_throughput_eps: Optional[float] = None
    max_dsp: Optional[int] = None
    max_bram_18k: Optional[int] = None
    fp: Optional[FixedPointConfig] = None
    part: Optional[str] = None
    replicas: int = 1
    clock_mhz: float = 200.0
    objective: str = "latency"

    def __post_init__(self):
        if self.objective not in OBJECTIVES:
            raise ValueError(
                f"objective {self.objective!r} not in {OBJECTIVES}")
        if self.clock_mhz <= 0:
            raise ValueError(f"clock_mhz must be > 0: {self.clock_mhz}")
        if not isinstance(self.replicas, int) or self.replicas < 1:
            raise ValueError(f"replicas must be an int >= 1: "
                             f"{self.replicas!r}")
        for name in ("max_latency_us", "min_throughput_eps", "max_dsp",
                     "max_bram_18k"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be > 0 when set: {v}")

    def describe(self) -> str:
        """Human-readable constraint list for reports and error messages."""
        parts = []
        if self.max_latency_us is not None:
            parts.append(f"latency <= {self.max_latency_us:g}us"
                         f"@{self.clock_mhz:g}MHz")
        if self.min_throughput_eps is not None:
            rep = f" over {self.replicas} replicas" if self.replicas > 1 \
                else ""
            parts.append(f"throughput >= {self.min_throughput_eps:g}ev/s"
                         f"{rep}")
        if self.max_dsp is not None:
            parts.append(f"dsp <= {self.max_dsp}")
        if self.max_bram_18k is not None:
            parts.append(f"bram <= {self.max_bram_18k}")
        if self.fp is not None:
            parts.append(f"ap_fixed<{self.fp.total_bits},"
                         f"{self.fp.integer_bits}>")
        if self.part is not None:
            parts.append(f"fits {self.part}")
        cons = ", ".join(parts) if parts else "unconstrained"
        goal = ("maximize throughput" if self.objective == "throughput"
                else f"minimize {self.objective}")
        return f"[{cons}; {goal}]"
