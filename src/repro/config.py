"""Configuration dataclasses for models, shapes, training, serving, quantization.

Every assigned architecture is expressed as a ``ModelConfig``; every assigned
input shape as a ``ShapeConfig``.  Configs are frozen (hashable) so they can be
used as jit static arguments and dict keys.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.kernels.schedule import KernelSchedule

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (GShard-style routed experts)."""

    n_experts: int = 8
    top_k: int = 2
    n_shared_experts: int = 0          # always-on shared experts (DeepSeek/Qwen style)
    d_ff_expert: int = 0               # per-expert hidden dim (0 -> use d_ff)
    capacity_factor: float = 1.25      # train-time capacity (tokens dropped beyond)
    eval_capacity_factor: float = 2.0
    router_z_loss: float = 1e-3
    aux_loss_weight: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block configuration."""

    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256              # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma / Griffin RG-LRU configuration."""

    lru_width: int = 0                 # 0 -> d_model
    conv_width: int = 4
    window: int = 2048                 # local-attention window in hybrid blocks
    # repeating block pattern: 2 recurrent blocks then 1 local-attention block
    pattern: Tuple[str, ...] = ("rglru", "rglru", "local_attn")


@dataclass(frozen=True)
class RNNConfig:
    """Paper-core recurrent layer configuration (LSTM / GRU taggers)."""

    cell: str = "lstm"                  # "lstm" | "gru"
    hidden: int = 20
    seq_len: int = 20
    input_size: int = 6
    dense_sizes: Tuple[int, ...] = (64,)
    n_outputs: int = 1
    output_activation: str = "sigmoid"  # "sigmoid" | "softmax"
    mode: str = "static"                # "static" | "nonstatic"
    # hls4ml-style knobs
    reuse_kernel: int = 1
    reuse_recurrent: int = 1
    # explicit kernel schedule; None derives one from the knobs above
    schedule: Optional[KernelSchedule] = None

    def kernel_schedule(self) -> KernelSchedule:
        """The schedule this layer executes AND is costed with — models pick
        it from config, kernels run it, core.hls estimates from it."""
        if self.schedule is not None:
            return self.schedule
        return KernelSchedule(reuse_factor=self.reuse_kernel, mode=self.mode)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A single architecture. Families: dense | moe | ssm | hybrid | audio | vlm | rnn."""

    name: str = "unnamed"
    family: str = "dense"

    # transformer backbone
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                  # 0 -> d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1000
    mlp_type: str = "swiglu"           # swiglu | geglu | relu2 | gelu
    norm_type: str = "rmsnorm"         # rmsnorm | layernorm
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    logits_softcap: float = 0.0        # gemma-style soft capping (0 = off)
    attn_window: int = 0               # 0 = full attention; >0 = local window

    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    rnn: Optional[RNNConfig] = None

    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_encoder_layers: int = 0
    n_decoder_layers: int = 0
    max_encoder_len: int = 1500

    # modality frontend stub: none | audio | vision
    frontend: str = "none"
    n_frontend_tokens: int = 0         # vision: number of patch tokens prepended

    # numerics / execution
    param_dtype: str = "float32"       # dry-run big models use bfloat16
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True           # lax.scan over stacked layer weights
    remat: str = "full"                # full | dots | none
    attn_chunk_q: int = 1024           # blockwise-attention query chunk
    attn_chunk_kv: int = 2048          # blockwise-attention kv chunk
    impl: str = "xla"                  # xla | pallas (kernel hot paths)

    # distribution knobs (overridable per arch)
    grad_accum: int = 1                # microbatch steps inside train_step
    seq_shard_residual: bool = True    # Megatron-style sequence-parallel residual

    # cost-probe instrumentation: python-unroll inner lax.scan loops
    # (attention kv loop, SSD chunk loop, MoE chunk loop) so XLA's
    # cost_analysis — which counts while bodies once — sees every FLOP.
    probe_unroll: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- helpers -----------------------------------------------------------
    @property
    def qkv_dims(self) -> Tuple[int, int]:
        return self.n_heads * self.head_dim, self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def param_count(self) -> int:
        """Analytical parameter count (used for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.n_layers, self.vocab_size
        if self.family == "rnn":
            assert self.rnn is not None
            r = self.rnn
            g = 4 if r.cell == "lstm" else 3
            n = g * (r.input_size * r.hidden + r.hidden * r.hidden + r.hidden)
            if r.cell == "gru":
                n += 3 * r.hidden  # keras GRU reset_after: separate recurrent bias (2x 3h total)
            prev = r.hidden
            for h in r.dense_sizes:
                n += prev * h + h
                prev = h
            n += prev * r.n_outputs + r.n_outputs
            return n
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        q_dim, kv_dim = self.qkv_dims
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        if self.mlp_type in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "ssm":
            assert self.ssm is not None
            s = self.ssm
            d_in = s.expand * d
            n_heads = d_in // s.head_dim
            per_layer = (
                d * (2 * d_in + 2 * s.n_groups * s.d_state + n_heads)  # in_proj
                + s.d_conv * (d_in + 2 * s.n_groups * s.d_state)       # conv
                + n_heads * 2                                          # A_log, D
                + d_in * d                                             # out_proj
            )
            return emb // 2 + L * per_layer + 2 * d  # tied embedding, final norm
        if self.family == "moe":
            assert self.moe is not None
            m = self.moe
            dff = m.d_ff_expert or self.d_ff
            mlp = m.n_experts * 3 * d * dff + d * m.n_experts
            mlp += m.n_shared_experts * 3 * d * dff
        if self.family == "hybrid":
            assert self.rglru is not None
            rg = self.rglru
            w = rg.lru_width or d
            n_rec = sum(1 for p in self._pattern_for_layers() if p == "rglru")
            n_att = L - n_rec
            rec = 2 * d * w + rg.conv_width * w + 3 * w + w * d  # in/out proj + conv + gates
            att = attn
            return emb + n_rec * (rec + mlp + 2 * d) + n_att * (att + mlp + 2 * d) + d
        per_layer += attn + mlp + 2 * d
        if self.enc_dec:
            # encoder + decoder stacks; decoder layers add cross-attention
            L = self.n_encoder_layers + self.n_decoder_layers
            n = emb + L * per_layer + self.n_decoder_layers * (attn + d) + d
            return n
        n = emb + L * per_layer + d
        return n

    def _pattern_for_layers(self):
        assert self.rglru is not None
        pat = self.rglru.pattern
        return [pat[i % len(pat)] for i in range(self.n_layers)]

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        assert self.moe is not None
        m = self.moe
        d, L = self.d_model, self.n_layers
        dff = m.d_ff_expert or self.d_ff
        q_dim, kv_dim = self.qkv_dims
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d
        mlp_active = (m.top_k + m.n_shared_experts) * 3 * d * dff + d * m.n_experts
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + mlp_active + 2 * d) + d


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs whose state is sub-quadratic in context (run long_500k)
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, "full-attention arch: 524k dense KV decode out of scope (DESIGN.md §4)"
    return True, ""


# ---------------------------------------------------------------------------
# Training / serving / quantization configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    state_dtype: str = "float32"


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    grad_accum: int = 1
    loss_dtype: str = "float32"
    z_loss: float = 1e-4
    compress_grads: bool = False       # int8 error-feedback cross-pod compression
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq_len: int = 2048
    prefill_chunk: int = 512
    cache_dtype: str = "bfloat16"


@dataclass(frozen=True)
class FixedPointConfig:
    """ap_fixed<total, integer> — paper's quantization scheme."""

    total_bits: int = 16
    integer_bits: int = 6
    signed: bool = True
    rounding: str = "rnd"              # rnd (round-half-even) | trn (truncate)
    saturation: str = "sat"            # sat | wrap

    @property
    def fractional_bits(self) -> int:
        return self.total_bits - self.integer_bits

    @property
    def scale(self) -> float:
        return float(2 ** self.fractional_bits)

    @property
    def max_value(self) -> float:
        sign = 1 if self.signed else 0
        return (2 ** (self.total_bits - sign) - 1) / self.scale

    @property
    def min_value(self) -> float:
        return -(2 ** (self.total_bits - 1)) / self.scale if self.signed else 0.0


# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e target) — used by roofline analysis
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12    # per chip
    hbm_bw: float = 819e9              # bytes/s per chip
    ici_link_bw: float = 50e9          # bytes/s per link (one direction)
    ici_links: int = 4                 # 2D torus: 4 links/chip (single pod 16x16)
    hbm_bytes: int = 16 * 2 ** 30      # 16 GiB
    vmem_bytes: int = 128 * 2 ** 20    # ~128 MiB VMEM


TPU_V5E = HardwareConfig()
