"""Unified model facade: one object per architecture with
param_specs / init / loss / forward, dispatched by config family."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import rnn_tagger, transformer
from repro.models.init import (
    ParamSpecs,
    abstract_params,
    init_params,
    param_bytes,
)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # -- parameters ---------------------------------------------------------
    def param_specs(self) -> ParamSpecs:
        if self.cfg.family == "rnn":
            return rnn_tagger.param_specs(self.cfg)
        return transformer.param_specs(self.cfg)

    def init(self, rng: jax.Array) -> Dict:
        return init_params(rng, self.param_specs())

    def abstract_params(self, ctx=None) -> Dict:
        return abstract_params(self.param_specs(), ctx)

    def param_bytes(self) -> int:
        return param_bytes(self.param_specs())

    # -- training loss ------------------------------------------------------
    def loss(self, params: Dict, batch: Dict) -> Tuple[jax.Array, Dict]:
        cfg = self.cfg
        if cfg.family == "rnn":
            return rnn_tagger.loss_fn(cfg, params, batch["x"], batch["y"])
        hidden, aux = transformer.forward(
            cfg, params, batch["tokens"], train=True,
            img_embeds=batch.get("img_embeds"),
            frame_embeds=batch.get("frame_embeds"))
        loss, metrics = transformer.lm_loss(cfg, params, hidden,
                                            batch["labels"])
        if "moe_load_balance" in aux:
            m = cfg.moe
            loss = loss + m.aux_loss_weight * aux["moe_load_balance"] \
                        + m.router_z_loss * aux["moe_z_loss"]
            metrics.update({k: v for k, v in aux.items()})
        return loss, metrics

    # -- inference ----------------------------------------------------------
    def forward(self, params: Dict, batch: Dict) -> jax.Array:
        cfg = self.cfg
        if cfg.family == "rnn":
            return rnn_tagger.forward(cfg, params, batch["x"])
        hidden, _ = transformer.forward(
            cfg, params, batch["tokens"], train=False,
            img_embeds=batch.get("img_embeds"),
            frame_embeds=batch.get("frame_embeds"))
        return transformer.logits_fn(cfg, params, hidden)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
