"""Mixture-of-Experts block: token-choice top-k routing with per-expert
capacity, expert-parallel over the 'model' mesh axis.

Dispatch strategy (GSPMD-friendly, no manual all-to-all):
  activations are kept replicated across the 'model' axis; each expert shard
  gathers the top-C tokens routed to its local experts, runs the expert FFN
  [E_local, C, d], and scatter-adds weighted results back, which XLA lowers
  to a psum across the expert axis.  Capacity selection is a per-expert
  ``top_k`` over token scores (static shapes — dropped tokens beyond C fall
  back to the residual path, exactly GShard semantics).

Experts that do not divide the model axis are padded with phantom experts
(router logits -inf -> zero combine weight; ~E_pad/E extra expert FLOPs,
recorded in DESIGN.md).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.init import ParamSpec
from repro.sharding.api import constrain, current_context
from repro.kernels.compat import shard_map


def padded_n_experts(cfg: ModelConfig) -> int:
    assert cfg.moe is not None
    e = cfg.moe.n_experts
    ctx = current_context()
    tp = 1
    if ctx is not None:
        tp = ctx.mesh.shape.get("model", 1)
    return -(-e // tp) * tp


def moe_specs(cfg: ModelConfig, prefix: str, stacked=None, n_experts_padded=None) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    d = cfg.d_model
    f = m.d_ff_expert or cfg.d_ff
    e = n_experts_padded or m.n_experts
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    dt = cfg.param_dtype
    specs = {
        f"{prefix}/router": ParamSpec(lead + (d, e), lax_ + ("embed_nofsdp", "experts"),
                                      "lecun", dt),
        f"{prefix}/we_gate": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", None),
                                       "lecun", dt),
        f"{prefix}/we_up": ParamSpec(lead + (e, d, f), lax_ + ("experts", "embed", None),
                                     "lecun", dt),
        f"{prefix}/we_down": ParamSpec(lead + (e, f, d), lax_ + ("experts", None, "embed"),
                                       "lecun", dt),
    }
    if m.n_shared_experts:
        fs = m.n_shared_experts * f
        specs.update({
            f"{prefix}/ws_gate": ParamSpec(lead + (d, fs), lax_ + ("embed", "ffn"), "lecun", dt),
            f"{prefix}/ws_up": ParamSpec(lead + (d, fs), lax_ + ("embed", "ffn"), "lecun", dt),
            f"{prefix}/ws_down": ParamSpec(lead + (fs, d), lax_ + ("ffn", "embed"), "lecun", dt),
            f"{prefix}/shared_gate": ParamSpec(lead + (d, 1), lax_ + ("embed_nofsdp", None),
                                               "lecun", dt),
        })
    return specs


_CHUNK_TOKENS = 8192   # per-device token budget for dispatch buffers


def _shard_map_combine(ctx, ye, sel_idx, t, d):
    """Scatter expert outputs locally per expert shard, then psum tokens.

    RETIRED (§Perf MOE-3): measured 2x MORE wire than the plain scatter-add
    under GSPMD on qwen3 train_4k — kept for the record; not called."""
    import jax
    from jax.sharding import PartitionSpec as P

    def combine(ye_l, idx_l):
        # ye_l: [e_local, C, d]; idx_l: [e_local, C]
        out_l = jnp.zeros((t, d), ye_l.dtype).at[idx_l.reshape(-1)].add(
            ye_l.reshape(-1, d))
        return jax.lax.psum(out_l, "model")

    other = tuple(a for a in ctx.mesh.axis_names if a != "model")
    fn = shard_map(
        combine, mesh=ctx.mesh,
        in_specs=(P("model", None, None), P("model", None)),
        out_specs=P(), check_vma=False)
    return fn(ye, sel_idx)


def moe_block(
    cfg: ModelConfig, x: jax.Array, p: dict, prefix: str, *, train: bool
) -> Tuple[jax.Array, dict]:
    """x: [b, s, d] -> (out [b, s, d], aux losses dict).

    Long sequences are processed in sequential SEQ chunks (lax.scan) so the
    [E, C, d] dispatch buffers stay bounded regardless of sequence length —
    capacity C scales with the chunk (GShard-style local capacity).  Chunking
    along seq keeps the batch dim's 'data' sharding intact."""
    assert cfg.moe is not None
    b, s, d = x.shape
    ctx = current_context()
    dp = 1
    if ctx is not None:
        for a in ctx.data_axes:
            dp *= ctx.mesh.shape.get(a, 1)
    per_dev = (b * s) // max(dp, 1)
    n_chunks = 1
    while (per_dev // n_chunks > _CHUNK_TOKENS and s % (n_chunks * 2) == 0
           and s // (n_chunks * 2) >= 1):
        n_chunks *= 2
    if n_chunks > 1:
        sc = s // n_chunks
        xc = jnp.moveaxis(x.reshape(b, n_chunks, sc, d), 1, 0)

        def chunk_fn(carry, xci):
            out_i, aux_i = _moe_tokens(cfg, xci, p, prefix, train=train)
            return carry, (out_i, aux_i)

        if cfg.probe_unroll:  # cost-probe mode: no hidden while-loop work
            outs, auxs = [], []
            for c in range(n_chunks):
                _, (o_c, a_c) = chunk_fn(0, xc[c])
                outs.append(o_c)
                auxs.append(a_c)
            outs = jnp.stack(outs)
            auxs = {k: jnp.stack([a[k] for a in auxs]) for k in auxs[0]}
        else:
            _, (outs, auxs) = jax.lax.scan(chunk_fn, 0, xc)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, d)
        aux = {k: jnp.mean(v) for k, v in auxs.items()}
        return out, aux
    return _moe_tokens(cfg, x, p, prefix, train=train)


def _moe_tokens(
    cfg: ModelConfig, x: jax.Array, p: dict, prefix: str, *, train: bool
) -> Tuple[jax.Array, dict]:
    """x: [b, s, d] chunk -> (out [b, s, d], aux)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    w_router = p[f"{prefix}/router"]
    e_pad = w_router.shape[-1]
    e_real = m.n_experts

    logits = jnp.einsum("td,de->te", xf, w_router.astype(xf.dtype)).astype(jnp.float32)
    if e_pad > e_real:
        phantom = jnp.arange(e_pad) >= e_real
        logits = jnp.where(phantom[None, :], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)                     # [t, e]

    top_p, top_i = jax.lax.top_k(probs, m.top_k)                # [t, k]
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # per-(token, expert) combine weight (0 if not routed)
    onehot = jax.nn.one_hot(top_i, e_pad, dtype=jnp.float32)    # [t, k, e]
    combine_te = jnp.einsum("tk,tke->te", top_p, onehot)        # [t, e]

    # capacity: top-C tokens per expert by combine weight
    cf = m.capacity_factor if train else m.eval_capacity_factor
    cap = max(int(t * m.top_k * cf / e_real), 4)
    cap = min(cap, t)
    scores_et = combine_te.T                                    # [e, t]
    sel_w, sel_idx = jax.lax.top_k(scores_et, cap)              # [e, C]
    sel_w = jnp.where(sel_w > 0, sel_w, 0.0)                    # drop non-routed

    xe = jnp.take(xf, sel_idx.reshape(-1), axis=0)              # [e*C, d]
    xe = xe.reshape(e_pad, cap, d)
    xe = constrain(xe, "experts", "expert_cap", None)

    wg = p[f"{prefix}/we_gate"].astype(xe.dtype)
    wu = p[f"{prefix}/we_up"].astype(xe.dtype)
    wd = p[f"{prefix}/we_down"].astype(xe.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, wg)) * jnp.einsum(
        "ecd,edf->ecf", xe, wu)
    h = constrain(h, "experts", "expert_cap", None)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)                      # [e, C, d]
    ye = ye * sel_w[..., None].astype(ye.dtype)

    # combine: scatter-add back to tokens (psum across expert shards).
    # GSPMD all-reduces the [E*C, d] dispatch buffer here (~5x the minimal
    # [t, d] wire) — §Perf MOE-3 tried an explicit shard_map local-scatter +
    # psum and MEASURED WORSE (2.2 -> 4.1 TiB: the replicated-out psum and
    # its backward gathers dominate); the scatter formulation stands.
    out = jnp.zeros((t, d), ye.dtype).at[sel_idx.reshape(-1)].add(
        ye.reshape(-1, d))
    out = constrain(out, "batch", None)

    # shared experts (always-on) + learned gate (qwen2-moe style)
    if m.n_shared_experts:
        from repro.models.mlp import mlp
        xs = x
        g = jax.nn.silu(jnp.einsum("bsd,df->bsf", xs, p[f"{prefix}/ws_gate"].astype(xs.dtype)))
        u = jnp.einsum("bsd,df->bsf", xs, p[f"{prefix}/ws_up"].astype(xs.dtype))
        hs = constrain(g * u, "batch", "seq_nosp", "ffn")
        ys = jnp.einsum("bsf,fd->bsd", hs, p[f"{prefix}/ws_down"].astype(xs.dtype))
        gate = jax.nn.sigmoid(
            jnp.einsum("bsd,do->bso", xs, p[f"{prefix}/shared_gate"].astype(xs.dtype)))
        out = out + (gate * ys).reshape(t, d)

    # aux losses: load balance (Switch) + router z-loss
    me = jnp.mean(combine_te, axis=0) * e_real                  # frac prob mass
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_i, e_pad, dtype=jnp.float32), 1), axis=0) * e_real / m.top_k
    aux = {
        "moe_load_balance": jnp.sum(me[:e_real] * ce[:e_real]) / e_real,
        "moe_z_loss": jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
    }
    return out.reshape(b, s, d).astype(x.dtype), aux
