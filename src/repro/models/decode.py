"""Single-token decode steps for every family — the paper's "static mode"
state update at LLM scale: state (KV cache / SSM state / LRU state) is
resident, one block processes each new element, II = 1 step.

Cache layout is spec-driven (same machinery as params) so dry-run lowering
gets correctly sharded ShapeDtypeStructs: KV caches shard their sequence dim
over 'model' (flash-decode: the softmax max/sum reductions partition across
the TP axis), batch over the data axes.

Schedule-driven decode: ``decode_step(..., schedule=)`` routes the per-token
matmuls of the dense-decoder stack (q|k|v, output projection, MLP) through
the reuse-tiled, weight-resident kernels of ``repro.kernels.decode_step`` —
the request's :class:`~repro.kernels.schedule.KernelSchedule` changes what
the hot path EXECUTES: projections are gate-fused ([B, d] @ [d, G*h],
packed ONCE per (params, schedule key) via the weight-residency cache), the
layer loop is unrolled over the pre-sliced resident weights instead of
dynamic-slicing a stacked scan carry, and Pallas backends run the R
column-tile passes in-block.  ``schedule=None`` is the unchanged einsum
golden path, and the scheduled path is bit-identical to it (column tiling
never splits a K reduction) — enforced by tests/test_decode_schedule.py.
Families whose step is not matmul-shaped (ssm / hybrid / enc-dec / moe)
accept the argument and keep the einsum path.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels.decode_step import decode_matmul
from repro.kernels.ops import resident
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.models import transformer as tf
from repro.models.attention import decode_attention, decode_attention_masked
from repro.models.init import ParamSpec, ParamSpecs
from repro.models.layers import ACTIVATIONS, apply_rope, embed, norm
from repro.models.moe import moe_block
from repro.models.mlp import mlp
from repro.models.rglru import rglru_decode_step
from repro.models.ssm import ssm_decode_step, ssm_dims
from repro.sharding.api import constrain


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                cache_dtype: str = "bfloat16") -> ParamSpecs:
    L, hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads_r", "head_dim")
    specs: ParamSpecs = {}
    if cfg.family == "ssm":
        d_in, h, conv_dim = ssm_dims(cfg)
        s = cfg.ssm
        specs["cache/state"] = ParamSpec(
            (L, batch, h, s.head_dim, s.d_state),
            ("layers", "batch", "ssm_heads", None, None), "zeros", "float32")
        specs["cache/conv"] = ParamSpec(
            (L, batch, s.d_conv - 1, conv_dim),
            ("layers", "batch", None, "ssm_inner"), "zeros", cache_dtype)
        return specs
    if cfg.family == "hybrid":
        rg = cfg.rglru
        w = rg.lru_width or cfg.d_model
        n_super, rem = divmod(cfg.n_layers, len(rg.pattern))
        for grp, n in (("hyb", n_super),) + tuple(
                (f"hybrem{j}", 1) for j in range(rem)):
            pats = list(enumerate(rg.pattern)) if grp == "hyb" else [
                (int(grp[6:]), rg.pattern[int(grp[6:])])]
            for j, kind in pats:
                pre = f"cache/{grp}{j}" if grp == "hyb" else f"cache/{grp}"
                lead = (n, batch) if grp == "hyb" else (batch,)
                la = ("layers", "batch") if grp == "hyb" else ("batch",)
                if kind == "rglru":
                    specs[f"{pre}_state"] = ParamSpec(
                        lead + (w,), la + ("lru_width",), "zeros", "float32")
                    specs[f"{pre}_conv"] = ParamSpec(
                        lead + (rg.conv_width - 1, w), la + (None, "lru_width"),
                        "zeros", cache_dtype)
                else:
                    W = min(rg.window, max_len)
                    specs[f"{pre}_k"] = ParamSpec(
                        lead + (W, hk, hd), la + ("kv_seq", "kv_heads_r", "head_dim"),
                        "zeros", cache_dtype)
                    specs[f"{pre}_v"] = ParamSpec(
                        lead + (W, hk, hd), la + ("kv_seq", "kv_heads_r", "head_dim"),
                        "zeros", cache_dtype)
                    specs[f"{pre}_pos"] = ParamSpec(
                        lead + (W,), la + ("kv_seq",), "zeros", "int32")
        return specs
    if cfg.enc_dec:
        Ld = cfg.n_decoder_layers
        specs["cache/k"] = ParamSpec((Ld, batch, max_len, hk, hd), kv_axes,
                                     "zeros", cache_dtype)
        specs["cache/v"] = ParamSpec((Ld, batch, max_len, hk, hd), kv_axes,
                                     "zeros", cache_dtype)
        specs["cache/xk"] = ParamSpec((Ld, batch, max_len, hk, hd), kv_axes,
                                      "zeros", cache_dtype)
        specs["cache/xv"] = ParamSpec((Ld, batch, max_len, hk, hd), kv_axes,
                                      "zeros", cache_dtype)
        return specs
    # dense / moe / vlm
    specs["cache/k"] = ParamSpec((L, batch, max_len, hk, hd), kv_axes,
                                 "zeros", cache_dtype)
    specs["cache/v"] = ParamSpec((L, batch, max_len, hk, hd), kv_axes,
                                 "zeros", cache_dtype)
    return specs


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------


def _update_cache(cache_l: jax.Array, new: jax.Array, pos: jax.Array):
    """cache_l: [b, S, hk, hd]; new: [b, 1, hk, hd]; pos: [b].

    One-hot masked write instead of per-batch dynamic_update_slice: under
    GSPMD the select keeps the cache's (batch, seq) sharding intact, where a
    scatter would trigger 'involuntary full rematerialization' (replicating
    the whole cache — the 19GiB decode peaks in the baseline dry-run)."""
    S = cache_l.shape[1]
    sel = (jnp.arange(S)[None, :] == pos[:, None])         # [b, S]
    return jnp.where(sel[..., None, None], new.astype(cache_l.dtype), cache_l)


def _ring_write(cache_l, new, slot):
    """cache_l: [b, W, hk, hd]; new: [b, 1, hk, hd]; slot: [b].
    One-hot masked write (sharding-preserving, see _update_cache)."""
    W = cache_l.shape[1]
    sel = (jnp.arange(W)[None, :] == slot[:, None])
    return jnp.where(sel[..., None, None], new.astype(cache_l.dtype), cache_l)


def _ring_write_pos(pos_l, slot, pos):
    """pos_l: [b, W] stores (absolute position + 1); 0 = empty slot."""
    sel = (jnp.arange(pos_l.shape[1])[None, :] == slot[:, None])
    return jnp.where(sel, pos[:, None] + 1, pos_l)


def _qkv(cfg, x, p, pre, pos, rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{pre}/wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p[f"{pre}/wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p[f"{pre}/wv"].astype(x.dtype))
    if rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    return q, k, v


def _attn_decode(cfg, x, p, pre, ck, cv, pos, window=0, rope=True):
    """x: [b,1,d] pre-normed. Returns (out [b,1,d], new_ck, new_cv)."""
    q, k, v = _qkv(cfg, x, p, pre, pos, rope)
    ck = _update_cache(ck, k.astype(ck.dtype), pos)
    cv = _update_cache(cv, v.astype(cv.dtype), pos)
    ck = constrain(ck, "batch", "kv_seq", "kv_heads_r", "head_dim")
    cv = constrain(cv, "batch", "kv_seq", "kv_heads_r", "head_dim")
    o = decode_attention(q, ck.astype(x.dtype), cv.astype(x.dtype),
                         pos + 1, window=window)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype),
                     p[f"{pre}/wo"].astype(x.dtype))
    return out, ck, cv


# ---------------------------------------------------------------------------
# Schedule-driven decode: fused, weight-resident dense-decoder step
# ---------------------------------------------------------------------------


def decode_schedulable(cfg: ModelConfig) -> bool:
    """Families whose per-token hot path is matmul-shaped and therefore
    runs the scheduled kernel path: the dense decoder stack (dense / vlm).
    MoE routing, SSM scans, the hybrid block pattern, and enc-dec cross
    attention keep the einsum path (a schedule is accepted but ignored)."""
    return cfg.family in ("dense", "vlm") and not cfg.enc_dec


def pack_decode_params(cfg: ModelConfig, params: Dict,
                       schedule: Optional[KernelSchedule]) -> Dict:
    """The weight-resident decode layout, packed ONCE per (params identity,
    schedule key) through the kernels' residency cache.

    Per decoder layer: q|k|v gate-fused into ``__wqkv`` [d, (hq+2*hk)*hd]
    (the LSTM-style gate packing of the paper, at LM scale), the MLP in/up
    projections fused into ``__wgu`` (or ``__wup``), the output/down
    projections flattened 2D, everything cast to the compute dtype, and the
    remaining per-layer params (norm scales/biases) pre-sliced out of their
    stacked [L, ...] arrays — so the per-token program re-derives none of
    it.  Tracer params pack in-trace (bit-identical, just not cached)."""
    stacked = tf.slice_layer(params, "decoder/")
    srcs = tuple(stacked[k] for k in sorted(stacked))
    cdt = jnp.dtype(cfg.compute_dtype)
    glu = cfg.mlp_type in ("swiglu", "geglu")

    def pack() -> Dict:
        layers: List[Dict] = []
        d = cfg.d_model
        for l in range(cfg.n_layers):
            p_l = {k: v[l] for k, v in stacked.items()}
            entry = {k: v for k, v in p_l.items()
                     if "/attn/w" not in k and "/mlp/w" not in k}
            entry["__wqkv"] = jnp.concatenate(
                [p_l[f"decoder/attn/{n}"].reshape(d, -1).astype(cdt)
                 for n in ("wq", "wk", "wv")], axis=-1)
            entry["__wo"] = p_l["decoder/attn/wo"].reshape(-1, d).astype(cdt)
            if glu:
                entry["__wgu"] = jnp.concatenate(
                    [p_l["decoder/mlp/w_gate"].astype(cdt),
                     p_l["decoder/mlp/w_up"].astype(cdt)], axis=-1)
            else:
                entry["__wup"] = p_l["decoder/mlp/w_up"].astype(cdt)
            entry["__wdown"] = p_l["decoder/mlp/w_down"].astype(cdt)
            layers.append(entry)
        return {"layers": layers}

    return resident(srcs, f"lm-decode/{schedule_key(schedule)}", pack)


def _dense_steps(cfg: ModelConfig, params: Dict, packed: Dict,
                 cache: Dict, x: jax.Array, pos: jax.Array,
                 schedule: Optional[KernelSchedule]
                 ) -> Tuple[jax.Array, Dict]:
    """The fused dense-decoder pass under ``schedule`` for a CHUNK of
    ``S = x.shape[1]`` tokens per row: same math as the einsum branch of
    :func:`decode_step` (bit-identical — every fused / tiled matmul keeps
    each output column's full-K reduction), executed as scheduled
    ``decode_matmul`` calls over the resident packed weights.

    S = 1 is exactly the PR 5 single step.  For S > 1 (the speculative
    verify pass) the chunk matmuls run once over ``[B*S, d]`` — matmul
    rows are independent, so each row's result equals the sequential
    step's — and the attention of position ``pos+i`` masks every cache
    entry at index >= ``pos+i+1`` with NEG_INF before the softmax, so
    entries written by LATER chunk positions (or stale entries from a
    rejected draft) contribute exactly zero: the batched pass matches the
    sequential chain token by token, caches included.
    """
    B, S = x.shape[0], x.shape[1]
    d, hq, hk, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    glu = cfg.mlp_type in ("swiglu", "geglu")
    positions = pos[:, None] + jnp.arange(S, dtype=pos.dtype)     # [B, S]

    def mm(a, w):
        return decode_matmul(a, w, schedule=schedule)

    ck_all, cv_all = cache["cache/k"], cache["cache/v"]
    cks, cvs = [], []
    h = x
    for l, p_l in enumerate(packed["layers"]):
        hn = norm(cfg, h, p_l, "decoder/norm1")
        z = mm(hn.reshape(B * S, d), p_l["__wqkv"])
        q = z[:, :hq * hd].reshape(B, S, hq, hd)
        k = z[:, hq * hd:(hq + hk) * hd].reshape(B, S, hk, hd)
        v = z[:, (hq + hk) * hd:].reshape(B, S, hk, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        ck, cv = ck_all[l], cv_all[l]
        for i in range(S):
            ck = _update_cache(ck, k[:, i:i + 1].astype(ck_all.dtype),
                               pos + i if i else pos)
            cv = _update_cache(cv, v[:, i:i + 1].astype(cv_all.dtype),
                               pos + i if i else pos)
        ck = constrain(ck, "batch", "kv_seq", "kv_heads_r", "head_dim")
        cv = constrain(cv, "batch", "kv_seq", "kv_heads_r", "head_dim")
        outs = [decode_attention(q[:, i:i + 1], ck.astype(h.dtype),
                                 cv.astype(h.dtype), pos + i + 1,
                                 window=cfg.attn_window)
                for i in range(S)]
        o = outs[0] if S == 1 else jnp.concatenate(outs, axis=1)
        h = h + mm(o.astype(h.dtype).reshape(B * S, hq * hd),
                   p_l["__wo"]).reshape(B, S, d)
        h2 = norm(cfg, h, p_l, "decoder/norm2")
        if glu:
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
            zgu = mm(h2.reshape(B * S, d), p_l["__wgu"])
            f = zgu.shape[-1] // 2
            mid = act(zgu[:, :f]) * zgu[:, f:]
        else:
            act = ACTIVATIONS["relu2" if cfg.mlp_type == "relu2" else "gelu"]
            mid = act(mm(h2.reshape(B * S, d), p_l["__wup"]))
        mid = constrain(mid.reshape(B, S, -1), "batch", "seq_nosp",
                        "ffn").reshape(B * S, -1)
        h = h + mm(mid, p_l["__wdown"]).reshape(B, S, d)
        cks.append(ck)
        cvs.append(cv)
    new_cache = dict(cache)
    new_cache["cache/k"] = jnp.stack(cks)
    new_cache["cache/v"] = jnp.stack(cvs)
    h = norm(cfg, h, params, "final_norm")
    return tf.logits_fn(cfg, params, h), new_cache


# ---------------------------------------------------------------------------
# Decode step (per family)
# ---------------------------------------------------------------------------


def decode_step(cfg: ModelConfig, params: Dict, cache: Dict,
                tokens: jax.Array, pos: jax.Array, *,
                schedule: Optional[KernelSchedule] = None,
                packed: Optional[Dict] = None
                ) -> Tuple[jax.Array, Dict]:
    """tokens: [b, 1] int32; pos: [b] current positions. Returns
    (logits [b, 1, V], new cache).

    ``schedule`` routes the dense-stack matmuls through the reuse-tiled,
    weight-resident decode kernels (see module docstring); ``packed`` is
    the pre-packed layout from :func:`pack_decode_params` (derived — and
    cached — from ``params`` when omitted).  ``schedule=None`` is the
    unchanged einsum path, bit-identical to earlier revisions."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = embed(tokens, params["embed/table"], cdt)
    if cfg.family in ("dense", "vlm", "hybrid") or cfg.enc_dec:
        x = x * math.sqrt(cfg.d_model)
    if schedule is not None and decode_schedulable(cfg):
        if packed is None:
            packed = pack_decode_params(cfg, params, schedule)
        return _dense_steps(cfg, params, packed, cache, x, pos, schedule)
    if cfg.enc_dec:
        # whisper decoder: sinusoidal position at each sequence's pos
        d = cfg.d_model
        dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, dim / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]
        x = x + pe[:, None, :].astype(x.dtype)
    new_cache = dict(cache)

    if cfg.family == "ssm":
        stacked = tf.slice_layer(params, "decoder/")

        def body(h, xs):
            p_l, st, cv = xs
            hn = norm(cfg, h, p_l, "decoder/norm1")
            out, (st2, cv2) = ssm_decode_step(cfg, hn, p_l, "decoder/ssm",
                                              st, cv)
            return h + out, (st2, cv2)

        x, (st, cv) = jax.lax.scan(
            body, x, (stacked, cache["cache/state"], cache["cache/conv"]))
        new_cache["cache/state"], new_cache["cache/conv"] = st, cv

    elif cfg.family == "hybrid":
        rg = cfg.rglru
        n_super, rem = divmod(cfg.n_layers, len(rg.pattern))
        stacked = {k: v for k, v in params.items()
                   if k.startswith("hyb") and not k.startswith("hybrem")}
        cache_keys = sorted(k for k in cache if k.startswith("cache/hyb")
                            and "rem" not in k)

        def body(h, xs):
            p_l = xs[0]
            c_l = dict(zip(cache_keys, xs[1]))
            new_c = []
            for j, kind in enumerate(rg.pattern):
                pre = f"hyb{j}"
                hn = norm(cfg, h, p_l, f"{pre}/norm1")
                if kind == "rglru":
                    out, (st2, cv2) = rglru_decode_step(
                        cfg, hn, p_l, f"{pre}/mix",
                        c_l[f"cache/{pre}_state"], c_l[f"cache/{pre}_conv"])
                    c_l[f"cache/{pre}_state"] = st2
                    c_l[f"cache/{pre}_conv"] = cv2
                else:
                    out, ck, cv_, cp = _local_attn_decode(
                        cfg, hn, p_l, f"{pre}/attn",
                        c_l[f"cache/{pre}_k"], c_l[f"cache/{pre}_v"],
                        c_l[f"cache/{pre}_pos"], pos, rg.window)
                    c_l[f"cache/{pre}_k"] = ck
                    c_l[f"cache/{pre}_v"] = cv_
                    c_l[f"cache/{pre}_pos"] = cp
                h = h + out
                h2 = norm(cfg, h, p_l, f"{pre}/norm2")
                h = h + mlp(cfg, h2, p_l, f"{pre}/mlp")
            return h, tuple(c_l[k] for k in cache_keys)

        x, new_vals = jax.lax.scan(
            body, x, (stacked, tuple(cache[k] for k in cache_keys)))
        for k, v in zip(cache_keys, new_vals):
            new_cache[k] = v
        for j in range(rem):
            pre = f"hybrem{j}"
            p_r = tf.slice_layer(params, f"{pre}/")
            hn = norm(cfg, x, p_r, f"{pre}/norm1")
            kind = rg.pattern[j]
            if kind == "rglru":
                out, (st2, cv2) = rglru_decode_step(
                    cfg, hn, p_r, f"{pre}/mix",
                    cache[f"cache/{pre}_state"], cache[f"cache/{pre}_conv"])
                new_cache[f"cache/{pre}_state"] = st2
                new_cache[f"cache/{pre}_conv"] = cv2
            else:
                out, ck, cv_, cp = _local_attn_decode(
                    cfg, hn, p_r, f"{pre}/attn", cache[f"cache/{pre}_k"],
                    cache[f"cache/{pre}_v"], cache[f"cache/{pre}_pos"],
                    pos, rg.window)
                new_cache[f"cache/{pre}_k"] = ck
                new_cache[f"cache/{pre}_v"] = cv_
                new_cache[f"cache/{pre}_pos"] = cp
            x = x + out
            h2 = norm(cfg, x, p_r, f"{pre}/norm2")
            x = x + mlp(cfg, h2, p_r, f"{pre}/mlp")

    elif cfg.enc_dec:
        stacked = tf.slice_layer(params, "xdecoder/")

        def body(h, xs):
            p_l, ck, cv, xk, xv = xs
            hn = norm(cfg, h, p_l, "xdecoder/norm1")
            out, ck, cv = _attn_decode(cfg, hn, p_l, "xdecoder/attn",
                                       ck, cv, pos, rope=False)
            h = h + out
            hx = norm(cfg, h, p_l, "xdecoder/norm_x")
            qx = jnp.einsum("bsd,dhk->bshk", hx,
                            p_l["xdecoder/xattn/wq"].astype(hx.dtype))
            enc_len = jnp.full((h.shape[0],), xk.shape[1], jnp.int32)
            ox = decode_attention(qx, xk.astype(hx.dtype),
                                  xv.astype(hx.dtype), enc_len)
            h = h + jnp.einsum("bshk,hkd->bsd", ox.astype(hx.dtype),
                               p_l["xdecoder/xattn/wo"].astype(hx.dtype))
            h2 = norm(cfg, h, p_l, "xdecoder/norm2")
            h = h + mlp(cfg, h2, p_l, "xdecoder/mlp")
            return h, (ck, cv)

        x, (ck, cv) = jax.lax.scan(
            body, x, (stacked, cache["cache/k"], cache["cache/v"],
                      cache["cache/xk"], cache["cache/xv"]))
        new_cache["cache/k"], new_cache["cache/v"] = ck, cv

    else:  # dense / moe / vlm
        stacked = tf.slice_layer(params, "decoder/")

        # §Perf D3: the cache lives in the scan CARRY and is updated in
        # place with dynamic_update_index_in_dim — passing it as xs/ys
        # double-buffers the full stacked cache (the 2x decode peaks in
        # the v2 dry-run: stablelm 18.6GiB, phi3 22.5GiB).
        def body(carry, p_l):
            h, ck_all, cv_all, l = carry
            ck = jax.lax.dynamic_index_in_dim(ck_all, l, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, l, 0, keepdims=False)
            hn = norm(cfg, h, p_l, "decoder/norm1")
            out, ck, cv = _attn_decode(cfg, hn, p_l, "decoder/attn", ck, cv,
                                       pos, window=cfg.attn_window)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, l, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, l, 0)
            h = h + out
            h2 = norm(cfg, h, p_l, "decoder/norm2")
            if cfg.family == "moe":
                out2, _ = moe_block(cfg, h2, p_l, "decoder/moe", train=False)
            else:
                out2 = mlp(cfg, h2, p_l, "decoder/mlp")
            return (h + out2, ck_all, cv_all, l + 1), ()

        (x, ck, cv, _), _ = jax.lax.scan(
            body, (x, cache["cache/k"], cache["cache/v"], jnp.int32(0)),
            stacked)
        new_cache["cache/k"], new_cache["cache/v"] = ck, cv

    x = norm(cfg, x, params, "final_norm")
    logits = tf.logits_fn(cfg, params, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Multi-token verify + KV rollback (the speculative-decode seam)
# ---------------------------------------------------------------------------


def decode_steps(cfg: ModelConfig, params: Dict, cache: Dict,
                 tokens: jax.Array, pos: jax.Array, *,
                 schedule: Optional[KernelSchedule] = None,
                 packed: Optional[Dict] = None
                 ) -> Tuple[jax.Array, Dict]:
    """Multi-token decode: process ``S = tokens.shape[1]`` consecutive
    positions per row in ONE pass.  tokens: [b, S] int32; pos: [b] position
    of each row's FIRST token.  Returns (logits [b, S, V], new cache) —
    ``logits[:, i]`` is what :func:`decode_step` would have produced for
    token i with the cache advanced through tokens ``< i``.

    This is the speculative decoder's verify pass: the K draft tokens plus
    the bonus position are checked in a single batched program instead of
    K+1 sequential steps.  Dense-stack families run :func:`_dense_steps`
    (chunk matmuls over [B*S, d]; per-position attention masks make the
    pass bit-match the sequential chain — see its docstring).  Families
    whose step is not matmul-shaped — and the ``schedule=None`` default,
    whose sequential step is the einsum path rather than the fused matmul
    chain — unroll the sequential step inside one trace, which preserves
    exactness trivially (the fused plain-dot chain is NOT bit-identical to
    the einsum chain once the cache carries earlier steps' rounding).
    """
    S = tokens.shape[1]
    if schedule is not None and decode_schedulable(cfg):
        cdt = jnp.dtype(cfg.compute_dtype)
        x = embed(tokens, params["embed/table"], cdt) * math.sqrt(cfg.d_model)
        if packed is None:
            packed = pack_decode_params(cfg, params, schedule)
        return _dense_steps(cfg, params, packed, cache, x, pos, schedule)
    logits: List[jax.Array] = []
    for i in range(S):
        li, cache = decode_step(cfg, params, cache, tokens[:, i:i + 1],
                                pos + i if i else pos, schedule=schedule)
        logits.append(li)
    return (logits[0] if S == 1 else jnp.concatenate(logits, axis=1)), cache


def kv_trim(cache: Dict, keep: jax.Array) -> Dict:
    """Roll the self-attention KV cache back to ``keep[b]`` valid entries
    per row: positions ``>= keep[b]`` of ``cache/k`` / ``cache/v`` return
    to their initial all-zeros state, so a cache that saw rejected
    speculative writes becomes bit-equal to one that only ever advanced
    through the accepted prefix.

    Not needed for exactness — ``decode_attention`` masks every entry at
    index >= cache_len with NEG_INF before the softmax, so stale entries
    already contribute exactly zero, and the next verify window rewrites
    them before they could become visible — this is the STRICT rollback
    mode (``SpecConfig.trim``): it makes the resident cache itself an
    auditable bit-copy of the sequential baseline's, which is what the
    rollback-boundary tests compare.  Encoder caches (``cache/xk`` /
    ``cache/xv``) and the non-dense families' ring/state caches are left
    untouched: their entries do not depend on decode position.
    """
    new = dict(cache)
    for name in ("cache/k", "cache/v"):
        if name not in cache:
            continue
        c = cache[name]                      # [L, b, S, hk, hd]
        sel = jnp.arange(c.shape[2])[None, :] < keep[:, None]       # [b, S]
        new[name] = jnp.where(sel[None, :, :, None, None], c,
                              jnp.zeros((), c.dtype))
    return new


def _local_attn_decode(cfg, x, p, pre, ck, cv, cpos, pos, window):
    """Ring-buffer windowed attention decode (Griffin local layers)."""
    q, k, v = _qkv(cfg, x, p, pre, pos, rope=True)
    W = ck.shape[1]
    slot = jnp.mod(pos, W)
    ck = _ring_write(ck, k.astype(ck.dtype), slot)
    cv = _ring_write(cv, v.astype(cv.dtype), slot)
    cpos = _ring_write_pos(cpos, slot, pos)
    # slots hold pos+1 (0 = never written); window mask on absolute position
    valid = (cpos > 0) & (cpos <= pos[:, None] + 1) & \
            (cpos > pos[:, None] + 1 - window)
    o = decode_attention_masked(q, ck.astype(x.dtype), cv.astype(x.dtype),
                                valid)
    out = jnp.einsum("bshk,hkd->bsd", o.astype(x.dtype),
                     p[f"{pre}/wo"].astype(x.dtype))
    return out, ck, cv, cpos
