"""The paper's benchmark models: RNN (LSTM/GRU) + dense head classifiers.

Top tagging:    [b, 20, 6]  -> LSTM/GRU(20)  -> Dense(64, ReLU) -> sigmoid(1)
Flavor tagging: [b, 15, 6]  -> LSTM/GRU(120) -> Dense(50) -> Dense(10) -> softmax(3)
QuickDraw:      [b, 100, 3] -> LSTM/GRU(128) -> Dense(256) -> Dense(128) -> softmax(5)
"""

from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig, ModelConfig
from repro.core.rnn.cells import rnn_param_specs
from repro.core.rnn.layer import rnn_layer
from repro.core.quant.fixed_point import quantize
from repro.models.init import ParamSpec, ParamSpecs


def param_specs(cfg: ModelConfig) -> ParamSpecs:
    rnn = cfg.rnn
    assert rnn is not None
    specs = dict(rnn_param_specs(rnn, "rnn"))
    prev = rnn.hidden
    for i, width in enumerate(rnn.dense_sizes):
        specs[f"dense{i}/w"] = ParamSpec((prev, width), (None, None), "lecun")
        specs[f"dense{i}/b"] = ParamSpec((width,), (None,), "zeros")
        prev = width
    specs["head/w"] = ParamSpec((prev, rnn.n_outputs), (None, None), "lecun")
    specs["head/b"] = ParamSpec((rnn.n_outputs,), (None,), "zeros")
    return specs


def forward(
    cfg: ModelConfig,
    params: Dict,
    x: jax.Array,                        # [b, T, features]
    *,
    fp: Optional[FixedPointConfig] = None,
    mode: Optional[str] = None,
    impl: str = "xla",
    schedule=None,
    lengths=None,
    return_logits: bool = False,
) -> jax.Array:
    """Returns class probabilities [b, n_outputs] (or pre-activation logits).

    ``schedule`` (a KernelSchedule) overrides the config-derived execution
    schedule of the recurrent layer.  ``lengths`` [b] routes a padded batch
    of variable-length sequences through the masked-scan ragged path (each
    row's recurrence stops at its true length)."""
    rnn = cfg.rnn
    h = rnn_layer(rnn, x, params["rnn/kernel"], params["rnn/recurrent"],
                  params["rnn/bias"], fp=fp, mode=mode, impl=impl,
                  schedule=schedule, lengths=lengths)

    def q(t):
        return t if fp is None else quantize(t, fp)

    h = q(h)
    for i in range(len(rnn.dense_sizes)):
        h = q(h @ q(params[f"dense{i}/w"]) + q(params[f"dense{i}/b"]))
        h = q(jax.nn.relu(h))
    logits = h @ q(params["head/w"]) + q(params["head/b"])
    if return_logits:
        return logits
    if rnn.output_activation == "sigmoid":
        return jax.nn.sigmoid(q(logits))
    # paper note (Sec 5.1): softmax LUT gets extra precision in hls4ml —
    # we therefore do NOT quantize through the softmax.
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)


def loss_fn(cfg: ModelConfig, params: Dict, x: jax.Array, y: jax.Array):
    """Binary or categorical cross entropy (matches the paper's training)."""
    rnn = cfg.rnn
    logits = forward(cfg, params, x, return_logits=True)
    if rnn.output_activation == "sigmoid":
        yl = y.astype(jnp.float32).reshape(logits.shape)
        ls = jax.nn.log_sigmoid(logits)
        lns = jax.nn.log_sigmoid(-logits)
        loss = -jnp.mean(yl * ls + (1 - yl) * lns)
        acc = jnp.mean(((logits[..., 0] > 0) == (y > 0.5)).astype(jnp.float32))
    else:
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
    return loss, {"loss": loss, "accuracy": acc}
