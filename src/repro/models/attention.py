"""Attention: GQA/MQA, blockwise (memory-linear) causal/local/bidirectional,
cross-attention, and decode attention over a (possibly seq-sharded) KV cache.

The causal path uses an *unrolled triangular block schedule*: a python loop
over query chunks, each attending only to its kv prefix via an inner
``lax.scan`` with online-softmax (flash-style) f32 accumulators.  This makes
the compiled FLOPs exactly the triangular count (no masked-out waste) while
keeping peak memory at chunk x chunk.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.sharding.api import constrain

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: [b, sq, h, d], k: [b, sk, hk, d] -> scores [b, h, sq, sk] (f32)."""
    b, sq, h, d = q.shape
    hk = k.shape[2]
    g = h // hk
    qg = q.reshape(b, sq, hk, g, d)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=jnp.float32)
    return s.reshape(b, hk * g, sq, k.shape[1])


def _gqa_values(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: [b, h, sq, sk] (f32), v: [b, sk, hk, d] -> [b, sq, h, d]."""
    b, h, sq, sk = p.shape
    hk = v.shape[2]
    g = h // hk
    pg = p.reshape(b, hk, g, sq, sk)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pg, v.astype(jnp.float32))
    return o.reshape(b, sq, h, v.shape[-1])


def _chunk_scores_block(q, k, v, bias):
    """One (q-chunk, kv-chunk) block -> (scores_max, exp_sum, weighted_v)."""
    s = _gqa_scores(q, k)                                  # [b,h,cq,ck] f32
    if bias is not None:
        s = s + bias
    m = jnp.max(s, axis=-1)                                # [b,h,cq]
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)                                # [b,h,cq]
    o = _gqa_values(p, v)                                  # [b,cq,h,d] f32
    return m, l, o


def _merge(acc, m, l, o):
    """Online-softmax merge of a new block into (m_acc, l_acc, o_acc)."""
    m_acc, l_acc, o_acc = acc
    m_new = jnp.maximum(m_acc, m)
    c_old = jnp.exp(m_acc - m_new)
    c_new = jnp.exp(m - m_new)
    l_new = l_acc * c_old + l * c_new
    # o carried as [b, cq, h, d]; coefficients are [b, h, cq]
    co = jnp.transpose(c_old, (0, 2, 1))[..., None]
    cn = jnp.transpose(c_new, (0, 2, 1))[..., None]
    o_new = o_acc * co + o * cn
    return m_new, l_new, o_new


def _finalize(m, l, o):
    li = jnp.transpose(1.0 / jnp.maximum(l, 1e-30), (0, 2, 1))[..., None]
    return o * li


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool,
    scale: Optional[float] = None,
    chunk_q: int = 1024,
    chunk_kv: int = 2048,
    window: int = 0,
    q_offset: int = 0,
    unroll_kv: bool = False,
) -> jax.Array:
    """Memory-linear attention. q: [b,sq,h,d], k/v: [b,sk,hk,d] -> [b,sq,h,d].

    causal=True uses the triangular unrolled schedule (exact FLOPs).
    window>0 additionally restricts attention to the last `window` positions.
    q_offset: absolute position of q[0] relative to k[0] (decode/cross-chunk).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    q = (q * scale).astype(q.dtype)

    cq = min(chunk_q, sq)
    ck = min(chunk_kv, sk)
    nq = -(-sq // cq)
    # pad to chunk multiples
    pad_q = nq * cq - sq
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nk = -(-sk // ck)
    pad_k = nk * ck - sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    k_chunks = k.reshape(b, nk, ck, *k.shape[2:])
    v_chunks = v.reshape(b, nk, ck, *v.shape[2:])

    q_pos_base = jnp.arange(cq)
    k_pos_base = jnp.arange(ck)

    outs = []
    for i in range(nq):
        qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=1)
        q_pos = q_pos_base + i * cq + q_offset
        # kv prefix this q-chunk can see (static per i -> exact FLOPs)
        if causal:
            hi = min(nk, -(-(i * cq + cq + q_offset) // ck))
            hi = max(hi, 1)
        else:
            hi = nk
        kci = k_chunks[:, :hi]
        vci = v_chunks[:, :hi]

        def kv_step(acc, inputs):
            kc, vc, j = inputs
            k_pos = k_pos_base + j * ck
            bias = jnp.zeros((cq, ck), jnp.float32)
            if causal:
                bias = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, bias)
            if window > 0:
                bias = jnp.where(
                    k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, bias)
            if pad_k:
                bias = jnp.where(k_pos[None, :] >= sk, NEG_INF, bias)
            m, l, o = _chunk_scores_block(qi, kc, vc, bias[None, None])
            return _merge(acc, m, l, o), ()

        acc0 = (
            jnp.full((b, h, cq), NEG_INF, jnp.float32),
            jnp.zeros((b, h, cq), jnp.float32),
            jnp.zeros((b, cq, h, d), jnp.float32),
        )
        if unroll_kv:
            acc = acc0
            for j in range(hi):
                acc, _ = kv_step(acc, (kci[:, j], vci[:, j], jnp.int32(j)))
            m, l, o = acc
        else:
            (m, l, o), _ = jax.lax.scan(
                kv_step, acc0,
                (jnp.moveaxis(kci, 1, 0), jnp.moveaxis(vci, 1, 0),
                 jnp.arange(hi)))
        outs.append(_finalize(m, l, o))

    out = jnp.concatenate(outs, axis=1)[:, :sq]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Full (einsum) attention — used for short sequences & reference in tests
# ---------------------------------------------------------------------------


def full_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool, scale: Optional[float] = None, window: int = 0,
    q_offset: int = 0,
) -> jax.Array:
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = _gqa_scores(q * scale, k)                          # [b,h,sq,sk]
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    if causal:
        s = jnp.where(k_pos[None, :] > q_pos[:, None], NEG_INF, s)
    if window > 0:
        s = jnp.where(k_pos[None, :] <= q_pos[:, None] - window, NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_values(p, v)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode attention over a KV cache (single new token per sequence)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,          # [b, 1, h, d]
    k_cache: jax.Array,    # [b, S, hk, d]  (seq dim may be mesh-sharded)
    v_cache: jax.Array,
    cache_len: jax.Array,  # [b] valid lengths
    *,
    scale: Optional[float] = None,
    window: int = 0,
) -> jax.Array:
    """Masked attention over the cache. Works under GSPMD with the cache's
    seq dim sharded over 'model': the max/sum reductions become cross-device
    collectives (flash-decode semantics, XLA-partitioned)."""
    b, _, h, d = q.shape
    S = k_cache.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = _gqa_scores(q * scale, k_cache)                    # [b,h,1,S] f32
    pos = jnp.arange(S)
    mask = pos[None, :] >= cache_len[:, None]              # [b,S]
    if window > 0:
        mask = mask | (pos[None, :] <= (cache_len[:, None] - 1 - window))
    s = jnp.where(mask[:, None, None, :], NEG_INF, s)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = _gqa_values(p / jnp.maximum(l, 1e-30), v_cache)    # [b,1,h,d]
    return o.astype(q.dtype)


def decode_attention_masked(
    q: jax.Array,          # [b, 1, h, d]
    k_cache: jax.Array,    # [b, S, hk, d]
    v_cache: jax.Array,
    valid: jax.Array,      # [b, S] bool — which slots participate
    *,
    scale: Optional[float] = None,
) -> jax.Array:
    """Decode attention with an explicit slot-validity mask (ring buffers)."""
    b, _, h, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    s = _gqa_scores(q * scale, k_cache)                    # [b,h,1,S]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = _gqa_values(p / jnp.maximum(l, 1e-30), v_cache)
    return o.astype(q.dtype)
