"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

This is the paper's technique at LLM scale: a *gated linear recurrence*
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
    a_t = exp(-c * softplus(Lambda) * r_t),  r_t, i_t input-dependent gates
— the direct analogue of the hls4ml LSTM/GRU state update (Eq. 1 of the
paper), with the Hadamard-product structure the paper had to add to hls4ml.

Train/prefill uses an associative scan (log-depth); decode is the O(1)
"static-mode" state update.  Width is TP-sharded over 'model' (recurrence is
elementwise -> no collectives inside the scan).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.init import ParamSpec
from repro.sharding.api import constrain

_C = 8.0  # Griffin's fixed gate sharpness


def rglru_specs(cfg: ModelConfig, prefix: str, stacked=None) -> dict:
    rg = cfg.rglru
    d = cfg.d_model
    w = rg.lru_width or d
    lead = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    dt = cfg.param_dtype
    return {
        f"{prefix}/w_x": ParamSpec(lead + (d, w), la + ("embed", "lru_width"), "lecun", dt),
        f"{prefix}/w_gate": ParamSpec(lead + (d, w), la + ("embed", "lru_width"), "lecun", dt),
        f"{prefix}/conv_w": ParamSpec(lead + (rg.conv_width, w), la + ("conv", "lru_width"),
                                      "lecun", dt, 3.0),
        f"{prefix}/conv_b": ParamSpec(lead + (w,), la + ("lru_width",), "zeros", dt),
        f"{prefix}/lambda": ParamSpec(lead + (w,), la + ("lru_width",), "ones", dt),
        f"{prefix}/wa_gate": ParamSpec(lead + (w, w), la + ("lru_width", None), "lecun", dt),
        f"{prefix}/wi_gate": ParamSpec(lead + (w, w), la + ("lru_width", None), "lecun", dt),
        f"{prefix}/ba_gate": ParamSpec(lead + (w,), la + ("lru_width",), "zeros", dt),
        f"{prefix}/bi_gate": ParamSpec(lead + (w,), la + ("lru_width",), "zeros", dt),
        f"{prefix}/w_out": ParamSpec(lead + (w, d), la + ("lru_width", "embed"), "lecun", dt),
    }


def _lru_gates(p, prefix, xc):
    """Recurrence/input gates + log-decay.  xc: [b, s, w] (post-conv).

    Gate matmuls run in the compute dtype (bf16 MXU path — §Perf iteration
    RG-2: they were f32, costing 4x MXU throughput and 2x HBM bytes);
    the sigmoid/softplus decay math stays f32."""
    r = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p[f"{prefix}/wa_gate"].astype(xc.dtype),
                   preferred_element_type=jnp.float32)
        + p[f"{prefix}/ba_gate"].astype(jnp.float32))
    i = jax.nn.sigmoid(
        jnp.einsum("bsw,wv->bsv", xc, p[f"{prefix}/wi_gate"].astype(xc.dtype),
                   preferred_element_type=jnp.float32)
        + p[f"{prefix}/bi_gate"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p[f"{prefix}/lambda"].astype(jnp.float32)) * r
    return i, log_a


def _combine(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _scan_linear_recurrence(a: jax.Array, b: jax.Array, h0=None,
                            chunk: int = 256):
    """h_t = a_t * h_{t-1} + b_t over axis 1.

    §Perf iteration RG-3: chunked two-level scan instead of a full-length
    associative scan.  A log2(T)-level tree makes ~log2(T) full passes over
    the [b, T, w] arrays (T=4096 -> 12 passes of HBM traffic); chunking at
    256 does log2(256)=8 vectorized passes + one tiny [b, nc, w] carry
    recurrence + one combine pass (~9/12 of the traffic, measured in
    EXPERIMENTS.md §Perf)."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)

    B, T, W = a.shape
    if T <= chunk or T % chunk != 0:
        _, h = jax.lax.associative_scan(_combine, (a, b), axis=1)
        return h

    nc = T // chunk
    ar = a.reshape(B, nc, chunk, W)
    br = b.reshape(B, nc, chunk, W)
    # within-chunk scans, vectorized across chunks
    A_cum, h_within = jax.lax.associative_scan(_combine, (ar, br), axis=2)
    # carry states entering each chunk (tiny sequential recurrence over nc)
    A_c = A_cum[:, :, -1]                       # [B, nc, W] chunk decay
    h_c = h_within[:, :, -1]                    # [B, nc, W] chunk output

    def carry_step(h_in, inp):
        A, hw = inp
        return A * h_in + hw, h_in

    _, h_ins = jax.lax.scan(
        carry_step, jnp.zeros((B, W), a.dtype),
        (jnp.moveaxis(A_c, 1, 0), jnp.moveaxis(h_c, 1, 0)))
    h_ins = jnp.moveaxis(h_ins, 0, 1)           # state before each chunk
    h = h_within + A_cum * h_ins[:, :, None, :]
    return h.reshape(B, T, W)


def rglru_mix(cfg, x, p, prefix, state=None, conv_cache=None, return_state=False):
    """Griffin recurrent temporal-mixing block.  x: [b, s, d]."""
    from repro.models.ssm import _causal_conv

    rg = cfg.rglru
    w = rg.lru_width or cfg.d_model

    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p[f"{prefix}/w_gate"].astype(x.dtype)))
    xb = jnp.einsum("bsd,dw->bsw", x, p[f"{prefix}/w_x"].astype(x.dtype))
    xb = constrain(xb, "batch", "seq_nosp", "lru_width")
    xc, new_conv_cache = _causal_conv(
        xb, p[f"{prefix}/conv_w"].astype(x.dtype),
        p[f"{prefix}/conv_b"].astype(x.dtype), conv_cache)

    i, log_a = _lru_gates(p, prefix, xc)
    a = jnp.exp(log_a)                                      # [b,s,w] f32
    # sqrt(1 - a^2) input normalization (Griffin eq. 4)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bterm = beta * (i * xc.astype(jnp.float32))
    h = _scan_linear_recurrence(a, bterm,
                                None if state is None else state.astype(jnp.float32))
    h_last = h[:, -1]                                       # pre-gate state (f32)
    h = h.astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", h, p[f"{prefix}/w_out"].astype(x.dtype))
    if return_state:
        return out, (h_last, new_conv_cache)
    return out


def rglru_decode_step(cfg, x, p, prefix, state, conv_cache):
    """Single-token decode. x: [b,1,d]; state: [b,w] f32."""
    from repro.models.ssm import _causal_conv

    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", x, p[f"{prefix}/w_gate"].astype(x.dtype)))
    xb = jnp.einsum("bsd,dw->bsw", x, p[f"{prefix}/w_x"].astype(x.dtype))
    xc, new_conv_cache = _causal_conv(
        xb, p[f"{prefix}/conv_w"].astype(x.dtype),
        p[f"{prefix}/conv_b"].astype(x.dtype), conv_cache)

    i, log_a = _lru_gates(p, prefix, xc)                    # [b,1,w]
    a = jnp.exp(log_a[:, 0])
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a[:, 0]), 1e-12))
    new_state = a * state + beta * (i[:, 0] * xc[:, 0].astype(jnp.float32))
    h = new_state[:, None].astype(x.dtype) * gate
    out = jnp.einsum("bsw,wd->bsd", h, p[f"{prefix}/w_out"].astype(x.dtype))
    return out, (new_state, new_conv_cache)
