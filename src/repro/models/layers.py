"""Shared primitive layers: norms, embedding, rotary, activations."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def norm(cfg: ModelConfig, x: jax.Array, p: dict, prefix: str) -> jax.Array:
    if cfg.norm_type == "rmsnorm":
        return rms_norm(x, p[f"{prefix}/scale"], cfg.norm_eps)
    return layer_norm(x, p[f"{prefix}/scale"], p[f"{prefix}/bias"], cfg.norm_eps)


def norm_specs(cfg: ModelConfig, prefix: str, stacked: Optional[int] = None) -> dict:
    """ParamSpecs for a norm layer (optionally layer-stacked)."""
    from repro.models.init import ParamSpec

    lead = (stacked,) if stacked else ()
    lead_ax = ("layers",) if stacked else ()
    init_scale = "zeros" if cfg.norm_type == "rmsnorm" else "ones"
    out = {
        f"{prefix}/scale": ParamSpec(lead + (cfg.d_model,), lead_ax + ("embed_nofsdp",),
                                     init_scale, cfg.param_dtype)
    }
    if cfg.norm_type == "layernorm":
        out[f"{prefix}/bias"] = ParamSpec(lead + (cfg.d_model,),
                                          lead_ax + ("embed_nofsdp",),
                                          "zeros", cfg.param_dtype)
    return out


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)            # [head_dim//2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # [hd/2]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., s, hd/2]
    sin = jnp.sin(angles)[..., :, None, :]                        # [..., s, 1, hd/2]
    cos = jnp.cos(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array, compute_dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(compute_dtype)


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def squared_relu(x: jax.Array) -> jax.Array:
    return jnp.square(jax.nn.relu(x))


ACTIVATIONS = {
    "relu": jax.nn.relu,
    "relu2": squared_relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "sigmoid": jax.nn.sigmoid,
    "tanh": jnp.tanh,
}
