"""Parameter-spec machinery.

Every model declares a flat ``{path: ParamSpec}`` dict.  From it we derive:
  * materialized params (small configs, real runs),
  * abstract params (ShapeDtypeStruct — dry-run lowering, no allocation),
  * PartitionSpecs (via the active sharding context),
all guaranteed consistent because they come from one source of truth.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.sharding.api import ShardingContext


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical_axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | lecun | embed | rnn_ortho
    dtype: str = "float32"
    scale: float = 1.0

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (
            f"spec rank mismatch: {self.shape} vs {self.logical_axes}")


ParamSpecs = Dict[str, ParamSpec]
Params = Dict[str, jax.Array]


def _fan_in(shape: Tuple[int, ...]) -> int:
    if len(shape) == 1:
        return shape[0]
    # contraction dim is second-to-last by convention ([..., in, out])
    return int(np.prod(shape[:-1]))


def init_param(key: jax.Array, spec: ParamSpec) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "embed":
        v = jax.random.normal(key, spec.shape, jnp.float32)
        return (v * spec.scale).astype(dtype)
    if spec.init in ("normal", "lecun"):
        fan = _fan_in(spec.shape)
        std = spec.scale / np.sqrt(max(fan, 1))
        v = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
        return (v * std).astype(dtype)
    if spec.init == "rnn_ortho":
        # orthogonal recurrent kernel (keras default for RNN recurrent weights)
        rows, cols = spec.shape[-2], spec.shape[-1]
        n = max(rows, cols)
        a = jax.random.normal(key, spec.shape[:-2] + (n, n), jnp.float32)
        q, _ = jnp.linalg.qr(a)
        return (q[..., :rows, :cols] * spec.scale).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(rng: jax.Array, specs: ParamSpecs) -> Params:
    keys = jax.random.split(rng, len(specs))
    return {
        path: init_param(k, spec)
        for k, (path, spec) in zip(keys, sorted(specs.items()))
    }


def abstract_params(
    specs: ParamSpecs, ctx: Optional[ShardingContext] = None
) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins (optionally with shardings) — dry-run path."""
    out = {}
    for path, spec in specs.items():
        sharding = None
        if ctx is not None:
            sharding = NamedSharding(ctx.mesh, ctx.pspec(spec.logical_axes))
        out[path] = jax.ShapeDtypeStruct(spec.shape, jnp.dtype(spec.dtype),
                                         sharding=sharding)
    return out


def param_pspecs(specs: ParamSpecs, ctx: ShardingContext) -> Dict[str, P]:
    return {path: ctx.pspec(spec.logical_axes) for path, spec in specs.items()}


def param_shardings(specs: ParamSpecs, ctx: ShardingContext) -> Dict[str, NamedSharding]:
    return {
        path: NamedSharding(ctx.mesh, ctx.pspec(spec.logical_axes))
        for path, spec in specs.items()
    }


def param_bytes(specs: ParamSpecs) -> int:
    return sum(
        int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in specs.values()
    )
