"""Model zoo. Lazy exports to avoid import cycles with repro.core."""


def __getattr__(name):
    if name in ("build_model", "Model"):
        from repro.models import model as _m
        return getattr(_m, name)
    raise AttributeError(name)
