"""MLP blocks: SwiGLU / GeGLU / squared-ReLU / GELU, TP-sharded over 'ffn'."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.init import ParamSpec
from repro.models.layers import ACTIVATIONS
from repro.sharding.api import constrain


def mlp_specs(cfg: ModelConfig, prefix: str, stacked=None, d_ff=None) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    lead = (stacked,) if stacked else ()
    lax_ = ("layers",) if stacked else ()
    dt = cfg.param_dtype
    if cfg.mlp_type in ("swiglu", "geglu"):
        return {
            f"{prefix}/w_gate": ParamSpec(lead + (d, f), lax_ + ("embed", "ffn"), "lecun", dt),
            f"{prefix}/w_up": ParamSpec(lead + (d, f), lax_ + ("embed", "ffn"), "lecun", dt),
            f"{prefix}/w_down": ParamSpec(lead + (f, d), lax_ + ("ffn", "embed"), "lecun", dt),
        }
    return {
        f"{prefix}/w_up": ParamSpec(lead + (d, f), lax_ + ("embed", "ffn"), "lecun", dt),
        f"{prefix}/w_down": ParamSpec(lead + (f, d), lax_ + ("ffn", "embed"), "lecun", dt),
    }


def mlp(cfg: ModelConfig, x: jax.Array, p: dict, prefix: str) -> jax.Array:
    """x: [b, s, d] -> [b, s, d].  Hidden activations sharded over 'ffn'."""
    if cfg.mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}/w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p[f"{prefix}/w_up"].astype(x.dtype))
        h = act(g) * u
    else:
        act = ACTIVATIONS["relu2" if cfg.mlp_type == "relu2" else "gelu"]
        h = act(jnp.einsum("bsd,df->bsf", x,
                           p[f"{prefix}/w_up"].astype(x.dtype)))
    h = constrain(h, "batch", "seq_nosp", "ffn")
    return jnp.einsum("bsf,fd->bsd", h, p[f"{prefix}/w_down"].astype(x.dtype))
