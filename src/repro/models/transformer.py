"""Decoder-only LM assembly covering dense / moe / ssm / hybrid / vlm / audio.

Structure:
  * ``param_specs(cfg)``: single source of truth for parameters (stacked
    [L, ...] leading dim for scan-over-layers).
  * ``forward(cfg, params, tokens, ...)``: token embeddings -> scanned,
    rematerialized layer stack -> final norm.  Families share the residual
    skeleton and differ in the temporal-mixing block.
  * ``lm_loss``: vocab-parallel cross entropy (Megatron-style: logits stay
    sharded over 'vocab'; the LSE reductions partition across the TP axis).

Attention modes (chosen by the sharding context's meta, see sharding/auto.py):
  'tp'  — sequence gathered per device, heads TP-sharded, exact triangular
          blockwise schedule (no masked-out FLOPs).
  'sp'  — sequence stays sharded (one q-chunk per TP rank), KV gathered,
          rectangular masked blockwise (archs whose head count does not
          divide the TP axis: gemma-2b, deepseek-coder-33b).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import attention as attn_lib
from repro.models.init import ParamSpec, ParamSpecs
from repro.models.layers import apply_rope, embed, norm, norm_specs, softcap
from repro.models.mlp import mlp, mlp_specs
from repro.models.moe import moe_block, moe_specs, padded_n_experts
from repro.models.rglru import rglru_mix, rglru_specs
from repro.models.ssm import ssm_block, ssm_specs
from repro.sharding.api import constrain, current_context


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def _attn_specs(cfg: ModelConfig, prefix: str, stacked=None) -> ParamSpecs:
    d = cfg.d_model
    q_dim, kv_dim = cfg.qkv_dims
    lead = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    dt = cfg.param_dtype
    return {
        f"{prefix}/wq": ParamSpec(lead + (d, cfg.n_heads, cfg.head_dim),
                                  la + ("embed", "heads", "head_dim"), "lecun", dt),
        f"{prefix}/wk": ParamSpec(lead + (d, cfg.n_kv_heads, cfg.head_dim),
                                  la + ("embed", "kv_heads", "head_dim"), "lecun", dt),
        f"{prefix}/wv": ParamSpec(lead + (d, cfg.n_kv_heads, cfg.head_dim),
                                  la + ("embed", "kv_heads", "head_dim"), "lecun", dt),
        f"{prefix}/wo": ParamSpec(lead + (cfg.n_heads, cfg.head_dim, d),
                                  la + ("heads", "head_dim", "embed"), "lecun", dt),
    }


def _layer_specs(cfg: ModelConfig, n_stacked: int, kind: str = "decoder") -> ParamSpecs:
    """Specs for one (stacked) layer group of the given kind."""
    specs: ParamSpecs = {}
    pre = f"{kind}"
    if cfg.family == "ssm":
        specs.update(norm_specs(cfg, f"{pre}/norm1", n_stacked))
        specs.update(ssm_specs(cfg, f"{pre}/ssm", n_stacked))
        return specs
    specs.update(norm_specs(cfg, f"{pre}/norm1", n_stacked))
    specs.update(_attn_specs(cfg, f"{pre}/attn", n_stacked))
    specs.update(norm_specs(cfg, f"{pre}/norm2", n_stacked))
    if cfg.family == "moe":
        specs.update(moe_specs(cfg, f"{pre}/moe", n_stacked, padded_n_experts(cfg)))
    else:
        specs.update(mlp_specs(cfg, f"{pre}/mlp", n_stacked))
    if kind == "xdecoder":  # enc-dec decoder layer: + cross attention
        specs.update(norm_specs(cfg, f"{pre}/norm_x", n_stacked))
        specs.update(_attn_specs(cfg, f"{pre}/xattn", n_stacked))
    return specs


def _hybrid_specs(cfg: ModelConfig) -> ParamSpecs:
    """Griffin pattern: scan over super-blocks of (rglru, rglru, local_attn),
    plus unrolled remainder layers."""
    rg = cfg.rglru
    n_super, rem = divmod(cfg.n_layers, len(rg.pattern))
    specs: ParamSpecs = {}
    for j, kind in enumerate(rg.pattern):
        specs.update(norm_specs(cfg, f"hyb{j}/norm1", n_super))
        if kind == "rglru":
            specs.update(rglru_specs(cfg, f"hyb{j}/mix", n_super))
        else:
            specs.update(_attn_specs(cfg, f"hyb{j}/attn", n_super))
        specs.update(norm_specs(cfg, f"hyb{j}/norm2", n_super))
        specs.update(mlp_specs(cfg, f"hyb{j}/mlp", n_super))
    for j in range(rem):
        kind = rg.pattern[j]
        specs.update(norm_specs(cfg, f"hybrem{j}/norm1"))
        if kind == "rglru":
            specs.update(rglru_specs(cfg, f"hybrem{j}/mix"))
        else:
            specs.update(_attn_specs(cfg, f"hybrem{j}/attn"))
        specs.update(norm_specs(cfg, f"hybrem{j}/norm2"))
        specs.update(mlp_specs(cfg, f"hybrem{j}/mlp"))
    return specs


def padded_vocab(cfg: ModelConfig, multiple: int = 128) -> int:
    """Vocab padded for TP divisibility (MaxText-style; whisper's 51865 is
    odd).  Padded ids never appear in data; they carry ~0 probability mass."""
    return -(-cfg.vocab_size // multiple) * multiple


def param_specs(cfg: ModelConfig) -> ParamSpecs:
    d, V = cfg.d_model, padded_vocab(cfg)
    dt = cfg.param_dtype
    specs: ParamSpecs = {
        "embed/table": ParamSpec((V, d), ("vocab", "embed"), "embed", dt, 0.02),
    }
    specs.update(norm_specs(cfg, "final_norm"))
    if not cfg.tie_embeddings:
        specs["unembed/w"] = ParamSpec((d, V), ("embed", "vocab"), "lecun", dt)
    del V
    if cfg.frontend == "vision":
        specs["img_proj/w"] = ParamSpec((d, d), ("embed", None), "lecun", dt)
    if cfg.enc_dec:
        specs.update(_layer_specs(cfg, cfg.n_encoder_layers, "encoder"))
        specs.update(_layer_specs(cfg, cfg.n_decoder_layers, "xdecoder"))
        specs.update(norm_specs(cfg, "enc_final_norm"))
        return specs
    if cfg.family == "hybrid":
        specs.update(_hybrid_specs(cfg))
        return specs
    specs.update(_layer_specs(cfg, cfg.n_layers, "decoder"))
    return specs


def slice_layer(params: Dict, prefix: str) -> Dict:
    return {k: v for k, v in params.items() if k.startswith(prefix)}


# ---------------------------------------------------------------------------
# Attention block
# ---------------------------------------------------------------------------


def _attn_meta() -> Tuple[str, int]:
    ctx = current_context()
    if ctx is None:
        return "tp", 0
    mode = ctx.overrides.get("__attn_mode__", "tp")
    tp = ctx.mesh.shape.get("model", 1)
    return mode, tp


def attention_block(
    cfg: ModelConfig,
    x: jax.Array,
    p: Dict,
    prefix: str,
    *,
    causal: bool,
    window: int = 0,
    kv_source: Optional[jax.Array] = None,
    pos_offset: int = 0,
    return_kv: bool = False,
):
    """Pre-norm'd input -> attention output (pre-residual). x: [b, s, d]."""
    b, s, _ = x.shape
    mode, tp = _attn_meta()
    xs = kv_source if kv_source is not None else x

    q = jnp.einsum("bsd,dhk->bshk", x, p[f"{prefix}/wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xs, p[f"{prefix}/wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xs, p[f"{prefix}/wv"].astype(x.dtype))

    if kv_source is None and cfg.family != "audio":
        pos = jnp.arange(s) + pos_offset
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)

    if mode == "sp" and tp > 1 and causal:
        # sequence stays sharded; KV gathered (small for MQA/GQA archs)
        q = constrain(q, "batch", "seq", None, "head_dim")
        k = constrain(k, "batch", None, None, "head_dim")
        v = constrain(v, "batch", None, None, "head_dim")
        o = _sp_attention(q, k, v, causal=causal, window=window, tp=tp,
                          chunk_kv=min(cfg.attn_chunk_kv, 512),
                          unroll=cfg.probe_unroll)
    else:
        # heads-TP: gather sequence, shard heads (exact triangular schedule)
        q = constrain(q, "batch", None, "heads", "head_dim")
        k = constrain(k, "batch", None, "kv_heads", "head_dim")
        v = constrain(v, "batch", None, "kv_heads", "head_dim")
        o = attn_lib.blockwise_attention(
            q, k, v, causal=causal, window=window,
            chunk_q=cfg.attn_chunk_q, chunk_kv=cfg.attn_chunk_kv,
            unroll_kv=cfg.probe_unroll)
    o = o.astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", o, p[f"{prefix}/wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


def _sp_attention(q, k, v, *, causal, window, tp, chunk_kv, unroll=False):
    """Sequence-parallel attention: q chunk-grid sharded over 'model' (one
    chunk per rank), KV gathered; rectangular masked blockwise inner scan.
    Costs ~2x triangular FLOPs for causal (hillclimb target: ring schedule).
    """
    b, s, h, d = q.shape
    assert s % tp == 0
    cq = s // tp
    qg = q.reshape(b, tp, cq, h, d)
    qg = constrain(qg, "batch", "seq_chunks", None, None, None)

    def per_chunk(qc, idx):
        # qc: [b, cq, h, d]; absolute q offset = idx * cq
        return _masked_rect(qc, k, v, idx * cq, causal, window, chunk_kv,
                            unroll=unroll)

    o = jax.vmap(per_chunk, in_axes=(1, 0), out_axes=1)(
        qg, jnp.arange(tp))
    o = constrain(o, "batch", "seq_chunks", None, None, None)
    return o.reshape(b, s, h, d)


def _masked_rect(qc, k, v, q_off, causal, window, chunk_kv, unroll=False):
    """Rectangular blockwise attention for one q chunk at dynamic offset."""
    b, cq, h, d = qc.shape
    sk = k.shape[1]
    ck = min(chunk_kv, sk)
    nk = sk // ck
    scale = 1.0 / math.sqrt(d)
    qs = (qc * scale).astype(qc.dtype)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, *k.shape[2:]), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, *v.shape[2:]), 1, 0)
    q_pos = jnp.arange(cq) + q_off

    def step(acc, inp):
        kj, vj, j = inp
        k_pos = j * ck + jnp.arange(ck)
        mask = jnp.zeros((cq, ck), bool)
        if causal:
            mask = mask | (k_pos[None, :] > q_pos[:, None])
        if window > 0:
            mask = mask | (k_pos[None, :] <= q_pos[:, None] - window)
        s = attn_lib._gqa_scores(qs, kj)
        s = jnp.where(mask[None, None], attn_lib.NEG_INF, s)
        m = jnp.max(s, axis=-1)
        pexp = jnp.exp(s - m[..., None])
        l = jnp.sum(pexp, axis=-1)
        o = attn_lib._gqa_values(pexp, vj)
        return attn_lib._merge(acc, m, l, o), ()

    acc0 = (jnp.full((b, h, cq), attn_lib.NEG_INF, jnp.float32),
            jnp.zeros((b, h, cq), jnp.float32),
            jnp.zeros((b, cq, h, d), jnp.float32))
    if unroll:
        acc = acc0
        for j in range(nk):
            acc, _ = step(acc, (kc[j], vc[j], jnp.int32(j)))
        m, l, o = acc
    else:
        (m, l, o), _ = jax.lax.scan(step, acc0, (kc, vc, jnp.arange(nk)))
    return attn_lib._finalize(m, l, o).astype(qc.dtype)


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------


def _residual_in(x):
    return constrain(x, "batch", "seq", "embed_act")


def dense_layer(cfg, x, p, pre, *, causal=True, window=0, pos_offset=0,
                kv_source=None, cross=False):
    h = norm(cfg, _residual_in(x), p, f"{pre}/norm1")
    h = attention_block(cfg, h, p, f"{pre}/attn", causal=causal,
                        window=window, pos_offset=pos_offset)
    x = _residual_in(x + h)
    if cross:
        hx = norm(cfg, x, p, f"{pre}/norm_x")
        hx = attention_block(cfg, hx, p, f"{pre}/xattn", causal=False,
                             kv_source=kv_source)
        x = _residual_in(x + hx)
    h2 = norm(cfg, x, p, f"{pre}/norm2")
    h2 = mlp(cfg, h2, p, f"{pre}/mlp")
    return _residual_in(x + h2)


def moe_layer(cfg, x, p, pre, aux_acc, *, train, pos_offset=0):
    h = norm(cfg, _residual_in(x), p, f"{pre}/norm1")
    h = attention_block(cfg, h, p, f"{pre}/attn", causal=True,
                        pos_offset=pos_offset)
    x = _residual_in(x + h)
    h2 = norm(cfg, x, p, f"{pre}/norm2")
    h2, aux = moe_block(cfg, h2, p, f"{pre}/moe", train=train)
    for k2, v2 in aux.items():
        aux_acc[k2] = aux_acc.get(k2, 0.0) + v2
    return _residual_in(x + h2), aux_acc


def ssm_layer(cfg, x, p, pre):
    h = norm(cfg, _residual_in(x), p, f"{pre}/norm1")
    h = ssm_block(cfg, h, p, f"{pre}/ssm")
    return _residual_in(x + h)


def hybrid_layer(cfg, x, p, pre, kind, *, pos_offset=0):
    h = norm(cfg, _residual_in(x), p, f"{pre}/norm1")
    if kind == "rglru":
        h = rglru_mix(cfg, h, p, f"{pre}/mix")
    else:
        h = attention_block(cfg, h, p, f"{pre}/attn", causal=True,
                            window=cfg.rglru.window, pos_offset=pos_offset)
    x = _residual_in(x + h)
    h2 = norm(cfg, x, p, f"{pre}/norm2")
    h2 = mlp(cfg, h2, p, f"{pre}/mlp")
    return _residual_in(x + h2)


# ---------------------------------------------------------------------------
# Stack runner (scan over stacked layers + remat)
# ---------------------------------------------------------------------------


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


def _run_stack(cfg, x, params, kind, layer_fn, n_layers):
    """Scan layer_fn over stacked params under `kind` prefix."""
    if kind == "hyb":  # super-block group: hyb0/..., hyb1/..., hyb2/...
        stacked = {k: v for k, v in params.items()
                   if k.startswith("hyb") and not k.startswith("hybrem")}
    else:
        stacked = slice_layer(params, f"{kind}/")

    def body(carry, p_layer):
        return layer_fn(carry, p_layer), ()

    body = _remat(cfg, body)
    if cfg.scan_layers and n_layers > 1:
        x, _ = jax.lax.scan(body, x, stacked, length=n_layers)
        return x
    for i in range(n_layers):
        p_i = {k: v[i] for k, v in stacked.items()}
        x, _ = body(x, p_i)
    return x


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Dict,
    tokens: jax.Array,                     # [b, s_text]
    *,
    train: bool = True,
    img_embeds: Optional[jax.Array] = None,    # vlm: [b, n_patches, d]
    frame_embeds: Optional[jax.Array] = None,  # audio: [b, s_frames, d]
) -> Tuple[jax.Array, Dict]:
    """Returns (final hidden states [b, s, d], aux dict)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    aux: Dict = {}

    if cfg.enc_dec:
        assert frame_embeds is not None
        enc = _encode(cfg, params, frame_embeds.astype(cdt))
        x = embed(tokens, params["embed/table"], cdt)
        x = x * math.sqrt(cfg.d_model)
        x = _add_sinusoidal(x)
        x = _residual_in(x)

        def dec_fn(h, p_layer):
            return dense_layer(cfg, h, p_layer, "xdecoder", causal=True,
                               cross=True, kv_source=enc)

        # cross-attention consumes the (shared) encoder output — cannot scan
        # kv_source through scan xs cheaply; pass via closure (replicated).
        x = _run_stack(cfg, x, params, "xdecoder", dec_fn, cfg.n_decoder_layers)
        x = norm(cfg, x, params, "final_norm")
        return x, aux

    x = embed(tokens, params["embed/table"], cdt)
    if cfg.family in ("dense", "vlm", "hybrid"):
        x = x * math.sqrt(cfg.d_model)  # gemma/griffin-style embed scaling

    if cfg.frontend == "vision":
        assert img_embeds is not None
        img = jnp.einsum("bnd,de->bne", img_embeds.astype(cdt),
                         params["img_proj/w"].astype(cdt))
        x = jnp.concatenate([img, x], axis=1)

    x = _residual_in(x)

    if cfg.family == "ssm":
        x = _run_stack(cfg, x, params, "decoder",
                       lambda h, p: ssm_layer(cfg, h, p, "decoder"),
                       cfg.n_layers)
    elif cfg.family == "moe":
        aux_acc: Dict = {}

        def moe_fn(carry, p_layer):
            h, lb, zl = carry
            acc: Dict = {}
            h, acc = moe_layer(cfg, h, p_layer, "decoder", acc, train=train)
            return (h, lb + acc.get("moe_load_balance", 0.0),
                    zl + acc.get("moe_z_loss", 0.0)), ()

        body = _remat(cfg, moe_fn)
        stacked = slice_layer(params, "decoder/")
        if cfg.scan_layers:
            (x, lb, zl), _ = jax.lax.scan(
                body, (x, jnp.float32(0), jnp.float32(0)), stacked,
                length=cfg.n_layers)
        else:
            lb = zl = jnp.float32(0)
            for i in range(cfg.n_layers):
                p_i = {k: v[i] for k, v in stacked.items()}
                (x, lb, zl), _ = body((x, lb, zl), p_i)
        aux["moe_load_balance"] = lb / cfg.n_layers
        aux["moe_z_loss"] = zl / cfg.n_layers
    elif cfg.family == "hybrid":
        rg = cfg.rglru
        n_pat = len(rg.pattern)
        n_super, rem = divmod(cfg.n_layers, n_pat)

        def super_fn(h, p_sb):
            for j, kind in enumerate(rg.pattern):
                h = hybrid_layer(cfg, h, p_sb, f"hyb{j}", kind)
            return h

        x = _run_stack(cfg, x, params, "hyb", lambda h, p: super_fn(h, p),
                       n_super)
        for j in range(rem):
            p_r = slice_layer(params, f"hybrem{j}/")
            x = _remat(cfg, lambda h, p: hybrid_layer(
                cfg, h, p, f"hybrem{j}", rg.pattern[j]))(x, p_r)
    else:  # dense / vlm
        x = _run_stack(cfg, x, params, "decoder",
                       lambda h, p: dense_layer(cfg, h, p, "decoder"),
                       cfg.n_layers)

    x = norm(cfg, x, params, "final_norm")
    return x, aux


def _encode(cfg, params, frames):
    x = _add_sinusoidal(frames)
    x = _residual_in(x)
    x = _run_stack(cfg, x, params, "encoder",
                   lambda h, p: dense_layer(cfg, h, p, "encoder",
                                            causal=False),
                   cfg.n_encoder_layers)
    return norm(cfg, x, params, "enc_final_norm")


def _add_sinusoidal(x):
    b, s, d = x.shape
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)[:, :d]
    return x + pe[None].astype(x.dtype)


# ---------------------------------------------------------------------------
# Logits + vocab-parallel loss
# ---------------------------------------------------------------------------


def logits_fn(cfg: ModelConfig, params: Dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = params["embed/table"].astype(x.dtype)     # [V, d]
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, params["unembed/w"].astype(x.dtype))
    logits = softcap(logits, cfg.logits_softcap)
    return constrain(logits, "batch", "seq_nosp", "vocab")


def lm_loss(cfg: ModelConfig, params: Dict, hidden: jax.Array,
            labels: jax.Array, z_loss: float = 1e-4) -> Tuple[jax.Array, Dict]:
    """Vocab-parallel stable cross entropy.  labels: [b, s], -1 = masked."""
    logits = logits_fn(cfg, params, hidden).astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    shifted = logits - jax.lax.stop_gradient(m)
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    lab = jnp.maximum(labels, 0)
    picked = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = (labels >= 0).astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    zl = jnp.sum(jnp.square(lse) * mask) / denom
    metrics = {"nll": loss, "z_loss": zl,
               "accuracy": jnp.sum((jnp.argmax(logits, -1) == lab) * mask) / denom}
    return loss + z_loss * zl, metrics
