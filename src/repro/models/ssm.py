"""Mamba-2 (SSD, state-space duality) block — arXiv:2405.21060.

Prefill/train: chunked SSD — intra-chunk quadratic attention-like form +
inter-chunk linear recurrence over per-chunk states (a tiny sequential scan of
[b, h, p, n] states).  Decode: O(1) single-step state update — literally the
paper's "static mode" RNN block (state resident, one block per layer).

TP layout: value heads sharded over 'model' (n_groups=1 B/C replicated);
the recurrence is elementwise across heads so no cross-device communication
appears inside the scan.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.init import ParamSpec
from repro.models.layers import rms_norm
from repro.sharding.api import constrain


def ssm_dims(cfg: ModelConfig):
    s = cfg.ssm
    assert s is not None
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, n_heads, conv_dim


def ssm_specs(cfg: ModelConfig, prefix: str, stacked=None) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in, h, conv_dim = ssm_dims(cfg)
    lead = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    dt = cfg.param_dtype
    # in_proj emits [z (d_in), xBC (conv_dim), dt (h)]
    return {
        f"{prefix}/w_in": ParamSpec(lead + (d, 2 * d_in + 2 * s.n_groups * s.d_state + h),
                                    la + ("embed", "ssm_inner"), "lecun", dt),
        f"{prefix}/conv_w": ParamSpec(lead + (s.d_conv, conv_dim),
                                      la + ("conv", "ssm_inner"), "lecun", dt, 3.0),
        f"{prefix}/conv_b": ParamSpec(lead + (conv_dim,), la + ("ssm_inner",), "zeros", dt),
        f"{prefix}/dt_bias": ParamSpec(lead + (h,), la + ("ssm_heads",), "zeros", dt),
        f"{prefix}/a_log": ParamSpec(lead + (h,), la + ("ssm_heads",), "ones", dt),
        f"{prefix}/d_skip": ParamSpec(lead + (h,), la + ("ssm_heads",), "ones", dt),
        f"{prefix}/norm_scale": ParamSpec(lead + (d_in,), la + ("ssm_inner",), "zeros", dt),
        f"{prefix}/w_out": ParamSpec(lead + (d_in, d), la + ("ssm_inner", "embed"), "lecun", dt),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 cache: jax.Array | None = None):
    """Depthwise causal conv. x: [b, s, c]; w: [k, c].  Returns (y, new_cache)
    where cache holds the last k-1 inputs for streaming decode."""
    k = w.shape[0]
    if cache is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = cache.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                  # [b, s+k-1, c]
    y = sum(xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(k))
    y = y + b[None, None]
    new_cache = xp[:, -(k - 1):]
    return y, new_cache


def _ssd_chunked(xdt, log_a, B, C, chunk: int, initial_state=None,
                 unroll: bool = False):
    """SSD core — fused per-chunk scan (intra-chunk quadratic + inter-chunk
    recurrence computed together, state carried through the scan).

    xdt: [b,s,h,p] (x pre-multiplied by dt), log_a: [b,s,h] (f32),
    B,C: [b,s,g,n].  Heads are grouped as h = g * hg (B/C shared per group).
    Returns (y [b,s,h,p], final_state [b,h,p,n]).

    Memory: one [b, q, q, g, hg] decay/score tensor per chunk step (not
    materialized across all chunks), which is what makes 32k prefill fit.
    """
    b, s, h, p = xdt.shape
    g, n = B.shape[2], B.shape[3]
    s_orig = s
    pad = (-s) % chunk
    if pad:
        # identity padding: log_a=0 (a=1) and x=0 leave the state untouched
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] + [(0, 0)] * (t.ndim - 2))
        xdt, log_a, B, C = zpad(xdt), zpad(log_a), zpad(B), zpad(C)
        s = s + pad
    nc = s // chunk
    hg = h // g
    q = chunk

    xdt = xdt.reshape(b, nc, q, g, hg, p)
    log_a = log_a.reshape(b, nc, q, g, hg)
    B = B.reshape(b, nc, q, g, n)
    C = C.reshape(b, nc, q, g, n)

    tril = jnp.tril(jnp.ones((q, q), bool))
    init = (jnp.zeros((b, g, hg, p, n), jnp.float32)
            if initial_state is None
            else initial_state.reshape(b, g, hg, p, n).astype(jnp.float32))

    def chunk_step(state, inp):
        xdt_c, la_raw, B_c, C_c = inp                    # [b,q,...]
        la = jnp.cumsum(la_raw, axis=1)                  # [b,q,g,hg] f32
        # intra-chunk triangular term
        seg = la[:, :, None] - la[:, None, :]            # [b,i,j,g,hg]
        decay = jnp.where(tril[None, :, :, None, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bign,bjgn->bijg", C_c, B_c,
                        preferred_element_type=jnp.float32)
        y_intra = jnp.einsum("bijg,bijgh,bjghp->bighp", cb, decay,
                             xdt_c.astype(jnp.float32))
        # inter-chunk contribution from carried state
        decay_in = jnp.exp(la)                           # [b,q,g,hg]
        y_inter = jnp.einsum("bqgn,bghpn->bqghp", C_c.astype(jnp.float32),
                             state) * decay_in[..., None]
        # state update
        la_last = la[:, -1:]                             # [b,1,g,hg]
        decay_state = jnp.exp(la_last - la)              # [b,q,g,hg]
        s_c = jnp.einsum("bqgn,bqgh,bqghp->bghpn", B_c.astype(jnp.float32),
                         decay_state, xdt_c.astype(jnp.float32))
        new_state = state * jnp.exp(la_last[:, 0])[..., None, None] + s_c
        return new_state, (y_intra + y_inter).astype(xdt_c.dtype)

    mv = lambda t: jnp.moveaxis(t, 1, 0)
    if unroll:  # cost-probe mode: make every chunk visible to cost_analysis
        state, ys = init, []
        for c in range(nc):
            state, y_c = chunk_step(
                state, (xdt[:, c], log_a[:, c], B[:, c], C[:, c]))
            ys.append(y_c)
        final, y = state, jnp.stack(ys)
    else:
        final, y = jax.lax.scan(chunk_step, init,
                                (mv(xdt), mv(log_a), mv(B), mv(C)))
    y = jnp.moveaxis(y, 0, 1).reshape(b, s, h, p)[:, :s_orig]
    return y, final.reshape(b, h, p, n)


def ssm_block(cfg: ModelConfig, x: jax.Array, p: Dict, prefix: str) -> jax.Array:
    """Training/prefill forward. x: [b, s, d] -> [b, s, d]."""
    y, _ = ssm_block_with_state(cfg, x, p, prefix, initial_state=None)
    return y


def ssm_block_with_state(cfg, x, p, prefix, initial_state=None,
                         conv_cache=None):
    s_cfg = cfg.ssm
    d_in, h, conv_dim = ssm_dims(cfg)
    g, n = s_cfg.n_groups, s_cfg.d_state
    b, s, _ = x.shape

    zxbcdt = jnp.einsum("bsd,de->bse", x, p[f"{prefix}/w_in"].astype(x.dtype))
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xBC, new_conv_cache = _causal_conv(
        xBC, p[f"{prefix}/conv_w"].astype(x.dtype),
        p[f"{prefix}/conv_b"].astype(x.dtype), conv_cache)
    xBC = jax.nn.silu(xBC)
    xv, B, C = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p[f"{prefix}/dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p[f"{prefix}/a_log"].astype(jnp.float32))  # [h], negative
    log_a = dt * a[None, None, :]                           # [b,s,h]

    xv = xv.reshape(b, s, h, s_cfg.head_dim)
    xdt = xv * dt[..., None].astype(xv.dtype)
    B = B.reshape(b, s, g, n)
    C = C.reshape(b, s, g, n)

    xdt = constrain(xdt, "batch", "seq_nosp", "ssm_heads", None)
    y, final_state = _ssd_chunked(xdt, log_a, B, C,
                                  min(s_cfg.chunk_size, s), initial_state,
                                  unroll=cfg.probe_unroll)
    y = y + xv * p[f"{prefix}/d_skip"].astype(xv.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z), p[f"{prefix}/norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p[f"{prefix}/w_out"].astype(y.dtype))
    return out.astype(x.dtype), (final_state, new_conv_cache)


def ssm_decode_step(cfg, x, p, prefix, state, conv_cache):
    """Single-token decode: x [b, 1, d]; state [b,h,p,n]; conv_cache
    [b, d_conv-1, conv_dim].  O(1) in context length."""
    s_cfg = cfg.ssm
    d_in, h, conv_dim = ssm_dims(cfg)
    g, n = s_cfg.n_groups, s_cfg.d_state
    b = x.shape[0]

    zxbcdt = jnp.einsum("bsd,de->bse", x, p[f"{prefix}/w_in"].astype(x.dtype))
    z, xBC, dt = jnp.split(zxbcdt, [d_in, d_in + conv_dim], axis=-1)
    xBC, new_conv_cache = _causal_conv(
        xBC, p[f"{prefix}/conv_w"].astype(x.dtype),
        p[f"{prefix}/conv_b"].astype(x.dtype), conv_cache)
    xBC = jax.nn.silu(xBC)
    xv, B, C = jnp.split(xBC, [d_in, d_in + g * n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p[f"{prefix}/dt_bias"].astype(jnp.float32))[:, 0]  # [b,h]
    a = -jnp.exp(p[f"{prefix}/a_log"].astype(jnp.float32))
    a_t = jnp.exp(dt * a[None, :])                          # [b,h]

    xv = xv.reshape(b, h, s_cfg.head_dim)
    xdt = xv * dt[..., None].astype(xv.dtype)
    Bt = B.reshape(b, g, n)
    Ct = C.reshape(b, g, n)
    hg = h // g
    Bh = jnp.repeat(Bt, hg, axis=1)                         # [b,h,n]
    Ch = jnp.repeat(Ct, hg, axis=1)

    new_state = (state * a_t[..., None, None].astype(state.dtype)
                 + xdt[..., :, None] * Bh[..., None, :])    # [b,h,p,n]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    y = y + xv * p[f"{prefix}/d_skip"].astype(xv.dtype)[None, :, None]
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p[f"{prefix}/norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p[f"{prefix}/w_out"].astype(y.dtype))
    return out.astype(x.dtype), (new_state, new_conv_cache)
