"""Schedule-keyed serving bench: a mixed stream of requests carrying >= 3
distinct KernelSchedules is co-batched by schedule hash and served, then the
per-key measured latency is emitted next to ``estimate_schedule`` of the
SAME schedule object — the multi-tenant version of the paper's
measured-vs-analytical comparison (Sec. 5.2).

``smoke()`` is the CI fail-fast variant wired into ``run.py --smoke``: it
additionally asserts the served outputs bit-match direct per-schedule
``predict`` and that each schedule hash cost at most one jit trace.
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, train_tagger
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import RNNServingEngine

MIXED_SCHEDULES = (
    KernelSchedule(reuse_factor=1, mode="static", backend="xla"),
    KernelSchedule(reuse_factor=2, mode="static", block_batch=8,
                   backend="pallas_interpret"),
    KernelSchedule(reuse_factor=4, mode="nonstatic", block_batch=8,
                   backend="pallas_interpret"),
)


def _mixed_stream(eng: RNNServingEngine, n_per_key: int, seed: int = 0):
    """Interleave n_per_key requests per schedule; returns requests by key."""
    r = eng.cfg.rnn
    rng = np.random.RandomState(seed)
    xs = {s: rng.randn(n_per_key, r.seq_len, r.input_size).astype(np.float32)
          for s in MIXED_SCHEDULES}
    reqs = {s: [] for s in MIXED_SCHEDULES}
    for i in range(n_per_key):
        for s in MIXED_SCHEDULES:
            reqs[s].append(eng.submit(xs[s][i], schedule=s))
    eng.flush(force=True)
    return xs, reqs


def run(full: bool = False):
    cfg, m, params = train_tagger("top-tagging-gru", steps=60, n=600)
    eng = RNNServingEngine(cfg, params, max_batch=8)
    n = 32 if full else 16
    _mixed_stream(eng, n)
    for key, row in eng.serve_report().items():
        meas, est = row["measured"], row["analytical"]
        emit(f"serving/{key}", meas["latency_p50_s"] * 1e6,
             f"served={int(meas['served'])}|batches={int(meas['batches'])}"
             f"|traces={row['traces']}"
             f"|est_lat={est['latency_us']:.2f}us|est_ii={est['ii_cycles']}"
             f"|est_dsp={est['dsp']}")


def smoke() -> None:
    """Fail-fast mixed-schedule serving check (raises on any mismatch)."""
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = RNNServingEngine(cfg, params, max_batch=4)
    xs, reqs = _mixed_stream(eng, 4)
    ref = RNNServingEngine(cfg, params, max_batch=4)
    for s in MIXED_SCHEDULES:
        key = schedule_key(s)
        got = np.stack([r.result for r in reqs[s]])
        want = ref.predict(xs[s], schedule=s)
        assert np.array_equal(got, want), \
            f"served outputs diverged from direct predict for {key}"
        assert eng.trace_count(key) <= 1, \
            f"{key} retraced: {eng.trace_count(key)} jit traces"
    report = eng.serve_report()
    for s in MIXED_SCHEDULES:
        row = report[schedule_key(s)]
        assert row["schedule"] is s
        assert np.isfinite(row["measured"]["latency_mean_s"])
        print(f"smoke/serving/{schedule_key(s)},0,"
              f"served={int(row['measured']['served'])}"
              f"|traces={row['traces']}"
              f"|est_lat={row['analytical']['latency_us']:.2f}us")


if __name__ == "__main__":
    run()
