# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_latency_resources  Tables 2-4 + Figs 3-5 (HLS model vs paper numbers)
  bench_static_nonstatic   Table 5 + Fig 6 (II 315 -> 1) + measured modes
  bench_quantization       Fig 2 (PTQ AUC-ratio scans)
  bench_throughput         Sec 5.2 (FPGA vs V100 vs measured JAX batching)
  bench_kernels            Pallas kernel correctness + reuse Pareto
  bench_roofline           §Roofline rows from the dry-run artifacts

``--full`` widens sweeps (all 6 tagger models, finer quantization grid).
``--smoke`` is the CI fail-fast path: import every bench module (catching
import-time API drift), then run a minimal KernelSchedule conformance sweep;
exits non-zero on ANY failure instead of swallowing it.
``--json [PATH]`` writes BENCH_rnn_kernels.json — the persistent
hoisted-vs-in-loop perf-regression record (per-schedule wall clock + the
analytical estimate of the same schedule object); wired into
scripts/check.sh so the perf trajectory is tracked every run.  Exits
non-zero if the hoisted acceptance speedup (>= 1.3x on the flavor-tagging
fin~h LSTM) regresses.
"""

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))


def smoke() -> int:
    """Fast import + conformance check; returns a process exit code."""
    t0 = time.time()
    from benchmarks import (bench_autotune, bench_decode,  # noqa: F401
                            bench_kernels, bench_latency_resources,
                            bench_quant, bench_quantization,
                            bench_roofline, bench_serving, bench_spec,
                            bench_static_nonstatic, bench_streaming,
                            bench_throughput, bench_warmup)
    print("smoke/imports,0,ok")

    from repro.kernels.schedule import KernelSchedule
    from repro.testing import assert_schedule_conformance
    for cell in ("lstm", "gru"):
        for sched in KernelSchedule.sweep((1, 4), block_batch=8,
                                          backend="pallas_interpret"):
            err = assert_schedule_conformance(cell, sched, B=3, T=5, F=4, H=8)
            print(f"smoke/{cell}/{sched.mode}/R{sched.reuse_factor},"
                  f"0,max_err={err:.1e}")
    # mixed-schedule serving path: co-batching by schedule hash must
    # bit-match direct predict without retracing (fail-fast, raises)
    bench_serving.smoke()
    print(f"smoke/wall_s,{(time.time()-t0)*1e6:.0f},ok")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="import benches + minimal schedule sweep, fail fast")
    ap.add_argument("--json", nargs="?", const="BENCH_rnn_kernels.json",
                    default=None, metavar="PATH",
                    help="write the hoisted-vs-in-loop perf record + the "
                         "autotune frontier (BENCH_rnn_kernels.json) and "
                         "exit")
    ap.add_argument("--autotune-smoke", action="store_true",
                    help="explorer fail-fast: tiny space, non-empty "
                         "frontier, monotone latency-vs-R (analytical only)")
    ap.add_argument("--decode-smoke", action="store_true",
                    help="decode fail-fast: scheduled-vs-einsum bit-match, "
                         "RNN single-step conformance, batch-1 fast path")
    ap.add_argument("--quant-smoke", action="store_true",
                    help="quantized fail-fast: golden-model conformance "
                         "slice, native-vs-emulation bitwise identity, "
                         "packed-bytes == pricing")
    ap.add_argument("--warmup-smoke", action="store_true",
                    help="zero-warmup fail-fast: fresh engine over a warm "
                         "compile cache must serve its first request with "
                         "zero jit traces, bit-identical; records cold-vs-"
                         "warm first-request latency into the perf JSON")
    ap.add_argument("--spec-smoke", action="store_true",
                    help="speculative-decode fail-fast: the autotuned "
                         "(draft, verify, K) triple must beat the PR 5 "
                         "scheduled R4 decode path in tokens/s with greedy "
                         "exact-match enforced in the same run; measured-vs-"
                         "assumed accept rate rides the perf JSON under "
                         "'speculative'")
    ap.add_argument("--stream-smoke", action="store_true",
                    help="streaming fail-fast: overload replay at 0.5x/1x/2x "
                         "priced throughput; <=1x must never shed, 2x must "
                         "shed and/or downgrade, admitted p99 within "
                         "deadline, exact accounting, full drain; per-stage "
                         "percentiles ride the perf JSON under 'streaming'")
    ap.add_argument("--router-smoke", action="store_true",
                    help="replicated-serving fail-fast: mixed-schedule "
                         "stream at N=1 vs N=3 replicas with a mid-stream "
                         "replica kill; fails on lost/duplicated requests, "
                         "divergence from the single-replica oracle, broken "
                         "accounting, or sim-throughput scaling < 1.6x; "
                         "rides the perf JSON under 'router'")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (e.g. roofline,kernels)")
    args, _ = ap.parse_known_args()

    if args.smoke:
        sys.exit(smoke())

    if args.autotune_smoke:
        from benchmarks import bench_autotune
        bench_autotune.smoke()
        sys.exit(0)

    if args.decode_smoke:
        from benchmarks import bench_decode
        bench_decode.smoke()
        sys.exit(0)

    if args.quant_smoke:
        from benchmarks import bench_quant
        bench_quant.smoke()
        sys.exit(0)

    if args.warmup_smoke:
        from benchmarks import bench_warmup
        bench_warmup.smoke(args.json or "BENCH_rnn_kernels.json")
        sys.exit(0)

    if args.stream_smoke:
        from benchmarks import bench_streaming
        bench_streaming.smoke(args.json or "BENCH_rnn_kernels.json")
        sys.exit(0)

    if args.spec_smoke:
        from benchmarks import bench_spec
        bench_spec.smoke(args.json or "BENCH_rnn_kernels.json")
        sys.exit(0)

    if args.router_smoke:
        from benchmarks import bench_router
        bench_router.smoke(args.json or "BENCH_rnn_kernels.json")
        sys.exit(0)

    if args.json is not None:
        from benchmarks import bench_kernels
        doc = bench_kernels.write_json(args.json, full=args.full)
        acc = doc["acceptance"]
        rank = doc["autotune"]["rank_check"]
        dec = doc["decode"]["acceptance"]
        qnt = doc["quant"]["acceptance"]
        conf = doc["quant"]["conformance"]
        print(f"json/acceptance,{acc['speedup'] * 1e6:.0f},"
              f"speedup={acc['speedup']:.2f}x|passed={acc['passed']}")
        print(f"json/autotune_rank,{rank['spearman'] * 1e6:.0f},"
              f"spearman={rank['spearman']:.3f}|passed={rank['passed']}")
        print(f"json/decode_acceptance,{dec['speedup'] * 1e6:.0f},"
              f"speedup={dec['speedup']:.2f}x|passed={dec['passed']}")
        print(f"json/quant_acceptance,0,"
              f"int4_ratio={qnt['int4_ratio']:.3f}"
              f"|conformance={conf['passed']}|passed={qnt['passed']}")
        sys.exit(0 if acc["passed"] and rank["passed"] and dec["passed"]
                 and qnt["passed"] else 1)

    from benchmarks import (bench_autotune, bench_decode, bench_kernels,
                            bench_latency_resources, bench_quant,
                            bench_quantization, bench_roofline,
                            bench_serving, bench_spec,
                            bench_static_nonstatic, bench_streaming,
                            bench_throughput, bench_warmup)
    benches = {
        "latency_resources": bench_latency_resources,
        "static_nonstatic": bench_static_nonstatic,
        "kernels": bench_kernels,
        "roofline": bench_roofline,
        "quantization": bench_quantization,
        "throughput": bench_throughput,
        "serving": bench_serving,
        "autotune": bench_autotune,
        "decode": bench_decode,
        "quant": bench_quant,
        "warmup": bench_warmup,
        "streaming": bench_streaming,
        "spec": bench_spec,
    }
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            benches[name].run(full=args.full)
            print(f"bench/{name}/wall_s,{(time.time()-t0)*1e6:.0f},ok")
        except Exception as e:  # keep the harness running
            print(f"bench/{name}/ERROR,0,{type(e).__name__}: "
                  f"{str(e)[:160]}")


if __name__ == '__main__':
    main()
