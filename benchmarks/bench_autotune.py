"""Auto-scheduler benchmarks: the chosen Pareto frontier as a persistent
record, plus the predicted-vs-measured sanity loop.

``frontier_record`` (appended to BENCH_rnn_kernels.json by ``run.py
--json``) captures, for the flavor-tagging LSTM:

  * the analytical Pareto frontier (latency_cycles x dsp x bram) the
    explorer reduced the legal space to;
  * per DesignTarget: the selected schedule, its predicted latency, and its
    measured steady-state wall-clock;
  * a rank-correlation check — Spearman rho of predicted latency ordering
    vs measured wall-clock ordering along the static in-loop reuse chain
    (the paper's Fig. 1 axis; interpret-mode wall clock scales with the
    sequential grid length, which is exactly what the estimate prices).
    A non-positive rho means the analytical model no longer sorts real
    schedules correctly and the record FAILS.

``smoke`` is the check.sh fail-fast stage: tiny space, asserts a non-empty
frontier and an analytically monotone latency-vs-R curve (no kernels run).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.autotune import (DesignTarget, SpaceSpec, explore, measure_points,
                            select)
from repro.registry import get_config

CFG_NAME = "flavor-tagging-lstm"

_SPEC = SpaceSpec(reuse_factors=(1, 2, 4, 8), iis=(0, 1),
                  block_batches=(32,), backends=("pallas_interpret",))
_SPEC_FULL = SpaceSpec(reuse_factors=(1, 2, 4, 8, 16), iis=(0, 1, 2),
                       block_batches=(32,), backends=("pallas_interpret",))

#: the paper's three deployment postures as DesignTargets
TARGETS = (
    ("trigger", DesignTarget(max_latency_us=2.0, objective="latency")),
    ("resource-saver", DesignTarget(max_dsp=8000, objective="resources")),
    ("throughput", DesignTarget(min_throughput_eps=1e6,
                                objective="throughput")),
)


def _spearman(a, b) -> float:
    """Rank correlation without scipy (ties broken by position)."""
    a, b = np.asarray(a, float), np.asarray(b, float)
    ra = np.argsort(np.argsort(a)).astype(float)
    rb = np.argsort(np.argsort(b)).astype(float)
    ra -= ra.mean()
    rb -= rb.mean()
    denom = float(np.sqrt((ra ** 2).sum() * (rb ** 2).sum()))
    return float((ra * rb).sum() / denom) if denom else 1.0


def frontier_record(full: bool = False) -> dict:
    """The autotune section of BENCH_rnn_kernels.json."""
    cfg = get_config(CFG_NAME)
    spec = _SPEC_FULL if full else _SPEC
    ex = explore(cfg, spec=spec)
    assert ex.frontier, "explorer returned an empty frontier"

    # the static in-loop reuse chain — the rank-check population — plus
    # every per-target selection, measured in one pass
    chain = sorted((p for p in ex.points
                    if p.schedule.mode == "static"
                    and not p.schedule.hoist_input),
                   key=lambda p: p.schedule.reuse_factor)
    picks = {name: select(cfg, t, spec) for name, t in TARGETS}
    to_measure = {p.key: p for p in chain}
    to_measure.update((p.key, p) for p in picks.values())
    walls = measure_points(cfg, list(to_measure.values()), batch=16, iters=3)

    pred = [p.latency_cycles for p in chain]
    meas = [walls[p.key] for p in chain]
    rho = _spearman(pred, meas)
    rank_check = {
        "population": "static in-loop chain",
        "points": len(chain),
        "predicted_latency_cycles": pred,
        "measured_wall_us": [w * 1e6 for w in meas],
        "spearman": rho,
        "passed": rho > 0.0,
    }

    targets_out = []
    for name, t in TARGETS:
        p = picks[name]
        targets_out.append({
            "name": name,
            "target": t.describe(),
            "selected_key": p.key,
            "predicted_latency_us": p.latency_us(t.clock_mhz),
            "predicted_ii_cycles": p.ii_cycles,
            "predicted_dsp": p.dsp,
            "measured_wall_us": walls[p.key] * 1e6,
        })
        emit(f"autotune/target/{name}", walls[p.key] * 1e6,
             f"key={p.key}|pred_lat_us={p.latency_us(t.clock_mhz):.3f}"
             f"|dsp={p.dsp}")
    emit("autotune/rank_check", rho * 1e6,
         f"spearman={rho:.3f}|points={len(chain)}|passed={rank_check['passed']}")

    return {
        "config": CFG_NAME,
        "space_points": len(ex.points),
        "frontier": [p.report_row() for p in ex.frontier],
        "targets": targets_out,
        "rank_check": rank_check,
    }


def run(full: bool = False):
    frontier_record(full=full)


def smoke() -> None:
    """Fail-fast explorer regression check (analytical only, no kernels):
    non-empty frontier over a tiny space + monotone latency-vs-R."""
    cfg = get_config("top-tagging-lstm")
    spec = SpaceSpec(reuse_factors=(1, 2, 4), backends=("pallas_interpret",))
    ex = explore(cfg, spec=spec)
    assert ex.frontier, "autotune smoke: empty frontier"
    for f in ex.frontier:
        bad = [p.key for p in ex.points if p.dominates(f)]
        assert not bad, f"autotune smoke: {f.key} dominated by {bad}"
    chain = sorted((p for p in ex.points
                    if p.schedule.mode == "static"
                    and not p.schedule.hoist_input),
                   key=lambda p: p.schedule.reuse_factor)
    lats = [p.latency_cycles for p in chain]
    assert lats == sorted(lats) and len(set(lats)) == len(lats), \
        f"autotune smoke: latency not strictly monotone in R: {lats}"
    dsps = [p.dsp for p in chain]
    assert dsps == sorted(dsps, reverse=True), \
        f"autotune smoke: dsp not monotone-decreasing in R: {dsps}"
    # a target must resolve end to end
    pt = select(cfg, DesignTarget(max_dsp=max(dsps) - 1), spec)
    assert pt.dsp < max(dsps)
    emit("autotune/smoke", 0.0,
         f"frontier={len(ex.frontier)}|space={len(ex.points)}"
         f"|selected={pt.key}")


if __name__ == "__main__":
    run()
