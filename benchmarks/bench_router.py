"""Replicated-serving smoke: N=1 vs N=3 scaling + mid-stream replica kill.

The router (``repro.serving.router``) promises three things a trigger farm
lives by: (1) data-parallel scaling — N replicas behind the consistent-hash
ring sustain ~N x one replica's simulated throughput on a mixed-schedule
stream; (2) fault transparency — killing a replica mid-stream loses NOTHING
(every request still reaches exactly one terminal state, answered by a
surviving replica, outputs bit-identical to a single-replica engine); and
(3) exact accounting (``submitted == answered + failed + shed + in_flight``
per key, hedges reconciled, duplicates counted).

This bench replays one deterministic mixed-schedule arrival trace
(round-robin over ~6 schedule keys, overdriven at ~4 x a single replica's
aggregate capacity so BOTH legs are capacity-limited) through three legs:

  * ``n1``     — single replica: the throughput baseline AND the
                 bit-identity oracle;
  * ``n3``     — three healthy replicas: the scaling leg;
  * ``chaos``  — three replicas, one crashed (dead forever) a third of the
                 way in: the failover leg.

``smoke()`` raises (-> scripts/check.sh exits non-zero) if:
  * any request is lost or duplicated (not exactly one terminal state, or
    an answered request with != 1 surfaced result);
  * any chaos-leg answer diverges bit-wise from the n1 oracle;
  * router accounting breaks in any leg;
  * sim-throughput scaling n3/n1 falls below 1.6x.

``record()`` read-modify-writes an EXISTING perf-record JSON under
``doc["router"]`` (run.py --router-smoke runs AFTER --json in check.sh,
whose write_json rebuilds the document from scratch — order load-bearing,
as with warmup/streaming).
"""

import json
import os
import sys
from typing import Dict, List, Optional

import jax
import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.common import emit  # noqa: E402
from repro.autotune import SpaceSpec, enumerate_space  # noqa: E402
from repro.kernels.schedule import schedule_key  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.registry import get_config  # noqa: E402
from repro.serving import ReplicaPool, Router, RouterPolicy  # noqa: E402
from repro.serving.faults import crash_replica  # noqa: E402

SPEC = SpaceSpec(reuse_factors=(1, 2, 4), iis=(0, 1), backends=("xla",))
CLOCK_MHZ = 200.0
N_EVENTS = 240
N_KEYS = 6
OVERDRIVE = 4.0          # arrival rate as a multiple of 1-replica capacity
MIN_SCALING = 1.6
KILL_AT = N_EVENTS // 3


def _harness():
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    # a mixed stream over ~N_KEYS distinct schedule keys: the consistent
    # hash ring spreads KEYS (not single requests) across replicas, so
    # scaling needs key diversity — exactly the production shape
    seen, schedules = set(), []
    for s in enumerate_space(cfg, SPEC):
        k = schedule_key(s)
        if k not in seen:
            seen.add(k)
            schedules.append(s)
        if len(schedules) == N_KEYS:
            break
    r = cfg.rnn
    xs = np.random.RandomState(0).randn(
        N_EVENTS, r.seq_len, r.input_size).astype(np.float32)
    return cfg, params, schedules, xs


def _run_leg(cfg, params, schedules, xs, n_replicas: int,
             kill_at: Optional[int] = None) -> Dict[str, object]:
    pool = ReplicaPool.build(cfg, params, n_replicas)
    router = Router(pool, policy=RouterPolicy(timeout_s=1.0,
                                              consecutive_failures=2,
                                              probe_interval_s=1e9),
                    clock_mhz=CLOCK_MHZ)
    # price one replica's aggregate capacity for THIS mix, then overdrive
    occ = [router._price(schedule_key(*router.reference_engine.resolve(s)),
                         *router.reference_engine.resolve(s))[1]
           for s in schedules]
    dt = float(np.mean(occ)) / OVERDRIVE
    done, t = [], 0.0
    for i, x in enumerate(xs):
        if kill_at is not None and i == kill_at:
            # dead board: every later call on it crashes, including probes
            victim = router.place(done[0].key) if done else pool.reference
            crash_replica(victim)
        done.append(router.submit(x, schedule=schedules[i % len(schedules)],
                                  now=t))
        t += dt
    acc = router.verify_router_accounting()      # raises if inexact
    answered = [r for r in done if r.status == "answered"]
    makespan = max(r.done_s for r in answered) - done[0].arrival_s
    return {
        "replicas": n_replicas,
        "events": len(done),
        "answered": len(answered),
        "failed": sum(1 for r in done if r.status == "failed"),
        "shed": sum(1 for r in done if r.status == "shed"),
        "retries": sum(c["retries"] for c in acc.values()),
        "duplicates": sum(c["duplicates"] for c in acc.values()),
        "re_placements": sum(c["re_placements"] for c in acc.values()),
        "makespan_s": makespan,
        "sim_eps": len(answered) / makespan,
        "healthy_after": router.healthy_count(),
        "events_log": list(router.events),
        "keys": len(acc),
        "results": [r.result for r in done],
        "statuses": [r.status for r in done],
    }


def record(json_path: Optional[str] = None) -> Dict[str, object]:
    """Run the three legs; optionally persist under ``doc["router"]`` of
    an EXISTING perf-record JSON (read-modify-rewrite, never rebuilt)."""
    cfg, params, schedules, xs = _harness()
    n1 = _run_leg(cfg, params, schedules, xs, 1)
    n3 = _run_leg(cfg, params, schedules, xs, 3)
    chaos = _run_leg(cfg, params, schedules, xs, 3, kill_at=KILL_AT)

    scaling = n3["sim_eps"] / n1["sim_eps"]
    identical = all(
        st != "answered" or np.array_equal(res, ref)
        for st, res, ref in zip(chaos["statuses"], chaos["results"],
                                n1["results"]))
    lost = sum(1 for s in chaos["statuses"] if s not in
               ("answered", "failed", "shed"))
    rec = {
        "criterion": f"mixed {len(schedules)}-key stream overdriven at "
                     f"{OVERDRIVE:g}x one replica's capacity: N=3 sustains "
                     f">={MIN_SCALING}x N=1 sim-throughput; killing a "
                     f"replica at event {KILL_AT} loses/duplicates nothing "
                     f"and stays bit-identical to the N=1 oracle; exact "
                     f"accounting in every leg",
        "clock_mhz": CLOCK_MHZ,
        "schedule_keys": [schedule_key(s) for s in schedules],
        "legs": {name: {k: v for k, v in leg.items()
                        if k not in ("results", "statuses")}
                 for name, leg in (("n1", n1), ("n3", n3),
                                   ("chaos", chaos))},
        "scaling_n3_over_n1": scaling,
        "min_scaling": MIN_SCALING,
        "chaos_bit_identical": identical,
        "chaos_lost": lost,
        "passed": (scaling >= MIN_SCALING and identical and lost == 0
                   and chaos["answered"] == N_EVENTS
                   and n1["answered"] == N_EVENTS
                   and n3["answered"] == N_EVENTS),
    }
    if json_path is not None and os.path.exists(json_path):
        with open(json_path) as f:
            doc = json.load(f)
        doc["router"] = rec
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return rec


def smoke(json_path: str = "BENCH_rnn_kernels.json") -> None:
    """Replicated-serving fail-fast: raises unless every bar holds."""
    rec = record(json_path=json_path)
    for name, leg in rec["legs"].items():
        emit(f"router/{name}/sim_eps", leg["sim_eps"],
             f"answered={leg['answered']}/{leg['events']}"
             f"|retries={leg['retries']}|dup={leg['duplicates']}"
             f"|healthy_after={leg['healthy_after']}")
        assert leg["answered"] == leg["events"], \
            (f"{name}: {leg['events'] - leg['answered']} requests not "
             f"answered (failed={leg['failed']}, shed={leg['shed']}) — "
             f"the ladder lost work")
    assert rec["chaos_lost"] == 0, \
        f"chaos leg lost {rec['chaos_lost']} requests to a non-terminal state"
    assert rec["chaos_bit_identical"], \
        "chaos-leg answers diverge from the single-replica oracle"
    chaos = rec["legs"]["chaos"]
    assert chaos["healthy_after"] == 2 and chaos["re_placements"] >= 1, \
        (f"mid-stream kill not absorbed: healthy={chaos['healthy_after']}, "
         f"re_placements={chaos['re_placements']}")
    assert rec["scaling_n3_over_n1"] >= rec["min_scaling"], \
        (f"replica scaling too weak: N=3/N=1 = "
         f"{rec['scaling_n3_over_n1']:.2f}x < {rec['min_scaling']}x — "
         f"placement is clumping keys or occupancy is serialized")
    emit("router/scaling", rec["scaling_n3_over_n1"],
         f"min={rec['min_scaling']}|bit_identical="
         f"{rec['chaos_bit_identical']}|passed={rec['passed']}")
    emit("router/json", 0.0,
         f"recorded={os.path.exists(json_path)}|path={json_path}"
         f"|passed={rec['passed']}")


def run(full: bool = False) -> None:
    del full
    smoke()


if __name__ == "__main__":
    smoke()
