"""Kernel-level benchmarks: correctness deltas vs oracles, the reuse-factor
VMEM/latency Pareto (the paper's resource/latency tradeoff on TPU terms),
and — ``write_json`` — the persistent hoisted-vs-in-loop perf-regression
record (BENCH_rnn_kernels.json, written by ``run.py --json``).

The schedule sweep emits structural numbers (VMEM bytes, sequential grid
length) AND measured wall-clock: interpret-mode timings are dominated by
grid-cell count x streamed block bytes rather than FLOPs, which is exactly
the axis the hoisted/pipelined schedules optimize, so the speedups are
meaningful (and tracked) even on the CPU container."""

from __future__ import annotations

import json
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import FixedPointConfig
from repro.core.hls.resources import estimate_schedule
from repro.kernels import ops, ref
from repro.kernels.reuse_matmul import vmem_bytes
from repro.kernels.schedule import KernelSchedule
from repro.registry import get_config
from repro.testing import assert_schedule_conformance


def run(full: bool = False):
    rng = np.random.RandomState(0)

    # the KernelSchedule sweep: conformance error + latency/DSP derived from
    # the SAME schedule object the kernel just executed (paper Fig. 1 curve)
    rnn = get_config("top-tagging-lstm").rnn
    reuses = (1, 2, 4, 8, 16) if full else (1, 2, 4, 8)
    for sched in KernelSchedule.sweep(reuses, block_batch=8,
                                      backend="pallas_interpret"):
        err = assert_schedule_conformance(
            "lstm", sched, B=4, T=rnn.seq_len, F=rnn.input_size, H=rnn.hidden)
        est = estimate_schedule(sched, rnn)
        emit(f"kernels/schedule/lstm/{sched.mode}/R{sched.reuse_factor}",
             float(est.latency_cycles),
             f"max_err={err:.2e}|ii={est.ii_cycles}|dsp={est.dsp}"
             f"|bram={est.bram_18k}|vmem_bytes={est.vmem_bytes}")

    # correctness deltas (paper benchmark shapes)
    for name, B, T, F, H in (("top", 8, 20, 6, 20),
                             ("flavor", 8, 15, 6, 120),
                             ("quickdraw", 4, 100, 3, 128)):
        xs = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
        W = jnp.asarray(rng.randn(F, 4 * H).astype(np.float32) * .3)
        U = jnp.asarray(rng.randn(H, 4 * H).astype(np.float32) * .3)
        b = jnp.asarray(rng.randn(4 * H).astype(np.float32) * .1)
        err = float(jnp.abs(ops.lstm_scan(xs, W, U, b)
                            - ref.lstm_scan_ref(xs, W, U, b)).max())
        emit(f"kernels/lstm_scan/{name}", 0.0, f"max_err={err:.2e}")

    # reuse-factor Pareto: VMEM working set vs sequential passes
    M, K, N = 128, 512, 256
    for R in (1, 2, 4, 8, 16):
        vb = vmem_bytes(M, K, N, R)
        emit(f"kernels/reuse_matmul/R{R}", float(R),
             f"vmem_bytes={vb}|grid_len={R}"
             f"|analogy=DSPs~1/R, latency~R (paper Tables 2-4)")

    fp = FixedPointConfig(16, 6)
    x = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 4)
    err = float(jnp.abs(ops.fixed_point(x, fp)
                        - ref.fixed_point_ref(x, fp)).max())
    emit("kernels/fixed_point", 0.0, f"max_err={err:.2e}")


# ---------------------------------------------------------------------------
# Persistent perf-regression record: hoisted vs in-loop wall clock + the
# analytical estimate of the SAME schedule object (run.py --json)
# ---------------------------------------------------------------------------


def _time_call(fn, *args, iters: int = 5, **kw) -> float:
    """Steady-state seconds per call (min over iters; first call compiles)."""
    fn(*args, **kw).block_until_ready()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


#: benchmarked shapes: the paper's flavor-tagging LSTM (Table 1) plus its
#: fin~h variant — the regime the hoist targets (per-step FLOPs halve); the
#: acceptance speedup is read off the fin~h config
_JSON_CONFIGS = (
    ("flavor-tagging-lstm", "lstm", 32, 15, 6, 120),
    ("flavor-tagging-lstm-finh", "lstm", 32, 15, 120, 120),
    ("flavor-tagging-gru-finh", "gru", 32, 15, 120, 120),
)


def _sched_variants(reuses):
    """(label, schedule, baseline_label) grid: in-loop baselines first so
    hoisted/pipelined rows can reference their wall-clock."""
    out = []
    for r in reuses:
        kw = dict(reuse_factor=r, block_batch=32,
                  backend="pallas_interpret")
        out.append((f"static-R{r}", KernelSchedule(mode="static", **kw),
                    None))
        out.append((f"nonstatic-R{r}",
                    KernelSchedule(mode="nonstatic", **kw), None))
        out.append((f"static-hoist-R{r}",
                    KernelSchedule(mode="static", hoist_input=True, **kw),
                    f"static-R{r}"))
        out.append((f"nonstatic-hoist-R{r}",
                    KernelSchedule(mode="nonstatic", hoist_input=True, **kw),
                    f"nonstatic-R{r}"))
        # the fused pipelined-NONSTATIC kernel; baseline = the in-loop
        # static scan (the seed's default executor for this R)
        out.append((f"pipeline-R{r}",
                    KernelSchedule(mode="pipeline", **kw), f"static-R{r}"))
    return out


def write_json(path: str = "BENCH_rnn_kernels.json",
               full: bool = False) -> dict:
    """Measure hoisted vs in-loop wall clock for every schedule variant and
    write the perf-trajectory record the acceptance criterion reads.

    Each entry pairs measured seconds with ``estimate_schedule`` of the
    SAME schedule object; hoisted/pipelined entries carry
    ``speedup_vs_inloop`` against their in-loop baseline.
    """
    import dataclasses

    reuses = (1, 2, 4, 8) if full else (1, 4)
    rng = np.random.RandomState(0)
    doc = {"bench": "rnn_kernels", "created_unix": int(time.time()),
           "env": {"backend": "pallas_interpret",
                   "note": "CPU container; interpret wall-clock scales with "
                           "grid cells x streamed block bytes (the axis "
                           "hoisting/pipelining optimizes)"},
           "configs": []}
    acceptance = None
    for name, cell, B, T, F, H in _JSON_CONFIGS:
        base_cfg = get_config(f"flavor-tagging-{cell}").rnn
        rnn = dataclasses.replace(base_cfg, input_size=F, seq_len=T,
                                  hidden=H)
        g = 4 if cell == "lstm" else 3
        xs = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
        W = jnp.asarray(rng.randn(F, g * H).astype(np.float32) * .3)
        U = jnp.asarray(rng.randn(H, g * H).astype(np.float32) * .3)
        bshape = (g * H,) if cell == "lstm" else (2, g * H)
        b = jnp.asarray(rng.randn(*bshape).astype(np.float32) * .1)
        op = ops.SCHEDULED_KERNELS[cell][0]

        wall: dict = {}
        entries = []
        for label, sched, baseline in _sched_variants(reuses):
            secs = _time_call(op, xs, W, U, b, schedule=sched)
            wall[label] = secs
            est = estimate_schedule(sched, rnn)
            entry = {
                "label": label,
                "schedule_key": sched.key(),
                "mode": sched.mode,
                "reuse_factor": sched.reuse_factor,
                "hoisted": sched.hoist_input,
                "wall_us": secs * 1e6,
                "analytical": {
                    "latency_cycles": est.latency_cycles,
                    "ii_cycles": est.ii_cycles,
                    "dsp": est.dsp,
                    "bram_18k": est.bram_18k,
                    "vmem_bytes": est.vmem_bytes,
                },
            }
            if baseline is not None:
                entry["baseline"] = baseline
                entry["speedup_vs_inloop"] = wall[baseline] / secs
            entries.append(entry)
        doc["configs"].append({"name": name, "cell": cell, "B": B, "T": T,
                               "F": F, "H": H, "entries": entries})
        if name == "flavor-tagging-lstm-finh":
            best = max((e for e in entries if e["hoisted"]),
                       key=lambda e: e.get("speedup_vs_inloop", 0.0))
            acceptance = {
                "config": name,
                "criterion": ">= 1.3x wall-clock, hoisted vs in-loop, "
                             "B>=32, fin~h",
                "schedule_key": best["schedule_key"],
                "baseline": best["baseline"],
                "speedup": best["speedup_vs_inloop"],
                "passed": best["speedup_vs_inloop"] >= 1.3,
            }
    doc["acceptance"] = acceptance
    # the chosen Pareto frontier + predicted-vs-measured rank check
    # (per-target selected schedule) rides the same persistent record
    from benchmarks import bench_autotune
    doc["autotune"] = bench_autotune.frontier_record(full=full)
    # the decode path: scheduled weight-resident decode vs the einsum
    # baseline, tokens/s + per-token wall clock (acceptance >= 1.3x at R>1)
    from benchmarks import bench_decode
    doc["decode"] = bench_decode.decode_record(full=full)
    # the quantized datapath: native int8/int4 resident bytes + wall clock,
    # gated by the golden-model conformance slice (run.py --json exits
    # non-zero if the bound is violated)
    from benchmarks import bench_quant
    doc["quant"] = bench_quant.quant_record(full=full)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)
        f.write("\n")
    emit("kernels/json/acceptance_speedup", acceptance["speedup"] * 1e6,
         f"schedule={acceptance['schedule_key']}"
         f"|baseline={acceptance['baseline']}"
         f"|passed={acceptance['passed']}|path={path}")
    return doc


if __name__ == "__main__":
    run()
