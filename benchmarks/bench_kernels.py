"""Kernel-level benchmarks: correctness deltas vs oracles + the reuse-factor
VMEM/latency Pareto (the paper's resource/latency tradeoff on TPU terms).

No wall-clock kernel numbers: this container executes Pallas in interpret
mode (Python), so timing is structural — VMEM bytes and sequential grid
length are the roofline-relevant quantities."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import FixedPointConfig
from repro.core.hls.resources import estimate_schedule
from repro.kernels import ops, ref
from repro.kernels.reuse_matmul import vmem_bytes
from repro.kernels.schedule import KernelSchedule
from repro.registry import get_config
from repro.testing import assert_schedule_conformance


def run(full: bool = False):
    rng = np.random.RandomState(0)

    # the KernelSchedule sweep: conformance error + latency/DSP derived from
    # the SAME schedule object the kernel just executed (paper Fig. 1 curve)
    rnn = get_config("top-tagging-lstm").rnn
    reuses = (1, 2, 4, 8, 16) if full else (1, 2, 4, 8)
    for sched in KernelSchedule.sweep(reuses, block_batch=8,
                                      backend="pallas_interpret"):
        err = assert_schedule_conformance(
            "lstm", sched, B=4, T=rnn.seq_len, F=rnn.input_size, H=rnn.hidden)
        est = estimate_schedule(sched, rnn)
        emit(f"kernels/schedule/lstm/{sched.mode}/R{sched.reuse_factor}",
             float(est.latency_cycles),
             f"max_err={err:.2e}|ii={est.ii_cycles}|dsp={est.dsp}"
             f"|bram={est.bram_18k}|vmem_bytes={est.vmem_bytes}")

    # correctness deltas (paper benchmark shapes)
    for name, B, T, F, H in (("top", 8, 20, 6, 20),
                             ("flavor", 8, 15, 6, 120),
                             ("quickdraw", 4, 100, 3, 128)):
        xs = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
        W = jnp.asarray(rng.randn(F, 4 * H).astype(np.float32) * .3)
        U = jnp.asarray(rng.randn(H, 4 * H).astype(np.float32) * .3)
        b = jnp.asarray(rng.randn(4 * H).astype(np.float32) * .1)
        err = float(jnp.abs(ops.lstm_scan(xs, W, U, b)
                            - ref.lstm_scan_ref(xs, W, U, b)).max())
        emit(f"kernels/lstm_scan/{name}", 0.0, f"max_err={err:.2e}")

    # reuse-factor Pareto: VMEM working set vs sequential passes
    M, K, N = 128, 512, 256
    for R in (1, 2, 4, 8, 16):
        vb = vmem_bytes(M, K, N, R)
        emit(f"kernels/reuse_matmul/R{R}", float(R),
             f"vmem_bytes={vb}|grid_len={R}"
             f"|analogy=DSPs~1/R, latency~R (paper Tables 2-4)")

    fp = FixedPointConfig(16, 6)
    x = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 4)
    err = float(jnp.abs(ops.fixed_point(x, fp)
                        - ref.fixed_point_ref(x, fp)).max())
    emit("kernels/fixed_point", 0.0, f"max_err={err:.2e}")


if __name__ == "__main__":
    run()
