"""Quantized-execution benchmark: native int8/int4 scan kernels vs the
float datapath, gated by the golden-model conformance suite.

``quant_record`` produces the persistent record appended to
BENCH_rnn_kernels.json by ``run.py --json``: per-fp resident packed weight
bytes (MEASURED — ``pack_ints(...).nbytes`` — against the analytical
``packed_weight_bytes``/``estimate_schedule`` pricing, which must agree
exactly) and the steady-state wall-clock of the flavor-tagging LSTM scan
under fp in {float, int8, int4}, plus a ``conformance`` block re-running a
compact (kernel x mode x R x fp) slice of the golden-model suite.  A bound
violation flips ``conformance.passed`` and ``run.py --json`` exits
non-zero on it — perf rows for a datapath that no longer matches its
golden model never land silently.

``smoke`` is the fail-fast CI stage (``run.py --quant-smoke``): the same
conformance slice plus the native-vs-emulation bitwise identity and the
measured-equals-priced packing identity; raises on any violation.
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.hls.resources import estimate_schedule, gate_count
from repro.core.quant.fixed_point import packed_weight_bytes
from repro.kernels.schedule import KernelSchedule
from repro.registry import get_config
from repro.testing import assert_quantized_conformance, native_fp_configs


#: compact conformance slice: every kernel family, both scan modes, the
#: R axis, both native widths — tiny shapes, so the whole slice is fast
_CONF_KERNELS = ("lstm", "gru", "rglru", "reuse_matmul")
_CONF_MODES = ("static", "nonstatic")


def _conformance(full: bool = False) -> Dict:
    """Run the conformance slice; returns the record block (never raises —
    the caller gates on ``passed``)."""
    reuses = (1, 2, 4) if full else (1, 4)
    cells: list = []
    max_err, passed = 0.0, True
    for name, fp in sorted(native_fp_configs().items()):
        for kernel in _CONF_KERNELS:
            for mode in _CONF_MODES:
                if kernel in ("rglru", "reuse_matmul") and mode != "static":
                    continue        # mode is a scan-cell axis only
                for r in reuses:
                    sched = KernelSchedule(reuse_factor=r, mode=mode,
                                           block_batch=8,
                                           backend="pallas_interpret")
                    cell = {"kernel": kernel, "mode": mode, "reuse": r,
                            "fp": name}
                    try:
                        err = assert_quantized_conformance(kernel, sched, fp)
                        cell.update(max_err=err, ok=True)
                        max_err = max(max_err, err)
                    except AssertionError as e:
                        cell.update(ok=False, error=str(e)[:200])
                        passed = False
                    cells.append(cell)
    return {"criterion": "every (kernel x mode x R x fp) cell within "
                         "2x fixed_point_error_bound of its numpy integer "
                         "golden model",
            "cells": len(cells), "max_err": max_err, "passed": passed,
            "failures": [c for c in cells if not c["ok"]]}


def _scan_inputs(rnn, seed: int = 0):
    g = gate_count(rnn.cell)
    rng = np.random.RandomState(seed)
    xs = jnp.asarray(rng.randn(8, rnn.seq_len, rnn.input_size)
                     .astype(np.float32))
    W = jnp.asarray(rng.randn(rnn.input_size, g * rnn.hidden)
                    .astype(np.float32) * .3)
    U = jnp.asarray(rng.randn(rnn.hidden, g * rnn.hidden)
                    .astype(np.float32) * .3)
    b = jnp.asarray(rng.randn(g * rnn.hidden).astype(np.float32) * .1)
    return xs, W, U, b


def _time_scan(fn, *args, iters: int = 10) -> float:
    jax.block_until_ready(fn(*args))        # compile
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def quant_record(full: bool = False) -> Dict:
    """The quantized-execution record: resident packed bytes + wall-clock
    per fp on the flavor-tagging LSTM scan, gated by conformance."""
    from repro.kernels import ops
    from repro.kernels.quantized import pack_ints

    cfg = get_config("flavor-tagging-lstm")
    rnn = cfg.rnn
    g = gate_count(rnn.cell)
    iters = 10 if full else 5
    xs, W, U, b = _scan_inputs(rnn)
    sched = KernelSchedule(reuse_factor=2, block_batch=8,
                           backend="pallas_interpret")

    record = {
        "bench": "quantized_scan",
        "config": {"arch": "flavor-tagging-lstm", "cell": rnn.cell,
                   "input_size": rnn.input_size, "hidden": rnn.hidden,
                   "seq_len": rnn.seq_len, "batch": int(xs.shape[0]),
                   "schedule_key": sched.key()},
        "entries": [],
        "conformance": _conformance(full=full),
    }

    variants = [("float", None)] + sorted(native_fp_configs().items())
    float_bytes = None
    for label, fp in variants:
        secs = _time_scan(
            lambda a, w, u, bb, _fp=fp: ops.lstm_scan(
                a, w, u, bb, schedule=sched, fp=_fp),
            xs, W, U, b, iters=iters)
        priced = (packed_weight_bytes(rnn.input_size, g * rnn.hidden, fp)
                  + packed_weight_bytes(rnn.hidden, g * rnn.hidden, fp))
        if fp is None:
            measured = int(W.nbytes + U.nbytes)
            float_bytes = priced
        else:
            measured = int(pack_ints(W, fp).nbytes + pack_ints(U, fp).nbytes)
        est = estimate_schedule(sched, cfg.rnn, fp)
        entry = {
            "label": label,
            "fp": None if fp is None else
                  f"ap_fixed<{fp.total_bits},{fp.integer_bits}>",
            "scan_us": secs * 1e6,
            "resident_weight_bytes": measured,
            "priced_weight_bytes": priced,
            "packing_matches_pricing": measured == priced,
            "bytes_vs_float": priced / float_bytes,
            "analytical": {"bram_18k": est.bram_18k,
                           "vmem_bytes": est.vmem_bytes,
                           "weight_vmem_bytes": est.weight_vmem_bytes},
        }
        record["entries"].append(entry)

    by = {e["label"]: e for e in record["entries"]}
    record["acceptance"] = {
        "criterion": "int4 resident weight bytes <= 1/4 of float, int8 <= "
                     "1/2, measured packing == analytical pricing, "
                     "conformance slice passes",
        "int4_ratio": by["int4"]["bytes_vs_float"],
        "int8_ratio": by["int8"]["bytes_vs_float"],
        "passed": (record["conformance"]["passed"]
                   and by["int4"]["bytes_vs_float"] <= 0.25
                   and by["int8"]["bytes_vs_float"] <= 0.5
                   and all(e["packing_matches_pricing"]
                           for e in record["entries"])),
    }
    return record


# ---------------------------------------------------------------------------
# Fail-fast CI stage
# ---------------------------------------------------------------------------


def smoke() -> None:
    """Quant smoke: the conformance slice (raises on any bound violation),
    the native-vs-emulation bitwise identity on a tiny LSTM, and the
    measured-equals-priced packing identity."""
    from repro.config import FixedPointConfig
    from repro.kernels import ops
    from repro.kernels.quantized import pack_ints
    from repro.testing import make_quantized_inputs

    conf = _conformance(full=False)
    if not conf["passed"]:
        raise AssertionError(
            f"quantized conformance bound violated in {len(conf['failures'])}"
            f" cell(s): {conf['failures'][0]['error']}")
    emit("quant/smoke/conformance", 0.0,
         f"cells={conf['cells']}|max_err={conf['max_err']:.1e}")

    # native int datapath must be bit-identical to the f32 emulation on
    # PTQ'd weights — a wall-clock win must never come from different math
    sched = KernelSchedule(reuse_factor=2, block_batch=8,
                           backend="pallas_interpret")
    for name, fp in sorted(native_fp_configs().items()):
        xs, W, U, b = make_quantized_inputs("lstm", fp, B=3, T=5, F=4, H=8)
        native = np.asarray(ops.lstm_scan(xs, W, U, b, schedule=sched, fp=fp))
        emu = np.asarray(ops._emulated_scan_jit(xs, W, U, b, cell="lstm",
                                                fp=fp))
        assert bool((native == emu).all()), \
            f"native {name} scan diverged bitwise from the fp emulation"
        emit(f"quant/smoke/{name}_bitmatch", 0.0, "ok")

    # packed bytes: measured == priced for both widths + the float baseline
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(21, 32).astype(np.float32))
    for fp, want in ((FixedPointConfig(8, 3), 21 * 32),
                     (FixedPointConfig(4, 2), 11 * 32)):
        got = pack_ints(w, fp).nbytes
        assert got == want == packed_weight_bytes(21, 32, fp), \
            f"packing bytes {got} != priced {packed_weight_bytes(21, 32, fp)}"
    emit("quant/smoke/packing_bytes", 0.0, "ok")


def run(full: bool = False):
    rec = quant_record(full=full)
    for e in rec["entries"]:
        emit(f"quant/{e['label']}", e["scan_us"],
             f"bytes={e['resident_weight_bytes']}"
             f"|vs_float={e['bytes_vs_float']:.2f}"
             f"|bram={e['analytical']['bram_18k']}")
    c = rec["conformance"]
    emit("quant/conformance", 0.0,
         f"cells={c['cells']}|max_err={c['max_err']:.1e}|passed={c['passed']}")
    a = rec["acceptance"]
    emit("quant/acceptance", 0.0,
         f"int4_ratio={a['int4_ratio']:.3f}|passed={a['passed']}")


if __name__ == "__main__":
    run()
