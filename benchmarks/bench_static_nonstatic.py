"""Paper Table 5 + Fig. 6: static vs non-static — II, latency, resources
(analytical), plus measured XLA wall-clock for both execution modes."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, time_fn
from repro.config import FixedPointConfig
from repro.core.hls import (RNNDesignPoint, design_point_for_schedule,
                            estimate_design)
from repro.kernels.schedule import KernelSchedule
from repro.models import build_model
from repro.registry import get_config
from repro.serving import RNNServingEngine

PAPER_T5 = {"static": {"ii": 315, "lat": (1.7, 1.7)},
            "nonstatic": {"ii": 1, "lat": (1.6, 1.6)}}


def run(full: bool = False):
    cfg = get_config("top-tagging-gru")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    for mode in ("static", "nonstatic"):
        sched = KernelSchedule(mode=mode)
        d = estimate_design(design_point_for_schedule(
            cfg, sched, FixedPointConfig(10, 6), strategy="latency"))
        p = PAPER_T5[mode]
        emit(f"table5/{mode}", d.latency_min_us,
             f"ii={d.ii_cycles}|paper_ii={p['ii']}"
             f"|latency={d.latency_min_us:.2f}us|paper={p['lat'][0]}us"
             f"|tput={d.throughput_eps:.0f}eps|dsp={d.dsp}|fits={d.fits}")

        # measured wall clock (XLA CPU; structural comparison of modes)
        eng = RNNServingEngine(cfg, params, mode=mode)
        eng.warmup()
        b = eng.benchmark(batch=1, iters=20)
        emit(f"table5/{mode}/measured_batch1", b["latency_s"] * 1e6,
             f"throughput={b['throughput_eps']:.0f}eps")

    # Fig 1 latency-resource curve: one schedule object sweeps it, and the
    # same object is what kernels/ops.py executes on TPU.  R values are
    # divisors of the GRU gate dim (3h = 60) so effective reuse == R —
    # the same hls4ml-style values the paper's Table 2 sweeps
    for sched in KernelSchedule.sweep((1, 2, 6, 12, 30)):
        d = estimate_design(design_point_for_schedule(
            cfg, sched, FixedPointConfig(16, 6)))
        emit(f"fig1/{sched.mode}/R{sched.reuse_factor}", d.latency_min_us,
             f"dsp={d.dsp}|lut={d.lut}|bram={d.bram_18k}|ii={d.ii_cycles}"
             f"|fits={d.fits}")

    # Fig 6: resource blowup of nonstatic vs static across widths
    for W in (10, 14, 18):
        ds = estimate_design(RNNDesignPoint(
            cfg, FixedPointConfig(W, 6), strategy="latency", mode="static"))
        dn = estimate_design(RNNDesignPoint(
            cfg, FixedPointConfig(W, 6), strategy="latency",
            mode="nonstatic"))
        emit(f"fig6/W{W}", 0.0,
             f"static_dsp={ds.dsp}|nonstatic_dsp={dn.dsp}"
             f"|static_lut={ds.lut}|nonstatic_lut={dn.lut}"
             f"|static_fits={ds.fits}|nonstatic_fits={dn.fits}"
             f"|resource_ratio={dn.lut/max(ds.lut,1):.1f}x")


if __name__ == "__main__":
    run()
