"""Speculative-decode smoke: the autotuned (draft, verify, K) triple must
beat the PR 5 scheduled R4 decode path in tokens/s — with greedy exact-match
enforced in the same run.

Three engines over the SAME model and request stream:

  1. baseline — the PR 5 scheduled path (static R4, sequential one token
     per tick): the tokens/s bar speculation has to clear;
  2. reference — sequential decode on the SELECTED verify schedule: the
     exactness oracle (speculation on verify schedule S is bit-identical
     to sequential decode on S, by the exact greedy-match invariant);
  3. speculative — ``select_speculative``'s analytic pick wired through
     ``LMServingEngine(spec=...)``.

``smoke()`` raises (-> scripts/check.sh exits non-zero) if speculation is
slower than the R4 baseline, if its token sequences diverge bitwise from
the sequential reference, or if the drafted == accepted + rejected
accounting breaks.  ``record()`` read-modify-writes the measurement under
``doc["speculative"]`` of an EXISTING perf JSON (run AFTER --json, which
rebuilds the document — check.sh order is load-bearing), pairing the
MEASURED accept rate with the rate ``estimate_speculative`` assumed.
"""

import dataclasses
import json
import os
import sys
from typing import Dict, List, Optional

import jax
import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.common import emit  # noqa: E402
from repro.autotune import SpaceSpec, select_speculative  # noqa: E402
from repro.kernels.schedule import KernelSchedule  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.registry import get_config  # noqa: E402
from repro.serving import LMServingEngine, SpecConfig  # noqa: E402
from repro.testing import tiny_config  # noqa: E402

ASSUMED_ACCEPT = 0.75


def _prompts(vocab: int) -> List[List[int]]:
    """Short, somewhat repetitive prompts (trigger-stream flavor): greedy
    decode on the tiny small-vocab model settles into cycles the n-gram
    table learns — the steady state speculation is priced for."""
    rng = np.random.RandomState(7)
    a, b, c = (int(t) for t in rng.randint(0, vocab, size=3))
    return [[a, b, a, b], [b, c, b], [a, c, a, c]]


def _run_engine(cfg, params, prompts, max_new: int,
                schedule: Optional[KernelSchedule],
                spec: Optional[SpecConfig]) -> Dict[str, object]:
    eng = LMServingEngine(cfg, params, max_batch=len(prompts) + 1,
                          max_seq=256, schedule=schedule, spec=spec)
    ids = [eng.add_request(list(p), max_new=max_new) for p in prompts]
    out = eng.run_to_completion(max_ticks=4096)
    key = eng.keys()[0]
    rep = eng.serve_report()[key]
    res = {"key": key,
           "tokens_per_s": rep["measured"]["tokens_per_s"],
           "tokens": [list(out[i]) for i in ids],
           "traces": rep["traces"]}
    if spec is not None:
        res["accounting"] = eng.verify_spec_accounting()[key]
        res["accept_rate"] = rep["accept_rate"]
        res["draft_traces"] = rep["draft_traces"]
    return res


def record(json_path: Optional[str] = None) -> Dict[str, object]:
    # small vocab: greedy decode on the random-init model locks into its
    # cycle quickly, so most of the stream is the repetitive steady state
    # an n-gram speculator is built for (trigger streams, log-like text)
    cfg = dataclasses.replace(tiny_config(get_config("stablelm-3b")),
                              vocab_size=32)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompts = _prompts(cfg.vocab_size)
    # long enough that the post-transient cycle dominates the measurement
    max_new = 160

    # the PR 5 scheduled baseline: static R4, one token per sequential tick
    base_sched = KernelSchedule(reuse_factor=4, block_batch=8,
                                backend="pallas_interpret")

    # price-then-measure: the analytic ranking (at the ASSUMED accept rate)
    # proposes the top-k triples, a short real-engine run re-ranks them —
    # the measured accept rate, not the assumption, picks the final K
    def measure_fn(p):
        sc = SpecConfig(k=p.k, draft=p.draft)
        return _run_engine(cfg, params, prompts, 48, p.verify,
                           sc)["tokens_per_s"]

    point = select_speculative(cfg, None,
                               SpaceSpec(backends=("pallas_interpret",)),
                               ks=(2, 3, 4), accept_rate=ASSUMED_ACCEPT,
                               measure_fn=measure_fn, measure_top_k=3)
    spec_cfg = SpecConfig(k=point.k, draft=point.draft)

    baseline = _run_engine(cfg, params, prompts, max_new, base_sched, None)
    reference = _run_engine(cfg, params, prompts, max_new, point.verify, None)
    spec = _run_engine(cfg, params, prompts, max_new, point.verify, spec_cfg)

    bit_identical = spec["tokens"] == reference["tokens"]
    speedup = (spec["tokens_per_s"]
               / max(baseline["tokens_per_s"], 1e-12))
    acc = spec["accounting"]
    exact_sum = acc["drafted"] == acc["accepted"] + acc["rejected"]
    rec = {
        "criterion": "autotuned speculative triple beats the PR 5 scheduled "
                     "R4 decode path in tokens/s, token sequences "
                     "bit-identical to sequential decode on the verify "
                     "schedule, drafted == accepted + rejected",
        "selected": point.key,
        "analytical": point.report_row(),
        "assumed_accept_rate": ASSUMED_ACCEPT,
        "measured_accept_rate": spec["accept_rate"],
        "baseline": {k: baseline[k] for k in
                     ("key", "tokens_per_s", "traces")},
        "sequential_verify": {k: reference[k] for k in
                              ("key", "tokens_per_s", "traces")},
        "speculative": {k: spec[k] for k in
                        ("key", "tokens_per_s", "traces", "draft_traces",
                         "accept_rate", "accounting")},
        "speedup_vs_baseline": speedup,
        "bit_identical": bit_identical,
        "passed": bool(speedup > 1.0 and bit_identical and exact_sum),
    }
    if json_path is not None and os.path.exists(json_path):
        with open(json_path) as f:
            doc = json.load(f)
        doc["speculative"] = rec
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return rec


def smoke(json_path: str = "BENCH_rnn_kernels.json") -> None:
    """Speculative fail-fast: slower-than-baseline or bitwise divergence
    (or broken accounting) raises -> check.sh exits non-zero."""
    rec = record(json_path=json_path)
    acc = rec["speculative"]["accounting"]
    emit("spec/selected", 0.0, rec["selected"])
    emit("spec/baseline_tokens_per_s", rec["baseline"]["tokens_per_s"], "R4")
    emit("spec/sequential_verify_tokens_per_s",
         rec["sequential_verify"]["tokens_per_s"],
         rec["sequential_verify"]["key"])
    emit("spec/speculative_tokens_per_s",
         rec["speculative"]["tokens_per_s"],
         f"speedup_vs_baseline={rec['speedup_vs_baseline']:.2f}x"
         f"|bit_identical={rec['bit_identical']}")
    emit("spec/accept_rate",
         0.0 if rec["measured_accept_rate"] is None
         else rec["measured_accept_rate"],
         f"assumed={rec['assumed_accept_rate']}"
         f"|drafted={acc['drafted']}|accepted={acc['accepted']}"
         f"|rejected={acc['rejected']}")
    assert rec["bit_identical"], \
        ("speculative token sequences diverged from sequential decode on "
         "the verify schedule — the exact greedy-match invariant broke")
    assert acc["drafted"] == acc["accepted"] + acc["rejected"], \
        f"speculative accounting broken: {acc}"
    assert rec["speedup_vs_baseline"] > 1.0, \
        (f"speculation is SLOWER than the PR 5 scheduled R4 baseline: "
         f"{rec['speculative']['tokens_per_s']:.1f} vs "
         f"{rec['baseline']['tokens_per_s']:.1f} tokens/s "
         f"(accept_rate={rec['measured_accept_rate']})")
    emit("spec/json", 0.0,
         f"recorded={os.path.exists(json_path)}|path={json_path}")


def run(full: bool = False) -> None:
    del full
    smoke()


if __name__ == "__main__":
    smoke()
