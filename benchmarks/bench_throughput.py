"""Paper Sec. 5.2: QuickDraw throughput — FPGA design points vs GPU batching.

Reproduces the paper's comparison table: FPGA II-derived throughput
(4300-9700 ev/s, batch-1) vs Nvidia V100 at batch {1, 10, 100}
(660 / 7700 / 30000 ev/s), plus THIS machine's measured JAX throughput at
the same batch sizes (CPU container — the batching trend is the point).
"""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, train_tagger
from repro.config import FixedPointConfig
from repro.core.hls import RNNDesignPoint, estimate_design
from repro.core.hls.design import V100_THROUGHPUT_EPS
from repro.serving import RNNServingEngine


def run(full: bool = False):
    cfg, m, params = train_tagger("quickdraw-lstm", steps=60, n=600)
    eng = RNNServingEngine(cfg, params)
    eng.warmup()

    # FPGA model: the paper's R sweep -> II -> events/s
    for rk, rr in ((48, 32), (96, 64), (192, 128), (384, 384)):
        d = estimate_design(RNNDesignPoint(
            cfg, FixedPointConfig(26, 10), rk, rr, part="u250"))
        emit(f"throughput/fpga_R{rk}_{rr}", d.latency_min_us,
             f"fpga_eps={d.throughput_eps:.0f}|paper_range=4300-9700")

    # paper's GPU reference + our measured batching curve
    for batch in (1, 10, 100):
        b = eng.benchmark(batch=batch, iters=5)
        emit(f"throughput/jax_batch{batch}", b["latency_s"] * 1e6,
             f"measured_eps={b['throughput_eps']:.0f}"
             f"|paper_v100_eps={V100_THROUGHPUT_EPS[batch]:.0f}")


if __name__ == "__main__":
    run()
