"""Paper Fig. 2: AUC(quantized)/AUC(float) vs fractional bits at fixed
integer bits {6, 8, 10, 12}, post-training quantization."""

from __future__ import annotations

import numpy as np

from benchmarks.common import dataset_for, emit, time_fn, train_tagger
from repro.core.quant.ptq import auc_scan
from repro.models import rnn_tagger


def run(full: bool = False):
    archs = ["top-tagging-gru", "top-tagging-lstm", "flavor-tagging-gru"]
    if full:
        archs += ["flavor-tagging-lstm", "quickdraw-gru", "quickdraw-lstm"]
    frac_bits = tuple(range(0, 15, 2)) if full else (0, 2, 4, 6, 8, 10, 14)
    int_bits = (6, 8, 10, 12)

    for arch in archs:
        cfg, m, params = train_tagger(
            arch, steps=120 if "quickdraw" in arch else 150,
            n=1200 if "quickdraw" in arch else 1500)
        x, y = dataset_for(arch)(1000, seed=99)
        scan = auc_scan(cfg, rnn_tagger.forward, params, x, y,
                        integer_bits=int_bits, fractional_bits=frac_bits)
        for ib, curve in scan.items():
            ratios = {fb: r for fb, r in curve}
            # paper claim: >=10 fractional bits recovers ~float AUC
            hi = ratios.get(10, ratios[max(ratios)])
            hi = max(ratios[fb] for fb in ratios if fb >= 10) \
                if any(fb >= 10 for fb in ratios) else hi
            derived = ";".join(f"f{fb}:{r:.4f}" for fb, r in curve)
            emit(f"fig2/{arch}/int{ib}", 0.0,
                 f"auc_ratio_at_hi_frac={hi:.4f}|{derived}")


if __name__ == "__main__":
    run()
