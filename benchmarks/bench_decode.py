"""Decode-path benchmark: scheduled, weight-resident decode vs the einsum
baseline (the PR-4 decode path), plus the batch-1 RNN latency fast path.

``decode_record`` produces the persistent tokens/s record appended to
BENCH_rnn_kernels.json by ``run.py --json``: per-token wall-clock and
tokens/s of the jitted LM decode step under each schedule variant, against
the unscheduled einsum step on the SAME params/cache — with a bit-match
check on the logits so a speedup can never come from computing something
else.  The acceptance criterion (>= 1.3x tokens/s at R > 1) reads off the
best scheduled Pallas variant.

Where the speedup comes from (all schedule-driven, all bit-identical):
q|k|v and MLP gate|up fused into single [B, d] @ [d, G*h] matmuls, the
layer loop unrolled over pre-sliced weight-resident layouts instead of a
``lax.scan`` dynamic-slicing stacked arrays per token, and the packed
layout derived ONCE per (params, schedule key) outside the per-token
program (kernels' weight-residency cache).

``smoke`` is the fail-fast CI stage: tiny-model scheduled-vs-einsum
bit-match + single-step RNN decode conformance + batch-1 fast path
bit-match; raises on any mismatch.
"""

from __future__ import annotations

import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.hls.resources import estimate_lm_decode
from repro.kernels.schedule import KernelSchedule
from repro.registry import get_config
from repro.testing import tiny_config


#: the bench config: a dense decoder large enough that the per-token step is
#: matmul-dominated (the regime the fusion/residency restructure targets) yet
#: CPU-container friendly
def _bench_cfg():
    cfg = tiny_config(get_config("stablelm-3b"))
    return cfg.replace(d_model=256, n_layers=4, vocab_size=4096, d_ff=512,
                       n_heads=8, n_kv_heads=8, head_dim=32)


def _setup(cfg, B: int, S: int):
    from repro.models import build_model
    from repro.models.decode import cache_specs

    params = build_model(cfg).init(jax.random.PRNGKey(0))
    specs = cache_specs(cfg, B, S, "float32")
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    toks = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), S // 2, jnp.int32)   # steady-state cache occupancy
    return params, cache, toks, pos


def _time_step(fn, *args, iters: int = 20) -> float:
    """Steady-state seconds per decode step (min over iters; first call
    compiles).  The cache is NOT donated here so every call sees identical
    inputs."""
    jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def decode_record(full: bool = False) -> Dict:
    """The decode-path perf record: scheduled vs einsum, tokens/s."""
    from repro.models.decode import decode_step, pack_decode_params

    cfg = _bench_cfg()
    B, S = 4, 128
    iters = 20 if full else 10
    params, cache, toks, pos = _setup(cfg, B, S)

    base = jax.jit(lambda p, c, t, q: decode_step(cfg, p, c, t, q))
    base_s = _time_step(base, params, cache, toks, pos, iters=iters)
    logits0 = np.asarray(base(params, cache, toks, pos)[0])

    record = {
        "bench": "lm_decode_step",
        "config": {"d_model": cfg.d_model, "n_layers": cfg.n_layers,
                   "d_ff": cfg.d_ff, "vocab": cfg.vocab_size,
                   "batch": B, "cache_len": S},
        "baseline": {"label": "einsum", "step_us": base_s * 1e6,
                     "us_per_token": base_s * 1e6 / B,
                     "tokens_per_s": B / base_s},
        "entries": [],
    }

    reuses = (1, 2, 4, 8) if full else (1, 2, 4)
    variants = [(f"sched-R{r}-pallas",
                 KernelSchedule(reuse_factor=r, block_batch=8,
                                backend="pallas_interpret"))
                for r in reuses]
    variants.append(("sched-R4-xla",
                     KernelSchedule(reuse_factor=4, block_batch=8,
                                    backend="xla")))

    best = None
    for label, sched in variants:
        packed = pack_decode_params(cfg, params, sched)
        fn = jax.jit(lambda p, pk, c, t, q, _s=sched: decode_step(
            cfg, p, c, t, q, schedule=_s, packed=pk))
        # a speedup must never come from computing something else
        logits1 = np.asarray(fn(params, packed, cache, toks, pos)[0])
        bitmatch = bool((logits0 == logits1).all())
        secs = _time_step(fn, params, packed, cache, toks, pos, iters=iters)
        est = estimate_lm_decode(sched, cfg)
        entry = {
            "label": label,
            "schedule_key": sched.key(),
            "reuse_factor": sched.reuse_factor,
            "backend": sched.backend,
            "step_us": secs * 1e6,
            "us_per_token": secs * 1e6 / B,
            "tokens_per_s": B / secs,
            "speedup_vs_einsum": base_s / secs,
            "bitmatch": bitmatch,
            "analytical": {
                "latency_cycles": est.latency_cycles,
                "ii_cycles": est.ii_cycles,
                "dsp": est.dsp,
                "bram_18k": est.bram_18k,
            },
        }
        record["entries"].append(entry)
        scheduled_r_gt1 = (sched.reuse_factor > 1
                           and sched.backend != "xla")
        if scheduled_r_gt1 and bitmatch and (
                best is None or entry["speedup_vs_einsum"]
                > best["speedup_vs_einsum"]):
            best = entry

    record["acceptance"] = {
        "criterion": ">= 1.3x tokens/s, scheduled weight-resident decode "
                     "at R > 1 vs the einsum decode, bit-matched",
        "schedule_key": None if best is None else best["schedule_key"],
        "speedup": 0.0 if best is None else best["speedup_vs_einsum"],
        "passed": best is not None and best["speedup_vs_einsum"] >= 1.3,
    }
    return record


# ---------------------------------------------------------------------------
# Fail-fast CI stage
# ---------------------------------------------------------------------------


def smoke() -> None:
    """Decode smoke: scheduled-vs-einsum bit-match on the tiny model, RNN
    single-step conformance, batch-1 fast path bit-match.  Raises on any
    divergence."""
    from repro.core.rnn.cells import initial_state
    from repro.kernels.decode_step import rnn_decode_step
    from repro.models import build_model, rnn_tagger
    from repro.models.decode import cache_specs, decode_step, \
        pack_decode_params
    from repro.models.init import init_params
    from repro.serving.engine import RNNServingEngine

    # scheduled LM decode bit-match (tiny model, one step, R=2)
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    specs = cache_specs(cfg, 2, 16, "float32")
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    toks = jnp.asarray([[3], [5]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    sched = KernelSchedule(reuse_factor=2, block_batch=8,
                           backend="pallas_interpret")
    l0, _ = decode_step(cfg, params, dict(cache), toks, pos)
    l1, _ = decode_step(cfg, params, dict(cache), toks, pos, schedule=sched,
                        packed=pack_decode_params(cfg, params, sched))
    assert bool((np.asarray(l0) == np.asarray(l1)).all()), \
        "scheduled LM decode diverged from the einsum path"
    emit("decode/smoke/lm_bitmatch", 0.0, "ok")

    # RNN single-step decode conformance (both cells, R=4)
    rng = np.random.RandomState(0)
    for cell, g in (("lstm", 4), ("gru", 3)):
        H, F = 8, 4
        W = jnp.asarray(rng.randn(F, g * H).astype(np.float32) * .3)
        U = jnp.asarray(rng.randn(H, g * H).astype(np.float32) * .3)
        bshape = (g * H,) if cell == "lstm" else (2, g * H)
        b = jnp.asarray(rng.randn(*bshape).astype(np.float32) * .1)
        x = jnp.asarray(rng.randn(3, F).astype(np.float32))
        st = initial_state(cell, 3, H)
        h1, _ = rnn_decode_step(cell, x, st, W, U, b, schedule=sched)
        h0, _ = rnn_decode_step(cell, x, st, W, U, b)
        assert bool((np.asarray(h1) == np.asarray(h0)).all()), \
            f"{cell} decode step diverged under {sched.key()}"
        emit(f"decode/smoke/rnn_{cell}_bitmatch", 0.0, "ok")

    # batch-1 fast path bit-match vs batched predict
    tcfg = get_config("top-tagging-lstm")
    tparams = init_params(jax.random.PRNGKey(0),
                          rnn_tagger.param_specs(tcfg))
    eng = RNNServingEngine(tcfg, tparams, impl="pallas", max_batch=8)
    xr = rng.randn(tcfg.rnn.seq_len, tcfg.rnn.input_size).astype(np.float32)
    one = eng.predict_one(xr, schedule=sched)
    assert bool((one == eng.predict(xr[None], schedule=sched)[0]).all()), \
        "predict_one diverged from batched predict"
    emit("decode/smoke/fast_path_bitmatch", 0.0, "ok")


def run(full: bool = False):
    rec = decode_record(full=full)
    b = rec["baseline"]
    emit("decode/einsum", b["step_us"], f"tokens_per_s={b['tokens_per_s']:.0f}")
    for e in rec["entries"]:
        emit(f"decode/{e['label']}", e["step_us"],
             f"tokens_per_s={e['tokens_per_s']:.0f}"
             f"|speedup={e['speedup_vs_einsum']:.2f}x"
             f"|bitmatch={e['bitmatch']}|ii={e['analytical']['ii_cycles']}")
    a = rec["acceptance"]
    emit("decode/acceptance", a["speedup"] * 1e6,
         f"schedule={a['schedule_key']}|passed={a['passed']}")


if __name__ == "__main__":
    run()
