"""Roofline report from the dry-run artifacts (EXPERIMENTS.md §Roofline).
One row per (arch x shape) cell on the single-pod production mesh."""

from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.launch.roofline import analyze_record

CANDIDATES = ("results/dryrun_v3.json", "results/dryrun_v2.json",
              "results/dryrun_baseline.json")


def run(full: bool = False):
    path = next((p for p in CANDIDATES if os.path.exists(p)), None)
    if path is None:
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --all "
             "--mesh single_pod --out results/dryrun_v2.json")
        return
    recs = json.load(open(path))
    for rec in recs:
        name = f"roofline/{rec.get('arch')}/{rec.get('shape')}"
        if "skipped" in rec:
            emit(name, 0.0, f"skipped({rec['skipped'][:50]})")
            continue
        if "error" in rec:
            emit(name, 0.0, f"ERROR({rec['error'][:60]})")
            continue
        r = analyze_record(rec)
        step_us = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        emit(name, step_us,
             f"dominant={r['dominant']}|compute={r['compute_s']:.3g}s"
             f"|memory={r['memory_s']:.3g}s"
             f"|collective={r['collective_s']:.3g}s"
             f"|model_hlo_ratio={r['useful_ratio']:.2f}"
             f"|roofline_frac={r['roofline_fraction']:.3f}"
             f"|peak={r['peak_gib']:.1f}GiB|fits={r['fits_hbm']}")


if __name__ == "__main__":
    run()
