"""Zero-warmup serving smoke: cold-vs-warm first-request latency.

The persistent compile cache (``repro.serving.compile_cache``) promises that
a FRESH engine pointed at a warm cache directory answers its very first
request without a single jit trace — the compiled executable is deserialized
from disk, not rebuilt.  This bench measures that promise on both serving
paths and records it in BENCH_rnn_kernels.json:

  1. RNN path: a cold engine serves one padded batch (compiling + storing
     the executable), then a brand-new engine over the SAME cache dir serves
     the same traffic.  The warm engine must report ``trace_count == 0`` and
     ``cold_compiles == 0`` for the key, and its outputs must be
     bit-identical to the cold engine's.
  2. LM path: same protocol for the keyed decode step (greedy tokens must
     match exactly).

``smoke()`` raises (-> scripts/check.sh exits non-zero) if the warm path
still compiles; ``record()`` returns the measurement dict and, when the
perf-record JSON already exists, read-modify-writes it under ``"warmup"``
(run.py --warmup-smoke runs AFTER --json, whose write_json rebuilds the
document from scratch — the order in check.sh is load-bearing).
"""

import json
import os
import shutil
import sys
import tempfile
import time
from typing import Dict, Optional

import jax
import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.common import emit  # noqa: E402
from repro.kernels.schedule import schedule_key  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.registry import get_config  # noqa: E402
from repro.serving import LMServingEngine, RNNServingEngine  # noqa: E402
from repro.testing import tiny_config  # noqa: E402


def _serve_batch(eng: RNNServingEngine, x: np.ndarray) -> np.ndarray:
    """Serve one padded batch through the submit/flush path; returns the
    per-request results stacked in submission order."""
    reqs = [eng.submit(x[i]) for i in range(x.shape[0])]
    eng.flush(force=True)
    return np.stack([r.result for r in reqs])


def _rnn_leg(cache_dir: str) -> Dict[str, object]:
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    r = cfg.rnn
    x = np.random.RandomState(0).randn(4, r.seq_len,
                                       r.input_size).astype(np.float32)

    cold_eng = RNNServingEngine(cfg, params, max_batch=4, cache_dir=cache_dir)
    key = schedule_key(*cold_eng.resolve())
    t0 = time.perf_counter()
    cold_out = _serve_batch(cold_eng, x)
    cold_s = time.perf_counter() - t0
    cold_traces = cold_eng.trace_count(key)

    # a brand-new engine over the same cache dir: first request must hit disk
    warm_eng = RNNServingEngine(cfg, params, max_batch=4, cache_dir=cache_dir)
    t0 = time.perf_counter()
    warm_out = _serve_batch(warm_eng, x)
    warm_s = time.perf_counter() - t0
    return {
        "key": key,
        "cold_first_request_s": cold_s,
        "warm_first_request_s": warm_s,
        "speedup": cold_s / max(warm_s, 1e-12),
        "cold_traces": cold_traces,
        "warm_traces": warm_eng.trace_count(key),
        "warm_cold_compiles": warm_eng.compile_cache.cold_compiles,
        "warm_hits": warm_eng.compile_cache.warm_hits,
        "bit_identical": bool((cold_out == warm_out).all()),
    }


def _lm_leg(cache_dir: str) -> Dict[str, object]:
    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    prompt, max_new = [5, 11, 2], 4

    cold_eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                               cache_dir=cache_dir)
    rid = cold_eng.add_request(list(prompt), max_new=max_new)
    t0 = time.perf_counter()
    cold_toks = cold_eng.run_to_completion()[rid]
    cold_s = time.perf_counter() - t0
    cold_traces = cold_eng.trace_count("default")

    warm_eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32,
                               cache_dir=cache_dir)
    rid = warm_eng.add_request(list(prompt), max_new=max_new)
    t0 = time.perf_counter()
    warm_toks = warm_eng.run_to_completion()[rid]
    warm_s = time.perf_counter() - t0
    return {
        "key": "default",
        "cold_first_request_s": cold_s,
        "warm_first_request_s": warm_s,
        "speedup": cold_s / max(warm_s, 1e-12),
        "cold_traces": cold_traces,
        "warm_traces": warm_eng.trace_count("default"),
        "warm_cold_compiles": warm_eng.compile_cache.cold_compiles,
        "warm_hits": warm_eng.compile_cache.warm_hits,
        "bit_identical": list(cold_toks) == list(warm_toks),
    }


def record(json_path: Optional[str] = None) -> Dict[str, object]:
    """Measure both legs in a throwaway cache dir; optionally persist the
    result under ``doc["warmup"]`` of an EXISTING perf-record JSON (the doc
    is read-modified-rewritten, never rebuilt here)."""
    tmp = tempfile.mkdtemp(prefix="warmup-bench-")
    try:
        rnn = _rnn_leg(os.path.join(tmp, "rnn"))
        lm = _lm_leg(os.path.join(tmp, "lm"))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)
    passed = all(leg["warm_traces"] == 0 and leg["warm_cold_compiles"] == 0
                 and leg["cold_traces"] >= 1 and leg["bit_identical"]
                 for leg in (rnn, lm))
    rec = {
        "criterion": "fresh engine over a warm cache dir answers its first "
                     "request with zero jit traces / zero cold compiles and "
                     "bit-identical outputs, both serving paths",
        "rnn": rnn,
        "lm": lm,
        "passed": passed,
    }
    if json_path is not None and os.path.exists(json_path):
        with open(json_path) as f:
            doc = json.load(f)
        doc["warmup"] = rec
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return rec


def smoke(json_path: str = "BENCH_rnn_kernels.json") -> None:
    """Warmup fail-fast: raises unless the warm path is trace-free and
    bit-identical on both serving paths."""
    rec = record(json_path=json_path)
    for name in ("rnn", "lm"):
        leg = rec[name]
        emit(f"warmup/{name}/cold_first_request",
             leg["cold_first_request_s"] * 1e6,
             f"traces={leg['cold_traces']}|key={leg['key']}")
        emit(f"warmup/{name}/warm_first_request",
             leg["warm_first_request_s"] * 1e6,
             f"traces={leg['warm_traces']}"
             f"|cold_compiles={leg['warm_cold_compiles']}"
             f"|warm_hits={leg['warm_hits']}"
             f"|speedup={leg['speedup']:.1f}x"
             f"|bit_identical={leg['bit_identical']}")
        assert leg["cold_traces"] >= 1, \
            f"{name}: cold engine never traced — the smoke measured nothing"
        assert leg["warm_traces"] == 0 and leg["warm_cold_compiles"] == 0, \
            (f"{name}: warm path still compiles "
             f"(traces={leg['warm_traces']}, "
             f"cold_compiles={leg['warm_cold_compiles']}) — the persistent "
             f"compile cache missed")
        assert leg["bit_identical"], \
            f"{name}: warm outputs diverged from the cold engine's"
    emit("warmup/json", 0.0,
         f"recorded={os.path.exists(json_path)}|path={json_path}")


def run(full: bool = False) -> None:
    del full
    smoke()


if __name__ == "__main__":
    smoke()
