"""Shared benchmark utilities: tagger training + timing + CSV emission."""

from __future__ import annotations

import sys
import time
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.config import OptimizerConfig  # noqa: E402
from repro.data import (flavor_tagging_dataset, quickdraw_dataset,  # noqa: E402
                        top_tagging_dataset)
from repro.models import build_model  # noqa: E402
from repro.registry import get_config  # noqa: E402
from repro.training import adamw_init, adamw_update  # noqa: E402

DATASETS = {
    "top-tagging": top_tagging_dataset,
    "flavor-tagging": flavor_tagging_dataset,
    "quickdraw": quickdraw_dataset,
}

_CACHE: Dict[str, Tuple] = {}


def dataset_for(arch: str):
    for key, fn in DATASETS.items():
        if key in arch:
            return fn
    raise KeyError(arch)


def train_tagger(arch: str, steps: int = 150, n: int = 1500,
                 lr: float = 5e-3, batch: int = 128):
    """Train (cached per-process) and return (cfg, model, params)."""
    if arch in _CACHE:
        return _CACHE[arch]
    cfg = get_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    data_fn = dataset_for(arch)
    x, y = data_fn(n, seed=0)
    opt = OptimizerConfig(lr=lr, warmup_steps=10, total_steps=steps,
                          weight_decay=1e-4)
    st = adamw_init(params, opt)

    @jax.jit
    def step(params, st, xb, yb):
        (_, _), g = jax.value_and_grad(
            lambda p: m.loss(p, {"x": xb, "y": yb}), has_aux=True)(params)
        return adamw_update(params, g, st, opt)[:2]

    for i in range(steps):
        idx = np.random.RandomState(i).randint(0, n, batch)
        params, st = step(params, st, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    _CACHE[arch] = (cfg, m, params)
    return _CACHE[arch]


def time_fn(fn: Callable, iters: int = 10, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: str):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{us_per_call:.2f},{derived}")
