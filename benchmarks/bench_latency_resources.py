"""Paper Tables 2-4 (latency vs reuse) + Figs 3-5 (DSP/FF/LUT vs width):
the analytical HLS model vs every number printed in the paper."""

from __future__ import annotations

from benchmarks.common import emit
from repro.config import FixedPointConfig
from repro.core.hls import RNNDesignPoint, estimate_design
from repro.registry import get_config

PAPER = {
    "top-tagging": {
        "fp": (16, 6), "part": "xcku115",
        "gru": {(6, 5): (2.4, 6.5), (12, 10): (3.2, 7.3),
                (30, 20): (5.0, 9.1), (60, 60): (8.0, 12.1)},
        "lstm": {(6, 5): (2.7, 6.8), (12, 10): (3.5, 7.6),
                 (30, 20): (5.3, 9.4), (60, 40): (8.3, 12.4)},
    },
    "flavor-tagging": {
        "fp": (16, 6), "part": "xcku115",
        "gru": {(48, 40): (6.7, 24.8), (90, 60): (9.8, 27.9),
                (120, 120): (11.5, 29.6), (240, 240): (20.5, 38.6)},
        "lstm": {(48, 40): (6.9, 25.0), (90, 60): (10.1, 28.2),
                 (120, 120): (11.7, 29.8), (240, 240): (20.7, 38.8)},
    },
    "quickdraw": {
        "fp": (26, 10), "part": "u250",
        "gru": {(48, 32): (35.4, 164.0), (96, 64): (59.4, 188.0),
                (192, 128): (107.0, 235.0), (384, 384): (203.0, 331.0)},
        "lstm": {(48, 32): (35.9, 164.0), (96, 64): (59.9, 188.0),
                 (192, 128): (107.0, 236.0), (384, 384): (203.0, 332.0)},
    },
}


def run(full: bool = False):
    max_err = 0.0
    for bench, spec in PAPER.items():
        W, I = spec["fp"]
        for cell in ("gru", "lstm"):
            cfg = get_config(f"{bench}-{cell}")
            for (rk, rr), (lo, hi) in spec[cell].items():
                d = estimate_design(RNNDesignPoint(
                    cfg, FixedPointConfig(W, I), rk, rr, part=spec["part"]))
                e_lo = abs(d.latency_min_us - lo) / lo
                e_hi = abs(d.latency_max_us - hi) / hi
                max_err = max(max_err, e_lo, e_hi)
                emit(f"table_latency/{bench}-{cell}/R{rk}_{rr}",
                     d.latency_min_us,
                     f"model={d.latency_min_us:.1f}-{d.latency_max_us:.1f}us"
                     f"|paper={lo}-{hi}us|err={100*max(e_lo,e_hi):.1f}%")
    emit("table_latency/max_relative_error", 0.0, f"{100*max_err:.1f}%")

    # Figs 3-5: resource curves vs total width (model values; paper figures
    # are plots — we assert the scaling behaviours, tested in test_hls_model)
    for bench, spec in PAPER.items():
        cfg = get_config(f"{bench}-gru")
        r = sorted(spec["gru"])[0]
        for W in (8, 12, 16, 20, 24):
            d = estimate_design(RNNDesignPoint(
                cfg, FixedPointConfig(W, spec["fp"][1]), r[0], r[1],
                part=spec["part"]))
            emit(f"fig3-5/{bench}/W{W}", 0.0,
                 f"dsp={d.dsp}|ff={d.ff}|lut={d.lut}|bram={d.bram_18k}"
                 f"|fits={d.fits}")


if __name__ == "__main__":
    run()
