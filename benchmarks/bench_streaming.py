"""Trigger-grade streaming smoke: overload replay at 0.5x / 1x / 2x.

The streaming pipeline (``repro.serving.streaming``) promises hard-real-time
degradation: admission token-bucketed at the PRICED throughput of the
resolved design point, deadline-aware shedding with exact per-key
accounting, and a pre-warmed degradation ladder that downgrades under
sustained backlog.  This bench replays a deterministic arrival trace at
three multiples of the rung-0 priced throughput over a virtual clock and
records, per rate, the per-stage p50/p99, the shed rate, and the downgrade
count under ``doc["streaming"]`` of BENCH_rnn_kernels.json.

``smoke()`` raises (-> scripts/check.sh exits non-zero) if:
  * any replay fails to drain completely (deadlock / lost requests);
  * the <=1x replays shed ANY request (the priced admission rate must
    sustain its own rated traffic);
  * an answered request's inference misses its deadline at ANY rate
    (admitted-request p99 within deadline is the acceptance bar);
  * per-key accounting breaks (submitted != answered + shed + failed);
  * the 2x run neither sheds nor downgrades (overload went unnoticed).

``record()`` read-modify-writes an EXISTING perf-record JSON (run.py
--stream-smoke runs AFTER --json in check.sh, whose write_json rebuilds
the document from scratch — the order is load-bearing, as with warmup).
"""

import json
import os
import sys
import warnings
from typing import Dict, List, Optional

import jax
import numpy as np

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.common import emit  # noqa: E402
from repro.autotune import (DesignTarget, SpaceSpec,  # noqa: E402
                            degradation_ladder, select)
from repro.models import build_model  # noqa: E402
from repro.registry import get_config  # noqa: E402
from repro.serving import (RNNServingEngine, StreamingPipeline,  # noqa: E402
                           VirtualClock)

SPEC = SpaceSpec(backends=("xla",), block_batches=(8,))
CLOCK_MHZ = 200.0
DEADLINE_US = 50.0
RATES = (0.5, 1.0, 2.0)
N_EVENTS = 600


def _harness():
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = RNNServingEngine(cfg, params, max_batch=8)
    # base rung: latency-best under a DSP budget (R4); degraded rungs walk
    # the autotuned frontier down-R toward higher priced throughput
    base = select(cfg, DesignTarget(max_dsp=400, objective="latency"), SPEC)
    ladder = degradation_ladder(cfg, base, spec=SPEC, max_rungs=3)
    r = cfg.rnn
    xs = np.random.RandomState(0).randn(
        N_EVENTS, r.seq_len, r.input_size).astype(np.float32)
    return eng, ladder, xs


def _replay_leg(eng, ladder, xs, rate_mult: float) -> Dict[str, object]:
    clk = VirtualClock()
    pipe = StreamingPipeline(eng, ladder, deadline_us=DEADLINE_US,
                             clock_mhz=CLOCK_MHZ, clock=clk, prewarm=False)
    dt = 1.0 / (rate_mult * pipe._rung_rate(0))
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for i, x in enumerate(xs):
            t = clk.advance(dt) if i else clk.t
            reqs.append(pipe.push(x, now=t))
            pipe.pump(now=t)
        pipe.drain()

    acc = pipe.verify_accounting()          # raises on broken accounting
    answered = [r for r in reqs if r.status == "answered"]
    missed: List = [r for r in answered
                    if r.stamps["infer"] > r.deadline_s + 1e-12]
    lat = np.asarray(sorted(r.infer_latency_s for r in answered)) \
        if answered else np.zeros(1)
    stages = {
        stage: {"p50_us": row["sim"]["latency_p50_s"] * 1e6,
                "p99_us": row["sim"]["latency_p99_s"] * 1e6,
                "events": int(row["sim"]["served"])}
        for stage, row in pipe.stage_report().items()
    }
    n = len(reqs)
    shed = sum(c["shed"] for c in acc.values())
    return {
        "rate_mult": rate_mult,
        "events": n,
        "answered": len(answered),
        "shed": shed,
        "shed_rate": shed / n,
        "failed": sum(c["failed"] for c in acc.values()),
        "downgrades": pipe.downgrades,
        "recoveries": pipe.recoveries,
        "deadline_misses": len(missed),
        "drained": pipe.in_flight() == 0,
        "admitted_p50_us": float(np.percentile(lat, 50)) * 1e6,
        "admitted_p99_us": float(np.percentile(lat, 99)) * 1e6,
        "stages": stages,
        "keys": acc,
    }


def record(json_path: Optional[str] = None) -> Dict[str, object]:
    """Replay the trace at each rate; optionally persist under
    ``doc["streaming"]`` of an EXISTING perf-record JSON (read-modify-
    rewrite, never rebuilt here)."""
    eng, ladder, xs = _harness()
    legs = {str(m): _replay_leg(eng, ladder, xs, m) for m in RATES}
    overload = legs[str(2.0)]
    passed = (
        all(leg["drained"] and leg["deadline_misses"] == 0
            and leg["admitted_p99_us"] <= DEADLINE_US
            for leg in legs.values())
        and all(legs[str(m)]["shed"] == 0 for m in (0.5, 1.0))
        and (overload["shed"] > 0 or overload["downgrades"] > 0)
    )
    rec = {
        "criterion": "replay at 0.5x/1x/2x priced throughput: <=1x never "
                     "sheds, 2x sheds and/or downgrades, admitted-request "
                     "p99 within deadline at every rate, exact per-key "
                     "accounting, full drain",
        "deadline_us": DEADLINE_US,
        "ladder": [{"key": p.key,
                    "throughput_eps": p.throughput_eps(CLOCK_MHZ)}
                   for p in ladder],
        "rates": legs,
        "passed": passed,
    }
    if json_path is not None and os.path.exists(json_path):
        with open(json_path) as f:
            doc = json.load(f)
        doc["streaming"] = rec
        with open(json_path, "w") as f:
            json.dump(doc, f, indent=2)
            f.write("\n")
    return rec


def smoke(json_path: str = "BENCH_rnn_kernels.json") -> None:
    """Streaming fail-fast: raises unless every acceptance bar holds."""
    rec = record(json_path=json_path)
    for mult, leg in rec["rates"].items():
        emit(f"streaming/{mult}x/admitted_p99", leg["admitted_p99_us"],
             f"answered={leg['answered']}|shed={leg['shed']}"
             f"|downgrades={leg['downgrades']}"
             f"|misses={leg['deadline_misses']}"
             f"|drained={leg['drained']}")
        for stage, row in leg["stages"].items():
            emit(f"streaming/{mult}x/{stage}_p99", row["p99_us"],
                 f"p50={row['p50_us']:.3f}us|events={row['events']}")
        assert leg["drained"], \
            f"{mult}x replay did not drain — deadlock or lost requests"
        assert leg["deadline_misses"] == 0 \
            and leg["admitted_p99_us"] <= rec["deadline_us"], \
            (f"{mult}x: admitted-request deadline violated "
             f"(p99={leg['admitted_p99_us']:.2f}us, "
             f"misses={leg['deadline_misses']})")
        assert leg["failed"] == 0, f"{mult}x: unexpected failures"
    for mult in ("0.5", "1.0"):
        assert rec["rates"][mult]["shed"] == 0, \
            (f"{mult}x sheds at rated throughput — admission rate is "
             f"mispriced ({rec['rates'][mult]['shed']} shed)")
    over = rec["rates"]["2.0"]
    assert over["shed"] > 0 or over["downgrades"] > 0, \
        "2x overload neither shed nor downgraded — overload went unnoticed"
    emit("streaming/json", 0.0,
         f"recorded={os.path.exists(json_path)}|path={json_path}"
         f"|passed={rec['passed']}")


def run(full: bool = False) -> None:
    del full
    smoke()


if __name__ == "__main__":
    smoke()
