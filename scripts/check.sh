#!/usr/bin/env bash
# Fail-fast repo check: import-time regressions first, then tier-1 tests.
#
#   1. pytest --collect-only  — catches JAX API drift at import time (the
#      AxisType / TPUCompilerParams class of breakage) in seconds
#   2. benchmarks/run.py --smoke — bench imports + minimal schedule sweep
#   3. benchmarks/run.py --autotune-smoke — explorer fail-fast: tiny space,
#      non-empty Pareto frontier, monotone latency-vs-R (analytical only)
#   4. benchmarks/run.py --decode-smoke — decode fail-fast: scheduled decode
#      bit-matches the einsum path, RNN single-step conformance, batch-1
#      fast path bit-matches batched predict
#   5. benchmarks/run.py --quant-smoke — quantized fail-fast: golden-model
#      conformance slice (exit non-zero on any bound violation), native
#      int8/int4 vs emulation bitwise identity, packed bytes == pricing
#   6. benchmarks/run.py --json — hoisted-vs-in-loop perf record + autotune
#      frontier + decode tokens/s record + quantized resident-bytes record
#      (BENCH_rnn_kernels.json); fails if any acceptance speedup regresses,
#      predicted/measured schedule ordering decorrelates, or the quantized
#      conformance bound is violated
#   7. benchmarks/run.py --warmup-smoke — zero-warmup fail-fast: a fresh
#      engine over a warm compile cache must answer its first request with
#      ZERO jit traces and bit-identical outputs (both serving paths); the
#      cold-vs-warm first-request latencies ride the perf record under
#      "warmup" (this stage must run AFTER --json, which rebuilds the doc)
#   8. benchmarks/run.py --stream-smoke — streaming fail-fast: deadline-
#      aware overload replay at 0.5x/1x/2x priced throughput; fails if any
#      replay deadlocks, an admitted request's p99 exceeds its deadline,
#      traffic at <=1x rate sheds at all, or 2x overload passes unnoticed
#      (neither shed nor downgraded); per-stage p50/p99 + shed/downgrade
#      counts ride the perf record under "streaming" (also after --json)
#   9. benchmarks/run.py --spec-smoke — speculative-decode fail-fast: the
#      autotuned (draft, verify, K) triple must beat the PR 5 scheduled R4
#      decode path in tokens/s, token sequences bit-identical to sequential
#      decode on the verify schedule, drafted == accepted + rejected exact;
#      measured-vs-assumed accept rate rides the perf record under
#      "speculative" (also after --json)
#  10. benchmarks/run.py --router-smoke — replicated-serving fail-fast:
#      mixed-schedule stream at N=1 vs N=3 replicas with a mid-stream
#      replica kill; fails on lost/duplicated requests, divergence from
#      the single-replica oracle, broken router accounting, or
#      sim-throughput scaling < 1.6x; scaling + per-leg stats ride the
#      perf record under "router" (also after --json)
#  11. tier-1: pytest -x -q   — the full suite, first failure stops
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== collection (import-time) check =="
python -m pytest -q --collect-only >/dev/null

echo "== benchmark smoke =="
python benchmarks/run.py --smoke

echo "== autotune smoke =="
python benchmarks/run.py --autotune-smoke

echo "== decode smoke =="
python benchmarks/run.py --decode-smoke

echo "== quant smoke =="
python benchmarks/run.py --quant-smoke

echo "== perf record (BENCH_rnn_kernels.json) =="
python benchmarks/run.py --json

echo "== warmup smoke =="
python benchmarks/run.py --warmup-smoke

echo "== streaming smoke =="
python benchmarks/run.py --stream-smoke

echo "== speculative smoke =="
python benchmarks/run.py --spec-smoke

echo "== router smoke =="
python benchmarks/run.py --router-smoke

echo "== tier-1 tests =="
python -m pytest -x -q
