import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import _extrapolate_cost
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

path = "results/dryrun_v2.json"
recs = json.load(open(path))
mesh = make_production_mesh()
for r in recs:
    if r.get("kind") == "prefill" and "memory" in r:
        cfg = get_config(r["arch"])
        try:
            r["cost_extrapolated"] = _extrapolate_cost(cfg, SHAPES[r["shape"]], mesh)
            print(r["arch"], "prefill flops/dev:", f"{r['cost_extrapolated']['flops']:.3e}", flush=True)
        except Exception as e:
            print(r["arch"], "FAIL", e, flush=True)
json.dump(recs, open(path, "w"), indent=1)
