import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, re
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

_, compiled = lower_cell(get_config(sys.argv[1]), SHAPES[sys.argv[2]], make_production_mesh())
txt = compiled.as_text()
seen = {}
for line in txt.splitlines():
    m = re.search(r"%(\S+) = (\S+) (all-reduce|all-gather)\(", line)
    if m:
        shape = m.group(2)
        meta = re.search(r'op_name="([^"]{0,120})', line)
        key = (m.group(3), shape, meta.group(1) if meta else "?")
        seen[key] = seen.get(key, 0) + 1
for (kind, shape, op), n in sorted(seen.items(), key=lambda kv: -kv[1])[:18]:
    print(f"{kind:12s} {shape:34s} x{n:3d}  {op[:100]}")
