import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import _extrapolate_cost
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

path = "results/dryrun_v2.json"
recs = json.load(open(path))
mesh = make_production_mesh()
for r in recs:
    if "memory" not in r:
        continue
    cfg = get_config(r["arch"])
    t0 = time.time()
    try:
        r["cost_extrapolated"] = _extrapolate_cost(cfg, SHAPES[r["shape"]], mesh)
        print(f"{r['arch']} {r['shape']}: flops/dev={r['cost_extrapolated']['flops']:.3e} "
              f"bytes/dev={r['cost_extrapolated']['bytes_accessed']:.3e} ({time.time()-t0:.0f}s)", flush=True)
    except Exception as e:
        print(f"{r['arch']} {r['shape']}: FAIL {type(e).__name__}: {str(e)[:150]}", flush=True)
    json.dump(recs, open(path + ".tmp", "w"), indent=1)
    os.replace(path + ".tmp", path)
