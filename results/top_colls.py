import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys, json
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import lower_cell
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

arch, shape = sys.argv[1], sys.argv[2]
mesh = make_production_mesh()
_, compiled = lower_cell(get_config(arch), SHAPES[shape], mesh)
a = analyze_hlo(compiled.as_text())
rows = sorted(a.collectives, key=lambda c: -c.wire_bytes * c.count)[:12]
for c in rows:
    print(f"{c.kind:18s} op_bytes={c.operand_bytes/2**20:9.1f}MiB gsize={c.group_size:3d} "
          f"count={c.count:5d} total_wire={c.wire_bytes*c.count/2**30:9.1f}GiB comp={c.computation[:40]}")
