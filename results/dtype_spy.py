import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "src")
import jax
from repro.config import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config
import repro.models.transformer as T

orig_layer = T.dense_layer
seen = []
def spy(cfg_, x, p, pre, **kw):
    if not seen:
        wq = p[f"{pre}/attn/wq"]
        seen.append(1)
        print("x dtype:", x.dtype, " wq dtype:", wq.dtype, flush=True)
    return orig_layer(cfg_, x, p, pre, **kw)
T.dense_layer = spy

orig_norm = T.norm
nseen = []
def spy_norm(cfg_, x, p, prefix):
    out = orig_norm(cfg_, x, p, prefix)
    if len(nseen) < 4:
        nseen.append(1)
        print(f"norm {prefix}: in {x.dtype} -> out {out.dtype}", flush=True)
    return out
T.norm = spy_norm

from repro.launch.dryrun import lower_cell
cfg = get_config("nemotron-4-340b")
mesh = make_production_mesh()
# trace only (lower, skip compile): patch compile away
import repro.launch.dryrun as D
lowered_holder = {}
orig_jit = jax.jit
lowered, compiled = None, None
try:
    l, c = lower_cell(cfg, SHAPES["train_4k"], mesh)
except Exception as e:
    print("ERR", e)
