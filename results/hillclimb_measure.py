"""Measure one (arch, shape) cell: full compile -> memory + collectives (+ optional probes)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys, time
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import cell_record
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

arch, shape, tag = sys.argv[1], sys.argv[2], sys.argv[3]
probes = len(sys.argv) > 4 and sys.argv[4] == "probes"
mesh = make_production_mesh()
t0 = time.time()
rec = cell_record(get_config(arch), SHAPES[shape], mesh, "single_pod", probes=probes)
rec["tag"] = tag
out = "results/hillclimb.json"
rows = json.load(open(out)) if os.path.exists(out) else []
rows.append(rec)
json.dump(rows, open(out, "w"), indent=1)
c = rec["collectives"]
print(f"[{tag}] {arch} {shape}: peak={rec['memory']['peak_bytes']/2**30:.2f}GiB "
      f"wire={c['wire_bytes_per_device']/2**40:.3f}TiB "
      f"by_kind={ {k: round(v/2**30,1) for k,v in c['by_kind'].items()} } ({time.time()-t0:.0f}s)")
