import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import cell_record
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

for path, mesh_name, multi, probes in [("results/dryrun_v2.json","single_pod",False,True),
                                       ("results/dryrun_multipod.json","multi_pod",True,False)]:
    recs = json.load(open(path))
    mesh = make_production_mesh(multi_pod=multi)
    for arch in ("deepseek-coder-33b", "qwen3-moe-30b-a3b"):
        rec = cell_record(get_config(arch), SHAPES["decode_32k"], mesh, mesh_name, probes=probes)
        for i, r in enumerate(recs):
            if r.get("arch")==arch and r.get("shape")=="decode_32k":
                recs[i] = rec
        print(f"{mesh_name} {arch}: peak={rec['memory']['peak_bytes']/2**30:.2f}GiB", flush=True)
    json.dump(recs, open(path, "w"), indent=1)
