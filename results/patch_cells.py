import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json, sys
sys.path.insert(0, "src")
from repro.config import SHAPES
from repro.launch.dryrun import cell_record
from repro.launch.mesh import make_production_mesh
from repro.registry import get_config

cells = [("stablelm-3b","decode_32k"), ("phi-3-vision-4.2b","decode_32k"),
         ("qwen3-moe-30b-a3b","decode_32k"), ("deepseek-coder-33b","decode_32k"),
         ("nemotron-4-340b","decode_32k")]
path = "results/dryrun_v2.json"
recs = json.load(open(path))
for arch, shape in cells:
    rec = cell_record(get_config(arch), SHAPES[shape], make_production_mesh(),
                      "single_pod", probes=True)
    for i, r in enumerate(recs):
        if r.get("arch")==arch and r.get("shape")==shape:
            recs[i] = rec
    print(f"{arch}: peak={rec['memory']['peak_bytes']/2**30:.2f}GiB", flush=True)
json.dump(recs, open(path, "w"), indent=1)
