"""Quickstart: the paper's full pipeline in ~2 minutes on CPU.

1. Train the top-quark tagger (paper benchmark 1) on synthetic LHC jets.
2. Post-training-quantize it to ap_fixed<16,6> (the paper's headline config).
3. Serve it (static mode) and print the paired FPGA design point.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FixedPointConfig, OptimizerConfig
from repro.core.quant.ptq import binary_auc, ptq_quantize_model
from repro.data import top_tagging_dataset
from repro.models import build_model, rnn_tagger
from repro.registry import get_config
from repro.serving import RNNServingEngine
from repro.training import adamw_init, adamw_update


def main():
    # 1. train ---------------------------------------------------------------
    cfg = get_config("top-tagging-gru")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    x, y = top_tagging_dataset(1500, seed=0)
    opt = OptimizerConfig(lr=5e-3, warmup_steps=10, total_steps=150,
                          weight_decay=1e-4)
    state = adamw_init(params, opt)

    @jax.jit
    def step(params, state, xb, yb):
        (loss, metrics), g = jax.value_and_grad(
            lambda p: model.loss(p, {"x": xb, "y": yb}), has_aux=True)(params)
        params, state, _ = adamw_update(params, g, state, opt)
        return params, state, loss

    for i in range(150):
        idx = np.random.RandomState(i).randint(0, len(x), 128)
        params, state, loss = step(params, state, jnp.asarray(x[idx]),
                                   jnp.asarray(y[idx]))
        if (i + 1) % 50 == 0:
            print(f"step {i+1}: loss={float(loss):.4f}")

    xt, yt = top_tagging_dataset(1000, seed=99)
    probs = np.asarray(model.forward(params, {"x": jnp.asarray(xt)}))
    auc_float = binary_auc(probs[:, 0], yt)
    print(f"\nfloat AUC: {auc_float:.4f}")

    # 2. quantize (paper Sec 5.1) ---------------------------------------------
    fp = FixedPointConfig(total_bits=16, integer_bits=6)
    qparams = ptq_quantize_model(params, fp)
    qprobs = np.asarray(rnn_tagger.forward(cfg, qparams, jnp.asarray(xt),
                                           fp=fp))
    auc_q = binary_auc(qprobs[:, 0], yt)
    print(f"ap_fixed<16,6> AUC: {auc_q:.4f}  "
          f"(ratio {auc_q/auc_float:.4f} — paper Fig. 2: ~1.0 at >=10 "
          f"fractional bits)")

    # 3. serve + FPGA design point (paper Sec 5.2/5.3) ------------------------
    eng = RNNServingEngine(cfg, qparams, mode="static", fp=fp)
    eng.warmup()
    bench = eng.benchmark(batch=1, iters=10)
    print(f"\nserving batch-1 latency (JAX/CPU): "
          f"{bench['latency_s']*1e3:.2f} ms")
    d = eng.fpga_design(strategy="latency")
    print(f"FPGA design (latency strategy, xcku115 @200MHz): "
          f"{d.latency_min_us:.2f} us, II={d.ii_cycles}, fits={d.fits}  "
          f"(paper Table 2: 1.7 us)")
    d_ns = eng.fpga_design(strategy="latency")
    from repro.core.hls import RNNDesignPoint, estimate_design
    d_ns = estimate_design(RNNDesignPoint(cfg, FixedPointConfig(10, 6),
                                          strategy="latency",
                                          mode="nonstatic"))
    print(f"non-static mode: II={d_ns.ii_cycles} (paper Table 5: 315 -> 1, "
          f">300x throughput)")


if __name__ == "__main__":
    main()
