"""Trigger-grade STREAMING scenarios: the paper's deployment settings as
live overload-aware pipelines.

Three scenarios over one `StreamingPipeline` (ingest -> prep -> queue ->
infer -> sink, monotone stamps at every boundary, per-request deadline):

  trigger   HEP level-1 trigger over simulated top-tagging jets: a TRAINED
            GRU scores each jet against a hard per-event deadline; the
            decision sink thresholds the logit into keep/drop, and the
            admission token bucket runs at the priced throughput of the
            DSP-budgeted design point — the paper's "fixed latency budget
            of O(10) us" as enforceable arithmetic.

  ticks     HFT-style tick replay: bursty arrivals (Poisson clumps) where
            the HEP trace was regular.  Bursts overrun the instantaneous
            admission rate, so the bucket's burst credit and the bounded
            queue do the work; every shed is counted per reason, never
            silent.

  stress    2x sustained overload with a mid-run infer stall: the
            degradation ladder (pre-warmed cheaper schedules from the
            autotuned frontier) downgrades at the high-water mark, sheds
            what it must, recovers at the low-water mark, and the exact
            per-key accounting (submitted == answered + shed + failed)
            survives the whole episode.

All replays run on a VIRTUAL clock with the analytical service model, so
every number below is deterministic and honest about the modeled FPGA,
not about this container's CPU.

Run:  PYTHONPATH=src python examples/streaming_scenarios.py [--events 400]
"""

import argparse
import os
import sys
import warnings

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from benchmarks.common import train_tagger
from repro.autotune import DesignTarget, SpaceSpec, degradation_ladder, select
from repro.data import top_tagging_dataset
from repro.serving import (FaultInjector, RNNServingEngine, StreamingPipeline,
                           VirtualClock, format_stream_report)

SPACE = SpaceSpec(backends=("xla",), block_batches=(8,))
CLOCK_MHZ = 200.0
DEADLINE_US = 50.0


def build(events):
    """Trained tagger engine + DSP-budgeted degradation ladder + jets."""
    cfg, _, params = train_tagger("top-tagging-gru", steps=120)
    eng = RNNServingEngine(cfg, params, max_batch=8)
    base = select(cfg, DesignTarget(max_dsp=400, objective="latency"), SPACE)
    ladder = degradation_ladder(cfg, base, spec=SPACE, max_rungs=3)
    x, y = top_tagging_dataset(events, seed=11)
    return eng, ladder, x, y


def replay(pipe, clk, xs, dts):
    """Push each event at its arrival offset, pumping as we go."""
    reqs = []
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        for x, dt in zip(xs, dts):
            t = clk.advance(dt)
            reqs.append(pipe.push(x, now=t))
            pipe.pump(now=t)
        pipe.drain()
    return reqs


def summarize(name, pipe, reqs, y=None):
    acc = pipe.verify_accounting()        # raises if a request went missing
    answered = [r for r in reqs if r.status == "answered"]
    shed = sum(c["shed"] for c in acc.values())
    print(f"\n-- {name}: {len(reqs)} events -> {len(answered)} answered, "
          f"{shed} shed, {sum(c['failed'] for c in acc.values())} failed, "
          f"{pipe.downgrades} downgrades / {pipe.recoveries} recoveries")
    if answered:
        lat = np.asarray([r.infer_latency_s for r in answered]) * 1e6
        print(f"   admitted latency p50/p99/max = {np.percentile(lat, 50):.2f}"
              f"/{np.percentile(lat, 99):.2f}/{lat.max():.2f} us "
              f"(deadline {pipe.deadline_s * 1e6:.0f} us, "
              f"misses {sum(c['deadline_miss'] for c in acc.values())})")
    if y is not None and answered:
        kept = [r for r in answered if r.result]
        idx = {r.req_id: i for i, r in enumerate(reqs)}
        tp = sum(1 for r in kept if y[idx[r.req_id]] == 1)
        sig = int((y[[idx[r.req_id] for r in answered]] == 1).sum())
        print(f"   trigger kept {len(kept)} jets; signal efficiency "
              f"{tp}/{max(sig, 1)} = {tp / max(sig, 1):.2f}")


def scenario_trigger(eng, ladder, x, y):
    """HEP trigger at 0.8x the rung-0 priced rate: regular bunch crossings,
    thresholded decision at the sink, no overload expected."""
    clk = VirtualClock()
    pipe = StreamingPipeline(
        eng, ladder, deadline_us=DEADLINE_US, clock_mhz=CLOCK_MHZ, clock=clk,
        decision_fn=lambda out: bool(np.asarray(out).ravel()[-1] > 0.5),
        stage_budgets_us={"infer": DEADLINE_US, "sink": 1.0})
    dt = 1.0 / (0.8 * pipe._rung_rate(0))
    reqs = replay(pipe, clk, x, [dt] * len(x))
    summarize("HEP trigger (0.8x, thresholded sink)", pipe, reqs, y=y)
    return pipe


def scenario_ticks(eng, ladder, x):
    """HFT tick replay: Poisson-bursty arrivals averaging 1.2x the rung-0
    rate — mean overload is mild but bursts slam the bucket and queue."""
    clk = VirtualClock()
    pipe = StreamingPipeline(eng, ladder, deadline_us=DEADLINE_US,
                             clock_mhz=CLOCK_MHZ, clock=clk, max_queue=16)
    rng = np.random.RandomState(3)
    mean_dt = 1.0 / (1.2 * pipe._rung_rate(0))
    # clumps of 1-8 back-to-back ticks separated by exponential gaps
    dts = []
    while len(dts) < len(x):
        burst = min(rng.randint(1, 9), len(x) - len(dts))
        dts.append(rng.exponential(mean_dt * burst))
        dts.extend([mean_dt * 0.02] * (burst - 1))
    reqs = replay(pipe, clk, x, dts[:len(x)])
    summarize("HFT tick replay (bursty, 1.2x mean)", pipe, reqs)
    return pipe


def scenario_stress(eng, ladder, x):
    """2x sustained overload plus a 60us infer stall a third of the way in:
    downgrade, shed, recover — with exact accounting throughout."""
    clk = VirtualClock()
    faults = FaultInjector().stall("infer", 60e-6, after=len(x) // 3)
    pipe = StreamingPipeline(eng, ladder, deadline_us=DEADLINE_US,
                             clock_mhz=CLOCK_MHZ, clock=clk, faults=faults)
    dt = 1.0 / (2.0 * pipe._rung_rate(0))
    reqs = replay(pipe, clk, x, [dt] * len(x))
    summarize("2x overload + 60us infer stall", pipe, reqs)
    print(f"   faults fired: {pipe.faults.fired}")
    return pipe


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=400)
    args = ap.parse_args()

    eng, ladder, x, y = build(args.events)
    print("degradation ladder (base = latency-best under max_dsp=400):")
    for i, pt in enumerate(ladder):
        print(f"  rung {i}: {pt.key}  {pt.throughput_eps(CLOCK_MHZ):.2e} "
              f"ev/s, dsp {pt.dsp}")

    scenario_trigger(eng, ladder, x, y)
    scenario_ticks(eng, ladder, x)
    pipe = scenario_stress(eng, ladder, x)

    print("\nfull stream report for the stress run:")
    print(format_stream_report(pipe))


if __name__ == "__main__":
    main()
