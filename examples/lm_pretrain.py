"""End-to-end TRAINING driver: pretrain a reduced stablelm-family LM on the
synthetic token stream for a few hundred steps with checkpoint/restart —
exercising the full substrate (data pipeline -> sharded train step ->
optimizer -> checkpoint manager -> resume).

Run:  PYTHONPATH=src python examples/lm_pretrain.py [--steps 200]
The same driver scales to the production mesh via launch/train.py.
"""

import argparse
import shutil
import sys

sys.path.insert(0, "src")

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="stablelm-3b")
    args = ap.parse_args()

    ckpt_dir = "/tmp/repro_lm_pretrain"
    shutil.rmtree(ckpt_dir, ignore_errors=True)

    print(f"=== pretraining tiny {args.arch} for {args.steps} steps ===")
    _, loss = train(args.arch, steps=args.steps, batch=16, lr=3e-3,
                    seq_len=128, tiny=True, checkpoint_dir=ckpt_dir)
    print(f"final loss: {loss:.4f}")

    print("\n=== simulated preemption: resume from checkpoint ===")
    _, loss2 = train(args.arch, steps=args.steps + 50, batch=16, lr=3e-3,
                     seq_len=128, tiny=True, checkpoint_dir=ckpt_dir,
                     resume=True)
    print(f"post-resume loss: {loss2:.4f}")


if __name__ == "__main__":
    main()
