"""End-to-end SERVING driver (the paper's deployment scenario): train the
flavor tagger, then serve a stream of batched requests through the
micro-batcher in both static and non-static modes, reporting latency
percentiles and the paired FPGA design space.

Run:  PYTHONPATH=src python examples/serve_tagger.py [--requests 512]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np

from benchmarks.common import train_tagger
from repro.data import flavor_tagging_dataset
from repro.serving import RNNServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    args = ap.parse_args()

    cfg, model, params = train_tagger("flavor-tagging-gru", steps=150)
    x, _ = flavor_tagging_dataset(args.requests, seed=5)

    for mode in ("static", "nonstatic"):
        eng = RNNServingEngine(cfg, params, mode=mode, max_batch=64)
        eng.warmup()
        lat = []
        t0 = time.perf_counter()
        for i in range(args.requests):
            eng.batcher.submit(x[i])
            for r in eng.batcher.run(eng.predict):
                lat.append(r.latency_s)
        leftovers = eng.batcher.drain()
        if leftovers:
            out = eng.predict(np.stack([r.payload for r in leftovers]))
            t = time.perf_counter()
            for i, r in enumerate(leftovers):
                r.result, r.done_s = out[i], t
                lat.append(r.latency_s)
        wall = time.perf_counter() - t0
        lat_ms = np.asarray(lat) * 1e3
        print(f"[{mode:9s}] {args.requests} requests in {wall:.2f}s "
              f"({args.requests/wall:.0f} ev/s)  "
              f"p50={np.percentile(lat_ms,50):.1f}ms "
              f"p99={np.percentile(lat_ms,99):.1f}ms")
        d = eng.fpga_design(reuse_kernel=48, reuse_recurrent=40,
                            strategy="resource")
        print(f"            FPGA R=(48,40): {d.latency_min_us:.1f}-"
              f"{d.latency_max_us:.1f}us (paper Table 3: 6.7-24.8us) "
              f"II={d.ii_cycles} -> {d.throughput_eps:.0f} ev/s")


if __name__ == "__main__":
    main()
