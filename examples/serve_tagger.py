"""End-to-end SERVING driver (the paper's deployment scenarios): train the
flavor tagger, then serve a MIXED stream of requests — every tenant states a
DESIGN TARGET (latency / resource / throughput budget) instead of a
hard-coded KernelSchedule, and the auto-scheduler resolves each target to a
point on the latency-resource curve: the explorer enumerates the legal
schedule space, prices it analytically, reduces it to a Pareto frontier,
and picks the objective-optimal feasible point.  Requests then co-batch by
the selected schedule's hash (one compiled kernel per key, one jit trace
each) and the final report pairs each key's measured latency with
``estimate_schedule`` of the same schedule object: the paper's
measured-vs-analytical two-column table, per tenant — with the schedules
chosen by the machine, not the operator.

Run:  PYTHONPATH=src python examples/serve_tagger.py [--requests 512]
"""

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from benchmarks.common import train_tagger
from repro.autotune import DesignTarget, SpaceSpec
from repro.data import flavor_tagging_dataset
from repro.serving import RNNServingEngine, format_serve_report

# three tenants on one engine, each stating WHAT it needs — the trigger
# latency budget, the resource-capped co-tenant, and the throughput-driven
# coprocessor farm — paper Fig. 1 as live traffic, auto-scheduled
TENANT_TARGETS = (
    ("trigger", DesignTarget(max_latency_us=1.0, objective="latency")),
    ("saver", DesignTarget(max_dsp=12000, objective="resources")),
    ("farm", DesignTarget(min_throughput_eps=1e6, objective="throughput")),
)

# the slice of schedule space this deployment may execute (interpret-mode
# Pallas kernels in the CPU container; pallas_tpu on hardware)
SPACE = SpaceSpec(reuse_factors=(1, 2, 4), iis=(0, 1), block_batches=(8,),
                  backends=("pallas_interpret",))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--max-batch", type=int, default=64)
    args = ap.parse_args()

    cfg, model, params = train_tagger("flavor-tagging-gru", steps=150)
    x, _ = flavor_tagging_dataset(args.requests, seed=5)

    eng = RNNServingEngine(cfg, params, max_batch=args.max_batch)
    for name, target in TENANT_TARGETS:   # resolve + compile each tenant once
        pt = eng.schedule_for_target(target, spec=SPACE)
        print(f"tenant {name:8s} {target.describe()}")
        print(f"  -> {pt.key}  pred {pt.latency_us():.2f}us, "
              f"II {pt.ii_cycles}, dsp {pt.dsp}, bram {pt.bram_18k}")
        eng.warmup(schedule=pt.schedule, fp=pt.fp)

    rng = np.random.RandomState(7)
    t0 = time.perf_counter()
    for i in range(args.requests):
        _, target = TENANT_TARGETS[rng.randint(len(TENANT_TARGETS))]
        eng.submit(x[i], target=target)   # target -> memoized schedule queue
        eng.flush()                       # flush whichever queues are ready
    leftovers = eng.flush(force=True)     # end of stream
    wall = time.perf_counter() - t0

    print(f"served {args.requests} mixed-target requests in {wall:.2f}s "
          f"({args.requests / wall:.0f} ev/s), "
          f"{len(leftovers)} flushed at end of stream")
    print(format_serve_report(eng.serve_report()))

    d = eng.fpga_design(reuse_kernel=48, reuse_recurrent=40,
                        strategy="resource")
    print(f"FPGA R=(48,40): {d.latency_min_us:.1f}-"
          f"{d.latency_max_us:.1f}us (paper Table 3: 6.7-24.8us) "
          f"II={d.ii_cycles} -> {d.throughput_eps:.0f} ev/s")


if __name__ == "__main__":
    main()
