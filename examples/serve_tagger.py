"""End-to-end SERVING driver (the paper's deployment scenarios): train the
flavor tagger, then serve a MIXED stream of requests — every request carries
its own KernelSchedule, i.e. its own point on the latency-resource curve —
through the schedule-keyed micro-batcher.  Requests co-batch by schedule
hash (one compiled kernel per key, one jit trace each), ragged sequence
lengths share batches, and the final report pairs each key's measured
latency with ``estimate_schedule`` of the same schedule object: the paper's
measured-vs-analytical two-column table, per tenant.

Run:  PYTHONPATH=src python examples/serve_tagger.py [--requests 512]
"""

import argparse
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

import numpy as np

from benchmarks.common import train_tagger
from repro.data import flavor_tagging_dataset
from repro.kernels.schedule import KernelSchedule
from repro.serving import RNNServingEngine, format_serve_report

# four tenants on one engine: the trigger design point (fully parallel,
# lowest latency), a resource-saving R=4 static design, the non-static
# block chain, and the hoisted pipelined NONSTATIC design (II = 1) —
# paper Fig. 1 as live traffic
TENANT_SCHEDULES = (
    KernelSchedule(reuse_factor=1, mode="static", backend="xla"),
    KernelSchedule(reuse_factor=4, mode="static", block_batch=8,
                   backend="pallas_interpret"),
    KernelSchedule(reuse_factor=2, mode="nonstatic", block_batch=8,
                   backend="pallas_interpret"),
    KernelSchedule(reuse_factor=4, mode="pipeline", ii=1, block_batch=8,
                   backend="pallas_interpret"),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--max-batch", type=int, default=64)
    args = ap.parse_args()

    cfg, model, params = train_tagger("flavor-tagging-gru", steps=150)
    x, _ = flavor_tagging_dataset(args.requests, seed=5)

    eng = RNNServingEngine(cfg, params, max_batch=args.max_batch)
    for s in TENANT_SCHEDULES:          # compile each tenant's kernel once
        eng.warmup(schedule=s)

    rng = np.random.RandomState(7)
    t0 = time.perf_counter()
    for i in range(args.requests):
        s = TENANT_SCHEDULES[rng.randint(len(TENANT_SCHEDULES))]
        eng.submit(x[i], schedule=s)
        eng.flush()                     # flush whichever queues are ready
    leftovers = eng.flush(force=True)   # end of stream
    wall = time.perf_counter() - t0

    print(f"served {args.requests} mixed-schedule requests in {wall:.2f}s "
          f"({args.requests / wall:.0f} ev/s), "
          f"{len(leftovers)} flushed at end of stream")
    print(format_serve_report(eng.serve_report()))

    d = eng.fpga_design(reuse_kernel=48, reuse_recurrent=40,
                        strategy="resource")
    print(f"FPGA R=(48,40): {d.latency_min_us:.1f}-"
          f"{d.latency_max_us:.1f}us (paper Table 3: 6.7-24.8us) "
          f"II={d.ii_cycles} -> {d.throughput_eps:.0f} ev/s")


if __name__ == "__main__":
    main()
