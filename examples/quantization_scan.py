"""Reproduce paper Fig. 2 for the top tagger: AUC ratio vs fractional bits
at integer bits {6, 8, 10, 12}, printed as an ASCII table.

Run:  PYTHONPATH=src python examples/quantization_scan.py
"""

import sys

sys.path.insert(0, "src")

from benchmarks.common import train_tagger
from repro.core.quant.ptq import auc_scan
from repro.data import top_tagging_dataset
from repro.models import rnn_tagger


def main():
    cfg, model, params = train_tagger("top-tagging-gru", steps=150)
    x, y = top_tagging_dataset(1000, seed=99)
    frac_bits = (0, 2, 4, 6, 8, 10, 12, 14)
    scan = auc_scan(cfg, rnn_tagger.forward, params, x, y,
                    integer_bits=(6, 8, 10, 12), fractional_bits=frac_bits)

    print("\nAUC(quantized)/AUC(float) — paper Fig. 2(a) protocol")
    print("frac bits: " + "".join(f"{fb:>8d}" for fb in frac_bits))
    for ib, curve in sorted(scan.items()):
        print(f"  int {ib:2d}:  " + "".join(f"{r:8.4f}" for _, r in curve))
    print("\npaper claim: >=10 fractional bits recovers ~float AUC; "
          "6 integer bits suffice for the taggers.")


if __name__ == "__main__":
    main()
