"""End-to-end serving conformance: every engine (mode x impl x schedule R x
fp) cell must reproduce the XLA ``lax.scan`` golden model, the schedule-keyed
co-batcher must serve mixed-schedule traffic bit-identically to direct
``predict`` with at most one jit trace per schedule hash, and ``serve_report``
must pair each measured number with ``estimate_schedule`` of the SAME
schedule object (paper deployment scenarios: batch-1 trigger + batched
coprocessor)."""

import jax
import numpy as np
import pytest

from repro.config import FixedPointConfig
from repro.core.hls.resources import estimate_schedule
from repro.kernels.schedule import MODES, KernelSchedule, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import RNNServingEngine
from repro.testing import assert_serving_conformance, serving_golden

REUSE_FACTORS = (1, 4)
BACKENDS = ("xla", "pallas_interpret")       # impl axis: golden vs kernels
FPS = (None, FixedPointConfig(16, 6))


def _params_for(arch):
    cfg = get_config(arch)
    return cfg, build_model(cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def gru_tagger():
    return _params_for("top-tagging-gru")


@pytest.fixture(scope="module")
def lstm_tagger():
    return _params_for("top-tagging-lstm")


@pytest.fixture(scope="module")
def gru_engine(gru_tagger):
    cfg, params = gru_tagger
    return RNNServingEngine(cfg, params, max_batch=8)


def _sched(reuse, mode, backend):
    return KernelSchedule(reuse_factor=reuse, mode=mode, block_batch=8,
                          backend=backend)


# ---------------------------------------------------------------------------
# The acceptance sweep: engine.predict vs golden for every
# (mode x impl x R x fp) cell, batch-1 (trigger) + batched (coprocessor)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fp", FPS, ids=("float", "ap16_6"))
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", MODES)
def test_engine_conformance_cells(gru_engine, mode, reuse, backend, fp, rng):
    s = _sched(reuse, mode, backend)
    x1 = rng.randn(1, 20, 6).astype(np.float32)    # batch-1 trigger path
    xb = rng.randn(5, 20, 6).astype(np.float32)    # batched coprocessor path
    assert_serving_conformance(gru_engine, x1, schedule=s, fp=fp)
    assert_serving_conformance(gru_engine, xb, schedule=s, fp=fp)


@pytest.mark.parametrize("mode", MODES)
def test_engine_conformance_lstm(lstm_tagger, mode, rng):
    cfg, params = lstm_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8)
    x = rng.randn(4, 20, 6).astype(np.float32)
    assert_serving_conformance(eng, x,
                               schedule=_sched(4, mode, "pallas_interpret"))
    assert_serving_conformance(eng, x, schedule=_sched(1, mode, "xla"),
                               fp=FixedPointConfig(16, 6))


def test_schedule_key_roundtrip():
    """key()/schedule_key are stable and from_key inverts them, including
    the fp-suffixed form the serving reports use."""
    s = _sched(4, "nonstatic", "pallas_interpret")
    assert KernelSchedule.from_key(s.key()) == s
    fp = FixedPointConfig(16, 6)
    assert schedule_key(s, fp).startswith(s.key())
    assert KernelSchedule.from_key(schedule_key(s, fp)) == s
    assert schedule_key(s, fp) != schedule_key(s, None)


def test_hoisted_pipeline_serving_roundtrip(gru_tagger, rng):
    """The new schedule axes (hoist_input / pipeline / ii) ride the serving
    path end to end: distinct co-batching keys, from_key round-trip of the
    fp-suffixed keys, submit/flush bit-matches direct predict, and
    serve_report prices the SAME schedule object."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=4)
    scheds = [
        _sched(2, "static", "pallas_interpret").replace(hoist_input=True),
        _sched(4, "pipeline", "pallas_interpret"),
        _sched(4, "pipeline", "pallas_interpret").replace(ii=1),
    ]
    fp = FixedPointConfig(16, 6)
    keys = {schedule_key(s, None) for s in scheds}
    assert len(keys) == len(scheds)        # new axes separate the queues
    for s in scheds:
        assert KernelSchedule.from_key(schedule_key(s, fp)) == s
        assert_serving_conformance(eng, rng.randn(3, 20, 6)
                                   .astype(np.float32), schedule=s)
    x = rng.randn(6, 20, 6).astype(np.float32)
    reqs = eng.serve([x[i] for i in range(6)],
                     schedules=[scheds[i % 3] for i in range(6)])
    for i, r in enumerate(reqs):
        direct = eng.predict(x[i:i + 1], schedule=scheds[i % 3])
        np.testing.assert_array_equal(np.asarray(r.result), direct[0])
    report = eng.serve_report()
    for s in scheds:
        row = report[schedule_key(s, None)]
        assert row["schedule"] == s
        est = estimate_schedule(s, cfg.rnn)
        assert row["analytical"]["ii_cycles"] == est.ii_cycles
    # the ii=1 pipeline queue must report the lowest analytical II
    iis = {k: r["analytical"]["ii_cycles"] for k, r in report.items()}
    assert iis[schedule_key(scheds[2], None)] == 1


def test_xla_backend_engine_is_exact(gru_engine, rng):
    """backend='xla' serving must equal the golden model bit-for-bit."""
    x = rng.randn(3, 20, 6).astype(np.float32)
    err = assert_serving_conformance(gru_engine, x,
                                     schedule=_sched(1, "static", "xla"))
    assert err == 0.0


# ---------------------------------------------------------------------------
# Mixed-schedule co-batching (the PR's acceptance criterion)
# ---------------------------------------------------------------------------


def test_mixed_schedule_stream_bitmatches_direct_predict(gru_tagger, rng):
    """>= 3 distinct schedules interleaved in one stream: outputs bit-match
    per-schedule direct predict, one jit trace per schedule hash, and
    serve_report pairs measured latency with estimate_schedule of the SAME
    object."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=4)
    scheds = [
        _sched(1, "static", "xla"),
        _sched(2, "static", "pallas_interpret"),
        _sched(4, "nonstatic", "pallas_interpret"),
    ]
    xs = {s: rng.randn(8, 20, 6).astype(np.float32) for s in scheds}
    reqs = {s: [] for s in scheds}
    for i in range(8):                       # interleave round-robin
        for s in scheds:
            reqs[s].append(eng.submit(xs[s][i], schedule=s))
    done = eng.flush(force=True)
    assert len(done) == 24
    assert all(r.result is not None for r in done)

    # direct predict on a FRESH engine (no shared traces/stats)
    ref = RNNServingEngine(cfg, params, max_batch=4)
    for s in scheds:
        got = np.stack([r.result for r in reqs[s]])
        want = ref.predict(xs[s], schedule=s)
        assert np.array_equal(got, want), schedule_key(s)
        # at most one jit trace per schedule hash across the whole stream
        assert eng.trace_count(schedule_key(s)) == 1

    report = eng.serve_report()
    assert set(report) == {schedule_key(s) for s in scheds}
    for s in scheds:
        row = report[schedule_key(s)]
        assert row["schedule"] is s          # the SAME object, not a copy
        est = estimate_schedule(s, cfg.rnn)
        assert row["analytical"]["latency_cycles"] == est.latency_cycles
        assert row["analytical"]["ii_cycles"] == est.ii_cycles
        assert row["measured"]["served"] == 8
        assert np.isfinite(row["measured"]["latency_mean_s"])


def test_mixed_fp_requests_get_distinct_keys(gru_tagger, rng):
    """Same schedule, different fixed-point config -> different queue (a
    different compiled datapath)."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=2)
    s = _sched(1, "static", "xla")
    fp = FixedPointConfig(16, 6)
    r1 = eng.submit(rng.randn(20, 6).astype(np.float32), schedule=s)
    r2 = eng.submit(rng.randn(20, 6).astype(np.float32), schedule=s, fp=fp)
    assert r1.key != r2.key
    eng.flush(force=True)
    ref = RNNServingEngine(cfg, params, max_batch=2)
    np.testing.assert_array_equal(
        r1.result, ref.predict(np.asarray(r1.payload)[None], schedule=s)[0])
    np.testing.assert_array_equal(
        r2.result,
        ref.predict(np.asarray(r2.payload)[None], schedule=s, fp=fp)[0])


# ---------------------------------------------------------------------------
# Ragged (variable seq_len) serving
# ---------------------------------------------------------------------------


def test_ragged_bucket_serving_bitmatches_direct(gru_tagger, rng):
    """Length-bucketed ragged flushes are bit-identical to per-request
    direct predict — on the Pallas backend too."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8)
    s = _sched(2, "static", "pallas_interpret")
    lens = [20, 12, 20, 7, 12, 5]
    reqs = [eng.submit(rng.randn(n, 6).astype(np.float32), schedule=s)
            for n in lens]
    eng.flush(force=True)
    ref = RNNServingEngine(cfg, params, max_batch=8)
    for r in reqs:
        want = ref.predict(np.asarray(r.payload)[None], schedule=s)[0]
        assert np.array_equal(r.result, want)


def test_ragged_mask_serving_bitmatches_direct(gru_tagger, rng):
    """Pad-and-mask shares ONE batch across lengths; on the XLA datapath the
    frozen-state trick is bit-identical to scanning each row unpadded."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8, ragged="mask")
    lens = [20, 3, 11, 20, 6]
    reqs = [eng.submit(rng.randn(n, 6).astype(np.float32)) for n in lens]
    eng.flush(force=True)
    ref = RNNServingEngine(cfg, params, max_batch=8)
    for r in reqs:
        want = ref.predict(np.asarray(r.payload)[None])[0]
        assert np.array_equal(r.result, want)


def test_predict_ragged_matches_golden_with_lengths(gru_tagger, rng):
    """The masked forward itself: padded batch + lengths == per-row golden."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8, ragged="mask")
    xs = [rng.randn(n, 6).astype(np.float32) for n in (20, 9, 14)]
    outs = eng.predict_ragged(xs)
    for x, out in zip(xs, outs):
        want = serving_golden(cfg, params, x[None])[0]
        np.testing.assert_array_equal(out, want)


# ---------------------------------------------------------------------------
# Per-key latency accounting: finite, keyed, analytical monotone in R
# ---------------------------------------------------------------------------


def test_benchmark_keyed_finite_and_monotone_in_reuse(gru_tagger):
    """benchmark() numbers are finite and keyed by schedule hash; the
    analytical column obeys the paper's trade-off (latency up, DSP down as R
    grows) — the same monotonicity assertions as the kernel conformance
    suite, now through the serving surface."""
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=8)
    rows = [eng.benchmark(4, iters=2, schedule=_sched(r, "static", "xla"))
            for r in (1, 2, 4)]          # divisors of 3h = 60
    keys = [b["key"] for b in rows]
    assert len(set(keys)) == 3
    for b in rows:
        assert np.isfinite(b["latency_s"]) and b["latency_s"] > 0
        assert np.isfinite(b["throughput_eps"])
    lat = [b["latency_cycles"] for b in rows]
    dsp = [b["dsp"] for b in rows]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a > b for a, b in zip(dsp, dsp[1:])), dsp


def test_serve_report_analytical_monotone_in_reuse(gru_tagger, rng):
    cfg, params = gru_tagger
    eng = RNNServingEngine(cfg, params, max_batch=2)
    scheds = [_sched(r, "static", "xla") for r in (1, 2, 4)]
    for s in scheds:
        for _ in range(2):
            eng.submit(rng.randn(20, 6).astype(np.float32), schedule=s)
    eng.flush(force=True)
    report = eng.serve_report()
    rows = [report[schedule_key(s)] for s in scheds]
    for row in rows:
        m = row["measured"]
        assert m["served"] == 2 and m["batches"] == 1
        assert all(np.isfinite(v) for v in m.values())
        assert all(np.isfinite(v) for v in row["analytical"].values()
                   if not isinstance(v, str))
    lat = [r["analytical"]["latency_cycles"] for r in rows]
    dsp = [r["analytical"]["dsp"] for r in rows]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a > b for a, b in zip(dsp, dsp[1:])), dsp
