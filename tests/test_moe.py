"""MoE block invariants: combine-weight normalization, chunking equivalence,
capacity semantics, phantom-expert padding."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import build_model
from repro.models.moe import _moe_tokens, moe_block
from repro.registry import get_config
from repro.testing import tiny_config


def _setup(arch="qwen3-moe-30b-a3b", **moe_kw):
    cfg = tiny_config(get_config(arch))
    if moe_kw:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, **moe_kw))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    p_moe = {k[len("decoder/"):]: v[0]
             for k, v in params.items() if k.startswith("decoder/moe")}
    return cfg, p_moe


def test_chunked_equals_unchunked(rng):
    cfg, p = _setup(capacity_factor=8.0, eval_capacity_factor=8.0)
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32) * 0.3)
    o1, a1 = _moe_tokens(cfg, x, p, "moe", train=False)
    # force chunking by reshaping through moe_block on a longer seq built
    # from tiling — instead compare two manual chunk sizes
    xa = x[:, :8]
    xb = x[:, 8:]
    oa, _ = _moe_tokens(cfg, xa, p, "moe", train=False)
    ob, _ = _moe_tokens(cfg, xb, p, "moe", train=False)
    o2 = jnp.concatenate([oa, ob], axis=1)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)


def test_no_drop_outputs_match_manual_topk(rng):
    cfg, p = _setup(capacity_factor=8.0, eval_capacity_factor=8.0,
                    n_shared_experts=0)
    m = cfg.moe
    x = jnp.asarray(rng.randn(1, 6, cfg.d_model).astype(np.float32) * 0.3)
    out, _ = _moe_tokens(cfg, x, p, "moe", train=False)

    # manual per-token computation
    xf = np.asarray(x).reshape(6, cfg.d_model)
    logits = xf @ np.asarray(p["moe/router"], np.float32)
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    ref = np.zeros_like(xf)
    for t in range(6):
        top = np.argsort(-probs[t])[: m.top_k]
        w = probs[t][top] / probs[t][top].sum()
        for e, we in zip(top, w):
            wg = np.asarray(p["moe/we_gate"][e], np.float32)
            wu = np.asarray(p["moe/we_up"][e], np.float32)
            wd = np.asarray(p["moe/we_down"][e], np.float32)
            h = (xf[t] @ wg)
            h = h / (1 + np.exp(-h)) * (xf[t] @ wu)
            ref[t] += we * (h @ wd)
    np.testing.assert_allclose(np.asarray(out).reshape(6, -1), ref,
                               rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens_gracefully(rng):
    cfg, p = _setup(capacity_factor=0.1, eval_capacity_factor=0.1,
                    n_shared_experts=0)
    x = jnp.asarray(rng.randn(2, 32, cfg.d_model).astype(np.float32))
    out, _ = _moe_tokens(cfg, x, p, "moe", train=True)
    assert bool(jnp.all(jnp.isfinite(out)))
    # some rows should be exactly zero (dropped -> residual only)
    norms = np.linalg.norm(np.asarray(out).reshape(-1, cfg.d_model), axis=1)
    assert (norms < 1e-7).any()


def test_aux_losses_positive_and_bounded(rng):
    cfg, p = _setup()
    x = jnp.asarray(rng.randn(2, 16, cfg.d_model).astype(np.float32))
    _, aux = _moe_tokens(cfg, x, p, "moe", train=True)
    lb = float(aux["moe_load_balance"])
    assert 0.5 < lb < float(cfg.moe.n_experts)
    assert float(aux["moe_z_loss"]) >= 0


def test_phantom_expert_padding_never_selected(rng):
    """qwen2's 60 experts pad to the TP multiple; phantoms get -inf router
    logits so no token routes to them."""
    from repro.sharding.api import ShardingContext, _STATE
    from repro.sharding.rules import rules_for

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 2, "model": 8}

    cfg = tiny_config(get_config("qwen2-moe-a2.7b"))
    # simulate a padded router (n_experts=8 real, padded to 16)
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, n_experts=6))
    m = build_model(cfg2)
    ctx = ShardingContext(FakeMesh(), rules_for("moe"), ("data",))
    _STATE.ctx = ctx
    try:
        specs = m.param_specs()
        e_pad = specs["decoder/moe/router"].shape[-1]
        assert e_pad == 8                       # padded to model axis
    finally:
        _STATE.ctx = None
    params = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
              for k, s in specs.items()}
    p_moe = {k[len("decoder/"):]: v[0]
             for k, v in params.items() if k.startswith("decoder/moe")}
    x = jnp.asarray(rng.randn(1, 4, cfg2.d_model).astype(np.float32))
    out, _ = _moe_tokens(cfg2, x, p_moe, "moe", train=True)
    assert bool(jnp.all(jnp.isfinite(out)))
