"""Checkpoint manager: roundtrip, atomicity, corruption detection, GC,
restore with different shardings (elastic restart)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.config import OptimizerConfig
from repro.training import adamw_init


def _params(rng):
    return {"layer/w": jnp.asarray(rng.randn(4, 8).astype(np.float32)),
            "layer/b": jnp.asarray(rng.randn(8).astype(np.float32)),
            "emb/table": jnp.asarray(rng.randn(16, 4), dtype=jnp.bfloat16)}


def test_roundtrip_params_and_opt_state(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    params = _params(rng)
    opt = adamw_init(params, OptimizerConfig())
    opt = opt._replace(step=jnp.asarray(7, jnp.int32))
    mgr.save(7, params, opt)
    step, p2, o2 = mgr.restore()
    assert step == 7 and o2["step"] == 7
    for k in params:
        np.testing.assert_array_equal(
            np.asarray(params[k], np.float32), np.asarray(p2[k], np.float32))
        assert p2[k].dtype == params[k].dtype
    for k in opt.m:
        np.testing.assert_array_equal(np.asarray(opt.m[k]),
                                      np.asarray(o2["m"][k]))


def test_latest_step_and_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    params = _params(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, params)
    assert mgr.latest_step() == 4
    kept = sorted(os.listdir(tmp_path))
    assert kept == ["step_000000003", "step_000000004"]


def test_corruption_detected(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    params = _params(rng)
    d = mgr.save(3, params)
    # flip bytes in one array
    target = os.path.join(d, "params__layer__w.npy")
    arr = np.load(target)
    arr[0, 0] += 1.0
    np.save(target, arr)
    with pytest.raises(IOError, match="checksum"):
        mgr.restore(3)


def test_tmp_dir_never_visible_as_checkpoint(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(os.path.join(tmp_path, "step_000000009.tmp"))
    assert mgr.latest_step() is None       # interrupted write is invisible
    mgr.save(1, _params(rng))
    assert mgr.latest_step() == 1


def test_restore_with_new_shardings(tmp_path, rng):
    """Elastic restart: restore applies the NEW mesh's shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mgr = CheckpointManager(str(tmp_path))
    params = _params(rng)
    mgr.save(5, params)
    mesh = make_mesh((1,), ("data",))      # 1-device "new cluster"
    sh = {k: NamedSharding(mesh, P()) for k in params}
    _, p2, _ = mgr.restore(5, shardings=sh)
    for k in params:
        assert p2[k].sharding == sh[k]
        np.testing.assert_array_equal(
            np.asarray(params[k], np.float32), np.asarray(p2[k], np.float32))


def test_extra_metadata_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    d = mgr.save(2, _params(rng), extra={"arch": "gemma-2b", "loss": 1.5})
    manifest = json.load(open(os.path.join(d, "manifest.json")))
    assert manifest["extra"]["arch"] == "gemma-2b"
