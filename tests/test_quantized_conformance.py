"""The quantized conformance tier: native int8/int4 kernel bodies vs
numpy integer golden models, emulation bit-identity, fp-keyed residency
packing, fp-aware pricing, serving reports, autotune feasibility, and the
paper's precision-vs-AUC regression (Figs. 6-9 protocol).

Every (kernel x mode x R x fp) cell must stay inside its
``fixed_point_error_bound``-derived tolerance; the matmul/Hadamard parts
of the datapath are exact, so observed errors are ~0 (only an activation
rounding tie may legally move a value one grid step).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.config import FixedPointConfig
from repro.core.quant.fixed_point import (fixed_point_error_bound,
                                          is_native_int, packed_weight_bytes,
                                          quantize_np, to_ints)
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.testing import (assert_quantized_conformance, make_kernel_inputs,
                           make_quantized_inputs, native_fp_configs)

NATIVE_FPS = native_fp_configs()
KERNELS = ("lstm", "gru", "rglru", "reuse_matmul")
MODES = ("static", "nonstatic")
REUSES = (1, 2, 4)


def _sched(mode="static", R=1, backend="pallas_interpret", bb=8):
    return KernelSchedule(reuse_factor=R, mode=mode, backend=backend,
                          block_batch=bb)


# ---------------------------------------------------------------------------
# The (kernel x mode x R x fp) conformance grid
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", KERNELS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("R", REUSES)
@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
def test_quantized_conformance_cell(kernel, mode, R, fp_name):
    err = assert_quantized_conformance(
        kernel, _sched(mode=mode, R=R), NATIVE_FPS[fp_name])
    assert err <= 2.0 * fixed_point_error_bound(NATIVE_FPS[fp_name])


@pytest.mark.parametrize("kernel", ("lstm", "gru"))
@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
def test_quantized_conformance_xla_backend(kernel, fp_name):
    """The emulation fallback (backend=xla) must satisfy the same golden
    model — native and emulated routes share the quantization points."""
    assert_quantized_conformance(kernel, _sched(backend="xla"),
                                 NATIVE_FPS[fp_name])


@pytest.mark.parametrize("kernel", ("rglru", "reuse_matmul"))
@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
def test_matmul_free_cells_bit_exact(kernel, fp_name):
    """Cells without activations have NO legal divergence: all-integer
    datapaths must match the numpy golden bit-for-bit."""
    err = assert_quantized_conformance(kernel, _sched(R=2),
                                       NATIVE_FPS[fp_name])
    assert err == 0.0, err


@pytest.mark.parametrize("cell", ("lstm", "gru"))
@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
@pytest.mark.parametrize("seed", (0, 1, 2))
def test_native_matches_emulation_bitwise(cell, fp_name, seed):
    """The seed-robust identity at the heart of the design: with PTQ'd
    (on-grid) weights the native int path and the f32 emulation cells are
    the SAME jax computation bit-for-bit — int32 gate accumulators rescale
    exactly, so no tolerance is needed (both sides share XLA's
    sigmoid/tanh, unlike the numpy golden)."""
    from repro.kernels import ops

    fp = NATIVE_FPS[fp_name]
    xs, W, U, b = make_quantized_inputs(cell, fp, seed=seed)
    nat = ops.SCHEDULED_KERNELS[cell][0](xs, W, U, b, schedule=_sched(R=2),
                                         fp=fp)
    emu = ops._emulated_scan_jit(xs, W, U, b, cell=cell, fp=fp)
    np.testing.assert_array_equal(np.asarray(nat), np.asarray(emu))


def test_fp_none_route_unchanged():
    """fp=None must stay bit-compatible with the pre-quantization float
    route (the tentpole's compatibility clause)."""
    from repro.kernels import ops

    xs, W, U, b = make_kernel_inputs("lstm")
    s = _sched(R=2)
    np.testing.assert_array_equal(
        np.asarray(ops.lstm_scan(xs, W, U, b, schedule=s)),
        np.asarray(ops.lstm_scan(xs, W, U, b, schedule=s, fp=None)))


# ---------------------------------------------------------------------------
# Native decode steps (the single-event engine's quantized route)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", ("lstm", "gru"))
@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
def test_decode_step_native_matches_emulation(cell, fp_name):
    """Chained native decode steps == chained emulation cells, bitwise,
    on PTQ'd weights — the decode route's version of the scan identity."""
    from repro.core.rnn.cells import (gru_cell_quantized, initial_state,
                                      lstm_cell_quantized)
    from repro.kernels.decode_step import rnn_decode_step

    fp = NATIVE_FPS[fp_name]
    xs, W, U, b = make_quantized_inputs(cell, fp, seed=3)
    B, T, _ = xs.shape
    H = U.shape[0]
    sched = _sched(R=2)
    ref_step = lstm_cell_quantized if cell == "lstm" else gru_cell_quantized
    st_n = initial_state(cell, B, H, jnp.float32)
    st_e = initial_state(cell, B, H, jnp.float32)
    for t in range(min(T, 4)):
        h_n, st_n = rnn_decode_step(cell, xs[:, t], st_n, W, U, b,
                                    schedule=sched, fp=fp)
        h_e, st_e = ref_step(xs[:, t], st_e, W, U, b, fp)
        np.testing.assert_array_equal(np.asarray(h_n), np.asarray(h_e))


def test_decode_step_nonnative_fp_still_emulates():
    """A wide (non-native) fp keeps the existing quantized-cell route."""
    from repro.core.rnn.cells import initial_state, lstm_cell_quantized
    from repro.kernels.decode_step import rnn_decode_step

    fp = FixedPointConfig(16, 6)
    assert not is_native_int(fp)
    xs, W, U, b = make_kernel_inputs("lstm")
    st = initial_state("lstm", xs.shape[0], U.shape[0], jnp.float32)
    h, _ = rnn_decode_step("lstm", xs[:, 0], st, W, U, b,
                           schedule=_sched(), fp=fp)
    h_ref, _ = lstm_cell_quantized(xs[:, 0], st, W, U, b, fp)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-6)


# ---------------------------------------------------------------------------
# Residency packing: round-trip, fp keying, packed-byte eviction accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fp_name", sorted(NATIVE_FPS))
@pytest.mark.parametrize("k", (7, 8, 21))
def test_pack_unpack_round_trip(fp_name, k):
    from repro.kernels.quantized import pack_ints, unpack_ints

    fp = NATIVE_FPS[fp_name]
    rng = np.random.RandomState(k)
    w = jnp.asarray(rng.randn(k, 12).astype(np.float32))
    packed = pack_ints(w, fp)
    assert packed.nbytes == packed_weight_bytes(k, 12, fp)
    got = unpack_ints(packed, fp, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(to_ints(w, fp)))


def test_pack_saturates_at_rails():
    """Values beyond the grid must clamp to the int rails, not wrap."""
    from repro.kernels.quantized import pack_ints, unpack_ints

    fp = NATIVE_FPS["int4"]
    w = jnp.asarray([[100.0, -100.0, 0.0, fp.max_value]], jnp.float32).T
    got = np.asarray(unpack_ints(pack_ints(w, fp), fp, 4)).ravel()
    np.testing.assert_array_equal(got, [7, -8, 0, 7])


def test_residency_keys_on_fp():
    """A precision change must never serve a stale layout: the same weight
    array packed under float, int8 and int4 keys yields THREE distinct
    cache entries, each with its own packed bytes."""
    from repro.kernels.ops import RESIDENT_WEIGHTS
    from repro.kernels.quantized import resident_quantized

    sched = _sched()
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(20, 16).astype(np.float32))
    before = len(RESIDENT_WEIGHTS)
    p8 = resident_quantized(w, NATIVE_FPS["int8"], schedule=sched, tag="k")
    p4 = resident_quantized(w, NATIVE_FPS["int4"], schedule=sched, tag="k")
    assert len(RESIDENT_WEIGHTS) == before + 2
    assert p8.nbytes == 20 * 16 and p4.nbytes == 10 * 16
    # repeat calls hit (identity + key match), no repacking
    h0 = RESIDENT_WEIGHTS.hits
    p8b = resident_quantized(w, NATIVE_FPS["int8"], schedule=sched, tag="k")
    assert RESIDENT_WEIGHTS.hits == h0 + 1 and p8b is p8
    # the two fp keys embed the ap token, so they can never collide
    assert schedule_key(sched, NATIVE_FPS["int8"]) \
        != schedule_key(sched, NATIVE_FPS["int4"])


def test_scan_after_float_serves_fresh_quantized_layout():
    """Running the float route first must not poison the fp route: the
    quantized scan still matches its golden model afterwards."""
    from repro.kernels import ops

    fp = NATIVE_FPS["int8"]
    s = _sched(R=2)
    xs, W, U, b = make_quantized_inputs("lstm", fp, seed=5)
    ops.lstm_scan(xs, W, U, b, schedule=s)            # float layout cached
    assert_quantized_conformance("lstm", s, fp, seed=5)


def test_eviction_accounts_packed_bytes():
    """The LRU byte budget must count the PACKED payload (int4: /8), not
    the float source bytes — else quantized entries evict 8x too early."""
    from repro.kernels.ops import WeightResidency
    from repro.kernels.quantized import pack_ints

    fp = NATIVE_FPS["int4"]
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(64, 64).astype(np.float32))   # f32: 16384 B
    packed_nb = packed_weight_bytes(64, 64, fp)             # int4: 2048 B
    cache = WeightResidency(max_entries=64, max_bytes=4 * packed_nb)
    for i in range(3):
        wi = w + float(i)                  # distinct identities
        cache.get(wi, f"quant/t/ap4_2_{i}", lambda wi=wi: pack_ints(wi, fp))
    # 3 packed entries = 6144 B fit a budget 4 float copies would blow
    assert len(cache) == 3 and cache.bytes == 3 * packed_nb


# ---------------------------------------------------------------------------
# Pricing: packed bytes identical in measurement and estimate, int4 <= 1/4
# ---------------------------------------------------------------------------


def test_decode_pricing_equals_measured_packing():
    """estimate_decode_step's weight_vmem_bytes must equal the residency
    cache's measured packed nbytes for the same weights — the single
    packed_weight_bytes formula, realized and priced."""
    from repro.core.hls.resources import estimate_decode_step
    from repro.kernels.quantized import pack_ints
    from repro.registry import get_config

    cfg = get_config("flavor-tagging-lstm")
    rnn = cfg.rnn
    g = 4
    rng = np.random.RandomState(0)
    W = jnp.asarray(rng.randn(rnn.input_size, g * rnn.hidden)
                    .astype(np.float32))
    U = jnp.asarray(rng.randn(rnn.hidden, g * rnn.hidden).astype(np.float32))
    s = _sched(R=2)
    for fp in NATIVE_FPS.values():
        est = estimate_decode_step(s, rnn, fp)
        measured = pack_ints(W, fp).nbytes + pack_ints(U, fp).nbytes
        assert est.weight_vmem_bytes == measured, (fp.total_bits,)


@pytest.mark.parametrize("estimator", ("estimate_schedule",
                                       "estimate_decode_step"))
def test_int4_vmem_quarter_of_float(estimator):
    """Acceptance: int4 resident vmem_bytes <= 1/4 the float layout
    (weights /8, activations /4) — and int8 <= 1/2."""
    from repro.core.hls import resources
    from repro.registry import get_config

    rnn = get_config("flavor-tagging-lstm").rnn
    fn = getattr(resources, estimator)
    for mode in MODES:
        s = _sched(mode=mode, R=2)
        v_f = fn(s, rnn, None).vmem_bytes
        v_8 = fn(s, rnn, NATIVE_FPS["int8"]).vmem_bytes
        v_4 = fn(s, rnn, NATIVE_FPS["int4"]).vmem_bytes
        assert v_4 <= v_f / 4, (mode, v_4, v_f)
        assert v_8 <= v_f / 2, (mode, v_8, v_f)
        assert fn(s, rnn, NATIVE_FPS["int4"]).weight_vmem_bytes * 8 \
            <= fn(s, rnn, None).weight_vmem_bytes + 8


def test_emulated_fp_prices_like_float_vmem():
    """A non-native fp (e.g. the paper's <16,6>) executes the f32 emulation,
    so its vmem must stay the float layout's (only BRAM/DSP scale with
    total_bits)."""
    from repro.core.hls.resources import estimate_schedule
    from repro.registry import get_config

    rnn = get_config("flavor-tagging-lstm").rnn
    s = _sched()
    assert estimate_schedule(s, rnn, FixedPointConfig(16, 6)).vmem_bytes \
        == estimate_schedule(s, rnn, None).vmem_bytes


def test_lm_decode_pricing_shrinks_native():
    from repro.core.hls.resources import estimate_lm_decode
    from repro.registry import get_config
    from repro.testing import tiny_config

    cfg = tiny_config(get_config("stablelm-3b"))
    s = _sched()
    v_f = estimate_lm_decode(s, cfg, None).vmem_bytes
    v_4 = estimate_lm_decode(s, cfg, NATIVE_FPS["int4"]).vmem_bytes
    assert v_4 <= v_f / 4


# ---------------------------------------------------------------------------
# Serving report + autotune feasibility under native precision
# ---------------------------------------------------------------------------


def _engine(arch="flavor-tagging-lstm"):
    from repro.models import build_model
    from repro.registry import get_config
    from repro.serving.engine import RNNServingEngine

    cfg = get_config(arch)
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return RNNServingEngine(cfg=cfg, params=params, impl="pallas",
                            max_batch=8)


def test_serve_report_quantized_rows_show_reduced_vmem():
    """serve_report's analytical column for a quantized key must carry the
    packed-layout vmem/BRAM, visibly below the float key's row."""
    eng = _engine()
    rng = np.random.RandomState(0)
    x = rng.randn(4, eng.cfg.rnn.seq_len, eng.cfg.rnn.input_size) \
        .astype(np.float32)
    s = _sched(R=2)
    fp = NATIVE_FPS["int8"]
    eng.predict(x, schedule=s)
    eng.predict(x, schedule=s, fp=fp)
    report = eng.serve_report()
    row_f = report[schedule_key(s, None)]["analytical"]
    row_q = report[schedule_key(s, fp)]["analytical"]
    assert row_q["vmem_bytes"] < row_f["vmem_bytes"]
    assert row_q["weight_vmem_bytes"] * 2 <= row_f["weight_vmem_bytes"]
    assert row_q["bram_18k"] <= row_f["bram_18k"] / 2 + 1


def test_auto_schedule_int8_feasible_where_float_is_not():
    """Satellite acceptance: a BRAM budget only the int8 packing satisfies —
    the float-only space raises InfeasibleTargetError, the same target with
    fp=int8 selects a point (the autotuner trades precision for BRAM)."""
    from repro.autotune import DesignTarget
    from repro.autotune.explorer import InfeasibleTargetError

    eng = _engine()
    tight = 30          # float space min bram is 53; int8 static min is 27
    with pytest.raises(InfeasibleTargetError):
        eng.auto_schedule(DesignTarget(max_bram_18k=tight), warmup=False)
    pt = eng.auto_schedule(
        DesignTarget(max_bram_18k=tight, fp=NATIVE_FPS["int8"]),
        warmup=False)
    assert pt.bram_18k <= tight
    assert eng.fp is not None and eng.fp.total_bits == 8
    # and the selected point is native-executable (no hoist/pipeline)
    assert not pt.schedule.hoist_input and pt.schedule.mode != "pipeline"


def test_explore_prunes_native_illegal_points():
    from repro.autotune import DesignTarget
    from repro.autotune.explorer import explore
    from repro.autotune.space import native_int_legal
    from repro.registry import get_config

    cfg = get_config("flavor-tagging-lstm")
    ex = explore(cfg, DesignTarget(fp=NATIVE_FPS["int8"]))
    assert ex.points
    assert all(native_int_legal(p.schedule) for p in ex.points)


def test_serving_engine_native_fp_predict_matches_emulation():
    """End-to-end: engine.predict on the native int8 Pallas route equals
    the XLA emulation datapath bitwise once the weights are PTQ'd."""
    from repro.core.quant.ptq import ptq_quantize_model
    from repro.models import rnn_tagger

    eng = _engine()
    fp = NATIVE_FPS["int8"]
    eng.params = ptq_quantize_model(eng.params, fp)
    rng = np.random.RandomState(1)
    x = rng.randn(4, eng.cfg.rnn.seq_len, eng.cfg.rnn.input_size) \
        .astype(np.float32)
    got = eng.predict(x, schedule=_sched(R=2), fp=fp)
    want = np.asarray(rnn_tagger.forward(
        eng.cfg, eng.params, jnp.asarray(x), fp=fp, impl="xla"))
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# The paper's precision-vs-AUC regression (Fig. 2 protocol, pinned)
# ---------------------------------------------------------------------------


def _train_flavor_lstm(steps=100, n=1200):
    from repro.config import OptimizerConfig
    from repro.data import flavor_tagging_dataset
    from repro.models import build_model
    from repro.registry import get_config
    from repro.training import adamw_init, adamw_update

    cfg = get_config("flavor-tagging-lstm")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x, y = flavor_tagging_dataset(n, seed=0)
    opt = OptimizerConfig(lr=5e-3, warmup_steps=10, total_steps=steps,
                          weight_decay=1e-4)
    st = adamw_init(params, opt)

    @jax.jit
    def step(params, st, xb, yb):
        (_, _), g = jax.value_and_grad(
            lambda p: m.loss(p, {"x": xb, "y": yb}), has_aux=True)(params)
        return adamw_update(params, g, st, opt)[:2]

    for i in range(steps):
        idx = np.random.RandomState(i).randint(0, n, 128)
        params, st = step(params, st, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    return cfg, params


def test_auc_scan_flavor_tagging_pinned():
    """Pinned regression of the paper-shaped precision-vs-AUC curve on the
    flavor-tagging LSTM: at integer_bits=6 the AUC ratio is within 1% of
    float for >= 8 fractional bits and degrades sharply at <= 2 — the
    shape of paper Figs. 6-8."""
    from repro.core.quant.ptq import auc_scan
    from repro.data import flavor_tagging_dataset
    from repro.models import rnn_tagger

    cfg, params = _train_flavor_lstm()
    xt, yt = flavor_tagging_dataset(512, seed=7)
    scan = auc_scan(cfg, rnn_tagger.forward, params, xt, yt,
                    integer_bits=(6,), fractional_bits=(2, 8, 12))
    curve = dict(scan[6])
    assert curve[8] >= 0.99, curve
    assert curve[12] >= 0.995, curve
    assert curve[2] < 0.95, curve          # coarse grids must visibly hurt


def test_auc_scan_quickdraw_ranking_preserved():
    """Quickdraw (multiclass) counterpart, self-labelled from the float
    model's own predictions so float AUC is exactly rankable: quantization
    at <6,10> must preserve the ranking within 1%, and 0 fractional bits
    must destroy it."""
    from repro.core.quant.ptq import auc_scan
    from repro.models import build_model, rnn_tagger
    from repro.registry import get_config

    cfg = get_config("quickdraw-lstm")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    x = rng.randn(192, cfg.rnn.seq_len, cfg.rnn.input_size) \
        .astype(np.float32)
    probs = np.asarray(rnn_tagger.forward(cfg, params, jnp.asarray(x)))
    y = np.argmax(probs, axis=-1).astype(np.int32)
    scan = auc_scan(cfg, rnn_tagger.forward, params, x, y,
                    integer_bits=(6,), fractional_bits=(0, 10))
    curve = dict(scan[6])
    assert curve[10] >= 0.99, curve
    assert curve[0] < 0.9, curve
