"""Serving layer: micro-batcher policy, LM continuous batching, RNN engine."""

import jax
import numpy as np
import pytest

from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.models import build_model
from repro.registry import get_config
from repro.serving import LMServingEngine, MicroBatcher, RNNServingEngine
from repro.serving.batcher import _pad_stack
from repro.testing import tiny_config


def test_microbatcher_flushes_on_size_and_timeout():
    mb = MicroBatcher(max_batch=3, max_wait_s=1.0)
    mb.submit(np.zeros(2), now=0.0)
    assert not mb.ready(now=0.5)
    mb.submit(np.zeros(2), now=0.5)
    mb.submit(np.zeros(2), now=0.6)
    assert mb.ready(now=0.6)               # size trigger
    done = mb.run(lambda x: x + 1, now=0.7)
    assert len(done) == 3
    assert done[0].latency_s == pytest.approx(0.7)
    mb.submit(np.zeros(2), now=1.0)
    assert not mb.ready(now=1.5)
    assert mb.ready(now=2.1)               # timeout trigger


def test_microbatcher_ragged_payloads_padded_and_unpadded():
    """Regression: ragged payloads used to crash np.stack; now they pad to
    the per-batch max and results come back unpadded per request."""
    mb = MicroBatcher(max_batch=3, max_wait_s=0.0)
    payloads = [np.arange(8, dtype=np.float32).reshape(4, 2),
                np.ones((2, 2), np.float32) * 7,
                np.full((3, 2), -1.0, np.float32)]
    for p in payloads:
        mb.submit(p, now=0.0)
    with pytest.warns(RuntimeWarning):     # plain fn: no lengths parameter
        done = mb.run(lambda x: x, now=0.1)    # identity keeps the seq axis
    assert len(done) == 3
    for r, p in zip(done, payloads):
        np.testing.assert_array_equal(r.result, p)   # unpadded round-trip


def test_microbatcher_ragged_nonseq_outputs_not_truncated():
    """Outputs whose leading dim merely coincides with the padded length
    (e.g. class probabilities) must come back whole, and an infer function
    without a ``lengths`` parameter gets a RuntimeWarning on ragged input."""
    mb = MicroBatcher(max_batch=2, max_wait_s=0.0)
    mb.submit(np.zeros((4, 2), np.float32), now=0.0)
    mb.submit(np.zeros((2, 2), np.float32), now=0.0)
    with pytest.warns(RuntimeWarning, match="lengths"):
        done = mb.run(lambda x: np.ones((x.shape[0], 4), np.float32), now=0.1)
    assert [r.result.shape for r in done] == [(4,), (4,)]


def test_microbatcher_ragged_passes_lengths_when_accepted():
    mb = MicroBatcher(max_batch=2, max_wait_s=0.0)
    mb.submit(np.zeros((4, 2), np.float32), now=0.0)
    mb.submit(np.zeros((2, 2), np.float32), now=0.0)
    seen = {}

    def infer(x, lengths=None):
        seen["lengths"] = lengths
        return np.ones((x.shape[0], 1), np.float32)

    done = mb.run(infer, now=0.1)
    assert len(done) == 2
    np.testing.assert_array_equal(seen["lengths"], [4, 2])


def test_microbatcher_multiqueue_keys_do_not_mix():
    mb = MicroBatcher(max_batch=2, max_wait_s=10.0)
    a = [mb.submit(np.zeros(2), now=0.0, key="a") for _ in range(3)]
    b = [mb.submit(np.ones(2), now=0.0, key="b") for _ in range(2)]
    assert mb.pending("a") == 3 and mb.pending("b") == 2
    assert set(mb.ready_keys(now=0.0)) == {"a", "b"}
    seen = {"a": [], "b": []}
    while mb.pending():
        batch = mb.run(lambda x: x + 1, now=0.1, force=True)
        assert len(batch) <= 2
        keys = {r.key for r in batch}
        assert len(keys) == 1            # one flush never mixes keys
        seen[keys.pop()].extend(r.req_id for r in batch)
    assert seen["a"] == [r.req_id for r in a]       # FIFO within key
    assert seen["b"] == [r.req_id for r in b]
    assert mb.key_stats("a").served == 3
    assert mb.key_stats("b").served == 2


def test_microbatcher_per_key_policy():
    mb = MicroBatcher(max_batch=8, max_wait_s=10.0)
    mb.set_policy("fast", max_batch=1, max_wait_s=0.0)
    mb.submit(np.zeros(2), now=0.0, key="fast")
    mb.submit(np.zeros(2), now=0.0, key="slow")
    assert mb.ready_keys(now=0.0) == ["fast"]       # slow waits for 8/10 s
    assert len(mb.run(lambda x: x, now=0.0)) == 1


def test_microbatcher_latencies_survive_backwards_wallclock(monkeypatch):
    """Regression (ISSUE 7): the batcher stamped arrival/done with wall-clock
    ``time.time()`` while the engines measured with ``perf_counter`` — an
    NTP step backwards between submit and flush produced NEGATIVE latencies
    in KeyStats.  The batcher is monotonic end-to-end now: a time.time()
    that jumps backwards must not be consulted at all."""
    import time as _time

    from repro.serving import batcher as batcher_mod

    wall = iter([1000.0, 999.0, 500.0, 100.0, 3.0])    # NTP stepping back
    monkeypatch.setattr(batcher_mod.time, "time",
                        lambda: next(wall), raising=True)
    mb = MicroBatcher(max_batch=2, max_wait_s=0.0)
    mb.submit(np.zeros(2, np.float32))                 # no now=: real clocks
    _time.sleep(0.001)
    mb.submit(np.zeros(2, np.float32))
    done = mb.run(lambda x: x + 1)
    assert len(done) == 2
    for r in done:
        assert r.latency_s is not None and r.latency_s >= 0.0
    s = mb.key_stats("default")
    assert s.latency_sum_s >= 0.0 and s.latency_max_s >= 0.0
    assert all(v >= 0.0 for v in s.latencies_s)


def test_pad_stack_mixed_dtypes_raise():
    """Regression (ISSUE 7): _pad_stack padded with arrs[0].dtype, silently
    down/up-casting mixed-dtype payloads sharing one queue."""
    with pytest.raises(ValueError, match="mixed payload dtypes"):
        _pad_stack([np.zeros((3, 2), np.float32),
                    np.zeros((2, 2), np.float64)])
    # and through the batcher path
    mb = MicroBatcher(max_batch=2, max_wait_s=0.0)
    mb.submit(np.zeros((3, 2), np.float32), now=0.0)
    mb.submit(np.zeros((2, 2), np.float16), now=0.0)
    with pytest.raises(ValueError, match="mixed payload dtypes"):
        mb.run(lambda x: x, now=0.1, force=True)
    # uniform dtypes still pad fine
    out, lengths, ragged = _pad_stack([np.zeros((3, 2), np.float32),
                                       np.zeros((2, 2), np.float32)])
    assert ragged and out.dtype == np.float32 and list(lengths) == [3, 2]


def test_benchmark_and_mask_ragged_keep_one_trace_per_key(rng):
    """Regression (ISSUE 7): benchmark() and the ragged='mask' path of
    predict_ragged called _predict_key directly, bypassing _pad_rows — each
    distinct batch size stacked an extra trace on the key, silently breaking
    the one-trace-per-key invariant and inflating serve_report's traces."""
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    s = KernelSchedule(reuse_factor=1, mode="static", backend="xla")
    key = schedule_key(s)

    eng = RNNServingEngine(cfg, params, max_batch=8)
    for batch in (2, 4, 7):                       # mixed batch sizes
        eng.benchmark(batch, iters=1, schedule=s)
    assert eng.trace_count(key) == 1

    eng2 = RNNServingEngine(cfg, params, max_batch=8, ragged="mask")
    full = rng.randn(8, 20, 6).astype(np.float32)
    for n in (2, 3, 5):                           # mixed request counts
        outs = eng2.predict_ragged([full[i] for i in range(n)], schedule=s)
        assert len(outs) == n
    assert eng2.trace_count(key) == 1
    # mask-path results still match direct predict row-wise
    want = eng2.predict(full[:3], schedule=s)
    got = np.stack(eng2.predict_ragged([full[i] for i in range(3)],
                                       schedule=s))
    np.testing.assert_array_equal(got, want)


def test_serve_report_does_not_double_count_default_traces(rng):
    """Regression (ISSUE 7): when BOTH the bare default queue and the
    resolved key's own queue saw traffic, serve_report attributed the
    resolved key's trace count to both rows — the same compiles reported
    twice.  The default row now reports traces=0 with a resolved_key
    pointer; the compiles live on the resolved row only."""
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = RNNServingEngine(cfg, params, max_batch=4)
    resolved = schedule_key(*eng.resolve())
    x = rng.randn(4, 20, 6).astype(np.float32)
    for i in range(2):
        eng.batcher.submit(x[i])                       # bare default queue
    for i in range(2, 4):
        eng.submit(x[i], schedule=eng.resolved_schedule)   # resolved queue
    eng.flush(force=True)

    report = eng.serve_report()
    assert report["default"]["resolved_key"] == resolved
    assert report["default"]["traces"] == 0            # never double-counted
    assert report[resolved]["traces"] == eng.trace_count(resolved) == 1
    total_reported = sum(r["traces"] for r in report.values())
    assert total_reported == sum(eng._traces.values())  # exact accounting
    assert report["default"]["measured"]["served"] == 2
    assert report[resolved]["measured"]["served"] == 2


def test_rnn_engine_static_nonstatic_same_predictions(rng):
    cfg = get_config("top-tagging-gru")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x = rng.randn(9, 20, 6).astype(np.float32)
    p1 = RNNServingEngine(cfg, params, mode="static").predict(x)
    p2 = RNNServingEngine(cfg, params, mode="nonstatic").predict(x)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)


def test_rnn_engine_pallas_impl(rng):
    cfg = get_config("top-tagging-lstm")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x = rng.randn(5, 20, 6).astype(np.float32)
    p1 = RNNServingEngine(cfg, params, impl="xla").predict(x)
    p2 = RNNServingEngine(cfg, params, impl="pallas").predict(x)
    np.testing.assert_allclose(p1, p2, rtol=1e-4, atol=1e-5)


def test_lm_engine_continuous_batching_slot_reuse():
    cfg = tiny_config(get_config("stablelm-3b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    a = eng.add_request([3, 4, 5], max_new=2)
    b = eng.add_request([6], max_new=3)
    assert eng.add_request([7]) is None    # full
    done = eng.run_to_completion()
    assert set(done) == {a, b}
    assert len(done[a]) == 3 + 2 and len(done[b]) == 1 + 3
    # slots recycled
    c = eng.add_request([8, 9], max_new=2)
    assert c is not None
    done2 = eng.run_to_completion()
    assert len(done2[c]) == 4


def test_lm_engine_greedy_determinism():
    cfg = tiny_config(get_config("gemma-2b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
        rid = eng.add_request([5, 11, 2], max_new=5)
        outs.append(tuple(eng.run_to_completion()[rid]))
    assert outs[0] == outs[1]


def test_flush_failure_in_one_key_does_not_drop_other_keys(rng):
    """Regression (ISSUE 8): an exception in one key's flush fn used to
    propagate out of run_all mid-drain — requests already queued on OTHER
    keys were silently dropped.  Now exactly the broken key's batch fails
    (error attached, counted), other queues flush normally."""
    cfg = get_config("top-tagging-gru")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = RNNServingEngine(cfg, params, max_batch=4)
    good = KernelSchedule(reuse_factor=1, mode="static", backend="xla")
    bad = KernelSchedule(reuse_factor=2, mode="static", backend="xla")
    bad_key = schedule_key(bad)
    x = rng.randn(4, 20, 6).astype(np.float32)

    good_reqs = [eng.submit(x[i], schedule=good) for i in range(2)]
    bad_reqs = [eng.submit(x[i], schedule=bad) for i in range(2, 4)]
    boom = RuntimeError("kernel fault")

    def raiser(*a, **kw):
        raise boom

    eng._infer_cache[bad_key] = raiser                 # break ONE key
    with pytest.warns(RuntimeWarning, match="other queues unaffected"):
        done = eng.flush(force=True)

    assert len(done) == 4                              # nothing dropped
    for r in good_reqs:                                # healthy key served
        assert r.status == "answered" and r.result is not None
    for r in bad_reqs:                                 # broken key reported
        assert r.status == "failed" and r.error is boom
        assert r.done_s is not None
    assert eng.batcher.key_stats(bad_key).failed == 2
    assert eng.batcher.key_stats(schedule_key(good)).summary()["served"] == 2


def test_bounded_queue_rejects_explicitly(rng):
    """Regression (ISSUE 8): MicroBatcher queues grew without limit under
    overload.  A per-key bound now rejects at submit with QueueFullError —
    counted, never silent."""
    from repro.serving import QueueFullError

    mb = MicroBatcher(max_batch=8, max_queue=2)
    mb.submit(np.zeros(2, np.float32), now=0.0, key="k")
    mb.submit(np.zeros(2, np.float32), now=0.0, key="k")
    with pytest.raises(QueueFullError) as ei:
        mb.submit(np.zeros(2, np.float32), now=0.0, key="k")
    assert ei.value.key == "k" and ei.value.bound == 2
    assert mb.pending("k") == 2                        # bound held
    assert mb.key_stats("k").rejected == 1

    # per-key override: unbounded keys stay unbounded
    mb.set_policy("free", max_queue=None)
    for _ in range(5):
        mb.submit(np.zeros(2, np.float32), now=0.0, key="free")
    assert mb.pending("free") == 5

    # draining frees capacity for the bounded key
    mb.run(lambda x: x, now=0.1, key="k", force=True)
    r = mb.submit(np.zeros(2, np.float32), now=0.2, key="k")
    assert r.status == "pending"
