"""Optimizer + grad compression + train-step machinery."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import OptimizerConfig
from repro.training import adamw_init, adamw_update, lr_schedule
from repro.training.grad_compression import (compress_decompress,
                                             compress_with_error_feedback,
                                             quantize_int8)


def test_adamw_minimizes_quadratic():
    opt = OptimizerConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, grad_clip=0)
    target = jnp.asarray(np.random.RandomState(0).randn(8).astype(np.float32))
    params = {"w": jnp.zeros(8)}
    st = adamw_init(params, opt)
    for _ in range(150):
        g = {"w": 2 * (params["w"] - target)}
        params, st, _ = adamw_update(params, g, st, opt)
    assert float(jnp.max(jnp.abs(params["w"] - target))) < 0.05


def test_grad_clip_bounds_update():
    opt = OptimizerConfig(lr=1.0, warmup_steps=0, total_steps=10,
                          grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    st = adamw_init(params, opt)
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(params, g, st, opt)
    assert float(metrics["grad_norm"]) > 1e5      # raw norm reported


def test_lr_schedule_warmup_and_cosine():
    opt = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_schedule(opt, jnp.asarray(0))) < 0.2
    assert float(lr_schedule(opt, jnp.asarray(9))) == pytest.approx(1.0)
    assert float(lr_schedule(opt, jnp.asarray(109))) < 0.01


def test_no_weight_decay_on_norms():
    opt = OptimizerConfig(lr=0.1, warmup_steps=0, total_steps=10,
                          weight_decay=1.0, grad_clip=0)
    params = {"layer/norm1/scale": jnp.ones(4), "layer/w": jnp.ones(4)}
    st = adamw_init(params, opt)
    g = {k: jnp.zeros(4) for k in params}
    new, _, _ = adamw_update(params, g, st, opt)
    assert float(jnp.max(jnp.abs(new["layer/norm1/scale"] - 1.0))) < 1e-6
    assert float(jnp.max(jnp.abs(new["layer/w"] - 1.0))) > 0.01


def test_int8_quantization_error_bounded():
    g = jnp.asarray(np.random.RandomState(1).randn(256).astype(np.float32))
    q, s = quantize_int8(g)
    err = jnp.abs(q.astype(jnp.float32) * s - g)
    assert float(jnp.max(err)) <= float(s) / 2 + 1e-7


def test_error_feedback_accumulates_lost_mass():
    rng = np.random.RandomState(2)
    grads = {"w": jnp.asarray(rng.randn(64).astype(np.float32))}
    plain = compress_decompress(grads)
    # one-shot loss
    loss1 = float(jnp.sum(jnp.abs(plain["w"] - grads["w"])))
    # error feedback: over repeated identical grads, the RUNNING SUM of
    # transmitted gradients converges to the running sum of true gradients
    err = None
    sent = jnp.zeros(64)
    for i in range(20):
        out, err = compress_with_error_feedback(grads, err)
        sent = sent + out["w"]
    drift = float(jnp.max(jnp.abs(sent - 20 * grads["w"])))
    assert drift <= loss1 + 1e-5       # residual bounded, not accumulating


def test_train_step_grad_accum_matches_full_batch():
    """accum=2 over a linear model == single step on the full batch."""
    from repro.config import TrainConfig
    from repro.models.model import build_model
    from repro.registry import get_config
    from repro.training import make_train_step

    cfg = get_config("top-tagging-gru")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tc = TrainConfig(optimizer=OptimizerConfig(lr=1e-2, warmup_steps=0,
                                               total_steps=10, grad_clip=0,
                                               weight_decay=0))
    x = jnp.asarray(np.random.RandomState(0)
                    .randn(8, 20, 6).astype(np.float32))
    y = jnp.asarray(np.arange(8) % 2, dtype=jnp.int32)
    batch = {"x": x, "y": y}

    s1 = make_train_step(m, tc, grad_accum=1)
    s2 = make_train_step(m, tc, grad_accum=2)
    st = adamw_init(params, tc.optimizer)
    p1, _, m1 = jax.jit(s1)(params, st, batch)
    p2, _, m2 = jax.jit(s2)(params, st, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for k in p1:
        np.testing.assert_allclose(np.asarray(p1[k]), np.asarray(p2[k]),
                                   rtol=1e-4, atol=1e-5)
