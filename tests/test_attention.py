"""Blockwise/decode attention vs the dense reference, swept + property."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (blockwise_attention, decode_attention,
                                    decode_attention_masked, full_attention)


def _qkv(rng, b, sq, sk, h, hk, d):
    q = jnp.asarray(rng.randn(b, sq, h, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, sk, hk, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, sk, hk, d).astype(np.float32))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("h,hk", [(4, 4), (8, 2), (8, 1)])
def test_blockwise_matches_full(causal, window, h, hk, rng):
    q, k, v = _qkv(rng, 2, 33, 33, h, hk, 16)
    o1 = blockwise_attention(q, k, v, causal=causal, window=window,
                             chunk_q=8, chunk_kv=16)
    o2 = full_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


@given(sq=st.integers(1, 40), cq=st.sampled_from([4, 8, 16]),
       ck=st.sampled_from([4, 8, 32]), causal=st.booleans())
@settings(max_examples=12, deadline=None)
def test_blockwise_chunk_invariance(sq, cq, ck, causal):
    r = np.random.RandomState(sq * 7 + cq + ck)
    q, k, v = _qkv(r, 1, sq, sq, 2, 2, 8)
    o1 = blockwise_attention(q, k, v, causal=causal, chunk_q=cq, chunk_kv=ck)
    o2 = full_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=3e-5, atol=3e-5)


def test_q_offset_cross_attention(rng):
    """Chunked-prefill semantics: q block at offset attends causally."""
    q, k, v = _qkv(rng, 1, 8, 24, 2, 2, 8)
    o = blockwise_attention(q, k, v, causal=True, q_offset=16,
                            chunk_q=4, chunk_kv=8)
    full_q = jnp.concatenate(
        [jnp.zeros((1, 16, 2, 8), jnp.float32), q], axis=1)
    o_full = full_attention(full_q, k, v, causal=True)[:, 16:]
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_full),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_respects_cache_len(rng):
    b, S, h, hk, d = 3, 32, 4, 2, 8
    q = jnp.asarray(rng.randn(b, 1, h, d).astype(np.float32))
    kc = jnp.asarray(rng.randn(b, S, hk, d).astype(np.float32))
    vc = jnp.asarray(rng.randn(b, S, hk, d).astype(np.float32))
    lens = jnp.asarray([1, 17, 32])
    o = decode_attention(q, kc, vc, lens)
    for i, L in enumerate([1, 17, 32]):
        o_ref = full_attention(q[i:i + 1], kc[i:i + 1, :L], vc[i:i + 1, :L],
                               causal=False)
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(o_ref[0]),
                                   rtol=2e-5, atol=2e-5)


def test_decode_attention_masked_equals_subset(rng):
    b, S, h, d = 2, 16, 2, 8
    q = jnp.asarray(rng.randn(b, 1, h, d).astype(np.float32))
    kc = jnp.asarray(rng.randn(b, S, h, d).astype(np.float32))
    vc = jnp.asarray(rng.randn(b, S, h, d).astype(np.float32))
    valid = jnp.asarray(rng.rand(b, S) > 0.4)
    valid = valid.at[:, 0].set(True)
    o = decode_attention_masked(q, kc, vc, valid)
    for i in range(b):
        idx = np.where(np.asarray(valid[i]))[0]
        o_ref = full_attention(q[i:i + 1], kc[i:i + 1, idx],
                               vc[i:i + 1, idx], causal=False)
        np.testing.assert_allclose(np.asarray(o[i]), np.asarray(o_ref[0]),
                                   rtol=2e-5, atol=2e-5)


def test_window_attention_equals_truncated_context(rng):
    """window=w must equal full attention over the last w keys per query."""
    q, k, v = _qkv(rng, 1, 12, 12, 2, 2, 8)
    w = 4
    o = full_attention(q, k, v, causal=True, window=w)
    for t in range(12):
        lo = max(0, t - w + 1)
        o_ref = full_attention(q[:, t:t + 1], k[:, lo:t + 1], v[:, lo:t + 1],
                               causal=False)
        np.testing.assert_allclose(np.asarray(o[:, t]),
                                   np.asarray(o_ref[:, 0]),
                                   rtol=2e-5, atol=2e-5)
