"""Minimal deterministic stand-in for ``hypothesis`` when it isn't installed.

``install()`` registers fake ``hypothesis`` / ``hypothesis.strategies``
modules in ``sys.modules`` *before* test collection (conftest.py calls it),
so ``from hypothesis import given, settings, strategies as st`` keeps
working.  ``@given`` degrades to a fixed number of deterministic examples
drawn from the declared strategies with a seeded PRNG — property tests
become parametrized-example tests instead of failing collection.

Only the strategy surface this repo's tests use is implemented:
``integers``, ``sampled_from``, ``booleans``, ``floats``.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_EXAMPLES = 10


class Strategy:
    """A sampler: draw(rnd) -> one example value."""

    def __init__(self, draw, name="strategy"):
        self._draw = draw
        self._name = name

    def draw(self, rnd: random.Random):
        return self._draw(rnd)

    def __repr__(self):
        return f"<stub {self._name}>"


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda r: r.randint(min_value, max_value),
                    f"integers({min_value},{max_value})")


def sampled_from(seq) -> Strategy:
    seq = list(seq)
    return Strategy(lambda r: seq[r.randrange(len(seq))], "sampled_from")


def booleans() -> Strategy:
    return Strategy(lambda r: bool(r.randrange(2)), "booleans")


def floats(min_value=0.0, max_value=1.0, **_kw) -> Strategy:
    return Strategy(lambda r: r.uniform(min_value, max_value), "floats")


def settings(**kw):
    """Decorator recording options (max_examples) for @given to pick up."""
    def deco(fn):
        fn._stub_settings = kw
        return fn
    return deco


def given(**strategies):
    """Replace the property test with a loop over deterministic examples."""
    def deco(fn):
        opts = getattr(fn, "_stub_settings", {})
        n = int(opts.get("max_examples", DEFAULT_EXAMPLES))

        @functools.wraps(fn)
        def runner(*args, **kwargs):
            # seed on the test name so examples are stable across runs
            rnd = random.Random(fn.__name__)
            for i in range(n):
                drawn = {k: s.draw(rnd) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"{fn.__name__} failed on stub-hypothesis example "
                        f"{i}: {drawn!r}") from e

        # @given supplies the strategy args itself; expose only the
        # remaining params (pytest fixtures) to collection
        del runner.__wrapped__
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items()
                if name not in strategies]
        runner.__signature__ = sig.replace(parameters=keep)
        return runner
    return deco


def install() -> bool:
    """Register the stub as ``hypothesis`` if the real package is absent.

    Returns True when the stub was installed, False when real hypothesis
    exists (in which case nothing is touched).
    """
    try:
        import hypothesis  # noqa: F401
        return False
    except ImportError:
        pass

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats"):
        setattr(st_mod, name, globals()[name])
    mod.strategies = st_mod
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
    return True
