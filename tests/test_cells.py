"""Paper-core RNN cells: Keras math, mode equivalence, Table 1 param counts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FixedPointConfig
from repro.core.rnn.cells import gru_cell, lstm_cell
from repro.core.rnn.layer import rnn_layer
from repro.models import build_model, rnn_tagger
from repro.registry import get_config

PAPER_TABLE_1 = {
    "top-tagging-lstm": 3569, "top-tagging-gru": 3089,
    "flavor-tagging-lstm": 67553, "flavor-tagging-gru": 52673,
    "quickdraw-lstm": 134149, "quickdraw-gru": 117637,
}


@pytest.mark.parametrize("arch,expected", sorted(PAPER_TABLE_1.items()))
def test_param_counts_match_paper_table_1(arch, expected):
    cfg = get_config(arch)
    assert cfg.param_count() == expected
    # actual parameter arrays agree with the analytical count
    m = build_model(cfg)
    n = sum(int(np.prod(s.shape)) for s in m.param_specs().values())
    assert n == expected


def _rand_weights(rng, cell, F, H):
    g = 4 if cell == "lstm" else 3
    W = jnp.asarray(rng.randn(F, g * H).astype(np.float32) * 0.3)
    U = jnp.asarray(rng.randn(H, g * H).astype(np.float32) * 0.3)
    shape = (g * H,) if cell == "lstm" else (2, g * H)
    b = jnp.asarray(rng.randn(*shape).astype(np.float32) * 0.1)
    return W, U, b


@pytest.mark.parametrize("arch", ["top-tagging-lstm", "flavor-tagging-gru",
                                  "quickdraw-lstm", "quickdraw-gru"])
def test_static_equals_nonstatic(arch, rng):
    cfg = get_config(arch)
    r = cfg.rnn
    W, U, b = _rand_weights(rng, r.cell, r.input_size, r.hidden)
    xs = jnp.asarray(rng.randn(5, r.seq_len, r.input_size).astype(np.float32))
    h_static = rnn_layer(r, xs, W, U, b, mode="static")
    h_nonstatic = rnn_layer(r, xs, W, U, b, mode="nonstatic")
    # fp32 association differences accumulate over up to 100 recurrent steps
    # (scan vs unroll fuse differently); real gate-order bugs are O(1)
    np.testing.assert_allclose(np.asarray(h_static),
                               np.asarray(h_nonstatic), rtol=5e-3, atol=5e-4)


def test_pallas_impl_equals_xla_impl(rng):
    cfg = get_config("top-tagging-lstm")
    r = cfg.rnn
    W, U, b = _rand_weights(rng, "lstm", r.input_size, r.hidden)
    xs = jnp.asarray(rng.randn(4, r.seq_len, r.input_size).astype(np.float32))
    h_x = rnn_layer(r, xs, W, U, b, impl="xla")
    h_p = rnn_layer(r, xs, W, U, b, impl="pallas")
    np.testing.assert_allclose(np.asarray(h_x), np.asarray(h_p),
                               rtol=1e-5, atol=1e-5)


def test_quantized_cell_outputs_on_grid(rng):
    fp = FixedPointConfig(12, 4)
    cfg = get_config("top-tagging-gru")
    r = cfg.rnn
    W, U, b = _rand_weights(rng, "gru", r.input_size, r.hidden)
    from repro.core.quant.fixed_point import quantize_np
    Wq = jnp.asarray(quantize_np(np.asarray(W), fp))
    Uq = jnp.asarray(quantize_np(np.asarray(U), fp))
    bq = jnp.asarray(quantize_np(np.asarray(b), fp))
    xs = jnp.asarray(rng.randn(3, r.seq_len, r.input_size).astype(np.float32))
    h = rnn_layer(r, xs, Wq, Uq, bq, fp=fp)
    scaled = np.asarray(h) * fp.scale
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-3)


def test_lstm_cell_forget_gate_semantics():
    """With i=0, o=1(ish): state decays by sigmoid(f) each step."""
    H = 4
    W = jnp.zeros((2, 4 * H))
    U = jnp.zeros((H, 4 * H))
    # bias: i very negative (gate 0), f = 0 -> sigmoid 0.5, o very positive
    b = jnp.concatenate([jnp.full((H,), -20.0), jnp.zeros(H),
                         jnp.zeros(H), jnp.full((H,), 20.0)])
    h0 = jnp.zeros((1, H))
    c0 = jnp.ones((1, H))
    _, (h1, c1) = lstm_cell(jnp.zeros((1, 2)), (h0, c0), W, U, b)
    np.testing.assert_allclose(np.asarray(c1), 0.5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.tanh(0.5), atol=1e-4)


def test_gru_cell_update_gate_semantics():
    """z=1 keeps the previous state exactly."""
    H = 3
    W = jnp.zeros((2, 3 * H))
    U = jnp.zeros((H, 3 * H))
    b = jnp.zeros((2, 3 * H)).at[0, :H].set(30.0)      # z -> 1
    h0 = jnp.full((1, H), 0.7)
    _, h1 = gru_cell(jnp.ones((1, 2)), h0, W, U, b)
    np.testing.assert_allclose(np.asarray(h1), 0.7, atol=1e-5)


def test_tagger_forward_shapes_and_probs(rng):
    for arch, n_out in [("top-tagging-lstm", 1), ("flavor-tagging-gru", 3),
                        ("quickdraw-gru", 5)]:
        cfg = get_config(arch)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        x = jnp.asarray(rng.randn(7, cfg.rnn.seq_len,
                                  cfg.rnn.input_size).astype(np.float32))
        p = np.asarray(rnn_tagger.forward(cfg, params, x))
        assert p.shape == (7, n_out)
        assert np.all(p >= 0) and np.all(p <= 1)
        if n_out > 1:
            np.testing.assert_allclose(p.sum(-1), 1.0, rtol=1e-5)
