"""Golden-model conformance for the reuse-factor scheduling layer.

Every (kernel x mode x reuse_factor x dtype) cell must match the XLA
``lax.scan`` reference within dtype tolerance, and the HLS estimates must be
computed from the SAME schedule object the kernel executes, with the paper's
monotone trade-off: latency rises and DSP falls as reuse_factor grows.

Hoisted-input cells additionally must be BIT-IDENTICAL to their in-loop
counterpart at the same (mode, R, dtype): hoisting only moves the xW half of
(xW + hU) + b outside the scan without changing the association order.
"""

import numpy as np
import pytest

from repro.core.hls.resources import estimate_schedule
from repro.kernels.schedule import BACKENDS, MODES, KernelSchedule
from repro.registry import get_config
from repro.testing import (assert_schedule_conformance,
                           make_kernel_inputs)

REUSE_FACTORS = (1, 2, 4, 8)
CELLS = ("lstm", "gru")
#: modes with a hoisted/in-loop PAIR (pipeline forces hoist_input, so its
#: in-loop counterpart is the nonstatic schedule, covered separately)
PAIRED_MODES = ("static", "nonstatic")


def _sched(reuse, mode, block_batch=8, **kw):
    return KernelSchedule(reuse_factor=reuse, mode=mode,
                          block_batch=block_batch,
                          backend="pallas_interpret", **kw)


def _assert_hoisted_bitmatch(kernel, sched, *, dtype="float32", seed=0,
                             **shape_kw):
    """Hoisted output must equal the in-loop output bit-for-bit."""
    from repro.kernels import ops

    scheduled, _ = ops.SCHEDULED_KERNELS[kernel]
    inputs = make_kernel_inputs(kernel, dtype=dtype, seed=seed, **shape_kw)
    hoisted = np.asarray(
        scheduled(*inputs, schedule=sched.replace(hoist_input=True)),
        np.float32)
    in_loop = np.asarray(scheduled(*inputs, schedule=sched), np.float32)
    np.testing.assert_array_equal(
        hoisted, in_loop,
        err_msg=f"hoisted != in-loop for {kernel} under {sched.key()} "
                f"(dtype={dtype}, shapes={shape_kw})")


# ---------------------------------------------------------------------------
# The acceptance sweep: {lstm, gru} x {static, nonstatic} x {1, 2, 4, 8}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_cell_schedule_conformance(cell, mode, reuse):
    assert_schedule_conformance(cell, _sched(reuse, mode),
                                B=4, T=10, F=6, H=20, seed=reuse)


@pytest.mark.parametrize("reuse", (1, 4))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_cell_schedule_conformance_bf16(cell, mode, reuse):
    assert_schedule_conformance(cell, _sched(reuse, mode), dtype="bfloat16",
                                B=4, T=8, F=6, H=20, seed=3)


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", MODES)
def test_rglru_schedule_conformance(mode, reuse):
    assert_schedule_conformance("rglru", _sched(reuse, mode),
                                B=3, T=9, H=128, seed=reuse)


@pytest.mark.parametrize("reuse", REUSE_FACTORS + (16,))
def test_reuse_matmul_schedule_conformance(reuse):
    assert_schedule_conformance("reuse_matmul", _sched(reuse, "static"),
                                M=33, K=64, N=48, seed=reuse)


def test_xla_backend_is_the_golden_model():
    """backend='xla' must be exactly the reference (error 0 by identity)."""
    s = KernelSchedule(backend="xla")
    for cell in CELLS:
        err = assert_schedule_conformance(cell, s, B=3, T=7, F=4, H=12)
        assert err == 0.0


# ---------------------------------------------------------------------------
# Edge shapes through the scheduling layer: ragged batch, T=1, off-lane H
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", (1, 3, 9))          # not multiples of 8
@pytest.mark.parametrize("cell", CELLS)
def test_ragged_batch(cell, B):
    assert_schedule_conformance(cell, _sched(2, "static"),
                                B=B, T=6, F=5, H=20, seed=B)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_single_timestep(cell, mode):
    assert_schedule_conformance(cell, _sched(4, mode), B=4, T=1, F=6, H=20)


@pytest.mark.parametrize("H", (20, 100, 130))     # off the 128-lane boundary
@pytest.mark.parametrize("cell", CELLS)
def test_off_lane_hidden(cell, H):
    assert_schedule_conformance(cell, _sched(4, "static"),
                                B=4, T=5, F=6, H=H, seed=H)


def test_ragged_reuse_degrades_to_divisor():
    """4h=52 is not divisible by 8: effective reuse falls back to gcd."""
    s = _sched(8, "static")
    assert s.effective_reuse(4 * 13) == 4
    assert_schedule_conformance("lstm", s, B=2, T=4, F=3, H=13)


def test_rglru_ragged_width():
    assert_schedule_conformance("rglru", _sched(4, "static"),
                                B=5, T=7, H=200)


# ---------------------------------------------------------------------------
# Hoisted input projection: bit-identical to the in-loop path for every
# (kernel x mode x R x dtype) pair, plus the pipeline (NONSTATIC) mode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", PAIRED_MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_hoisted_bitmatch(cell, mode, reuse):
    _assert_hoisted_bitmatch(cell, _sched(reuse, mode),
                             B=4, T=10, F=6, H=20, seed=reuse)
    # and the hoisted cell still conforms to the golden model
    assert_schedule_conformance(cell, _sched(reuse, mode, hoist_input=True),
                                B=4, T=10, F=6, H=20, seed=reuse)


@pytest.mark.parametrize("reuse", (1, 4))
@pytest.mark.parametrize("mode", PAIRED_MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_hoisted_bitmatch_bf16(cell, mode, reuse):
    _assert_hoisted_bitmatch(cell, _sched(reuse, mode), dtype="bfloat16",
                             B=4, T=8, F=6, H=20, seed=3)


@pytest.mark.parametrize("B", (1, 3, 9))          # not multiples of 8
@pytest.mark.parametrize("cell", CELLS)
def test_hoisted_ragged_batch(cell, B):
    _assert_hoisted_bitmatch(cell, _sched(2, "static"),
                             B=B, T=6, F=5, H=20, seed=B)


@pytest.mark.parametrize("mode", PAIRED_MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_hoisted_single_timestep(cell, mode):
    _assert_hoisted_bitmatch(cell, _sched(4, mode), B=4, T=1, F=6, H=20)


@pytest.mark.parametrize("H", (20, 100, 130))     # off the 128-lane boundary
@pytest.mark.parametrize("cell", CELLS)
def test_hoisted_off_lane_hidden(cell, H):
    _assert_hoisted_bitmatch(cell, _sched(4, "static"),
                             B=4, T=5, F=6, H=H, seed=H)


def test_hoisted_fin_approx_h():
    """The hoist's target regime (per-step FLOPs halve when fin ~ h)."""
    for cell in CELLS:
        _assert_hoisted_bitmatch(cell, _sched(4, "static"),
                                 B=9, T=6, F=24, H=24)


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("cell", CELLS)
def test_pipeline_conformance(cell, reuse):
    """Pipeline mode (fused hoisted NONSTATIC kernel) conforms to the
    golden model for every R, including the hr-tiled hoist stage."""
    assert_schedule_conformance(cell, _sched(reuse, "pipeline"),
                                B=4, T=10, F=6, H=20, seed=reuse)
    assert_schedule_conformance(
        cell, _sched(reuse, "pipeline", hoist_reuse=4),
        B=4, T=10, F=6, H=20, seed=reuse)


@pytest.mark.parametrize("cell", CELLS)
def test_pipeline_edge_shapes(cell):
    assert_schedule_conformance(cell, _sched(4, "pipeline"),
                                B=3, T=1, F=5, H=20)
    assert_schedule_conformance(cell, _sched(4, "pipeline"),
                                B=9, T=6, F=5, H=130)
    assert_schedule_conformance(cell, _sched(4, "pipeline"),
                                dtype="bfloat16", B=4, T=8, F=6, H=20)


def test_hoisted_xla_layer_preserves_dtype():
    """The hoisted XLA path must keep the in-loop carry dtype (a f32 zx on
    a bfloat16 scan used to crash lax.scan's carry type check) and stay
    close to the in-loop result in both static and unrolled modes."""
    import jax.numpy as jnp

    from repro.core.rnn.layer import rnn_layer
    from repro.registry import get_config

    rnn = get_config("top-tagging-lstm").rnn
    for dtype in ("float32", "bfloat16"):
        xs, W, U, b = make_kernel_inputs("lstm", B=4, T=rnn.seq_len,
                                         F=rnn.input_size, H=rnn.hidden,
                                         dtype=dtype)
        for mode in ("static", "nonstatic", "pipeline"):
            s = KernelSchedule(mode=mode, hoist_input=True, backend="xla")
            out = rnn_layer(rnn, xs, W, U, b, impl="xla", schedule=s)
            assert out.dtype == jnp.dtype(dtype), (mode, dtype)
            ref = rnn_layer(rnn, xs, W, U, b, impl="xla",
                            schedule=KernelSchedule(mode=mode,
                                                    backend="xla"))
            tol = 3e-5 if dtype == "float32" else 2e-2
            assert float(jnp.max(jnp.abs(
                out.astype(jnp.float32) - ref.astype(jnp.float32)))) <= tol


def test_engine_mode_override_survives_pipeline_ii_request():
    """An engine pinned to another mode replaces the mode on an incoming
    pipeline(ii=...) schedule — the ii knob must normalize away instead of
    raising (the serving mode-override path)."""
    s = KernelSchedule(mode="pipeline", ii=1, reuse_factor=4)
    assert s.replace(mode="static").ii == 0
    assert s.replace(mode="static").key().count("ii") == 0


def test_hoist_stage_tpu_alignment_checked():
    """The hoist stage's own column tiles are validated for pallas_tpu —
    a misaligned hoist_reuse tile must raise, not miscompile."""
    from repro.kernels import ops

    xs, W, U, b = make_kernel_inputs("gru", B=8, T=4, F=6, H=128)
    # 3h = 384 is 128-aligned at R=1, but hoist tiles of 384/4 = 96 are not
    bad = KernelSchedule(mode="pipeline", hoist_reuse=4, backend="pallas_tpu",
                         block_batch=8)
    with pytest.raises(ValueError, match="hoist_stage"):
        ops.gru_scan(xs, W, U, b, schedule=bad)


def test_rglru_hoist_is_noop():
    """The RG-LRU kernel is already in hoisted form (bx is a precomputed
    gated input): hoist_input must be accepted and change nothing."""
    _assert_hoisted_bitmatch("rglru", _sched(4, "static"), B=3, T=9, H=128)
    _assert_hoisted_bitmatch("rglru", _sched(2, "nonstatic"),
                             B=3, T=9, H=128)


# ---------------------------------------------------------------------------
# TPU lane-alignment validation (ROADMAP open item): pallas_tpu schedules
# with misaligned column tiles must raise instead of miscompiling
# ---------------------------------------------------------------------------


def test_tpu_alignment_rejects_misaligned_tiles():
    from repro.kernels.ops import check_tpu_alignment

    tpu = KernelSchedule(backend="pallas_tpu", reuse_factor=2)
    # 4h = 80, R = 2 -> gw = 40: not a 128 multiple
    with pytest.raises(ValueError, match="multiple of 128"):
        check_tpu_alignment(tpu, tile_width=40, block_batch=8,
                            kernel="lstm_scan")
    with pytest.raises(ValueError, match="sublanes"):
        check_tpu_alignment(tpu, tile_width=256, block_batch=5,
                            kernel="lstm_scan")
    # aligned tiles pass; non-TPU backends are exempt (interpret pads)
    check_tpu_alignment(tpu, tile_width=256, block_batch=8, kernel="x")
    check_tpu_alignment(_sched(2, "static"), tile_width=40, block_batch=5,
                        kernel="x")


def test_tpu_alignment_enforced_at_dispatch():
    """The scan dispatch applies the check before building the kernel (the
    error surfaces at trace time, not as a Mosaic miscompile)."""
    from repro.kernels import ops

    xs, W, U, b = make_kernel_inputs("lstm", B=8, T=4, F=6, H=20)
    bad = KernelSchedule(reuse_factor=2, backend="pallas_tpu",
                         block_batch=8)
    with pytest.raises(ValueError, match="multiple of 128"):
        ops.lstm_scan(xs, W, U, b, schedule=bad)
    xs, W, U, b = make_kernel_inputs("gru", B=8, T=4, F=6, H=20)
    with pytest.raises(ValueError, match="multiple of 128"):
        ops.gru_scan(xs, W, U, b, schedule=bad)


# ---------------------------------------------------------------------------
# schedule_key forward compatibility: PR 2-era keys parse after the new
# axes landed, unknown future axes are ignored, malformed cores raise
# ---------------------------------------------------------------------------


def test_from_key_parses_pr2_era_keys():
    """Keys minted before the hoist/pipeline axes existed must still parse
    to the schedule they named (all new axes at their defaults)."""
    for key in ("static-R4-bb128-auto",
                "nonstatic-R2-bb8-pallas_interpret",
                "static-R1-bb128-xla-ap16_6_rnd_sat"):
        s = KernelSchedule.from_key(key)
        assert not s.hoist_input and s.ii == 0 and s.hoist_reuse == 1
        assert key.startswith(s.key())


def test_from_key_ignores_unknown_fields():
    """A key minted by a FUTURE build with extra axes still parses here —
    known tokens apply, unknown ones are skipped."""
    s = KernelSchedule.from_key(
        "static-R4-bb128-auto-hoist-newaxis7-zz3-ap16_6_rnd_sat")
    assert s.hoist_input and s.reuse_factor == 4
    assert s == KernelSchedule(reuse_factor=4, hoist_input=True)


def test_from_key_roundtrips_new_axes():
    for s in (_sched(4, "pipeline"),
              _sched(4, "pipeline", ii=1),
              _sched(2, "static", hoist_input=True, hoist_reuse=4),
              _sched(2, "nonstatic", hoist_input=True)):
        assert KernelSchedule.from_key(s.key()) == s


def test_from_key_rejects_malformed_cores():
    for bad in ("", "static", "static-R4", "static-X4-bb8-auto",
                "static-R4-b8-auto"):
        with pytest.raises(ValueError):
            KernelSchedule.from_key(bad)


# ---------------------------------------------------------------------------
# Schedule object semantics + HLS estimates from the same object
# ---------------------------------------------------------------------------


def test_schedule_validation():
    with pytest.raises(ValueError):
        KernelSchedule(reuse_factor=0)
    with pytest.raises(ValueError):
        KernelSchedule(mode="pipelined")
    with pytest.raises(ValueError):
        KernelSchedule(backend="cuda")
    assert all(b in BACKENDS for b in ("xla", "auto"))
    # new-axis validation
    with pytest.raises(ValueError):
        KernelSchedule(ii=-1, mode="pipeline")
    with pytest.raises(ValueError):
        KernelSchedule(hoist_reuse=0)
    with pytest.raises(ValueError):
        KernelSchedule(hoist_reuse=2)              # no hoisted GEMM to tile
    # ii is a pipeline-only knob: on other modes it normalizes to 0 so the
    # mode-override path (engine / rnn_layer replace(mode=...)) stays total
    # and keys of semantically equal schedules collide as they should
    assert KernelSchedule(ii=2, mode="static").ii == 0
    p = KernelSchedule(mode="pipeline", ii=1)
    n = p.replace(mode="nonstatic")
    assert n.ii == 0 and n == KernelSchedule(mode="nonstatic",
                                             hoist_input=True)


def test_pipeline_mode_forces_hoist():
    """Pipelining REQUIRES the hoist (only slimmed blocks can free up at
    ii); the constructor enforces the implication."""
    s = KernelSchedule(mode="pipeline", reuse_factor=4)
    assert s.hoist_input
    assert "pipeline" in MODES


def test_schedule_sweep_grid():
    grid = KernelSchedule.sweep()
    n = len(MODES) * 4                     # modes x default reuse factors
    assert len(grid) == n
    assert len(set(grid)) == n             # hashable + distinct
    assert {s.mode for s in grid} == set(MODES)


def test_sequential_steps_and_ii():
    s = KernelSchedule(reuse_factor=4, mode="static")
    assert s.sequential_steps(20) == 80
    assert s.initiation_interval(20) == 80
    n = s.replace(mode="nonstatic")
    assert n.initiation_interval(20) == 4  # one block latency

    # same kernel, same grid: the Pallas static grid is (B/bt, T, R) whose
    # sequential length is exactly sequential_steps
    assert s.sequential_steps(20) == 20 * s.reuse_factor

    # pipeline: the recurrence chain (sequential steps) is irreducible but
    # the II drops to the explicit target (default: one block's R passes)
    p = KernelSchedule(reuse_factor=4, mode="pipeline")
    assert p.sequential_steps(20) == 80
    assert p.initiation_interval(20) == 4
    assert p.replace(ii=1).initiation_interval(20) == 1
    # hoisting alone changes neither axis — it shrinks the working set
    h = s.replace(hoist_input=True)
    assert h.sequential_steps(20) == s.sequential_steps(20)
    assert h.initiation_interval(20) == s.initiation_interval(20)


@pytest.mark.parametrize("cell", CELLS)
def test_estimates_monotone_in_reuse(cell):
    """Latency rises and DSP falls as R grows — from the SAME schedule
    objects the conformance sweep executed (acceptance criterion).

    hidden=24 makes every swept R an exact divisor of both 4h and 3h, so
    effective reuse == requested reuse across the sweep.
    """
    import dataclasses

    rnn = dataclasses.replace(get_config(f"top-tagging-{cell}").rnn,
                              hidden=24)
    ests = [estimate_schedule(_sched(r, "static"), rnn)
            for r in REUSE_FACTORS]
    lat = [e.latency_cycles for e in ests]
    dsp = [e.dsp for e in ests]
    vmem = [e.vmem_bytes for e in ests]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a > b for a, b in zip(dsp, dsp[1:])), dsp
    assert all(a >= b for a, b in zip(vmem, vmem[1:])), vmem


def test_estimate_prices_effective_reuse():
    """For non-divisor R the kernel clamps reuse to gcd (ops.py); the
    estimate must describe the schedule that actually executes, not the
    requested one."""
    rnn = get_config("top-tagging-gru").rnn        # 3h = 60, gcd(8, 60) = 4
    assert _sched(8, "static").effective_reuse(3 * rnn.hidden) == 4
    e8 = estimate_schedule(_sched(8, "static"), rnn)
    e4 = estimate_schedule(_sched(4, "static"), rnn)
    assert (e8.latency_cycles, e8.ii_cycles, e8.dsp, e8.vmem_bytes) == \
        (e4.latency_cycles, e4.ii_cycles, e4.dsp, e4.vmem_bytes)


def test_nonstatic_resource_blowup_static_ii_blowup():
    """Paper Table 5 / Fig. 6: non-static replicates resources x seq_len but
    drops II to one block; static is the reverse."""
    rnn = get_config("top-tagging-gru").rnn
    st = estimate_schedule(_sched(1, "static"), rnn)
    ns = estimate_schedule(_sched(1, "nonstatic"), rnn)
    assert ns.dsp == rnn.seq_len * st.dsp
    assert ns.ii_cycles < st.ii_cycles


def test_hoisted_estimate_shrinks_sequential_working_set():
    """Hoisting drops the per-block sequential mults from (fin+h)*G*h to
    h*G*h: the replicated-block DSP/BRAM shrink (the shared hoist GEMM is
    counted once), and at fin ~ h the live VMEM tile shrinks too."""
    import dataclasses

    rnn = dataclasses.replace(get_config("flavor-tagging-lstm").rnn,
                              input_size=120)        # fin ~ h regime
    for mode in ("static", "nonstatic"):
        for r in (1, 4):
            inl = estimate_schedule(_sched(r, mode), rnn)
            hst = estimate_schedule(_sched(r, mode, hoist_input=True), rnn)
            if mode == "nonstatic":
                # seq_len-replicated blocks: hoisting must win on DSP/BRAM
                assert hst.dsp < inl.dsp, (mode, r)
                assert hst.bram_18k < inl.bram_18k, (mode, r)
            assert hst.vmem_bytes < inl.vmem_bytes, (mode, r)
            # the front-stage GEMM adds latency cycles; the chain stays
            assert hst.latency_cycles >= inl.latency_cycles
            assert hst.ii_cycles == inl.ii_cycles


def test_pipeline_estimate_ii_target():
    """Pipeline mode prices the II at the schedule's target while the
    per-inference latency keeps the irreducible recurrence chain."""
    rnn = get_config("flavor-tagging-lstm").rnn
    st = estimate_schedule(_sched(4, "static"), rnn)
    pl = estimate_schedule(_sched(4, "pipeline"), rnn)
    pl1 = estimate_schedule(_sched(4, "pipeline", ii=1), rnn)
    assert pl.ii_cycles == 4 and pl1.ii_cycles == 1
    assert st.ii_cycles == rnn.seq_len * 4
    assert pl.latency_cycles >= st.latency_cycles     # chain + hoist stage
    # throughput is the point: Table 5's II 315 -> 1 shape
    assert pl1.throughput_eps() > 50 * st.throughput_eps()
    # resources replicate x seq_len like nonstatic (Fig. 6), minus the
    # hoisted kernel-GEMM which is shared
    ns = estimate_schedule(_sched(4, "nonstatic"), rnn)
    assert pl.dsp < ns.dsp


def test_design_bridge_prices_hoist_and_pipeline():
    """estimate_design_for_schedule consumes the new axes: hoisting removes
    the kernel GEMM from the replicated blocks, pipeline sets the II."""
    from repro.core.hls import estimate_design_for_schedule
    cfg = get_config("flavor-tagging-lstm")
    inl = estimate_design_for_schedule(cfg, _sched(4, "nonstatic"))
    hst = estimate_design_for_schedule(
        cfg, _sched(4, "nonstatic", hoist_input=True))
    assert hst.bram_18k < inl.bram_18k
    pl = estimate_design_for_schedule(cfg, _sched(4, "pipeline"))
    assert pl.ii_cycles == 4
    pl1 = estimate_design_for_schedule(cfg, _sched(4, "pipeline", ii=1))
    assert pl1.ii_cycles == 1
    assert pl1.throughput_eps > inl.throughput_eps


def test_design_bridge_uses_schedule():
    """The table-calibrated design model prices the same schedule object
    (R values are divisors of the GRU gate dim, so effective == requested)."""
    from repro.core.hls import estimate_design_for_schedule
    cfg = get_config("top-tagging-gru")
    designs = [estimate_design_for_schedule(cfg, _sched(r, "static"))
               for r in (1, 2, 6, 12)]
    lat = [d.latency_min_us for d in designs]
    dsp = [d.dsp for d in designs]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a >= b for a, b in zip(dsp, dsp[1:])), dsp

    # a non-divisor request is priced as the design that executes
    d8 = estimate_design_for_schedule(cfg, _sched(8, "static"))
    d4 = estimate_design_for_schedule(cfg, _sched(4, "static"))
    assert d8 == d4


def test_resolve_honors_schedule_block_batch():
    """A caller-supplied schedule's block_batch survives dispatch (rglru
    used to clobber it with its per-kernel default)."""
    from repro.kernels.ops import _resolve

    s = KernelSchedule(block_batch=64)
    assert _resolve(s, None).block_batch == 64
    assert _resolve(s, None, default_bb=8).block_batch == 64
    assert _resolve(None, None, default_bb=8).block_batch == 8
    assert _resolve(s, 16).block_batch == 16   # explicit arg still wins


def test_tiled_matmul_matches_untiled():
    """Column tiling at the cell level matches the full matmul to fp32
    accumulation-order tolerance for any divisor R."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rnn.cells import tiled_matmul

    r = np.random.RandomState(7)
    x = jnp.asarray(r.randn(5, 12).astype(np.float32))
    w = jnp.asarray(r.randn(12, 24).astype(np.float32))
    base = np.asarray(x @ w)
    for reuse in (1, 2, 3, 4, 6, 8, 12, 24):
        np.testing.assert_allclose(
            np.asarray(tiled_matmul(x, w, reuse)), base,
            rtol=1e-6, atol=1e-6)


def test_config_picks_schedule():
    """Models resolve their schedule from config; explicit schedule wins."""
    import dataclasses

    rnn = get_config("top-tagging-lstm").rnn
    assert rnn.kernel_schedule() == KernelSchedule(
        reuse_factor=rnn.reuse_kernel, mode=rnn.mode)
    s = KernelSchedule(reuse_factor=4, mode="nonstatic")
    rnn2 = dataclasses.replace(rnn, schedule=s)
    assert rnn2.kernel_schedule() is s


def test_layer_routes_schedule_through_pallas():
    """rnn_layer(impl='pallas', schedule=...) matches the XLA layer."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rnn.layer import rnn_layer
    from repro.testing import make_kernel_inputs

    rnn = get_config("top-tagging-lstm").rnn
    xs, W, U, b = make_kernel_inputs("lstm", B=5, T=rnn.seq_len,
                                     F=rnn.input_size, H=rnn.hidden)
    ref = rnn_layer(rnn, xs, W, U, b, impl="xla")
    for s in (KernelSchedule(reuse_factor=4, backend="pallas_interpret"),
              KernelSchedule(reuse_factor=2, mode="nonstatic",
                             backend="pallas_interpret")):
        out = rnn_layer(rnn, xs, W, U, b, impl="pallas", schedule=s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
