"""Golden-model conformance for the reuse-factor scheduling layer.

Every (kernel x mode x reuse_factor x dtype) cell must match the XLA
``lax.scan`` reference within dtype tolerance, and the HLS estimates must be
computed from the SAME schedule object the kernel executes, with the paper's
monotone trade-off: latency rises and DSP falls as reuse_factor grows.
"""

import pytest

from repro.core.hls.resources import estimate_schedule
from repro.kernels.schedule import BACKENDS, MODES, KernelSchedule
from repro.registry import get_config
from repro.testing import assert_schedule_conformance

REUSE_FACTORS = (1, 2, 4, 8)
CELLS = ("lstm", "gru")


def _sched(reuse, mode, block_batch=8):
    return KernelSchedule(reuse_factor=reuse, mode=mode,
                          block_batch=block_batch,
                          backend="pallas_interpret")


# ---------------------------------------------------------------------------
# The acceptance sweep: {lstm, gru} x {static, nonstatic} x {1, 2, 4, 8}
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_cell_schedule_conformance(cell, mode, reuse):
    assert_schedule_conformance(cell, _sched(reuse, mode),
                                B=4, T=10, F=6, H=20, seed=reuse)


@pytest.mark.parametrize("reuse", (1, 4))
@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_cell_schedule_conformance_bf16(cell, mode, reuse):
    assert_schedule_conformance(cell, _sched(reuse, mode), dtype="bfloat16",
                                B=4, T=8, F=6, H=20, seed=3)


@pytest.mark.parametrize("reuse", REUSE_FACTORS)
@pytest.mark.parametrize("mode", MODES)
def test_rglru_schedule_conformance(mode, reuse):
    assert_schedule_conformance("rglru", _sched(reuse, mode),
                                B=3, T=9, H=128, seed=reuse)


@pytest.mark.parametrize("reuse", REUSE_FACTORS + (16,))
def test_reuse_matmul_schedule_conformance(reuse):
    assert_schedule_conformance("reuse_matmul", _sched(reuse, "static"),
                                M=33, K=64, N=48, seed=reuse)


def test_xla_backend_is_the_golden_model():
    """backend='xla' must be exactly the reference (error 0 by identity)."""
    s = KernelSchedule(backend="xla")
    for cell in CELLS:
        err = assert_schedule_conformance(cell, s, B=3, T=7, F=4, H=12)
        assert err == 0.0


# ---------------------------------------------------------------------------
# Edge shapes through the scheduling layer: ragged batch, T=1, off-lane H
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", (1, 3, 9))          # not multiples of 8
@pytest.mark.parametrize("cell", CELLS)
def test_ragged_batch(cell, B):
    assert_schedule_conformance(cell, _sched(2, "static"),
                                B=B, T=6, F=5, H=20, seed=B)


@pytest.mark.parametrize("mode", MODES)
@pytest.mark.parametrize("cell", CELLS)
def test_single_timestep(cell, mode):
    assert_schedule_conformance(cell, _sched(4, mode), B=4, T=1, F=6, H=20)


@pytest.mark.parametrize("H", (20, 100, 130))     # off the 128-lane boundary
@pytest.mark.parametrize("cell", CELLS)
def test_off_lane_hidden(cell, H):
    assert_schedule_conformance(cell, _sched(4, "static"),
                                B=4, T=5, F=6, H=H, seed=H)


def test_ragged_reuse_degrades_to_divisor():
    """4h=52 is not divisible by 8: effective reuse falls back to gcd."""
    s = _sched(8, "static")
    assert s.effective_reuse(4 * 13) == 4
    assert_schedule_conformance("lstm", s, B=2, T=4, F=3, H=13)


def test_rglru_ragged_width():
    assert_schedule_conformance("rglru", _sched(4, "static"),
                                B=5, T=7, H=200)


# ---------------------------------------------------------------------------
# Schedule object semantics + HLS estimates from the same object
# ---------------------------------------------------------------------------


def test_schedule_validation():
    with pytest.raises(ValueError):
        KernelSchedule(reuse_factor=0)
    with pytest.raises(ValueError):
        KernelSchedule(mode="pipelined")
    with pytest.raises(ValueError):
        KernelSchedule(backend="cuda")
    assert all(b in BACKENDS for b in ("xla", "auto"))


def test_schedule_sweep_grid():
    grid = KernelSchedule.sweep()
    assert len(grid) == 8
    assert len(set(grid)) == 8             # hashable + distinct
    assert {s.mode for s in grid} == set(MODES)


def test_sequential_steps_and_ii():
    s = KernelSchedule(reuse_factor=4, mode="static")
    assert s.sequential_steps(20) == 80
    assert s.initiation_interval(20) == 80
    n = s.replace(mode="nonstatic")
    assert n.initiation_interval(20) == 4  # one block latency

    # same kernel, same grid: the Pallas static grid is (B/bt, T, R) whose
    # sequential length is exactly sequential_steps
    assert s.sequential_steps(20) == 20 * s.reuse_factor


@pytest.mark.parametrize("cell", CELLS)
def test_estimates_monotone_in_reuse(cell):
    """Latency rises and DSP falls as R grows — from the SAME schedule
    objects the conformance sweep executed (acceptance criterion).

    hidden=24 makes every swept R an exact divisor of both 4h and 3h, so
    effective reuse == requested reuse across the sweep.
    """
    import dataclasses

    rnn = dataclasses.replace(get_config(f"top-tagging-{cell}").rnn,
                              hidden=24)
    ests = [estimate_schedule(_sched(r, "static"), rnn)
            for r in REUSE_FACTORS]
    lat = [e.latency_cycles for e in ests]
    dsp = [e.dsp for e in ests]
    vmem = [e.vmem_bytes for e in ests]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a > b for a, b in zip(dsp, dsp[1:])), dsp
    assert all(a >= b for a, b in zip(vmem, vmem[1:])), vmem


def test_estimate_prices_effective_reuse():
    """For non-divisor R the kernel clamps reuse to gcd (ops.py); the
    estimate must describe the schedule that actually executes, not the
    requested one."""
    rnn = get_config("top-tagging-gru").rnn        # 3h = 60, gcd(8, 60) = 4
    assert _sched(8, "static").effective_reuse(3 * rnn.hidden) == 4
    e8 = estimate_schedule(_sched(8, "static"), rnn)
    e4 = estimate_schedule(_sched(4, "static"), rnn)
    assert (e8.latency_cycles, e8.ii_cycles, e8.dsp, e8.vmem_bytes) == \
        (e4.latency_cycles, e4.ii_cycles, e4.dsp, e4.vmem_bytes)


def test_nonstatic_resource_blowup_static_ii_blowup():
    """Paper Table 5 / Fig. 6: non-static replicates resources x seq_len but
    drops II to one block; static is the reverse."""
    rnn = get_config("top-tagging-gru").rnn
    st = estimate_schedule(_sched(1, "static"), rnn)
    ns = estimate_schedule(_sched(1, "nonstatic"), rnn)
    assert ns.dsp == rnn.seq_len * st.dsp
    assert ns.ii_cycles < st.ii_cycles


def test_design_bridge_uses_schedule():
    """The table-calibrated design model prices the same schedule object
    (R values are divisors of the GRU gate dim, so effective == requested)."""
    from repro.core.hls import estimate_design_for_schedule
    cfg = get_config("top-tagging-gru")
    designs = [estimate_design_for_schedule(cfg, _sched(r, "static"))
               for r in (1, 2, 6, 12)]
    lat = [d.latency_min_us for d in designs]
    dsp = [d.dsp for d in designs]
    assert all(a < b for a, b in zip(lat, lat[1:])), lat
    assert all(a >= b for a, b in zip(dsp, dsp[1:])), dsp

    # a non-divisor request is priced as the design that executes
    d8 = estimate_design_for_schedule(cfg, _sched(8, "static"))
    d4 = estimate_design_for_schedule(cfg, _sched(4, "static"))
    assert d8 == d4


def test_resolve_honors_schedule_block_batch():
    """A caller-supplied schedule's block_batch survives dispatch (rglru
    used to clobber it with its per-kernel default)."""
    from repro.kernels.ops import _resolve

    s = KernelSchedule(block_batch=64)
    assert _resolve(s, None).block_batch == 64
    assert _resolve(s, None, default_bb=8).block_batch == 64
    assert _resolve(None, None, default_bb=8).block_batch == 8
    assert _resolve(s, 16).block_batch == 16   # explicit arg still wins


def test_tiled_matmul_matches_untiled():
    """Column tiling at the cell level matches the full matmul to fp32
    accumulation-order tolerance for any divisor R."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rnn.cells import tiled_matmul

    r = np.random.RandomState(7)
    x = jnp.asarray(r.randn(5, 12).astype(np.float32))
    w = jnp.asarray(r.randn(12, 24).astype(np.float32))
    base = np.asarray(x @ w)
    for reuse in (1, 2, 3, 4, 6, 8, 12, 24):
        np.testing.assert_allclose(
            np.asarray(tiled_matmul(x, w, reuse)), base,
            rtol=1e-6, atol=1e-6)


def test_config_picks_schedule():
    """Models resolve their schedule from config; explicit schedule wins."""
    import dataclasses

    rnn = get_config("top-tagging-lstm").rnn
    assert rnn.kernel_schedule() == KernelSchedule(
        reuse_factor=rnn.reuse_kernel, mode=rnn.mode)
    s = KernelSchedule(reuse_factor=4, mode="nonstatic")
    rnn2 = dataclasses.replace(rnn, schedule=s)
    assert rnn2.kernel_schedule() is s


def test_layer_routes_schedule_through_pallas():
    """rnn_layer(impl='pallas', schedule=...) matches the XLA layer."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.rnn.layer import rnn_layer
    from repro.testing import make_kernel_inputs

    rnn = get_config("top-tagging-lstm").rnn
    xs, W, U, b = make_kernel_inputs("lstm", B=5, T=rnn.seq_len,
                                     F=rnn.input_size, H=rnn.hidden)
    ref = rnn_layer(rnn, xs, W, U, b, impl="xla")
    for s in (KernelSchedule(reuse_factor=4, backend="pallas_interpret"),
              KernelSchedule(reuse_factor=2, mode="nonstatic",
                             backend="pallas_interpret")):
        out = rnn_layer(rnn, xs, W, U, b, impl="pallas", schedule=s)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)
