"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes
(interpret mode on CPU), plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.config import FixedPointConfig
from repro.kernels import ops, ref


def _allclose(a, b, tol=3e-5):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    assert a.shape == b.shape
    np.testing.assert_allclose(a, b, rtol=tol, atol=tol)


# -- recurrent scan kernels ---------------------------------------------------

RNN_SHAPES = [(1, 5, 3, 8), (4, 20, 6, 20), (9, 15, 6, 120), (2, 100, 3, 128)]


@pytest.mark.parametrize("B,T,F,H", RNN_SHAPES)
def test_lstm_scan_matches_ref(B, T, F, H, rng):
    xs = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
    W = jnp.asarray(rng.randn(F, 4 * H).astype(np.float32) * 0.3)
    U = jnp.asarray(rng.randn(H, 4 * H).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.randn(4 * H).astype(np.float32) * 0.1)
    _allclose(ops.lstm_scan(xs, W, U, b), ref.lstm_scan_ref(xs, W, U, b))


@pytest.mark.parametrize("B,T,F,H", RNN_SHAPES)
def test_gru_scan_matches_ref(B, T, F, H, rng):
    xs = jnp.asarray(rng.randn(B, T, F).astype(np.float32))
    W = jnp.asarray(rng.randn(F, 3 * H).astype(np.float32) * 0.3)
    U = jnp.asarray(rng.randn(H, 3 * H).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.randn(2, 3 * H).astype(np.float32) * 0.1)
    _allclose(ops.gru_scan(xs, W, U, b), ref.gru_scan_ref(xs, W, U, b))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_lstm_scan_dtypes(dtype, rng):
    dt = jnp.dtype(dtype)
    xs = jnp.asarray(rng.randn(4, 10, 6), dtype=dt)
    W = jnp.asarray(rng.randn(6, 80) * 0.3, dtype=dt)
    U = jnp.asarray(rng.randn(20, 80) * 0.3, dtype=dt)
    b = jnp.asarray(rng.randn(80) * 0.1, dtype=dt)
    out = ops.lstm_scan(xs, W, U, b)
    assert out.dtype == dt
    _allclose(out, ref.lstm_scan_ref(xs, W, U, b), tol=2e-2)


# -- hadamard / fixed point ---------------------------------------------------

@given(n=st.integers(1, 7), m=st.integers(1, 130))
@settings(max_examples=10, deadline=None)
def test_hadamard_property(n, m):
    r = np.random.RandomState(n * 131 + m)
    a = jnp.asarray(r.randn(n, m).astype(np.float32))
    b = jnp.asarray(r.randn(n, m).astype(np.float32))
    _allclose(ops.hadamard(a, b), a * b, tol=0)


@given(total=st.integers(4, 24), integer=st.integers(1, 12))
@settings(max_examples=15, deadline=None)
def test_fixed_point_kernel_matches_quantizer(total, integer):
    if integer >= total:
        return
    fp = FixedPointConfig(total_bits=total, integer_bits=integer)
    r = np.random.RandomState(total * 31 + integer)
    x = jnp.asarray((r.randn(8, 33) * 3).astype(np.float32))
    _allclose(ops.fixed_point(x, fp), ref.fixed_point_ref(x, fp), tol=0)


# -- rglru + reuse matmul -----------------------------------------------------

@pytest.mark.parametrize("B,T,W", [(1, 7, 16), (5, 37, 200), (8, 64, 128)])
def test_rglru_scan_matches_ref(B, T, W, rng):
    a = jnp.asarray(np.exp(-np.abs(rng.randn(B, T, W))).astype(np.float32))
    bx = jnp.asarray(rng.randn(B, T, W).astype(np.float32))
    _allclose(ops.rglru_scan(a, bx), ref.rglru_scan_ref(a, bx))


@pytest.mark.parametrize("reuse", [1, 2, 4, 8, 16])
def test_reuse_matmul_all_reuse_factors(reuse, rng):
    x = jnp.asarray(rng.randn(100, 64).astype(np.float32))
    w = jnp.asarray(rng.randn(64, 48).astype(np.float32))
    _allclose(ops.reuse_matmul(x, w, reuse=reuse),
              ref.reuse_matmul_ref(x, w), tol=2e-5)


def test_reuse_matmul_vmem_tradeoff():
    """The paper's reuse knob: VMEM working set shrinks monotonically in R."""
    from repro.kernels.reuse_matmul import vmem_bytes
    sizes = [vmem_bytes(128, 512, 256, r) for r in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(sizes, sizes[1:]))
