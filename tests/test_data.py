"""Data pipelines: determinism, shapes, class separability, host sharding."""

import numpy as np

from repro.data import (flavor_tagging_dataset, lm_token_stream,
                        quickdraw_dataset, top_tagging_dataset)


def test_shapes_and_determinism():
    for fn, shape in [(top_tagging_dataset, (20, 6)),
                      (flavor_tagging_dataset, (15, 6)),
                      (quickdraw_dataset, (100, 3))]:
        x1, y1 = fn(64, seed=7)
        x2, y2 = fn(64, seed=7)
        assert x1.shape == (64,) + shape
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)
        x3, _ = fn(64, seed=8)
        assert np.abs(x1 - x3).max() > 0


def _class_separation(x, y):
    """Mean feature-vector distance between classes vs within class."""
    feats = x.reshape(len(x), -1)
    classes = np.unique(y)
    mus = np.stack([feats[y == c].mean(0) for c in classes])
    between = np.linalg.norm(mus[0] - mus[-1])
    within = np.mean([feats[y == c].std(0).mean() for c in classes])
    return between / max(within, 1e-9)


def test_datasets_are_separable():
    for fn in (top_tagging_dataset, flavor_tagging_dataset,
               quickdraw_dataset):
        x, y = fn(512, seed=0)
        assert _class_separation(x, y) > 0.3, fn.__name__


def test_labels_cover_all_classes():
    _, y = flavor_tagging_dataset(300, seed=0)
    assert set(np.unique(y)) == {0, 1, 2}
    _, y = quickdraw_dataset(300, seed=0)
    assert set(np.unique(y)) == {0, 1, 2, 3, 4}


def test_lm_stream_determinism_and_host_sharding():
    s1 = lm_token_stream(1000, 8, 16, seed=3)
    s2 = lm_token_stream(1000, 8, 16, seed=3)
    b1, b2 = next(s1), next(s2)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # two hosts: disjoint shards that concatenate to the global batch
    h0 = next(lm_token_stream(1000, 8, 16, seed=3, process_index=0,
                              process_count=2))
    h1 = next(lm_token_stream(1000, 8, 16, seed=3, process_index=1,
                              process_count=2))
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])


def test_lm_stream_token_range():
    b = next(lm_token_stream(500, 4, 32, seed=0))
    assert b["tokens"].min() >= 0 and b["tokens"].max() < 500


def test_lm_stream_has_learnable_structure():
    """Bigram mutual information should beat a shuffled control."""
    b = next(lm_token_stream(200, 16, 256, seed=1))
    t = b["tokens"].ravel()
    pairs = (t[:-1].astype(np.int64) * 200 + t[1:])
    shuf = t.copy()
    np.random.RandomState(0).shuffle(shuf)
    pairs_shuf = (shuf[:-1].astype(np.int64) * 200 + shuf[1:])
    # structured stream repeats bigrams far more often
    assert (len(np.unique(pairs)) < 0.9 * len(np.unique(pairs_shuf)))
