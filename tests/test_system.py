"""End-to-end behaviour: the paper's pipeline — train a tagger on physics
data, quantize it post-training, serve it, and reproduce the headline claims.
Plus an LM end-to-end driver sanity check."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FixedPointConfig, OptimizerConfig
from repro.core.quant.ptq import binary_auc, ptq_quantize_model
from repro.data import lm_token_stream, top_tagging_dataset
from repro.models import build_model, rnn_tagger
from repro.registry import get_config
from repro.testing import tiny_config
from repro.training import adamw_init, adamw_update


def _train_tagger(arch="top-tagging-gru", steps=150, n=1500):
    cfg = get_config(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    x, y = top_tagging_dataset(n, seed=0)
    opt = OptimizerConfig(lr=5e-3, warmup_steps=10, total_steps=steps,
                          weight_decay=1e-4)
    st = adamw_init(params, opt)

    @jax.jit
    def step(params, st, xb, yb):
        (_, _), g = jax.value_and_grad(
            lambda p: m.loss(p, {"x": xb, "y": yb}), has_aux=True)(params)
        return adamw_update(params, g, st, opt)[:2]

    for i in range(steps):
        idx = np.random.RandomState(i).randint(0, n, 128)
        params, st = step(params, st, jnp.asarray(x[idx]),
                          jnp.asarray(y[idx]))
    return cfg, m, params


@pytest.fixture(scope="module")
def trained_tagger():
    return _train_tagger()


@pytest.mark.slow
def test_tagger_trains_to_high_auc(trained_tagger):
    cfg, m, params = trained_tagger
    xt, yt = top_tagging_dataset(1000, seed=99)
    probs = np.asarray(m.forward(params, {"x": jnp.asarray(xt)}))
    auc = binary_auc(probs[:, 0], yt)
    assert auc > 0.9, auc


@pytest.mark.slow
def test_ptq_16_6_preserves_auc(trained_tagger):
    """Paper Fig. 2: at >=10 fractional bits the AUC ratio ~= 1."""
    cfg, m, params = trained_tagger
    xt, yt = top_tagging_dataset(1000, seed=99)
    x = jnp.asarray(xt)
    p_f = np.asarray(rnn_tagger.forward(cfg, params, x))
    auc_f = binary_auc(p_f[:, 0], yt)
    fp = FixedPointConfig(16, 6)
    qparams = ptq_quantize_model(params, fp)
    p_q = np.asarray(rnn_tagger.forward(cfg, qparams, x, fp=fp))
    auc_q = binary_auc(p_q[:, 0], yt)
    assert auc_q / auc_f > 0.98, (auc_q, auc_f)


@pytest.mark.slow
def test_low_precision_degrades(trained_tagger):
    """0 fractional bits must hurt (sanity of the quantized datapath)."""
    cfg, m, params = trained_tagger
    xt, yt = top_tagging_dataset(500, seed=98)
    x = jnp.asarray(xt)
    fp = FixedPointConfig(6, 6)          # no fractional bits
    qparams = ptq_quantize_model(params, fp)
    p_q = np.asarray(rnn_tagger.forward(cfg, qparams, x, fp=fp))
    auc_q = binary_auc(p_q[:, 0], yt)
    p_f = np.asarray(rnn_tagger.forward(cfg, params, x))
    auc_f = binary_auc(p_f[:, 0], yt)
    assert auc_q < auc_f - 0.02


@pytest.mark.slow
def test_lm_training_reduces_loss():
    cfg = tiny_config(get_config("stablelm-3b"))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = OptimizerConfig(lr=3e-3, warmup_steps=5, total_steps=40,
                          weight_decay=0.01)
    st = adamw_init(params, opt)
    stream = lm_token_stream(cfg.vocab_size, 8, 64, seed=0)

    @jax.jit
    def step(params, st, batch):
        (loss, _), g = jax.value_and_grad(
            lambda p: m.loss(p, batch), has_aux=True)(params)
        params, st, _ = adamw_update(params, g, st, opt)
        return params, st, loss

    losses = []
    for i in range(40):
        b = next(stream)
        params, st, loss = step(params, st,
                                {"tokens": jnp.asarray(b["tokens"]),
                                 "labels": jnp.asarray(b["labels"])})
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_checkpoint_restart_resumes_identically(tmp_path):
    """Fault-tolerance path: save at step k, 'crash', restore, continue —
    must match the uninterrupted run bit-for-bit."""
    from repro.checkpoint import CheckpointManager
    cfg = get_config("top-tagging-gru")
    m = build_model(cfg)
    opt = OptimizerConfig(lr=1e-3, warmup_steps=0, total_steps=20,
                          weight_decay=0.0)
    x, y = top_tagging_dataset(256, seed=0)

    @jax.jit
    def step(params, st, xb, yb):
        (_, _), g = jax.value_and_grad(
            lambda p: m.loss(p, {"x": xb, "y": yb}), has_aux=True)(params)
        return adamw_update(params, g, st, opt)[:2]

    def run(n, params, st):
        for i in range(n):
            idx = np.random.RandomState(100 + i).randint(0, 256, 32)
            params, st = step(params, st, jnp.asarray(x[idx]),
                              jnp.asarray(y[idx]))
        return params, st

    p0 = m.init(jax.random.PRNGKey(0))
    s0 = adamw_init(p0, opt)
    # uninterrupted 6 steps
    pa, _ = run(6, p0, s0)
    # interrupted at 3 + restore + 3 more
    pb, sb = run(3, p0, s0)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, pb, sb)
    _, pr, orst = mgr.restore()
    srst = sb._replace(step=jnp.asarray(orst["step"], jnp.int32),
                       m=orst["m"], v=orst["v"])
    pc, _ = run(3, pr, srst)
    # note: run() reseeds per-call from 100, so steps 4-6 of the restart see
    # the same batches as steps 4-6 of... they don't — use distinct check:
    for k in pa:
        assert np.isfinite(np.asarray(pc[k], np.float32)).all()
    # exact-resume equality on the same batch schedule
    pd, _ = run(3, pb, sb)
    for k in pd:
        np.testing.assert_allclose(np.asarray(pd[k]), np.asarray(pc[k]),
                                   rtol=1e-6, atol=1e-7)
