"""Distributed tests: 8 fake devices in subprocesses (device count is locked
at first jax init, so each multi-device scenario gets its own process)."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, devices: int = 8, timeout: int = 900):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_rules_resolve_and_conflict_handling():
    """Pure-python rule resolution (no devices needed)."""
    from jax.sharding import PartitionSpec as P
    from repro.sharding.rules import rules_for
    from repro.sharding.api import ShardingContext

    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}

    ctx = ShardingContext(FakeMesh(), rules_for("dense"),
                          data_axes=("pod", "data"))
    assert ctx.pspec(("batch", "seq", "embed_act")) == \
        P(("pod", "data"), "model", None)
    # conflict: same mesh axis twice -> later dim unsharded
    assert ctx.pspec(("seq", "heads")) == P("model", None)
    ctx.overrides["heads"] = None
    assert ctx.pspec(("batch", None, "heads", "head_dim")) == \
        P(("pod", "data"), None, None, None)


def test_auto_overrides_divisibility():
    from repro.config import SHAPES
    from repro.registry import get_config
    from repro.sharding.auto import auto_overrides

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # gemma: 8 heads on 16-wide TP -> sp attention, heads unsharded
    ov = auto_overrides(get_config("gemma-2b"), m, SHAPES["train_4k"])
    assert ov["__attn_mode__"] == "sp" and ov["heads"] is None
    # stablelm: 32 heads divide -> tp path
    ov = auto_overrides(get_config("stablelm-3b"), m, SHAPES["train_4k"])
    assert "__attn_mode__" not in ov
    # nemotron decode: 2D weight sharding kicks in
    ov = auto_overrides(get_config("nemotron-4-340b"), m, SHAPES["decode_32k"])
    assert ov["embed"] == "data" and ov["batch"] is None
    # long_500k batch=1 cannot shard
    ov = auto_overrides(get_config("mamba2-780m"), m, SHAPES["long_500k"])
    assert ov["batch"] is None


@pytest.mark.slow
def test_tiny_cells_compile_on_mesh():
    """lower+compile train/prefill/decode for representative families on a
    (2,4) mesh — the dry-run machinery end to end."""
    out = _run("""
        import jax
        from repro.config import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.dryrun import lower_cell
        from repro.registry import get_config
        from repro.testing import tiny_config

        mesh = make_mesh((2, 4), ("data", "model"))
        for arch in ("stablelm-3b", "mamba2-780m", "qwen3-moe-30b-a3b",
                     "recurrentgemma-9b"):
            cfg = tiny_config(get_config(arch))
            for shape in (ShapeConfig("t", 64, 8, "train"),
                          ShapeConfig("d", 64, 8, "decode")):
                lower_cell(cfg, shape, mesh)
                print("OK", arch, shape.kind)
    """)
    assert out.count("OK") == 8


@pytest.mark.slow
def test_sharded_train_equals_single_device():
    """Loss on a (2,4) mesh must equal the unsharded loss (SPMD soundness)."""
    out = _run("""
        import jax, numpy as np, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.models.model import build_model
        from repro.registry import get_config
        from repro.sharding.api import sharding_context
        from repro.sharding.auto import auto_overrides
        from repro.testing import tiny_config

        cfg = tiny_config(get_config("stablelm-3b"))
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(np.random.RandomState(0)
                                       .randint(0, 200, (8, 32))),
                 "labels": jnp.asarray(np.random.RandomState(1)
                                       .randint(0, 200, (8, 32)))}
        l0, _ = jax.jit(m.loss)(params, batch)

        mesh = make_mesh((2, 4), ("data", "model"))
        ov = auto_overrides(cfg, mesh)
        with sharding_context(mesh, cfg.family, "train", ov):
            l1, _ = jax.jit(m.loss)(params, batch)
        err = abs(float(l0) - float(l1))
        print("loss diff", err)
        assert err < 2e-4, err
    """)
    assert "loss diff" in out


@pytest.mark.slow
def test_pipelined_rnn_on_mesh():
    """Non-static pipelined execution == static scan across 4 stages."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.core.rnn.pipeline import pipelined_rnn
        from repro.kernels import ref
        from repro.registry import get_config

        mesh = make_mesh((2, 4), ("data", "model"))
        rng = np.random.RandomState(0)
        for arch in ("top-tagging-lstm", "top-tagging-gru"):
            cfg = get_config(arch)
            r = cfg.rnn
            g = 4 if r.cell == "lstm" else 3
            xs = jnp.asarray(rng.randn(6, r.seq_len, r.input_size)
                             .astype(np.float32))
            W = jnp.asarray(rng.randn(r.input_size, g * r.hidden)
                            .astype(np.float32) * .3)
            U = jnp.asarray(rng.randn(r.hidden, g * r.hidden)
                            .astype(np.float32) * .3)
            b = jnp.asarray(rng.randn(*((g * r.hidden,) if r.cell == "lstm"
                                        else (2, g * r.hidden)))
                            .astype(np.float32) * .1)
            o1 = jax.jit(lambda *a: pipelined_rnn(r, *a, mesh))(xs, W, U, b)
            o2 = (ref.lstm_scan_ref if r.cell == "lstm"
                  else ref.gru_scan_ref)(xs, W, U, b)
            err = float(jnp.abs(o1 - o2).max())
            print("pipe err", arch, err)
            assert err < 1e-5
            # hoisted stage pipeline: zx precomputed before the stage pipe,
            # per-stage blocks carry only hU — same result
            oh = jax.jit(lambda *a: pipelined_rnn(
                r, *a, mesh, hoist_input=True))(xs, W, U, b)
            errh = float(jnp.abs(oh - o2).max())
            print("pipe hoist err", arch, errh)
            assert errh < 1e-5
    """)
    assert out.count("pipe err") == 2 and out.count("pipe hoist err") == 2
