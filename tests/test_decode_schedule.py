"""Schedule-driven decode conformance (PR 5).

The contract under test: a KernelSchedule changes what the decode hot path
EXECUTES — reuse-tiled, weight-resident single-step kernels — while staying
bit-identical to the unscheduled einsum golden path:

  * ``rnn_decode_step`` bit-matches the golden cells per
    (cell x R x dtype x fp);
  * the scheduled LM ``decode_step`` bit-matches the einsum path, token by
    token, caches included;
  * the batch-1 fast path ``predict_one`` bit-matches batched ``predict``
    AND the padded submit/flush path;
  * the weight-residency cache returns the identical packed arrays across
    calls (and never serves a stale entry);
  * the decode estimators are monotone in R and the decode-legal space /
    selector behave.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.autotune import (DesignTarget, InfeasibleTargetError, SpaceSpec,
                            decode_legal, enumerate_decode_space,
                            select_decode)
from repro.config import FixedPointConfig
from repro.core.hls.resources import (estimate_decode_step, estimate_lm_decode,
                                      gate_count)
from repro.core.rnn.cells import initial_state
from repro.kernels import ops
from repro.kernels.decode_step import (decode_matmul, resident_fused,
                                       resident_matrix, rnn_decode_step)
from repro.kernels.schedule import KernelSchedule, schedule_key
from repro.registry import get_config
from repro.testing import tiny_config

SCHED = lambda R, backend="pallas_interpret": KernelSchedule(  # noqa: E731
    reuse_factor=R, block_batch=8, backend=backend)


# ---------------------------------------------------------------------------
# decode_matmul: the reuse-tiled weight-resident kernel vs plain dot
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("R", [1, 2, 4, 5, 10])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
def test_decode_matmul_bitmatch(R, dtype):
    rng = np.random.RandomState(0)
    dt = jnp.dtype(dtype)
    x = jnp.asarray(rng.randn(3, 26), dtype=dt)     # ragged M (padded to 8)
    w = jnp.asarray(rng.randn(26, 80), dtype=dt)
    got = decode_matmul(x, w, schedule=SCHED(R))
    want = jnp.dot(x, w)
    assert got.dtype == want.dtype and got.shape == want.shape
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_decode_matmul_degenerate_single_column_tiles():
    """R = N (one column per pass) stays value-correct; XLA reduces
    width-1 dots with a different (still full-K) accumulation strategy, so
    this degenerate tiling is tolerance-exact rather than bit-exact."""
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(3, 26).astype(np.float32))
    w = jnp.asarray(rng.randn(26, 80).astype(np.float32))
    got = np.asarray(decode_matmul(x, w, schedule=SCHED(80)))
    np.testing.assert_allclose(got, np.asarray(jnp.dot(x, w)),
                               rtol=1e-4, atol=1e-5)


def test_decode_matmul_xla_backend_is_plain_dot():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(4, 12).astype(np.float32))
    w = jnp.asarray(rng.randn(12, 24).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(decode_matmul(x, w, schedule=SCHED(4, "xla"))),
        np.asarray(jnp.dot(x, w)))
    np.testing.assert_array_equal(
        np.asarray(decode_matmul(x, w, schedule=None)),
        np.asarray(jnp.dot(x, w)))


def test_decode_matmul_tpu_alignment_raises():
    s = SCHED(2, "pallas_tpu")
    x = jnp.zeros((8, 16), jnp.float32)
    w = jnp.zeros((16, 80), jnp.float32)     # 40-wide tiles: off-lane
    with pytest.raises(ValueError, match="128"):
        decode_matmul(x, w, schedule=s)


# ---------------------------------------------------------------------------
# rnn_decode_step: (cell x R x dtype x fp) vs the golden cells
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cell", ["lstm", "gru"])
@pytest.mark.parametrize("R", [1, 2, 4])
@pytest.mark.parametrize("dtype", ["float32", "bfloat16"])
@pytest.mark.parametrize("fp", [None, FixedPointConfig(16, 6)])
def test_rnn_decode_step_bitmatch(cell, R, dtype, fp):
    rng = np.random.RandomState(0)
    dt = jnp.dtype(dtype)
    g = gate_count(cell)
    B, F, H = 3, 6, 12
    W = jnp.asarray(rng.randn(F, g * H) * .3, dtype=dt)
    U = jnp.asarray(rng.randn(H, g * H) * .3, dtype=dt)
    bshape = (g * H,) if cell == "lstm" else (2, g * H)
    b = jnp.asarray(rng.randn(*bshape) * .1, dtype=dt)
    x = jnp.asarray(rng.randn(B, F), dtype=dt)
    state = initial_state(cell, B, H, dt)
    # run TWO chained steps so the state feedback path is also covered
    for _ in range(2):
        h1, s1 = rnn_decode_step(cell, x, state, W, U, b,
                                 schedule=SCHED(R), fp=fp)
        h0, s0 = rnn_decode_step(cell, x, state, W, U, b,
                                 schedule=None, fp=fp)
        np.testing.assert_array_equal(np.asarray(h1, np.float32),
                                      np.asarray(h0, np.float32))
        for a, c in zip(jax.tree.leaves(s1), jax.tree.leaves(s0)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(c, np.float32))
        state = s0


# ---------------------------------------------------------------------------
# Scheduled LM decode vs the einsum golden path
# ---------------------------------------------------------------------------


def _lm_setup(arch="stablelm-3b", B=2, S=12, cache_dtype="float32"):
    from repro.models import build_model
    from repro.models.decode import cache_specs

    cfg = tiny_config(get_config(arch))
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    specs = cache_specs(cfg, B, S, cache_dtype)
    cache = {k: jnp.zeros(s.shape, jnp.dtype(s.dtype))
             for k, s in specs.items()}
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    return cfg, params, cache, toks


@pytest.mark.parametrize("R,backend", [(1, "pallas_interpret"),
                                       (2, "pallas_interpret"),
                                       (4, "xla")])
def test_lm_scheduled_decode_bitmatch(R, backend):
    from repro.models.decode import decode_step, pack_decode_params

    cfg, params, cache0, toks = _lm_setup()
    B = toks.shape[0]
    s = SCHED(R, backend)
    packed = pack_decode_params(cfg, params, s)
    base = jax.jit(lambda p, c, t, pos: decode_step(cfg, p, c, t, pos))
    sched = jax.jit(lambda p, pk, c, t, pos: decode_step(
        cfg, p, c, t, pos, schedule=s, packed=pk))
    c0, c1 = dict(cache0), dict(cache0)
    for t in range(3):
        pos = jnp.full((B,), t, jnp.int32)
        l0, c0 = base(params, c0, toks[:, t:t + 1], pos)
        l1, c1 = sched(params, packed, c1, toks[:, t:t + 1], pos)
        np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
        for k in c0:
            np.testing.assert_array_equal(np.asarray(c0[k]),
                                          np.asarray(c1[k]))


def test_lm_unschedulable_family_falls_back():
    """Families without a matmul-shaped step accept the schedule and keep
    the einsum path (bit-identical to schedule=None)."""
    from repro.models.decode import decode_schedulable, decode_step

    cfg, params, cache0, toks = _lm_setup("mamba2-780m")
    assert not decode_schedulable(cfg)
    B = toks.shape[0]
    pos = jnp.zeros((B,), jnp.int32)
    l0, _ = decode_step(cfg, params, dict(cache0), toks[:, :1], pos)
    l1, _ = decode_step(cfg, params, dict(cache0), toks[:, :1], pos,
                        schedule=SCHED(2))
    np.testing.assert_array_equal(np.asarray(l0), np.asarray(l1))
    # ... and its serving report must NOT fabricate a dense-stack estimate
    # for kernels that never ran
    from repro.serving.lm_engine import LMServingEngine

    eng = LMServingEngine(cfg, params, max_batch=1, max_seq=16)
    eng.add_request([3, 4], max_new=2, schedule=SCHED(2), now=0.0)
    eng.run_to_completion(now=1.0)
    row = eng.serve_report()[schedule_key(SCHED(2))]
    assert row["analytical"] is None
    assert row["measured"]["tokens"] > 0


def test_lm_engine_keyed_scheduled_decode():
    """Scheduled keys decode the same tokens as the default key, keep one
    jit trace each, and serve_report pairs tokens/s with the decode
    estimate of the SAME schedule object."""
    from repro.models import build_model
    from repro.serving.lm_engine import LMServingEngine

    cfg = tiny_config(get_config("stablelm-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    eng = LMServingEngine(cfg, params, max_batch=2, max_seq=32)
    s = SCHED(2)
    prompt = [5, 7, 11]
    r0 = eng.add_request(prompt, max_new=5, now=0.0)
    r1 = eng.add_request(prompt, max_new=5, schedule=s, now=0.0)
    out = eng.run_to_completion(now=1.0)
    assert out[r0] == out[r1]
    key = schedule_key(s)
    assert eng.trace_count(key) == 1
    assert eng.trace_count("default") == 1
    rep = eng.serve_report()
    row = rep[key]
    assert row["measured"]["tokens"] > 0
    assert row["measured"]["tokens_per_s"] > 0
    assert row["analytical"]["ii_cycles"] == 2          # II ~ R
    assert row["analytical"]["scheduled_kernels"] is True
    assert rep["default"]["analytical"] is None          # nothing to price


# ---------------------------------------------------------------------------
# Batch-1 fast path
# ---------------------------------------------------------------------------


def _rnn_engine(impl="pallas", **kw):
    from repro.models import rnn_tagger
    from repro.models.init import init_params
    from repro.serving.engine import RNNServingEngine

    cfg = get_config("top-tagging-lstm")
    params = init_params(jax.random.PRNGKey(0), rnn_tagger.param_specs(cfg))
    return cfg, RNNServingEngine(cfg, params, impl=impl, max_batch=16, **kw)


def test_predict_one_bitmatches_batched_predict():
    cfg, eng = _rnn_engine()
    r = cfg.rnn
    x = np.random.RandomState(0).randn(r.seq_len, r.input_size).astype(
        np.float32)
    for sched in (None, SCHED(4), SCHED(2, "xla")):
        one = eng.predict_one(x, schedule=sched)
        np.testing.assert_array_equal(one, eng.predict(x[None],
                                                       schedule=sched)[0])
        # and the padded submit/flush path (pad-to-max_batch round trip)
        req = eng.submit(x, schedule=sched, now=0.0)
        eng.flush(now=1.0, force=True)
        np.testing.assert_array_equal(np.asarray(req.result), one)


def test_predict_one_traces_and_stats_are_separate():
    cfg, eng = _rnn_engine()
    r = cfg.rnn
    x = np.random.RandomState(1).randn(r.seq_len, r.input_size).astype(
        np.float32)
    s = SCHED(4)
    key = schedule_key(s)
    for _ in range(3):
        eng.predict_one(x, schedule=s)
    assert eng.one_trace_count(key) == 1        # one batch-1 trace
    assert eng.trace_count(key) == 0            # batched path untouched
    rep = eng.serve_report()
    assert rep[key]["fast_path"]["served"] == 2.0   # compile call excluded
    # batched predict afterwards still costs exactly one batched trace
    eng.predict(x[None], schedule=s)
    assert eng.trace_count(key) == 1


def test_predict_one_accepts_target():
    cfg, eng = _rnn_engine()
    r = cfg.rnn
    x = np.random.RandomState(2).randn(r.seq_len, r.input_size).astype(
        np.float32)
    t = DesignTarget(objective="latency")
    out = eng.predict_one(x, target=t)
    pt = eng.schedule_for_target(t)
    np.testing.assert_array_equal(out, eng.predict_one(x,
                                                       schedule=pt.schedule))


# ---------------------------------------------------------------------------
# Weight residency
# ---------------------------------------------------------------------------


def test_residency_returns_identical_arrays_across_calls():
    rng = np.random.RandomState(0)
    w = jnp.asarray(rng.randn(6, 4, 8).astype(np.float32))
    s = SCHED(2)
    a = resident_matrix(w, schedule=s, tag="t")
    b = resident_matrix(w, schedule=s, tag="t")
    assert a is b                                   # the SAME packed array
    assert a.shape == (6, 32)
    # a different schedule key packs (and caches) independently
    c = resident_matrix(w, schedule=SCHED(4), tag="t")
    assert c is not a
    np.testing.assert_array_equal(np.asarray(c), np.asarray(a))


def test_residency_fused_identity_and_staleness_safety():
    rng = np.random.RandomState(1)
    w1 = jnp.asarray(rng.randn(6, 8).astype(np.float32))
    w2 = jnp.asarray(rng.randn(6, 8).astype(np.float32))
    s = SCHED(2)
    f1 = resident_fused((w1, w2), schedule=s)
    assert f1 is resident_fused((w1, w2), schedule=s)
    assert f1.shape == (6, 16)
    # different source arrays (same shapes) must NOT hit the stale entry
    w3 = jnp.asarray(rng.randn(6, 8).astype(np.float32))
    f2 = resident_fused((w1, w3), schedule=s)
    assert f2 is not f1
    np.testing.assert_array_equal(np.asarray(f2[:, 8:]), np.asarray(w3))


def test_residency_tracer_bypass():
    """Inside a jit trace the cache must not capture (or serve) tracers."""
    w = jnp.ones((4, 8), jnp.float32)
    n_before = len(ops.RESIDENT_WEIGHTS)

    @jax.jit
    def f(w):
        return resident_matrix(w, schedule=SCHED(2), tag="trace")

    np.testing.assert_array_equal(np.asarray(f(w)), np.asarray(w))
    assert len(ops.RESIDENT_WEIGHTS) == n_before


def test_residency_eviction_is_bounded():
    cache = ops.WeightResidency(max_entries=4)
    arrs = [jnp.full((2, 2), i, jnp.float32) for i in range(8)]
    for a in arrs:
        cache.get(a, "k", lambda a=a: a * 2)
    assert len(cache) == 4
    # evicted entries repack (miss), live ones hit
    cache.get(arrs[-1], "k", lambda: arrs[-1] * 2)
    assert cache.hits == 1


def test_residency_eviction_is_byte_bounded():
    # each packed payload is 64 bytes; a 160-byte budget holds two entries
    cache = ops.WeightResidency(max_entries=100, max_bytes=160)
    arrs = [jnp.full((4, 4), i, jnp.float32) for i in range(5)]
    for a in arrs:
        cache.get(a, "k", lambda a=a: a * 2)
    assert len(cache) == 2
    assert cache.bytes <= 160


def test_residency_never_caches_mutable_buffers():
    """In-place mutation of numpy weights must never be served stale: only
    immutable jax.Arrays are cacheable, everything else packs per call."""
    cache = ops.WeightResidency()
    w = np.ones((2, 2), np.float32)
    first = cache.get(w, "k", lambda: jnp.asarray(w * 2))
    w[...] = 5.0                    # in-place update
    second = cache.get(w, "k", lambda: jnp.asarray(w * 2))
    assert len(cache) == 0          # nothing was cached
    np.testing.assert_array_equal(np.asarray(first), 2 * np.ones((2, 2)))
    np.testing.assert_array_equal(np.asarray(second), 10 * np.ones((2, 2)))


def test_pack_decode_params_cached_per_schedule_key():
    from repro.models.decode import pack_decode_params

    cfg, params, _, _ = _lm_setup()
    s = SCHED(2)
    p1 = pack_decode_params(cfg, params, s)
    p2 = pack_decode_params(cfg, params, s)
    assert p1 is p2
    p3 = pack_decode_params(cfg, params, SCHED(4))
    assert p3 is not p1


# ---------------------------------------------------------------------------
# Pricing + decode-legal space
# ---------------------------------------------------------------------------


def test_estimate_decode_step_monotone_in_R():
    cfg = get_config("flavor-tagging-lstm")
    rs = [1, 2, 4, 8]
    ests = [estimate_decode_step(SCHED(R), cfg.rnn) for R in rs]
    lats = [e.latency_cycles for e in ests]
    dsps = [e.dsp for e in ests]
    assert lats == sorted(lats) and lats[0] < lats[-1]
    assert dsps == sorted(dsps, reverse=True) and dsps[0] > dsps[-1]
    for R, e in zip(rs, ests):
        assert e.ii_cycles == R                      # II ~ R
        assert e.bram_18k == ests[0].bram_18k        # residency: storage
        assert e.vmem_bytes == ests[0].vmem_bytes    # does not shrink with R


def test_estimate_lm_decode_monotone_in_R():
    cfg = tiny_config(get_config("stablelm-3b"))
    ests = [estimate_lm_decode(SCHED(R), cfg) for R in (1, 2, 4)]
    lats = [e.latency_cycles for e in ests]
    dsps = [e.dsp for e in ests]
    assert lats == sorted(lats) and lats[0] < lats[-1]
    assert dsps == sorted(dsps, reverse=True) and dsps[0] > dsps[-1]


def test_decode_space_is_single_step_legal():
    cfg = get_config("top-tagging-lstm")
    space = enumerate_decode_space(cfg)
    assert space, "decode space must not be empty"
    for s in space:
        assert decode_legal(s)
        assert s.mode == "static" and not s.hoist_input
        assert s.hoist_reuse == 1 and s.ii == 0
    # the scan-only axes really are pruned: widen the spec, same slice
    wide = SpaceSpec(hoist=(False, True), iis=(0, 1, 2))
    assert set(p.key() for p in enumerate_decode_space(cfg, wide)) \
        == set(p.key() for p in space)


def test_select_decode_objectives_and_infeasible():
    cfg = get_config("top-tagging-lstm")
    lat = select_decode(cfg, DesignTarget(objective="latency"))
    res = select_decode(cfg, DesignTarget(objective="resources"))
    assert lat.latency_cycles <= res.latency_cycles
    assert res.dsp <= lat.dsp
    assert lat.ii_cycles == lat.estimate.schedule.effective_reuse(
        gate_count(cfg.rnn.cell) * cfg.rnn.hidden)
    # a DSP budget forces reuse up (live multipliers ~ 1/R)
    tight = select_decode(cfg, DesignTarget(max_dsp=res.dsp,
                                            objective="latency"))
    assert tight.dsp <= res.dsp
    with pytest.raises(InfeasibleTargetError, match="nearest"):
        select_decode(cfg, DesignTarget(max_dsp=1))
